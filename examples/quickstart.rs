//! Quickstart: the end-to-end driver (DESIGN.md §6 validation run).
//!
//! Trains LeNet-5 on a synthetic MNIST-shaped dataset three ways with
//! identical data, initialization and hyperparameters:
//!   1. non-pipelined baseline (the paper's reference schedule),
//!   2. pipelined with stale weights (the paper's contribution),
//!   3. hybrid (pipelined prefix + non-pipelined tail, paper §4),
//! printing loss curves and final accuracies side by side.
//!
//! Runs on whichever backend is available (`--backend auto`): the XLA
//! executor when AOT artifacts + a real PJRT backend exist, otherwise
//! the native pure-Rust backend — so this works out of the box with no
//! artifacts and no Python step.
//!
//! Run: cargo run --release --example quickstart [--iters N] [--backend auto|native|xla]

use pipestale::config::{Backend, Mode, RunConfig};
use pipestale::util::bench::Table;
use pipestale::util::cli::Command;

fn main() -> anyhow::Result<()> {
    pipestale::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let m = Command::new("quickstart", "pipelined vs non-pipelined vs hybrid on LeNet-5")
        .opt("iters", "300", "training iterations")
        .opt("noise", "1.8", "synthetic dataset noise (higher = harder)")
        .opt("backend", "auto", "auto | native | xla")
        .parse(&argv)
        .map_err(|u| anyhow::anyhow!("{u}"))?;
    let iters: u64 = m.get_u64("iters").map_err(anyhow::Error::msg)?;
    let noise = m.get_f64("noise").map_err(anyhow::Error::msg)?;

    let mut base = RunConfig::new("quickstart_lenet");
    base.backend = Backend::parse(m.get("backend"))?;
    base.iters = iters;
    base.eval_every = (iters / 5).max(1);
    base.train_size = 1024;
    base.test_size = 256;
    base.noise = noise;

    let mut table = Table::new(&["schedule", "final test acc", "train loss", "wall s"]);
    for (label, mode, pipelined_iters) in [
        ("non-pipelined", Mode::Sequential, 0),
        ("pipelined (stale weights)", Mode::Pipelined, 0),
        ("hybrid 2/3 + 1/3", Mode::Hybrid, 2 * iters / 3),
    ] {
        let mut rc = base.clone();
        rc.mode = mode;
        rc.pipelined_iters = pipelined_iters;
        let res = pipestale::train::run(&rc)?;
        println!("\n== {label} ==");
        for e in &res.recorder.evals {
            println!("  iter {:>5}: test acc {:5.1}%", e.iter, 100.0 * e.accuracy);
        }
        table.row(&[
            label.to_string(),
            format!("{:.2}%", 100.0 * res.final_accuracy),
            format!("{:.4}", res.final_train_loss),
            format!("{:.1}", res.wall_seconds),
        ]);
    }
    println!("\n{}", table.render());
    println!(
        "\nAll three schedules share data, seeds and executables; only the\n\
         cycle schedule differs. See EXPERIMENTS.md for the full paper grid."
    );
    Ok(())
}
