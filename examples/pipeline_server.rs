//! Threaded pipeline demo: the paper's §5 "actual" deployment shape —
//! one OS thread per accelerator, channels as pipeline registers, each
//! worker owning its partition's weights (and, on the XLA backend, its
//! own PJRT client). Runs offline on the native backend when no
//! artifacts/XLA are present.
//!
//! On this 1-core container the threads time-slice, so wall-clock
//! speedup is not observable here (DESIGN.md §4); the example verifies
//! the distributed architecture end-to-end (training converges, weights
//! collected from workers, eval on the reassembled model) and prints the
//! DES-projected speedup for the same measured stage costs.
//!
//! Run: cargo run --release --example pipeline_server [--iters N]

use pipestale::backend::NativeExecutor;
use pipestale::config::RunConfig;
use pipestale::data::{load_or_synthesize, Batcher, SyntheticSpec};
use pipestale::model::ModelParams;
use pipestale::optim::Sgd;
use pipestale::pipeline::threaded::ThreadedPipeline;
use pipestale::pipeline::{Pipeline, XlaExecutor};
use pipestale::runtime::Runtime;
use pipestale::util::cli::Command;

fn main() -> anyhow::Result<()> {
    pipestale::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let use_xla = pipestale::xla_ready();
    let default_config = if use_xla { "resnet20_4s" } else { "native_lenet_small_4s" };
    let m = Command::new("pipeline_server", "thread-per-accelerator pipelined training")
        .opt("config", default_config, "artifact or native built-in config")
        .opt("iters", "120", "training iterations")
        .opt("noise", "2.0", "synthetic dataset noise")
        .parse(&argv)
        .map_err(|u| anyhow::anyhow!("{u}"))?;
    let iters = m.get_u64("iters").map_err(anyhow::Error::msg)?;
    let noise = m.get_f64("noise").map_err(anyhow::Error::msg)? as f32;

    // Despite the name, this prefers a built artifact meta.json (the
    // XLA contract) and only falls back to the native manifest.
    let meta = pipestale::train::load_native_meta(m.get("config"))?;
    let spec = SyntheticSpec { train: 1024, test: 256, noise, seed: 7 };
    let (train_ds, test_ds) = load_or_synthesize(&meta.dataset, None, &spec)?;

    let params = ModelParams::init(&meta.partitions, 42)?;
    let optims: Vec<Sgd> = pipestale::train::build_optims(&meta, iters, 1.0);

    println!(
        "launching {} accelerator threads (P={} partitions, PPV {:?}, {} workers)...",
        meta.partitions.len(),
        meta.partitions.len(),
        meta.ppv,
        if use_xla { "XLA" } else { "native" }
    );
    let mut pipe = if use_xla {
        ThreadedPipeline::launch(&meta, params, optims)?
    } else {
        ThreadedPipeline::launch_native(&meta, params, optims)?
    };
    let mut batcher = Batcher::new(train_ds.len(), meta.batch, 99);
    let (events, wall) = pipe.train(iters, 42, |_b| {
        let idxs = batcher.next_indices().to_vec();
        train_ds.gather(&idxs)
    })?;
    let trained = pipe.shutdown()?;
    println!(
        "threaded training: {} batches retired in {:.1}s ({:.1} batches/s), final loss {:.4}",
        events.len(),
        wall,
        events.len() as f64 / wall,
        events.last().map(|e| e.loss).unwrap_or(f32::NAN)
    );

    // Reassemble the model on a single-threaded pipeline and evaluate.
    let optims = pipestale::train::build_optims(&meta, iters, 1.0);
    let acc = if use_xla {
        let runtime = Runtime::cpu()?;
        let exec = XlaExecutor::new(&runtime, meta.clone(), trained, optims)?;
        let mut single = Pipeline::new(exec, meta.batch);
        pipestale::train::evaluate(&mut single, &test_ds, meta.batch)?
    } else {
        let exec = NativeExecutor::new(meta.clone(), trained, optims)?;
        let mut single = Pipeline::new(exec, meta.batch);
        pipestale::train::evaluate(&mut single, &test_ds, meta.batch)?
    };
    println!("eval on reassembled weights: {:.2}% top-1", 100.0 * acc);

    // Sanity: sequential training of the same budget for comparison.
    let mut rc = RunConfig::new(m.get("config"));
    rc.iters = iters;
    rc.noise = noise as f64;
    rc.train_size = 1024;
    rc.test_size = 256;
    rc.mode = pipestale::config::Mode::Sequential;
    let seq = pipestale::train::run(&rc)?;
    println!(
        "sequential reference: {:.2}% top-1 in {:.1}s (1 worker)",
        100.0 * seq.final_accuracy,
        seq.wall_seconds
    );
    println!("(see bench_table5_speedup for the calibrated multi-accelerator projection)");
    Ok(())
}
