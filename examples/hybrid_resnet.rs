//! Hybrid training on ResNet-20 with the paper's PPV (5,12,17) — the
//! §6.4 scenario: deep pipelining hurts accuracy; a non-pipelined tail
//! recovers it (Table 4 / Figure 7 shape).
//!
//! Runs offline out of the box: without artifacts the demo picks the
//! native block-IR ResNet fixture (`native_resnet20_4s`, the same
//! Table-4 cut snapped to block edges) instead of the XLA
//! `resnet20_hybrid` artifacts.
//!
//! Run: cargo run --release --example hybrid_resnet [--iters N]

use pipestale::config::{Mode, RunConfig};
use pipestale::util::bench::Table;
use pipestale::util::cli::Command;

fn main() -> anyhow::Result<()> {
    pipestale::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let m = Command::new("hybrid_resnet", "paper §6.4 hybrid-training demo (ResNet, 8 stages)")
        .opt("config", "auto", "config (auto: resnet20_hybrid w/ artifacts, else native_resnet20_4s)")
        .opt("iters", "240", "total training iterations")
        .opt("noise", "2.2", "synthetic dataset noise")
        .parse(&argv)
        .map_err(|u| anyhow::anyhow!("{u}"))?;
    let iters: u64 = m.get_u64("iters").map_err(anyhow::Error::msg)?;
    let noise = m.get_f64("noise").map_err(anyhow::Error::msg)?;
    let config: String = match m.get("config") {
        "auto" => {
            // mirror Backend::Auto's resolution rule exactly
            if pipestale::xla_ready() && pipestale::train::artifact_meta_exists("resnet20_hybrid")
            {
                "resnet20_hybrid".to_string() // PPV (5,12,17)
            } else {
                // same cut snapped to block edges, no artifacts needed
                "native_resnet20_4s".to_string()
            }
        }
        other => other.to_string(),
    };
    println!("config: {config}");

    let mut base = RunConfig::new(&config);
    base.iters = iters;
    base.eval_every = (iters / 6).max(1);
    base.train_size = 1024;
    base.test_size = 256;
    base.noise = noise;
    base.stale_lr_scale = 1.0;

    // Paper Table 4 grid: baseline 30k / pipelined 30k / hybrid 20k+10k /
    // hybrid 20k+20k, scaled to `iters`.
    let runs: Vec<(String, Mode, u64, u64)> = vec![
        ("baseline".into(), Mode::Sequential, iters, 0),
        ("pipelined".into(), Mode::Pipelined, iters, 0),
        (format!("{}+{} hybrid", 2 * iters / 3, iters / 3), Mode::Hybrid, iters, 2 * iters / 3),
        (format!("{}+{} hybrid", 2 * iters / 3, 2 * iters / 3),
         Mode::Hybrid, 2 * iters / 3 + 2 * iters / 3, 2 * iters / 3),
    ];

    let mut table = Table::new(&["schedule", "iters", "final test acc"]);
    for (label, mode, total, np) in runs {
        let mut rc = base.clone();
        rc.mode = mode;
        rc.iters = total;
        rc.pipelined_iters = np;
        let res = pipestale::train::run(&rc)?;
        println!("{label}: acc {:.2}% (wall {:.0}s)", 100.0 * res.final_accuracy, res.wall_seconds);
        table.row(&[label, total.to_string(), format!("{:.2}%", 100.0 * res.final_accuracy)]);
    }
    println!("\n{}", table.render());
    Ok(())
}
