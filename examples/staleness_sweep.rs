//! Staleness sweep (paper §6.3 in miniature): slide a single register
//! pair through ResNet-20 and watch accuracy fall as the percentage of
//! stale weights grows — the paper's Figure 6 "Sliding Stage" curve.
//!
//! Run: cargo run --release --example staleness_sweep [--iters N]

use pipestale::config::RunConfig;
use pipestale::meta::ConfigMeta;
use pipestale::util::bench::Table;
use pipestale::util::cli::Command;

fn main() -> anyhow::Result<()> {
    pipestale::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let m = Command::new("staleness_sweep", "Fig-6 sliding-stage sweep on ResNet-20")
        .opt("iters", "200", "training iterations per position")
        .opt("positions", "3,9,15,19", "register positions (comma-separated)")
        .opt("noise", "2.2", "synthetic dataset noise")
        .parse(&argv)
        .map_err(|u| anyhow::anyhow!("{u}"))?;
    let iters: u64 = m.get_u64("iters").map_err(anyhow::Error::msg)?;
    let noise = m.get_f64("noise").map_err(anyhow::Error::msg)?;
    let positions: Vec<usize> = m
        .get("positions")
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("bad --positions: {e}"))?;

    let root = pipestale::artifacts_root();
    let mut table = Table::new(&["register after layer", "% stale weights", "degree", "test acc"]);
    for p in positions {
        let name = format!("resnet20_slide{p}");
        let meta = ConfigMeta::load_named(&root, &name)?;
        let mut rc = RunConfig::new(&name);
        rc.iters = iters;
        rc.train_size = 1024;
        rc.test_size = 256;
        rc.noise = noise;
        let res = pipestale::train::run(&rc)?;
        println!(
            "slide {p}: %stale={:.1} acc={:.2}%",
            100.0 * meta.stale_weight_fraction(),
            100.0 * res.final_accuracy
        );
        table.row(&[
            p.to_string(),
            format!("{:.1}%", 100.0 * meta.stale_weight_fraction()),
            meta.degree_of_staleness(1).to_string(),
            format!("{:.2}%", 100.0 * res.final_accuracy),
        ]);
    }
    println!("\n{}", table.render());
    println!("(degree is constant at 2 — per the paper, accuracy tracks %stale, not degree)");
    Ok(())
}
