//! Offline shim of the `log` crate facade used by pipestale.
//!
//! Provides `Level`, `LevelFilter`, `Metadata`, `Record`, the `Log`
//! trait, `set_logger`/`set_max_level`, and the level macros. The
//! consumer (util/logging.rs) installs a static logger exactly as with
//! upstream `log`.

use std::fmt;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

/// Verbosity of a single log record. Ordered: Error < Warn < ... < Trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum verbosity the facade lets through.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Record metadata (level only — targets/modules are out of scope).
#[derive(Clone, Copy, Debug)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log event, borrowed for the duration of the `Log::log` call.
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logger sink, installed once per process.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

// The installed logger, stored as a raw pointer to the wide-pointer box.
static LOGGER: AtomicPtr<&'static dyn Log> = AtomicPtr::new(std::ptr::null_mut());
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let boxed = Box::into_raw(Box::new(logger));
    match LOGGER.compare_exchange(
        std::ptr::null_mut(),
        boxed,
        Ordering::SeqCst,
        Ordering::SeqCst,
    ) {
        Ok(_) => Ok(()),
        Err(_) => {
            // Someone else installed first; free our box.
            unsafe { drop(Box::from_raw(boxed)) };
            Err(SetLoggerError(()))
        }
    }
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::SeqCst);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __private_log(level: Level, args: fmt::Arguments) {
    if level > max_level() {
        return;
    }
    let ptr = LOGGER.load(Ordering::Relaxed);
    if ptr.is_null() {
        return;
    }
    let logger: &'static dyn Log = unsafe { *ptr };
    let record = Record { metadata: Metadata { level }, args };
    if logger.enabled(record.metadata()) {
        logger.log(&record);
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orderings_match_upstream() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Warn < Level::Info);
    }

    #[test]
    fn logging_without_logger_is_a_noop() {
        info!("nobody is listening: {}", 42);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
