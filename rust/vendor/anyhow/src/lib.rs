//! Offline shim of the `anyhow` API surface pipestale uses.
//!
//! The testbed has no crates.io access, so this workspace vendors a
//! minimal reimplementation: `Error` (a boxed message chain), `Result`,
//! the `anyhow!` / `bail!` / `ensure!` macros, and the `Context`
//! extension trait for `Result` and `Option`. Error chains render like
//! upstream anyhow: `{}` prints the outermost message, `{:#}` the full
//! `a: b: c` chain, `{:?}` a "Caused by" listing.
//!
//! Like upstream, `Error` deliberately does NOT implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message with an optional chain of causes.
pub struct Error {
    head: Box<Frame>,
}

struct Frame {
    msg: String,
    cause: Option<Box<Frame>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { head: Box::new(Frame { msg: m.to_string(), cause: None }) }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            head: Box::new(Frame { msg: c.to_string(), cause: Some(self.head) }),
        }
    }

    /// The cause messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(&self.head);
        while let Some(f) = cur {
            out.push(f.msg.as_str());
            cur = f.cause.as_ref();
        }
        out
    }

    /// The innermost message (the original failure).
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }

    fn from_std<E: std::error::Error>(e: E) -> Error {
        // Capture the source chain eagerly as messages.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut frame: Option<Box<Frame>> = None;
        for msg in msgs.into_iter().rev() {
            frame = Some(Box::new(Frame { msg, cause: frame }));
        }
        Error { head: frame.expect("at least one message") }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.head.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.head.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in &chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(e)
    }
}

mod private {
    /// Sealed conversion used by `Context` so it covers both plain
    /// `std::error::Error` results and already-`anyhow` results.
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> crate::Error;
    }

    impl IntoAnyhow for crate::Error {
        fn into_anyhow(self) -> crate::Error {
            self
        }
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoAnyhow for E {
        fn into_anyhow(self) -> crate::Error {
            crate::Error::from_std(self)
        }
    }
}

/// Attach context to errors (mirrors `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoAnyhow> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+)
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)))
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading weights")
            .unwrap_err()
            .context("loading checkpoint");
        assert_eq!(format!("{e}"), "loading checkpoint");
        assert_eq!(format!("{e:#}"), "loading checkpoint: reading weights: disk on fire");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().root_cause(), "disk on fire");
    }

    #[test]
    fn macros_build_errors() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("three is right out");
            }
            Ok(n)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        let e = anyhow!(String::from("plain message"));
        assert_eq!(e.to_string(), "plain message");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn f(a: usize, b: usize) -> Result<()> {
            ensure!(a == b);
            Ok(())
        }
        assert!(f(1, 2).unwrap_err().to_string().contains("a == b"));
    }
}
