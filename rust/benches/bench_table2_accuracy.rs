//! Table 2 (+ Table 1): inference accuracy of simulated pipelined
//! training, non-pipelined vs 4/6/8/10 stages, for LeNet-5 / AlexNet /
//! VGG-16 / ResNet-20.
//!
//! Paper values (Table 2, 30k-250k iters on real MNIST/CIFAR):
//!   LeNet-5   99.00 | 98.64 98.62 98.61 98.47
//!   AlexNet   82.51 | 78.47 78.32 78.47   —
//!   VGG-16    91.36 | 90.53 88.96 83.73 79.85
//!   ResNet-20 91.50 | 90.05 88.00 83.01   —
//! Shape to reproduce: pipelined converges; small drop at 4-6 stages,
//! larger drop as pipelining deepens (scaled protocol, DESIGN.md §4).

#[path = "common/mod.rs"]
mod common;

use pipestale::config::Mode;
use pipestale::util::bench::Table;

fn main() {
    if !pipestale::xla_ready() {
        eprintln!("skipping {}: needs artifacts + real XLA backend", file!());
        return;
    }
    pipestale::util::logging::init();
    let iters = common::bench_iters(240);
    let grid: &[(&str, &[(&str, &str)])] = &[
        ("lenet5", &[("4s", "lenet5_4s"), ("6s", "lenet5_6s"), ("8s", "lenet5_8s"), ("10s", "lenet5_10s")]),
        ("alexnet", &[("4s", "alexnet_4s"), ("6s", "alexnet_6s"), ("8s", "alexnet_8s")]),
        ("vgg16", &[("4s", "vgg16_4s"), ("6s", "vgg16_6s"), ("8s", "vgg16_8s"), ("10s", "vgg16_10s")]),
        ("resnet20", &[("4s", "resnet20_4s"), ("6s", "resnet20_6s"), ("8s", "resnet20_8s")]),
    ];

    let mut table = Table::new(&["CNN", "Non-pipelined", "4-Stage", "6-Stage", "8-Stage", "10-Stage"]);
    let mut csv = String::from("model,schedule,stages,ppv,accuracy\n");
    for (model, configs) in grid {
        // non-pipelined baseline uses the 4s artifacts sequentially
        let base = common::run(configs[0].1, Mode::Sequential, iters, 0);
        println!("{model} non-pipelined: {}", common::pct(base.final_accuracy));
        csv.push_str(&format!("{model},non-pipelined,1,-,{}\n", base.final_accuracy));
        let mut cells = vec![model.to_string(), common::pct(base.final_accuracy)];
        for (tag, cfg) in *configs {
            let r = common::run(cfg, Mode::Pipelined, iters, 0);
            println!("{model} {tag}: {}", common::pct(r.final_accuracy));
            csv.push_str(&format!(
                "{model},pipelined,{},{},{}\n",
                &tag[..tag.len() - 1],
                cfg,
                r.final_accuracy
            ));
            cells.push(common::pct(r.final_accuracy));
        }
        while cells.len() < 6 {
            cells.push("N/A".into());
        }
        table.row(&cells);
    }
    println!("\n=== Table 2 (measured, scaled protocol; {iters} iters) ===");
    println!("{}", table.render());
    println!(
        "\nPaper Table 2:        Non-pip  4s      6s      8s      10s\n\
         | LeNet-5   | 99.00% | 98.64% | 98.62% | 98.61% | 98.47% |\n\
         | AlexNet   | 82.51% | 78.47% | 78.32% | 78.47% | N/A    |\n\
         | VGG-16    | 91.36% | 90.53% | 88.96% | 83.73% | 79.85% |\n\
         | ResNet-20 | 91.50% | 90.05% | 88.00% | 83.01% | N/A    |"
    );
    common::write_results("table2.csv", &csv);
}
