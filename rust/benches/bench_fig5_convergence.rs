//! Figure 5: convergence curves of pipelined vs non-pipelined training.
//!
//! Paper shape to reproduce: for every network, pipelined and
//! non-pipelined accuracy curves climb with similar shape and converge
//! in a comparable number of iterations, possibly to slightly different
//! final accuracies.
//!
//! Writes results/fig5_<model>.csv with one accuracy series per
//! schedule, ready for plotting.

#[path = "common/mod.rs"]
mod common;

use pipestale::config::Mode;

fn main() {
    if !pipestale::xla_ready() {
        eprintln!("skipping {}: needs artifacts + real XLA backend", file!());
        return;
    }
    pipestale::util::logging::init();
    let iters = common::bench_iters(240);
    // one representative deep-pipelined config per model + baseline
    let grid: &[(&str, &[(&str, Mode, &str)])] = &[
        ("lenet5", &[
            ("non-pipelined", Mode::Sequential, "lenet5_4s"),
            ("4-stage", Mode::Pipelined, "lenet5_4s"),
            ("10-stage", Mode::Pipelined, "lenet5_10s"),
        ]),
        ("alexnet", &[
            ("non-pipelined", Mode::Sequential, "alexnet_4s"),
            ("4-stage", Mode::Pipelined, "alexnet_4s"),
            ("8-stage", Mode::Pipelined, "alexnet_8s"),
        ]),
        ("vgg16", &[
            ("non-pipelined", Mode::Sequential, "vgg16_4s"),
            ("4-stage", Mode::Pipelined, "vgg16_4s"),
            ("10-stage", Mode::Pipelined, "vgg16_10s"),
        ]),
        ("resnet20", &[
            ("non-pipelined", Mode::Sequential, "resnet20_4s"),
            ("4-stage", Mode::Pipelined, "resnet20_4s"),
            ("8-stage", Mode::Pipelined, "resnet20_8s"),
        ]),
    ];

    for (model, runs) in grid {
        let mut csv = String::from("schedule,iter,test_acc\n");
        println!("=== Figure 5: {model} ({iters} iters) ===");
        for (label, mode, cfg) in *runs {
            let r = common::run(cfg, mode.clone(), iters, 0);
            let curve: Vec<String> = r
                .recorder
                .evals
                .iter()
                .map(|e| format!("{:.0}@{}", 100.0 * e.accuracy, e.iter))
                .collect();
            println!("  {label:<14} {}", curve.join(" -> "));
            for e in &r.recorder.evals {
                csv.push_str(&format!("{label},{},{}\n", e.iter, e.accuracy));
            }
            // convergence check: the curve must rise from its start
            let first = r.recorder.evals.first().map(|e| e.accuracy).unwrap_or(0.0);
            let best = r.recorder.best_eval().map(|e| e.accuracy).unwrap_or(0.0);
            assert!(
                best >= first,
                "{model}/{label}: training did not improve ({first} -> {best})"
            );
        }
        common::write_results(&format!("fig5_{model}.csv"), &csv);
    }
    println!("\nPaper Fig 5 shape: pipelined curves track non-pipelined convergence.");
}
