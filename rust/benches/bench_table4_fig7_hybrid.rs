//! Table 4 + Figure 7: hybrid pipelined/non-pipelined training on
//! ResNet-20 with PPV (5,12,17) (8 stages, deep pipelining).
//!
//! Paper (30k-iter protocol):
//!   baseline 30k        91.50%
//!   pipelined 30k       88.29%
//!   20k+10k hybrid      90.71%
//!   20k+20k hybrid      91.72%
//! Shape to reproduce: deep pipelining costs accuracy; a non-pipelined
//! tail recovers it to (or past) baseline.

#[path = "common/mod.rs"]
mod common;

use pipestale::config::Mode;
use pipestale::util::bench::Table;
use pipestale::util::json;

/// Artifact-free Table-4 shape on the native block-IR ResNet fixture
/// (P=4, block-edge cuts): baseline / pipelined / two hybrid splits,
/// recorded to results/table4_native_resnet.json.
fn native_resnet_section() {
    let n = common::bench_iters(120);
    let p = 2 * n / 3;
    let cfg = "native_resnet_small_4s";
    println!("=== Native-ResNet hybrid (artifact-free, block IR; n={n}) ===");
    let runs = [
        ("baseline".to_string(), Mode::Sequential, n, 0),
        ("pipelined".to_string(), Mode::Pipelined, n, 0),
        (format!("{p}+{} hybrid", n - p), Mode::Hybrid, n, p),
        (format!("{p}+{p} hybrid"), Mode::Hybrid, p + p, p),
    ];
    let mut t = Table::new(&["Schedule", "Accuracy"]);
    let mut rows = Vec::new();
    for (label, mode, total, np) in runs {
        let r = common::run(cfg, mode, total, np);
        println!("{label}: {}", common::pct(r.final_accuracy));
        t.row(&[label.clone(), common::pct(r.final_accuracy)]);
        rows.push(json::obj(vec![
            ("schedule", json::s(&label)),
            ("iters", json::num(total as f64)),
            ("pipelined_iters", json::num(np as f64)),
            ("accuracy", json::num(r.final_accuracy)),
            (
                "evals",
                json::arr(r.recorder.evals.iter().map(|e| {
                    json::obj(vec![
                        ("iter", json::num(e.iter as f64)),
                        ("accuracy", json::num(e.accuracy)),
                    ])
                })),
            ),
        ]));
    }
    println!("\n{}", t.render());
    let doc = json::obj(vec![
        ("config", json::s(cfg)),
        ("iters", json::num(n as f64)),
        ("rows", json::arr(rows)),
    ]);
    common::write_results("table4_native_resnet.json", &doc.to_string_pretty());
}

fn main() {
    pipestale::util::logging::init();
    native_resnet_section();
    if !pipestale::xla_ready() {
        eprintln!("skipping XLA sections of {}: needs artifacts + real XLA backend", file!());
        return;
    }
    let n = common::bench_iters(300); // "30k" analog
    let p = 2 * n / 3; // "20k"
    let cfg = "resnet20_hybrid";

    let runs = [
        ("baseline".to_string(), Mode::Sequential, n, 0),
        ("pipelined".to_string(), Mode::Pipelined, n, 0),
        (format!("{p}+{} hybrid", n - p), Mode::Hybrid, n, p),
        (format!("{p}+{p} hybrid"), Mode::Hybrid, p + p, p),
    ];
    let paper = ["91.50%", "88.29%", "90.71%", "91.72%"];

    let mut table = Table::new(&["Schedule", "Accuracy", "Paper"]);
    let mut csv = String::from("schedule,iter,test_acc\n");
    for ((label, mode, total, np), paper_val) in runs.into_iter().zip(paper) {
        let r = common::run(cfg, mode, total, np);
        println!("{label}: {}", common::pct(r.final_accuracy));
        for e in &r.recorder.evals {
            csv.push_str(&format!("{label},{},{}\n", e.iter, e.accuracy));
        }
        table.row(&[label, common::pct(r.final_accuracy), paper_val.into()]);
    }
    println!("\n=== Table 4 (measured, scaled protocol; n={n}) ===");
    println!("{}", table.render());
    println!("\nFig 7 curves: see results/fig7.csv (accuracy series per schedule).");
    common::write_results("fig7.csv", &csv);
}
