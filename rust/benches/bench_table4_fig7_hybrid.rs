//! Table 4 + Figure 7: hybrid pipelined/non-pipelined training on
//! ResNet-20 with PPV (5,12,17) (8 stages, deep pipelining).
//!
//! Paper (30k-iter protocol):
//!   baseline 30k        91.50%
//!   pipelined 30k       88.29%
//!   20k+10k hybrid      90.71%
//!   20k+20k hybrid      91.72%
//! Shape to reproduce: deep pipelining costs accuracy; a non-pipelined
//! tail recovers it to (or past) baseline.

#[path = "common/mod.rs"]
mod common;

use pipestale::config::Mode;
use pipestale::util::bench::Table;

fn main() {
    if !pipestale::xla_ready() {
        eprintln!("skipping {}: needs artifacts + real XLA backend", file!());
        return;
    }
    pipestale::util::logging::init();
    let n = common::bench_iters(300); // "30k" analog
    let p = 2 * n / 3; // "20k"
    let cfg = "resnet20_hybrid";

    let runs = [
        ("baseline".to_string(), Mode::Sequential, n, 0),
        ("pipelined".to_string(), Mode::Pipelined, n, 0),
        (format!("{p}+{} hybrid", n - p), Mode::Hybrid, n, p),
        (format!("{p}+{p} hybrid"), Mode::Hybrid, p + p, p),
    ];
    let paper = ["91.50%", "88.29%", "90.71%", "91.72%"];

    let mut table = Table::new(&["Schedule", "Accuracy", "Paper"]);
    let mut csv = String::from("schedule,iter,test_acc\n");
    for ((label, mode, total, np), paper_val) in runs.into_iter().zip(paper) {
        let r = common::run(cfg, mode, total, np);
        println!("{label}: {}", common::pct(r.final_accuracy));
        for e in &r.recorder.evals {
            csv.push_str(&format!("{label},{},{}\n", e.iter, e.accuracy));
        }
        table.row(&[label, common::pct(r.final_accuracy), paper_val.into()]);
    }
    println!("\n=== Table 4 (measured, scaled protocol; n={n}) ===");
    println!("{}", table.render());
    println!("\nFig 7 curves: see results/fig7.csv (accuracy series per schedule).");
    common::write_results("fig7.csv", &csv);
}
