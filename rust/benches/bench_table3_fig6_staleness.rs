//! Table 3 + Figure 6: impact of weight staleness on ResNet-20.
//!
//! Experiment 1 ("Increasing Stages", Table 3): fine-grained pipelines
//! from 8 to 20 stages — accuracy degrades as stage count (and thus the
//! percentage of stale weights) grows. Paper: 91.50% non-pipelined down
//! to 79.09% at 20 stages.
//!
//! Experiment 2 ("Sliding Stage", Fig 6): ONE register pair slid through
//! the network — same %-stale-weights x-axis, but constant degree of
//! staleness (2). Paper finding to reproduce: the two curves roughly
//! coincide, i.e. accuracy is governed by the *percentage* of stale
//! weights, not their *degree*.
//!
//! Plus a beyond-the-paper section: the same accuracy-vs-PPV sweep
//! under every `--staleness-fix` (DESIGN.md §9), measuring how much of
//! the staleness-induced loss each mitigation buys back.

#[path = "common/mod.rs"]
mod common;

use pipestale::config::Mode;
use pipestale::meta::ConfigMeta;
use pipestale::pipeline::{FixKind, StalenessReport};
use pipestale::util::bench::Table;
use pipestale::util::json;

/// Artifact-free staleness sweep over the native block-IR ResNets:
/// sequential baseline, then pipelined runs of growing %-stale-weights
/// (early split -> deep split -> P=4 -> paper-depth P=4). Runs on any
/// machine and records results/table3_native_resnet.json.
fn native_resnet_section() {
    let iters = common::bench_iters(120);
    println!("=== Native-ResNet staleness (artifact-free, block IR; {iters} iters) ===");
    let mut t = Table::new(&["Config", "Stages", "% stale", "mean degree", "Accuracy"]);
    let baseline = common::run("native_resnet_small_4s", Mode::Sequential, iters, 0);
    t.row(&[
        "non-pipelined".into(),
        "1".into(),
        "0%".into(),
        "0".into(),
        common::pct(baseline.final_accuracy),
    ]);
    let mut rows = vec![json::obj(vec![
        ("config", json::s("native_resnet_small_4s")),
        ("schedule", json::s("sequential")),
        ("stages", json::num(1.0)),
        ("pct_stale", json::num(0.0)),
        ("mean_degree", json::num(0.0)),
        ("accuracy", json::num(baseline.final_accuracy)),
    ])];
    for cfg in [
        "native_resnet_small",
        "native_resnet_small_deep",
        "native_resnet_small_4s",
        "native_resnet20_4s",
    ] {
        let meta = pipestale::backend::native_config(cfg).unwrap();
        let rep = StalenessReport::from_meta(&meta);
        let r = common::run(cfg, Mode::Pipelined, iters, 0);
        println!(
            "{cfg}: stages={} %stale={:.1} acc={}",
            meta.paper_stages(),
            100.0 * rep.stale_weight_fraction,
            common::pct(r.final_accuracy)
        );
        t.row(&[
            cfg.into(),
            meta.paper_stages().to_string(),
            format!("{:.1}%", 100.0 * rep.stale_weight_fraction),
            format!("{:.1}", rep.mean_degree()),
            common::pct(r.final_accuracy),
        ]);
        rows.push(json::obj(vec![
            ("config", json::s(cfg)),
            ("schedule", json::s("pipelined")),
            ("stages", json::num(meta.paper_stages() as f64)),
            ("pct_stale", json::num(rep.stale_weight_fraction)),
            ("mean_degree", json::num(rep.mean_degree())),
            ("accuracy", json::num(r.final_accuracy)),
        ]));
    }
    println!("\n{}", t.render());
    let doc = json::obj(vec![("iters", json::num(iters as f64)), ("rows", json::arr(rows))]);
    common::write_results("table3_native_resnet.json", &doc.to_string_pretty());
}

/// Mitigation matrix: accuracy vs %-stale-weights under every
/// `--staleness-fix`, on the native ResNets (early split, deep split,
/// P=4) — does weight stashing / prediction / gradient damping buy
/// back the accuracy the stale schedule loses? Records
/// results/table3_native_resnet_mitigation.json.
fn native_resnet_mitigation_section() {
    let iters = common::bench_iters(120);
    println!("=== Native-ResNet mitigation matrix (artifact-free; {iters} iters) ===");
    let mut t = Table::new(&["Config", "Stages", "% stale", "none", "stash", "predict", "correct"]);
    let mut rows = Vec::new();
    for cfg in ["native_resnet_small", "native_resnet_small_deep", "native_resnet_small_4s"] {
        let meta = pipestale::backend::native_config(cfg).unwrap();
        let rep = StalenessReport::from_meta(&meta);
        let mut cells = vec![
            cfg.to_string(),
            meta.paper_stages().to_string(),
            format!("{:.1}%", 100.0 * rep.stale_weight_fraction),
        ];
        for fix in FixKind::all() {
            let r = common::run_with_fix(cfg, Mode::Pipelined, iters, fix);
            println!(
                "{cfg} [{}]: stages={} %stale={:.1} acc={}",
                fix.name(),
                meta.paper_stages(),
                100.0 * rep.stale_weight_fraction,
                common::pct(r.final_accuracy)
            );
            cells.push(common::pct(r.final_accuracy));
            rows.push(json::obj(vec![
                ("config", json::s(cfg)),
                ("fix", json::s(fix.name())),
                ("stages", json::num(meta.paper_stages() as f64)),
                ("pct_stale", json::num(rep.stale_weight_fraction)),
                ("mean_degree", json::num(rep.mean_degree())),
                ("accuracy", json::num(r.final_accuracy)),
            ]));
        }
        t.row(&cells);
    }
    println!("\n{}", t.render());
    let doc = json::obj(vec![("iters", json::num(iters as f64)), ("rows", json::arr(rows))]);
    common::write_results("table3_native_resnet_mitigation.json", &doc.to_string_pretty());
}

fn main() {
    pipestale::util::logging::init();
    native_resnet_section();
    native_resnet_mitigation_section();
    if !pipestale::xla_ready() {
        eprintln!("skipping XLA sections of {}: needs artifacts + real XLA backend", file!());
        return;
    }
    let iters = common::bench_iters(240);
    let root = pipestale::artifacts_root();

    let baseline = common::run("resnet20_4s", Mode::Sequential, iters, 0);
    println!("non-pipelined baseline: {}", common::pct(baseline.final_accuracy));

    let mut csv = String::from("experiment,config,stages,pct_stale,mean_degree,accuracy\n");
    csv.push_str(&format!("baseline,resnet20,1,0,0,{}\n", baseline.final_accuracy));

    // --- Experiment 1: increasing stages (Table 3) ----------------------
    let mut t3 = Table::new(&["Stages", "% stale", "mean degree", "Accuracy", "Paper"]);
    t3.row(&["Non-pipelined".into(), "0%".into(), "0".into(),
             common::pct(baseline.final_accuracy), "91.50%".into()]);
    let paper3 = [
        (8, "90.28%"), (10, "88.37%"), (12, "88.73%"), (14, "87.94%"),
        (16, "87.30%"), (18, "86.23%"), (20, "79.09%"),
    ];
    for (ns, paper) in paper3 {
        let cfg = format!("resnet20_fine{ns}");
        let meta = ConfigMeta::load_named(&root, &cfg).unwrap();
        let rep = StalenessReport::from_meta(&meta);
        let r = common::run(&cfg, Mode::Pipelined, iters, 0);
        println!(
            "fine {ns}-stage: %stale={:.1} acc={}",
            100.0 * rep.stale_weight_fraction,
            common::pct(r.final_accuracy)
        );
        t3.row(&[
            ns.to_string(),
            format!("{:.1}%", 100.0 * rep.stale_weight_fraction),
            format!("{:.1}", rep.mean_degree()),
            common::pct(r.final_accuracy),
            paper.into(),
        ]);
        csv.push_str(&format!(
            "increasing,{cfg},{ns},{},{},{}\n",
            rep.stale_weight_fraction,
            rep.mean_degree(),
            r.final_accuracy
        ));
    }
    println!("\n=== Table 3 (measured, scaled protocol; {iters} iters) ===");
    println!("{}", t3.render());

    // --- Experiment 2: sliding stage (Fig 6) ---------------------------
    let mut t6 = Table::new(&["Register after layer", "% stale", "degree", "Accuracy"]);
    for p in [3usize, 5, 7, 9, 11, 13, 15, 17, 19] {
        let cfg = format!("resnet20_slide{p}");
        let meta = ConfigMeta::load_named(&root, &cfg).unwrap();
        let rep = StalenessReport::from_meta(&meta);
        let r = common::run(&cfg, Mode::Pipelined, iters, 0);
        println!(
            "slide@{p}: %stale={:.1} acc={}",
            100.0 * rep.stale_weight_fraction,
            common::pct(r.final_accuracy)
        );
        t6.row(&[
            p.to_string(),
            format!("{:.1}%", 100.0 * rep.stale_weight_fraction),
            "2".into(),
            common::pct(r.final_accuracy),
        ]);
        csv.push_str(&format!(
            "sliding,{cfg},4,{},2,{}\n",
            rep.stale_weight_fraction, r.final_accuracy
        ));
    }
    println!("\n=== Figure 6 'Sliding Stage' series ===");
    println!("{}", t6.render());
    println!(
        "\nPaper Fig 6 finding: both series fall with %-stale-weights and\n\
         roughly coincide — the degree of staleness (high in Experiment 1,\n\
         constant 2 in Experiment 2) is not the driver."
    );
    common::write_results("table3_fig6.csv", &csv);
}
