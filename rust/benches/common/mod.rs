//! Shared helpers for the paper-reproduction benches.
//!
//! Scale knobs (environment):
//!   PIPESTALE_BENCH_ITERS  — training iterations per run (default 200)
//!   PIPESTALE_FAST=1       — cut everything ~4x for smoke runs

#![allow(dead_code)]

use pipestale::config::{Mode, RunConfig};
use pipestale::pipeline::FixKind;
use pipestale::train::TrainResult;

pub fn bench_iters(default: u64) -> u64 {
    let base = std::env::var("PIPESTALE_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default);
    if fast() {
        (base / 4).max(20)
    } else {
        base
    }
}

pub fn fast() -> bool {
    std::env::var("PIPESTALE_FAST").as_deref() == Ok("1")
}

/// One paired training run: every schedule in a bench shares seed, data
/// and hyperparameters, so differences isolate the schedule itself.
pub fn run(config: &str, mode: Mode, iters: u64, pipelined_iters: u64) -> TrainResult {
    let mut rc = RunConfig::new(config);
    rc.mode = mode;
    rc.iters = iters;
    rc.pipelined_iters = pipelined_iters;
    rc.eval_every = (iters / 6).max(1);
    rc.train_size = 1024;
    rc.test_size = 256;
    rc.noise = 2.0; // hard enough that schedules separate
    rc.seed = 42;
    pipestale::train::run(&rc).unwrap_or_else(|e| panic!("{config} [{mode:?}]: {e:#}"))
}

/// Like [`run`] but with a staleness mitigation installed
/// (`--staleness-fix`, DESIGN.md §9); same seed/data/hyperparameters,
/// so accuracy differences isolate the fix itself.
pub fn run_with_fix(config: &str, mode: Mode, iters: u64, fix: FixKind) -> TrainResult {
    let mut rc = RunConfig::new(config);
    rc.mode = mode;
    rc.iters = iters;
    rc.eval_every = (iters / 6).max(1);
    rc.train_size = 1024;
    rc.test_size = 256;
    rc.noise = 2.0;
    rc.seed = 42;
    rc.staleness_fix = fix;
    pipestale::train::run(&rc)
        .unwrap_or_else(|e| panic!("{config} [{mode:?}/{}]: {e:#}", fix.name()))
}

pub fn write_results(name: &str, content: &str) {
    let path = pipestale::results_root().join(name);
    std::fs::write(&path, content).unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    println!("[results] wrote {}", path.display());
}

pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}
