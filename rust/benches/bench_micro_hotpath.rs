//! Hot-path microbenches (the §Perf instrumentation): where a training
//! cycle's host-side time goes, independent of XLA compute.
//!
//! Each hot path is measured twice — the seed-era "before" shape and
//! the zero-copy "after" shape — so the speedups the pool/fused-kernel
//! work claims are reproduced in the same binary:
//!
//!   * literal <-> tensor conversion: vec1+reshape / to_vec+from_vec
//!     (two copies + fresh allocs) vs single-copy pooled conversion
//!   * SGD update (1M params): pre-fusion reference loops vs the fused
//!     kernel behind `Sgd::step`
//!   * conv2d / dense kernels: pre-lowering nested loops
//!     (`reference_*`) vs the im2col+GEMM core (`backend::gemm`),
//!     forward and backward — the native backend's compute hot path
//!   * raw GEMM core: scalar micro-kernel vs the detected SIMD one
//!     (`gemm_scalar_vs_simd`), and 1 GEMM thread vs the worker-pool
//!     dispatch (`gemm_1t_vs_nt`) — the tentpole's before/after pairs
//!   * scheduler cycle (mock executor, P=4): pool disabled (every
//!     backing store freshly allocated, as in the seed) vs pool enabled
//!   * streaming ingest: synchronous decode+augment on the consumer
//!     thread vs the prefetcher's worker-thread overlap (§11)
//!   * meta.json parse, DES throughput, XLA stage execution (unchanged
//!     paths, artifact/backend gated)
//!
//! Results go to stdout, `micro_hotpath.csv`, and machine-readable
//! `BENCH_micro.json` in `results_root()` so the perf trajectory is
//! tracked across PRs.

#[path = "common/mod.rs"]
mod common;

use pipestale::backend::{gemm, kernels, simd, threadpool, ActKind};
use pipestale::data::batch_seed;
use pipestale::meta::ConfigMeta;
use pipestale::model::ModelParams;
use pipestale::optim::{kernel, Schedule, Sgd};
use pipestale::pipeline::mock::MockExecutor;
use pipestale::pipeline::perfsim::*;
use pipestale::pipeline::{Feed, Pipeline, XlaExecutor};
use pipestale::pool::TensorPool;
use pipestale::tensor::{IntTensor, Tensor};
use pipestale::util::bench::{bench, bench_n, BenchStats};
use pipestale::util::json::{self, Json};
use pipestale::util::rng::Pcg32;

struct Report {
    all: Vec<BenchStats>,
    pairs: Vec<(&'static str, String, String)>,
}

impl Report {
    fn push(&mut self, st: BenchStats) -> String {
        println!("{}", st.report());
        let name = st.name.clone();
        self.all.push(st);
        name
    }

    fn pair(&mut self, key: &'static str, before: BenchStats, after: BenchStats) {
        let b = self.push(before);
        let a = self.push(after);
        self.pairs.push((key, b, a));
    }

    fn stat(&self, name: &str) -> &BenchStats {
        self.all.iter().find(|s| s.name == name).expect("bench name")
    }
}

fn main() {
    pipestale::util::logging::init();
    let root = pipestale::artifacts_root();
    let pool = TensorPool::global();
    let mut rep = Report { all: Vec::new(), pairs: Vec::new() };

    // ---- literal conversions (the FFI boundary), 2MB tensor ------------
    let mut rng = Pcg32::seeded(1);
    let mut data = vec![0.0f32; 32 * 32 * 32 * 16];
    data.iter_mut().for_each(|v| *v = rng.normal());
    let shape = [32usize, 32, 32, 16];
    let t = Tensor::from_vec(&shape, data).unwrap();
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();

    let before = bench("tensor->literal legacy (2MB)", 3, 0.5, || {
        // Seed path: rank-1 literal + reshape = two copies, two allocs.
        let lit = xla::Literal::vec1(t.data()).reshape(&dims).unwrap();
        std::hint::black_box(lit);
    });
    let after = bench("tensor->literal pooled (2MB)", 3, 0.5, || {
        std::hint::black_box(t.to_literal().unwrap());
    });
    rep.pair("tensor_to_literal_2mb", before, after);

    let lit = t.to_literal().unwrap();
    let before = bench("literal->tensor legacy (2MB)", 3, 0.5, || {
        // Seed path: to_vec allocates a fresh backing store every call.
        let v = lit.to_vec::<f32>().unwrap();
        std::hint::black_box(Tensor::from_vec(&shape, v).unwrap());
    });
    let after = bench("literal->tensor pooled (2MB)", 3, 0.5, || {
        std::hint::black_box(Tensor::from_literal(&lit, &shape).unwrap());
    });
    rep.pair("literal_to_tensor_2mb", before, after);

    // ---- SGD hot loop: 1M params with momentum+wd -----------------------
    let n = 1_000_000;
    let mut p_ref = vec![1.0f32; n];
    let g_ref = vec![1.0f32; n];
    let mut v_ref = vec![0.0f32; n];
    let before = bench("sgd step reference (1M params, momentum+wd)", 3, 0.5, || {
        kernel::reference_update(&mut p_ref, &g_ref, &mut v_ref, 0.1, 0.9, false, 1e-4);
    });
    let mut opt = Sgd::new(Schedule::Const { base: 0.1 }, 0.9, false, 1e-4);
    let mut params = vec![Tensor::ones(&[n])];
    let grads = vec![Tensor::ones(&[n])];
    let mut iter = 0usize;
    let after = bench("sgd step fused (1M params, momentum+wd)", 3, 0.5, || {
        opt.step(iter, &mut params, &grads).unwrap();
        iter += 1;
    });
    rep.pair("sgd_step_1m", before, after);

    // ---- conv/dense kernels: reference loops vs the GEMM lowering -------
    // LeNet-middle-layer geometry: big enough that cache behavior
    // matters, small enough that the reference loops stay benchable.
    {
        let mut rng = Pcg32::seeded(7);
        let (n, h, w, cin, cout, k) = (16usize, 14usize, 14usize, 8usize, 16usize, 5usize);
        let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.normal()).collect();
        let wgt: Vec<f32> = (0..k * k * cin * cout).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; n * h * w * cout]; // SAME stride 1
        let before = bench("conv2d fwd reference loops (16x14x14x8 -> 16, k5)", 3, 0.4, || {
            kernels::reference_conv2d_forward(
                &x,
                n,
                h,
                w,
                cin,
                &wgt,
                k,
                cout,
                1,
                true,
                Some(&bias),
                &mut y,
            );
        });
        let after = bench("conv2d fwd im2col+GEMM (16x14x14x8 -> 16, k5)", 3, 0.4, || {
            kernels::conv2d_forward(&x, n, h, w, cin, &wgt, k, cout, 1, true, Some(&bias), &mut y);
        });
        rep.pair("conv_fwd_gemm", before, after);

        let dy: Vec<f32> = (0..y.len()).map(|_| rng.normal()).collect();
        let mut dx = vec![0.0f32; x.len()];
        let mut dw = vec![0.0f32; wgt.len()];
        let mut db = vec![0.0f32; cout];
        let before = bench("conv2d bwd reference loops (16x14x14x8 -> 16, k5)", 3, 0.4, || {
            dx.fill(0.0);
            dw.fill(0.0);
            db.fill(0.0);
            kernels::reference_conv2d_backward(
                &x,
                n,
                h,
                w,
                cin,
                &wgt,
                k,
                cout,
                1,
                true,
                &dy,
                &mut dx,
                &mut dw,
                Some(&mut db),
            );
        });
        let after = bench("conv2d bwd im2col+GEMM (16x14x14x8 -> 16, k5)", 3, 0.4, || {
            dx.fill(0.0);
            dw.fill(0.0);
            db.fill(0.0);
            kernels::conv2d_backward(
                &x,
                n,
                h,
                w,
                cin,
                &wgt,
                k,
                cout,
                1,
                true,
                &dy,
                &mut dx,
                &mut dw,
                Some(&mut db),
            );
        });
        rep.pair("conv_bwd_gemm", before, after);

        // dense: the LeNet fc1 shape (400 -> 120) at batch 64.
        let (dn, din, dout) = (64usize, 400usize, 120usize);
        let fx: Vec<f32> = (0..dn * din).map(|_| rng.normal()).collect();
        let fw: Vec<f32> = (0..din * dout).map(|_| rng.normal()).collect();
        let fb: Vec<f32> = (0..dout).map(|_| rng.normal()).collect();
        let mut fy = vec![0.0f32; dn * dout];
        let before = bench("dense fwd reference loops (64x400 -> 120, tanh)", 3, 0.4, || {
            kernels::reference_dense_forward(&fx, dn, din, &fw, &fb, dout, ActKind::Tanh, &mut fy);
        });
        let after = bench("dense fwd GEMM (64x400 -> 120, tanh)", 3, 0.4, || {
            kernels::dense_forward(&fx, dn, din, &fw, &fb, dout, ActKind::Tanh, &mut fy);
        });
        rep.pair("dense_fwd_gemm", before, after);
    }

    // ---- raw GEMM core: scalar vs SIMD, 1 thread vs worker pool ---------
    // ResNet-mid-layer im2col geometry: C[4096x64] = A[4096x576]*B[576x64]
    // (16 images of 16x16 spatial, 64 output channels, 3x3x64 patches).
    // Both axes pin the other axis so each pair isolates one effect; the
    // N-thread leg forces >= 2 threads so the worker pool is exercised
    // even on a 1-core container.
    {
        let mut rng = Pcg32::seeded(11);
        let (m, n, k) = (4096usize, 64usize, 576usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut c = vec![0.0f32; m * n];
        let micro = simd::detected();
        let nt = threadpool::configured_threads().max(2);
        println!("[gemm] micro-kernel: {} / threads: {}", micro.name(), nt);

        let before = bench("gemm scalar 1t (4096x64x576)", 3, 0.4, || {
            gemm::sgemm_with(simd::Micro::Scalar, 1, false, false, m, n, k, &a, &b, false, &mut c);
        });
        let after = bench(&format!("gemm {} 1t (4096x64x576)", micro.name()), 3, 0.4, || {
            gemm::sgemm_with(micro, 1, false, false, m, n, k, &a, &b, false, &mut c);
        });
        rep.pair("gemm_scalar_vs_simd", before, after);

        let name = format!("gemm {} 1t serial baseline (4096x64x576)", micro.name());
        let before = bench(&name, 3, 0.4, || {
            gemm::sgemm_with(micro, 1, false, false, m, n, k, &a, &b, false, &mut c);
        });
        let name = format!("gemm {} {}t worker pool (4096x64x576)", micro.name(), nt);
        let after = bench(&name, 3, 0.4, || {
            gemm::sgemm_with(micro, nt, false, false, m, n, k, &a, &b, false, &mut c);
        });
        rep.pair("gemm_1t_vs_nt", before, after);
    }

    // ---- scheduler overhead with mock executor, pool off vs on ----------
    let cycle_bench = |name: &str| -> BenchStats {
        let mut pipe = Pipeline::new(MockExecutor::new(4), 1);
        let mut b = 0u64;
        bench(name, 10, 0.3, || {
            let f = Feed {
                batch_id: b,
                seed: batch_seed(1, b),
                x: Tensor::filled(&[1], b as f32),
                labels: IntTensor::from_vec(&[1], vec![0]).unwrap(),
            };
            pipe.cycle(Some(f)).unwrap();
            b += 1;
        })
    };
    pool.set_enabled(false);
    let before = cycle_bench("scheduler cycle (mock, P=4, pool off)");
    pool.set_enabled(true);
    // Snapshot around the pool-on run only: the emitted counters must
    // reflect the optimized configuration, not the disabled control or
    // the legacy conversion benches above.
    let base = pool.stats();
    let after = cycle_bench("scheduler cycle (mock, P=4, pool on)");
    rep.pair("scheduler_cycle_mock_p4", before, after);
    let pool_stats = pool.stats().delta(&base);
    println!(
        "[pool] steady-state: fresh={} reuses={} hit_rate={:.3}",
        pool_stats.fresh_allocs,
        pool_stats.reuses,
        pool_stats.hit_rate()
    );

    // ---- native backend: one full pipeline cycle, artifact-free ---------
    // (the compute twin of the XLA cycle bench below; runs everywhere)
    {
        let meta = pipestale::backend::native_config("native_lenet_small").unwrap();
        let params = ModelParams::init(&meta.partitions, 1).unwrap();
        let optims = pipestale::train::build_optims(&meta, 1000, 1.0);
        let exec = pipestale::backend::NativeExecutor::new(meta.clone(), params, optims).unwrap();
        let mut pipe = Pipeline::new(exec, meta.batch);
        let spec = pipestale::data::SyntheticSpec { train: 64, test: 32, noise: 1.0, seed: 4 };
        let (ds, _) = pipestale::data::load_or_synthesize(&meta.dataset, None, &spec).unwrap();
        let idxs: Vec<usize> = (0..meta.batch).collect();
        let (x, labels) = ds.gather(&idxs);
        let mut b = 0u64;
        let iters = if common::fast() { 10 } else { 30 };
        let st = bench_n("pipeline cycle (native, lenet-small b16)", 3, iters, || {
            pipe.cycle(Some(Feed {
                batch_id: b,
                seed: batch_seed(3, b),
                x: x.clone(),
                labels: labels.clone(),
            }))
            .unwrap();
            b += 1;
        });
        rep.push(st);
    }

    // ---- threaded vs scheduler runtime (native, wall-clock) -------------
    // Both runtimes execute the identical schedule (bitwise — see
    // tests/threaded_native.rs), so this pair isolates pure runtime
    // overhead/benefit. On this 1-core container the workers
    // time-slice: expect a ratio near 1.0; multi-core hardware is
    // where the threaded runtime parallelizes (DESIGN.md §4).
    {
        let meta = pipestale::backend::native_config("native_lenet_small").unwrap();
        let spec = pipestale::data::SyntheticSpec { train: 128, test: 32, noise: 1.0, seed: 9 };
        let (ds, _) = pipestale::data::load_or_synthesize(&meta.dataset, None, &spec).unwrap();
        let mut batcher = pipestale::data::Batcher::new(ds.len(), meta.batch, 3);
        let n = if common::fast() { 10 } else { 40 };
        let batches: Vec<(Tensor, IntTensor)> = (0..n)
            .map(|_| {
                let idxs = batcher.next_indices().to_vec();
                ds.gather(&idxs)
            })
            .collect();
        let before = bench_n(&format!("train {n} iters scheduler (native lenet-small)"), 1, 3, || {
            let params = ModelParams::init(&meta.partitions, 1).unwrap();
            let optims = pipestale::train::build_optims(&meta, n as u64, 1.0);
            let exec =
                pipestale::backend::NativeExecutor::new(meta.clone(), params, optims).unwrap();
            let mut pipe = Pipeline::new(exec, meta.batch);
            for (b, (x, labels)) in batches.iter().enumerate() {
                pipe.cycle(Some(Feed {
                    batch_id: b as u64,
                    seed: batch_seed(1, b as u64),
                    x: x.clone(),
                    labels: labels.clone(),
                }))
                .unwrap();
            }
            pipe.drain().unwrap();
        });
        let after = bench_n(&format!("train {n} iters threaded (native lenet-small)"), 1, 3, || {
            let params = ModelParams::init(&meta.partitions, 1).unwrap();
            let optims = pipestale::train::build_optims(&meta, n as u64, 1.0);
            let mut pipe =
                pipestale::pipeline::ThreadedPipeline::launch_native(&meta, params, optims)
                    .unwrap();
            pipe.train(n as u64, 1, |b| Ok(batches[b as usize].clone())).unwrap();
            pipe.shutdown().unwrap();
        });
        rep.pair("threaded_vs_scheduler_native", before, after);
    }

    // ---- streaming ingest: synchronous decode vs prefetch overlap -------
    // Real CIFAR-format bytes through the record decode + augment path
    // (DESIGN.md §11). The sync leg decodes on the consumer thread; the
    // prefetch leg overlaps decode with the consumer, so on multi-core
    // hardware the consumer mostly dequeues finished batches. Output is
    // bitwise identical either way (tests/data_stream.rs).
    {
        let dir = std::env::temp_dir().join(format!("bench_ingest_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        pipestale::data::fixtures::write_cifar_fixture(&dir, 256, 8, 3).unwrap();
        let (train, _) = pipestale::data::load_cifar10_dir_stream(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let ds = std::sync::Arc::new(train);
        let mk = |threads: usize| {
            let mut o = pipestale::data::StreamOptions::plain(32, 5, 9);
            o.augment = pipestale::data::Augment::standard("cifar10");
            o.threads = threads;
            pipestale::data::BatchStream::new(std::sync::Arc::clone(&ds), o).unwrap()
        };
        let mut sync = mk(0);
        let before = bench("ingest decode+augment sync (cifar b32)", 3, 0.4, || {
            std::hint::black_box(sync.next_batch().unwrap());
        });
        let nt = threadpool::configured_threads().clamp(2, 4);
        let mut pre = mk(nt);
        let after =
            bench(&format!("ingest decode+augment prefetch {nt}t (cifar b32)"), 3, 0.4, || {
                std::hint::black_box(pre.next_batch().unwrap());
            });
        rep.pair("ingest_sync_vs_prefetch", before, after);
    }

    // ---- checkpoint store (fault-tolerance storage path) ----------------
    // What one supervisor segment boundary costs: an atomic rotating
    // save (tmp + fsync + rename + prune), and a newest-valid restore
    // (checksum + structural scan). DESIGN.md §8.
    {
        let meta = pipestale::backend::native_config("native_lenet_small").unwrap();
        let params = ModelParams::init(&meta.partitions, 5).unwrap();
        let dir = std::env::temp_dir().join(format!("bench_ckpts_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = pipestale::model::checkpoint::CheckpointStore::open(&dir, 3).unwrap();
        let mut iter = 0u64;
        let st = bench("checkpoint store save+rotate (lenet-small)", 2, 0.5, || {
            iter += 10;
            std::hint::black_box(store.save(&params, iter).unwrap());
        });
        rep.push(st);
        let st = bench("checkpoint store newest-valid restore", 2, 0.5, || {
            std::hint::black_box(store.newest_valid(Some(&meta)).unwrap());
        });
        rep.push(st);
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- artifact-dependent sections ------------------------------------
    if pipestale::artifacts_present() {
        let st = bench("meta.json parse (resnet110_4s)", 2, 0.5, || {
            std::hint::black_box(ConfigMeta::load_named(&root, "resnet110_4s").unwrap());
        });
        rep.push(st);

        let meta = ConfigMeta::load_named(&root, "resnet110_mem").unwrap();
        let costs = gtx1060_costs(&meta).scale_batch(128.0);
        let comm = CommModel::default();
        let st = bench("DES simulate 1000 batches (P=2)", 2, 0.5, || {
            std::hint::black_box(simulate_pipelined(&costs, &comm, Mapping::Paired, 1000));
        });
        rep.push(st);
    } else {
        eprintln!("[skip] meta/DES benches: artifacts not built");
    }

    if pipestale::xla_ready() {
        let meta = ConfigMeta::load_named(&root, "resnet20_4s").unwrap();
        let runtime = pipestale::runtime::Runtime::cpu().unwrap();
        let params = ModelParams::init(&meta.partitions, 1).unwrap();
        let optims = pipestale::train::build_optims(&meta, 100, 1.0);
        let exec = XlaExecutor::new(&runtime, meta.clone(), params, optims).unwrap();
        let mut pipe = Pipeline::new(exec, meta.batch);
        let x = Tensor::ones(&[meta.batch, 32, 32, 3]);
        let labels = IntTensor::from_vec(&[meta.batch], vec![0; meta.batch]).unwrap();
        let mut b = 0u64;
        let iters = if common::fast() { 10 } else { 30 };
        let st = bench_n("pipeline cycle (XLA, resnet20_4s b32)", 3, iters, || {
            pipe.cycle(Some(Feed {
                batch_id: b,
                seed: batch_seed(2, b),
                x: x.clone(),
                labels: labels.clone(),
            }))
            .unwrap();
            b += 1;
        });
        rep.push(st);
    } else {
        eprintln!("[skip] XLA cycle bench: needs artifacts + real backend");
    }

    // ---- emit machine-readable results ----------------------------------
    let mut benches = std::collections::BTreeMap::new();
    for st in &rep.all {
        benches.insert(st.name.clone(), st.to_json());
    }
    let mut pairs = std::collections::BTreeMap::new();
    for (key, before, after) in &rep.pairs {
        let (b, a) = (rep.stat(before), rep.stat(after));
        pairs.insert(
            key.to_string(),
            json::obj(vec![
                ("before", json::s(before)),
                ("after", json::s(after)),
                ("speedup_mean", json::num(b.mean_s / a.mean_s)),
                ("speedup_p50", json::num(b.p50_s / a.p50_s)),
            ]),
        );
    }
    let doc = json::obj(vec![
        ("schema", json::s("pipestale/bench_micro/v2")),
        ("benches", Json::Obj(benches)),
        ("pairs", Json::Obj(pairs)),
        (
            "pool",
            json::obj(vec![
                ("fresh_allocs", json::num(pool_stats.fresh_allocs as f64)),
                ("reuses", json::num(pool_stats.reuses as f64)),
                ("recycled", json::num(pool_stats.recycled as f64)),
                ("hit_rate", json::num(pool_stats.hit_rate())),
            ]),
        ),
    ]);
    common::write_results("BENCH_micro.json", &doc.to_string_pretty());

    let mut csv = String::from("bench,mean_ms,p50_ms,p95_ms,min_ms\n");
    for st in &rep.all {
        csv.push_str(&format!(
            "\"{}\",{},{},{},{}\n",
            st.name,
            st.mean_s * 1e3,
            st.p50_s * 1e3,
            st.p95_s * 1e3,
            st.min_s * 1e3
        ));
    }
    common::write_results("micro_hotpath.csv", &csv);
}
