//! Hot-path microbenches (the §Perf instrumentation): where a training
//! cycle's host-side time goes, independent of XLA compute.
//!
//!   * literal <-> tensor conversion (the FFI boundary)
//!   * SGD update loop (momentum + weight decay)
//!   * scheduler overhead with a no-op executor (cycles/s)
//!   * meta.json parse (startup cost)
//!   * DES throughput (batches simulated / s)
//!   * XLA stage execution for resnet20_4s (end-to-end cycle cost)

#[path = "common/mod.rs"]
mod common;

use pipestale::data::batch_seed;
use pipestale::meta::ConfigMeta;
use pipestale::model::ModelParams;
use pipestale::optim::{Schedule, Sgd};
use pipestale::pipeline::mock::MockExecutor;
use pipestale::pipeline::perfsim::*;
use pipestale::pipeline::{Feed, Pipeline, XlaExecutor};
use pipestale::tensor::{IntTensor, Tensor};
use pipestale::util::bench::{bench, bench_n};
use pipestale::util::rng::Pcg32;

fn main() {
    pipestale::util::logging::init();
    let root = pipestale::artifacts_root();

    // literal conversion
    let mut rng = Pcg32::seeded(1);
    let mut data = vec![0.0f32; 32 * 32 * 32 * 16];
    data.iter_mut().for_each(|v| *v = rng.normal());
    let t = Tensor::from_vec(&[32, 32, 32, 16], data).unwrap();
    let st = bench("tensor->literal (2MB)", 3, 0.5, || {
        std::hint::black_box(t.to_literal().unwrap());
    });
    println!("{}", st.report());
    let lit = t.to_literal().unwrap();
    let st = bench("literal->tensor (2MB)", 3, 0.5, || {
        std::hint::black_box(Tensor::from_literal(&lit, &[32, 32, 32, 16]).unwrap());
    });
    println!("{}", st.report());

    // SGD hot loop: 1M params with momentum+wd
    let mut opt = Sgd::new(Schedule::Const { base: 0.1 }, 0.9, false, 1e-4);
    let mut params = vec![Tensor::ones(&[1_000_000])];
    let grads = vec![Tensor::ones(&[1_000_000])];
    let mut iter = 0usize;
    let st = bench("sgd step (1M params, momentum+wd)", 3, 0.5, || {
        opt.step(iter, &mut params, &grads);
        iter += 1;
    });
    println!("{}", st.report());

    // scheduler overhead with mock executor
    let mut pipe = Pipeline::new(MockExecutor::new(4), 1);
    let mut b = 0u64;
    let st = bench("scheduler cycle (mock, P=4)", 10, 0.3, || {
        let f = Feed {
            batch_id: b,
            seed: batch_seed(1, b),
            x: Tensor::from_vec(&[1], vec![b as f32]).unwrap(),
            labels: IntTensor::from_vec(&[1], vec![0]).unwrap(),
        };
        pipe.cycle(Some(f)).unwrap();
        b += 1;
    });
    println!("{}", st.report());

    // meta.json parse
    let st = bench("meta.json parse (resnet110_4s)", 2, 0.5, || {
        std::hint::black_box(ConfigMeta::load_named(&root, "resnet110_4s").unwrap());
    });
    println!("{}", st.report());

    // DES throughput
    let meta = ConfigMeta::load_named(&root, "resnet110_mem").unwrap();
    let costs = gtx1060_costs(&meta).scale_batch(128.0);
    let comm = CommModel::default();
    let st = bench("DES simulate 1000 batches (P=2)", 2, 0.5, || {
        std::hint::black_box(simulate_pipelined(&costs, &comm, Mapping::Paired, 1000));
    });
    println!("{}", st.report());

    // XLA end-to-end cycle for resnet20_4s
    let meta = ConfigMeta::load_named(&root, "resnet20_4s").unwrap();
    let runtime = pipestale::runtime::Runtime::cpu().unwrap();
    let params = ModelParams::init(&meta.partitions, 1).unwrap();
    let optims = pipestale::train::build_optims(&meta, 100, 1.0);
    let exec = XlaExecutor::new(&runtime, meta.clone(), params, optims).unwrap();
    let mut pipe = Pipeline::new(exec, meta.batch);
    let x = Tensor::ones(&[meta.batch, 32, 32, 3]);
    let labels = IntTensor::from_vec(&[meta.batch], vec![0; meta.batch]).unwrap();
    let mut b = 0u64;
    let st = bench_n("pipeline cycle (XLA, resnet20_4s b32)", 3, if common::fast() { 10 } else { 30 }, || {
        pipe.cycle(Some(Feed {
            batch_id: b,
            seed: batch_seed(2, b),
            x: x.clone(),
            labels: labels.clone(),
        }))
        .unwrap();
        b += 1;
    });
    println!("{}", st.report());

    let mut csv = String::from("bench,mean_ms,p50_ms\n");
    csv.push_str(&format!("xla_cycle_resnet20_4s,{},{}\n", st.mean_s * 1e3, st.p50_s * 1e3));
    common::write_results("micro_hotpath.csv", &csv);
}
