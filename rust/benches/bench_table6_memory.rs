//! Table 6 (+ §6.7): memory usage of 4-stage pipelined ResNet training.
//!
//! Paper (torchsummary accounting, batch 128):
//!   ResNet  PPV    Activations  Weight   Increase        Increase %
//!   -20     (7)    3.84MB x bs  1.03MB   2.58MB x bs     67%
//!   -56     (19)   10.87MB x bs 3.25MB   6.32MB x bs     58%
//!   -110    (37)   21.43MB x bs 6.59MB   12.35MB x bs    57%
//!   -224    (75)   43.70MB x bs 13.64MB  25.07MB x bs    57%
//!   -362    (121)  70.67MB x bs 22.17MB  40.50MB x bs    57%
//! Shape to reproduce: modest increase (tens of %), roughly constant for
//! deeper nets; zero weight copies stashed (vs PipeDream).

#[path = "common/mod.rs"]
mod common;

use pipestale::memory::{pipedream_stash_bytes, MemoryReport};
use pipestale::meta::ConfigMeta;
use pipestale::util::bench::Table;

fn main() {
    if !pipestale::artifacts_present() {
        eprintln!("skipping {}: artifacts not built", file!());
        return;
    }
    let root = pipestale::artifacts_root();
    let mb = 1024.0 * 1024.0;
    let paper = [
        ("20", "3.84", "1.03", "2.58", "67%"),
        ("56", "10.87", "3.25", "6.32", "58%"),
        ("110", "21.43", "6.59", "12.35", "57%"),
        ("224", "43.70", "13.64", "25.07", "57%"),
        ("362", "70.67", "22.17", "40.50", "57%"),
    ];
    let mut t = Table::new(&[
        "ResNet", "PPV", "Act MB/sample", "Weight MB", "Incr MB/sample (paper-style)",
        "Incr %", "Paper %", "Ours (recompute) %",
    ]);
    let mut csv =
        String::from("model,ppv,act_mb,weight_mb,incr_paper_style_mb,incr_pct,incr_ours_pct\n");
    for (d, _pa, _pw, _pi, ppct) in paper {
        let meta = ConfigMeta::load_named(&root, &format!("resnet{d}_mem")).unwrap();
        let r = MemoryReport::from_meta(&meta);
        t.row(&[
            format!("-{d}"),
            format!("{:?}", meta.ppv),
            format!("{:.2}", r.activations_per_sample / mb),
            format!("{:.2}", r.weight_bytes / mb),
            format!("{:.2}", r.increase_paper_style_per_sample / mb),
            format!("{:.0}%", r.increase_pct_paper_style()),
            ppct.to_string(),
            format!("{:.0}%", r.increase_pct()),
        ]);
        csv.push_str(&format!(
            "resnet{d},\"{:?}\",{},{},{},{},{}\n",
            meta.ppv,
            r.activations_per_sample / mb,
            r.weight_bytes / mb,
            r.increase_paper_style_per_sample / mb,
            r.increase_pct_paper_style(),
            r.increase_pct()
        ));
    }
    println!("=== Table 6 (analytic model over meta.json shapes) ===");
    println!("{}", t.render());
    println!(
        "\nNotes: paper counts every torch module output; we count paper-\n\
         numbered layer outputs, so absolute MB are smaller but the\n\
         increase ratio (the paper's claim) is comparable. 'Ours' is the\n\
         actual footprint of this implementation, which recomputes the\n\
         stage forward in bwd and stores only the register carry."
    );

    // ---- §6.7: vs PipeDream weight stashing ---------------------------
    // Both schemes hold activations for in-flight batches; PipeDream
    // additionally stashes one weight version per in-flight batch per
    // stage. We compare the *extra* training footprint of each scheme
    // (activation increase [+ stash]) at batch 128.
    println!("\n=== §6.7: extra memory vs PipeDream (weight stashing) ===");
    let mut t2 = Table::new(&[
        "config", "ours MB (recompute)", "shared act incr MB", "PipeDream stash MB",
        "ours vs PipeDream",
    ]);
    for name in ["vgg16_4s", "resnet20_fine8", "resnet110_4s"] {
        let meta = ConfigMeta::load_named(&root, name).unwrap();
        let r = MemoryReport::from_meta(&meta);
        let ours = r.increase_per_sample * 128.0;
        let act = r.increase_paper_style_per_sample * 128.0;
        let stash = pipedream_stash_bytes(&meta);
        t2.row(&[
            name.to_string(),
            format!("{:.2}", ours / mb),
            format!("{:.2}", act / mb),
            format!("{:.2}", stash / mb),
            format!("-{:.0}%", 100.0 * (1.0 - ours / (act + stash))),
        ]);
    }
    println!("{}", t2.render());
    println!(
        "(paper §6.7 estimates 29-49% less memory than PipeDream for VGG-16;\n \
         our recompute-from-carry scheme stores even less than the paper's\n \
         own PyTorch implementation, and stashes zero weight copies)"
    );
    common::write_results("table6.csv", &csv);
}
