//! Table 5 (+ Table 7): speedup of 4-stage pipelined and hybrid training
//! over the non-pipelined 1-accelerator baseline, ResNet-20..362.
//!
//! Paper (2x GTX1060, 200 epochs CIFAR-10):
//!   ResNet:      -20    -56    -110   -224   -362
//!   pipelined    1.23X  1.65X  1.73X  1.81X  1.82X
//!   hybrid       1.10X  1.24X  1.26X  1.28X  1.29X
//!
//! Four estimates here (DESIGN.md §4 substitution — 1 CPU core, no
//! GPUs):
//!  (0) measured threaded-native wall-clock vs the scheduler runtime —
//!      the only section needing no artifacts/XLA, so it runs (and is
//!      recorded) everywhere. Both runtimes execute the GEMM-lowered
//!      native kernels (backend::gemm), so this wall-clock reflects
//!      the im2col+GEMM hot path, not the old nested loops — compare
//!      against results/BENCH_micro.json's conv/dense pairs when
//!      tracking the kernel trajectory;
//!  (0b) auto-vs-manual partition (DESIGN.md §10): measured per-block
//!      cost profile + bottleneck-minimizing solver, predicted per-stage
//!      cost validated against the threaded runtime's emergent busy
//!      counters — emits results/BENCH_partition.json;
//!  (a) GTX1060-roofline DES: analytic per-stage costs on the paper's
//!      hardware model + host-staged blocking communication;
//!  (b) measured-XLA DES: per-stage costs measured on the real compiled
//!      stage programs (this machine), same DES;
//!  (c) threaded wall-clock cross-check on 1 core (expected ~1.0x — the
//!      architecture runs, the hardware can't parallelize).

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use pipestale::data::{batch_seed, load_or_synthesize, Batcher, SyntheticSpec};
use pipestale::meta::ConfigMeta;
use pipestale::model::ModelParams;
use pipestale::pipeline::perfsim::*;
use pipestale::pipeline::{Feed, Pipeline, StageExecutor, ThreadedPipeline, XlaExecutor};
use pipestale::profile::CostProfile;
use pipestale::tensor::{IntTensor, Tensor};
use pipestale::util::bench::Table;
use pipestale::util::json;

/// Measured wall-clock of the threaded-native runtime vs the
/// scheduler runtime on the same feeds: the first *measured* (not
/// simulated) speedup number in the suite. On a 1-core container the
/// workers time-slice, so ~1.0x is the expected ceiling here; the DES
/// sections model the paper's multi-GPU testbed.
fn native_threaded_wall(name: &str, iters: usize) -> (usize, f64, f64) {
    let meta = pipestale::backend::native_config(name).unwrap();
    let spec = SyntheticSpec { train: 256, test: 64, noise: 1.0, seed: 3 };
    let (ds, _) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let mut batcher = Batcher::new(ds.len(), meta.batch, 5);
    let batches: Vec<(Tensor, IntTensor)> = (0..iters)
        .map(|_| {
            let idxs = batcher.next_indices().to_vec();
            ds.gather(&idxs)
        })
        .collect();

    let params = ModelParams::init(&meta.partitions, 1).unwrap();
    let optims = pipestale::train::build_optims(&meta, iters as u64, 1.0);
    let exec = pipestale::backend::NativeExecutor::new(meta.clone(), params, optims).unwrap();
    let mut pipe = Pipeline::new(exec, meta.batch);
    let t0 = Instant::now();
    for (b, (x, labels)) in batches.iter().enumerate() {
        pipe.cycle(Some(Feed {
            batch_id: b as u64,
            seed: batch_seed(42, b as u64),
            x: x.clone(),
            labels: labels.clone(),
        }))
        .unwrap();
    }
    pipe.drain().unwrap();
    let sched_wall = t0.elapsed().as_secs_f64();

    let params = ModelParams::init(&meta.partitions, 1).unwrap();
    let optims = pipestale::train::build_optims(&meta, iters as u64, 1.0);
    let mut tpipe = ThreadedPipeline::launch_native(&meta, params, optims).unwrap();
    let (events, thr_wall) =
        tpipe.train(iters as u64, 42, |b| Ok(batches[b as usize].clone())).unwrap();
    assert_eq!(events.len(), iters);
    tpipe.shutdown().unwrap();
    (meta.partitions.len(), sched_wall, thr_wall)
}

/// One real threaded-native training run on `meta`; returns the
/// per-stage busy seconds (time inside compute kernels) — the
/// *emergent* per-stage cost the profiler's prediction is validated
/// against (DESIGN.md §10).
fn emergent_busy_seconds(meta: &ConfigMeta, iters: u64) -> Vec<f64> {
    let spec = SyntheticSpec { train: 128, test: 32, noise: 1.0, seed: 3 };
    let (ds, _) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let mut batcher = Batcher::new(ds.len(), meta.batch, 5);
    let params = ModelParams::init(&meta.partitions, 1).unwrap();
    let optims = pipestale::train::build_optims(meta, iters, 1.0);
    let mut pipe = ThreadedPipeline::launch_native(meta, params, optims).unwrap();
    let (events, _) = pipe
        .train(iters, 42, |_| {
            let idxs = batcher.next_indices().to_vec();
            Ok(ds.gather(&idxs))
        })
        .unwrap();
    assert_eq!(events.len(), iters as usize);
    let busy = pipe.stage_busy_seconds();
    pipe.shutdown().unwrap();
    busy
}

/// Auto-vs-manual partition comparison (DESIGN.md §10): measure the
/// per-block cost profile on the real native kernels, solve for the
/// bottleneck-minimizing PPV at the manual stage count, then run both
/// partitions on the threaded runtime and record predicted vs emergent
/// per-stage cost. Emits `results/BENCH_partition.json` (recorded, not
/// asserted — 1-core wall timings are noisy; the *structural* claims
/// are asserted in tests/partition.rs).
fn partition_bench(csv: &mut String) {
    println!("\n=== Table 5 (0b): profile-guided auto-partition vs hand-tabulated PPV ===");
    let reps = if common::fast() { 3 } else { 5 };
    let iters: u64 = if common::fast() { 8 } else { 24 };
    let mut rows = Vec::new();
    for name in ["native_lenet_small_4s", "native_resnet20_4s"] {
        let prof = CostProfile::measure(name, 1, reps).unwrap();
        let prof_path = prof.save().unwrap();
        let manual = pipestale::backend::native_config(name).unwrap();
        let p = manual.partitions.len();
        let sol = prof.solve(p).unwrap();
        let man_totals = stage_totals(&prof.stage_costs(&manual.ppv).unwrap());
        let man_bottleneck = man_totals.iter().cloned().fold(0.0, f64::max);
        let auto_meta = if sol.ppv == manual.ppv {
            manual.clone()
        } else {
            pipestale::backend::native_config_with_ppv(name, Some(&sol.ppv)).unwrap()
        };
        let man_busy = emergent_busy_seconds(&manual, iters);
        let auto_busy = emergent_busy_seconds(&auto_meta, iters);
        println!(
            "{name} (P={p}): manual PPV {:?} bottleneck {:.2}ms (imbalance {:.3}) | \
             auto PPV {:?} bottleneck {:.2}ms (imbalance {:.3}, predicted speedup {:.2}x)",
            manual.ppv,
            man_bottleneck * 1e3,
            imbalance_ratio(&man_totals),
            sol.ppv,
            sol.bottleneck * 1e3,
            sol.imbalance,
            sol.predicted_speedup,
        );
        csv.push_str(&format!("{name},auto_partition_predicted,{},0\n", sol.predicted_speedup));
        rows.push(json::obj(vec![
            ("config", json::s(name)),
            ("stages", json::num(p as f64)),
            ("profile", json::s(&prof_path.display().to_string())),
            (
                "manual",
                json::obj(vec![
                    ("ppv", json::arr(manual.ppv.iter().map(|&c| json::num(c as f64)))),
                    ("predicted_stage_seconds", json::arr(man_totals.iter().map(|&t| json::num(t)))),
                    ("predicted_bottleneck_s", json::num(man_bottleneck)),
                    ("imbalance", json::num(imbalance_ratio(&man_totals))),
                    ("emergent_busy_seconds", json::arr(man_busy.iter().map(|&t| json::num(t)))),
                ]),
            ),
            (
                "auto",
                json::obj(vec![
                    ("ppv", json::arr(sol.ppv.iter().map(|&c| json::num(c as f64)))),
                    (
                        "predicted_stage_seconds",
                        json::arr(sol.stage_costs.iter().map(|&t| json::num(t))),
                    ),
                    ("predicted_bottleneck_s", json::num(sol.bottleneck)),
                    ("imbalance", json::num(sol.imbalance)),
                    ("predicted_speedup", json::num(sol.predicted_speedup)),
                    ("emergent_busy_seconds", json::arr(auto_busy.iter().map(|&t| json::num(t)))),
                ]),
            ),
        ]));
    }
    let doc = json::obj(vec![
        ("schema", json::s("pipestale/bench_partition/v1")),
        ("iters", json::num(iters as f64)),
        ("rows", json::arr(rows)),
    ]);
    common::write_results("BENCH_partition.json", &doc.to_string_pretty());
}

fn measured_costs(meta: &ConfigMeta, exec: &mut XlaExecutor, reps: usize) -> StageCosts {
    let p = meta.partitions.len();
    let mut fwd = vec![0.0; p];
    let mut bwd = vec![0.0; p];
    let labels = IntTensor::from_vec(&[meta.batch], vec![0; meta.batch]).unwrap();
    for (i, pm) in meta.partitions.iter().enumerate() {
        let carry: Vec<Tensor> = pm.carry_in.iter().map(|s| Tensor::ones(s)).collect();
        let gout: Vec<Tensor> = pm.carry_out.iter().map(|s| Tensor::ones(s)).collect();
        let mut tf = f64::MAX;
        let mut tb = f64::MAX;
        for _ in 0..reps {
            if i + 1 == p {
                let t0 = Instant::now();
                exec.last(1, &carry, &labels).unwrap();
                let dt = t0.elapsed().as_secs_f64();
                // fused stage: split ~1/3 fwd, 2/3 bwd (canonical ratio)
                tf = tf.min(dt / 3.0);
                tb = tb.min(2.0 * dt / 3.0);
            } else {
                let t0 = Instant::now();
                exec.forward(i, 1, &carry).unwrap();
                tf = tf.min(t0.elapsed().as_secs_f64());
                let t0 = Instant::now();
                exec.backward(i, 1, &carry, &gout).unwrap();
                tb = tb.min(t0.elapsed().as_secs_f64());
            }
        }
        fwd[i] = tf;
        bwd[i] = tb;
    }
    let edge_bytes = meta
        .partitions
        .iter()
        .take(p - 1)
        .map(|pm| pm.carry_out.iter().map(|s| s.iter().product::<usize>() as f64 * 4.0).sum())
        .collect();
    StageCosts { fwd, bwd, edge_bytes }
}

fn main() {
    pipestale::util::logging::init();
    let mut csv = String::from("model,estimate,pipelined_speedup,hybrid_speedup\n");

    // ---- (0) measured threaded-native wall-clock (runs everywhere) ----
    println!("=== Table 5 (0): threaded-native runtime wall-clock vs scheduler ===");
    let wall_iters = if common::fast() { 12 } else { 40 };
    for name in ["lenet5_4s", "native_lenet_small_4s"] {
        let (p, sched, thr) = native_threaded_wall(name, wall_iters);
        println!(
            "{name} (P={p}, {wall_iters} iters): scheduler {sched:.2}s, threaded {thr:.2}s \
             -> wall ratio {:.2} (1 CPU core: ~1.0 expected; see DESIGN.md §4)",
            sched / thr
        );
        csv.push_str(&format!("{name},threaded_native_wall,{},0\n", sched / thr));
    }

    // ---- (0b) auto-vs-manual partition (runs everywhere) ----------------
    partition_bench(&mut csv);

    if !pipestale::xla_ready() {
        eprintln!("skipping XLA sections of {}: needs artifacts + real XLA backend", file!());
        common::write_results("table5.csv", &csv);
        return;
    }
    let iters = 400u64;
    let comm = CommModel::default();
    let paper_p = [("20", 1.23), ("56", 1.65), ("110", 1.73), ("224", 1.81), ("362", 1.82)];
    let paper_h = [1.10, 1.24, 1.26, 1.28, 1.29];
    let root = pipestale::artifacts_root();

    // ---- (a) GTX1060 roofline projection, full-width, batch 128 -------
    let mut ta = Table::new(&[
        "ResNet", "PPV", "Pipelined", "Paper", "Hybrid", "Paper(h)",
    ]);
    for ((d, pp), ph) in paper_p.iter().zip(paper_h) {
        let meta = ConfigMeta::load_named(&root, &format!("resnet{d}_mem")).unwrap();
        let costs = gtx1060_costs(&meta).scale_batch(128.0);
        let tn = simulate_nonpipelined(&costs, iters);
        let tp = simulate_pipelined(&costs, &comm, Mapping::Paired, iters);
        let th = simulate_hybrid(&costs, &comm, Mapping::Paired, iters, iters / 2);
        ta.row(&[
            format!("-{d}"),
            format!("{:?}", meta.ppv),
            format!("{:.2}X", tn / tp),
            format!("{pp:.2}X"),
            format!("{:.2}X", tn / th),
            format!("{ph:.2}X"),
        ]);
        csv.push_str(&format!("resnet{d},roofline,{},{}\n", tn / tp, tn / th));
    }
    println!("=== Table 5 (a): GTX1060-roofline DES, batch 128, {iters} iters ===");
    println!("{}", ta.render());

    // ---- (b) measured-XLA-stage-time DES (this machine) ---------------
    println!("\n=== Table 5 (b): DES over measured XLA stage times (CPU) ===");
    let mut tb = Table::new(&["config", "fwd ms/stage", "bwd ms/stage", "Pipelined", "Hybrid"]);
    let measured_set: &[&str] = if common::fast() {
        &["resnet20_4s"]
    } else {
        &["resnet20_4s", "resnet56_4s", "resnet110_4s"]
    };
    for name in measured_set {
        let meta = ConfigMeta::load_named(&root, name).unwrap();
        let runtime = pipestale::runtime::Runtime::cpu().unwrap();
        let params = ModelParams::init(&meta.partitions, 1).unwrap();
        let optims = pipestale::train::build_optims(&meta, 100, 1.0);
        let mut exec = XlaExecutor::new(&runtime, meta.clone(), params, optims).unwrap();
        let costs = measured_costs(&meta, &mut exec, 3);
        let tn = simulate_nonpipelined(&costs, iters);
        let tp = simulate_pipelined(&costs, &comm, Mapping::Paired, iters);
        let th = simulate_hybrid(&costs, &comm, Mapping::Paired, iters, iters / 2);
        tb.row(&[
            name.to_string(),
            costs.fwd.iter().map(|t| format!("{:.1}", t * 1e3)).collect::<Vec<_>>().join("/"),
            costs.bwd.iter().map(|t| format!("{:.1}", t * 1e3)).collect::<Vec<_>>().join("/"),
            format!("{:.2}X", tn / tp),
            format!("{:.2}X", tn / th),
        ]);
        csv.push_str(&format!("{name},measured,{},{}\n", tn / tp, tn / th));
    }
    println!("{}", tb.render());

    // ---- (c) threaded wall-clock cross-check (1 core) ------------------
    println!("\n=== Table 5 (c): threaded runtime wall-clock (1-core container) ===");
    let meta = ConfigMeta::load_named(&root, "resnet20_4s").unwrap();
    let spec = SyntheticSpec { train: 256, test: 64, noise: 2.0, seed: 3 };
    let (train_ds, _) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let n = if common::fast() { 20 } else { 60 };

    // sequential reference on one runtime
    let seq = common::run("resnet20_4s", pipestale::config::Mode::Sequential, n, 0);

    let params = ModelParams::init(&meta.partitions, 1).unwrap();
    let optims = pipestale::train::build_optims(&meta, n, 1.0);
    let mut pipe =
        pipestale::pipeline::threaded::ThreadedPipeline::launch(&meta, params, optims).unwrap();
    let mut batcher = pipestale::data::Batcher::new(train_ds.len(), meta.batch, 5);
    let (events, wall) = pipe
        .train(n, 42, |_| {
            let idxs = batcher.next_indices().to_vec();
            Ok(train_ds.gather(&idxs))
        })
        .unwrap();
    pipe.shutdown().unwrap();
    println!(
        "threaded ({} workers): {} iters in {:.1}s vs sequential {:.1}s -> wall ratio {:.2} \
         (1 CPU core: parallel speedup physically unobservable; see (a)/(b))",
        meta.partitions.len(),
        events.len(),
        wall,
        seq.wall_seconds,
        seq.wall_seconds / wall,
    );
    csv.push_str(&format!("resnet20_4s,threaded_1core,{},0\n", seq.wall_seconds / wall));

    // ---- Table 7 echo ---------------------------------------------------
    println!("\n=== Table 7 (paper): BKS_2 learning rates for actual pipelined runs ===");
    println!("ResNet-20: 0.1 | ResNet-56: 0.01 | ResNet-110/224/362: 0.001");
    println!("(exposed as --stale-lr-scale / RunConfig::stale_lr_scale)");
    common::write_results("table5.csv", &csv);
}
