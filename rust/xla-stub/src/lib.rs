//! Host-only stub of the `xla` (xla_extension) binding surface.
//!
//! The testbed image has no xla_extension shared library, so this crate
//! supplies the exact API shape pipestale's runtime compiles against:
//! `Literal` is a real host container (fully functional — conversions,
//! reshape, tuples), while `PjRtClient::compile` fails with a clear
//! "stub backend" error. Everything except actually executing stage
//! programs therefore works offline: tensor<->literal conversion, the
//! mock-executor pipeline, the DES, benches and property tests.
//!
//! Swapping in a real binding: replace the `xla = { path = "xla-stub" }`
//! dependency with an xla_extension binding crate exposing this surface
//! (see rust/DESIGN.md §Backends). `IS_STUB` gates runtime-dependent
//! tests and benches.
//!
//! Beyond the upstream surface, the stub exposes two single-copy
//! constructors/readers (`from_f32_and_dims`, `f32_slice` and the i32
//! twins) used by pipestale's zero-copy data plane; upstream bindings
//! offer equivalents (`create_from_shape_and_untyped_data`, raw literal
//! views).

use std::fmt;
use std::path::Path;

/// True for this crate: lets consumers skip compile/execute paths.
pub const IS_STUB: bool = true;

#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element payload of a literal.
#[derive(Debug, Clone, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host literal: typed buffer + dimensions (row-major), or a tuple.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Element types a literal can hold (f32/i32 are all pipestale needs).
pub trait NativeType: Copy + Sized {
    fn to_payload(v: &[Self]) -> Payload;
    fn from_payload(p: &Payload) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn to_payload(v: &[Self]) -> Payload {
        Payload::F32(v.to_vec())
    }

    fn from_payload(p: &Payload) -> Result<Vec<Self>> {
        match p {
            Payload::F32(v) => Ok(v.clone()),
            _ => Err(Error::new("literal is not f32")),
        }
    }
}

impl NativeType for i32 {
    fn to_payload(v: &[Self]) -> Payload {
        Payload::I32(v.to_vec())
    }

    fn from_payload(p: &Payload) -> Result<Vec<Self>> {
        match p {
            Payload::I32(v) => Ok(v.clone()),
            _ => Err(Error::new("literal is not i32")),
        }
    }
}

fn dims_elems(dims: &[i64]) -> usize {
    dims.iter().map(|&d| d.max(0) as usize).product()
}

impl Literal {
    /// Rank-1 literal from a slice (upstream `Literal::vec1`).
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { payload: T::to_payload(v), dims: vec![v.len() as i64] }
    }

    /// Rank-0 literal (upstream `Literal::scalar`).
    pub fn scalar(v: i32) -> Literal {
        Literal { payload: Payload::I32(vec![v]), dims: vec![] }
    }

    /// Single-copy shaped construction (stub extension; upstream has
    /// `create_from_shape_and_untyped_data`).
    pub fn from_f32_and_dims(data: &[f32], dims: &[i64]) -> Result<Literal> {
        if dims_elems(dims) != data.len() {
            return Err(Error::new(format!(
                "dims {dims:?} want {} elements, got {}",
                dims_elems(dims),
                data.len()
            )));
        }
        Ok(Literal { payload: Payload::F32(data.to_vec()), dims: dims.to_vec() })
    }

    /// Single-copy shaped construction for i32 (stub extension).
    pub fn from_i32_and_dims(data: &[i32], dims: &[i64]) -> Result<Literal> {
        if dims_elems(dims) != data.len() {
            return Err(Error::new(format!(
                "dims {dims:?} want {} elements, got {}",
                dims_elems(dims),
                data.len()
            )));
        }
        Ok(Literal { payload: Payload::I32(data.to_vec()), dims: dims.to_vec() })
    }

    /// Zero-copy read of an f32 payload (stub extension).
    pub fn f32_slice(&self) -> Result<&[f32]> {
        match &self.payload {
            Payload::F32(v) => Ok(v),
            _ => Err(Error::new("literal is not f32")),
        }
    }

    /// Zero-copy read of an i32 payload (stub extension).
    pub fn i32_slice(&self) -> Result<&[i32]> {
        match &self.payload {
            Payload::I32(v) => Ok(v),
            _ => Err(Error::new("literal is not i32")),
        }
    }

    /// Reshape into new dimensions. Mirrors upstream cost: produces a
    /// fresh literal (payload copy), so the legacy vec1+reshape path
    /// pays two copies just like xla_extension does.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if dims_elems(dims) != self.element_count() {
            return Err(Error::new(format!(
                "cannot reshape {} elements into {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a typed vec (upstream `to_vec::<T>()`).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_payload(&self.payload)
    }

    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(_) => dims_elems(&self.dims),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Build a tuple literal (used by stub tests; stage programs return
    /// tuples in the real backend).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { payload: Payload::Tuple(parts), dims: vec![] }
    }

    /// Decompose a tuple literal (upstream `to_tuple`).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(parts) => Ok(parts),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }
}

/// Parsed HLO module placeholder. Parsing is deferred to the real
/// backend; the stub only checks the file exists so config errors
/// surface early with a useful message.
#[derive(Debug)]
pub struct HloModuleProto {
    path: std::path::PathBuf,
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        if !path.exists() {
            return Err(Error::new(format!("HLO text not found: {}", path.display())));
        }
        Ok(HloModuleProto { path: path.to_path_buf() })
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _path: std::path::PathBuf,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _path: proto.path.clone() }
    }
}

/// Device buffer handle returned by `execute` (never produced by the
/// stub, but required for the API shape).
#[derive(Debug)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("stub backend cannot execute programs"))
    }
}

/// One PJRT device client. The stub client constructs fine (so hosts
/// without xla_extension can still build executors around mocks) but
/// refuses to compile programs.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(
            "XLA backend unavailable: pipestale was built against the bundled \
             stub (rust/xla-stub). Point the `xla` dependency at a real \
             xla_extension binding to execute stage programs — see \
             rust/DESIGN.md §Backends",
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[0.0f32, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.element_count(), 6);
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn single_copy_paths_match_legacy() {
        let data = [1.5f32, -2.0, 0.25, 8.0];
        let fast = Literal::from_f32_and_dims(&data, &[2, 2]).unwrap();
        let legacy = Literal::vec1(&data).reshape(&[2, 2]).unwrap();
        assert_eq!(fast, legacy);
        assert_eq!(fast.f32_slice().unwrap(), &data);
        assert!(Literal::from_f32_and_dims(&data, &[3, 2]).is_err());
    }

    #[test]
    fn typed_mismatch_errors() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.f32_slice().is_err());
        assert_eq!(l.i32_slice().unwrap(), &[1, 2]);
        assert_eq!(Literal::scalar(7).to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::scalar(1), Literal::vec1(&[1.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0).to_tuple().is_err());
    }

    #[test]
    fn compile_is_gated_with_clear_error() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        let proto = HloModuleProto { path: std::path::PathBuf::from("/dev/null") };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("stub"), "{err}");
        assert!(IS_STUB);
    }
}
