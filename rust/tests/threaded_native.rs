//! Threaded-runtime test suite over the native backend: real
//! concurrent stale-weight training, executed unconditionally (no
//! artifacts, no Python, no XLA).
//!
//! The core claim under test: because every worker follows the
//! deterministic 1F1B alternation, the threaded runtime's *emergent*
//! staleness is event-for-event identical to the cycle-accurate
//! scheduler's *simulated* staleness — bitwise, including the final
//! weights. Plus soak/fault coverage for the concurrency machinery
//! itself: no deadlock, no lost or duplicated events, monotone retire
//! order, shutdown propagation from a failing worker, and
//! allocation-free steady-state tensor pooling under cross-thread
//! buffer migration.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use pipestale::backend::{native_config, NativeExecutor, NativePartition};
use pipestale::config::{Backend, Mode, RunConfig, RuntimeKind};
use pipestale::data::{batch_seed, load_or_synthesize, Batcher, Dataset, SyntheticSpec};
use pipestale::meta::ConfigMeta;
use pipestale::model::{ModelParams, PartitionParams};
use pipestale::optim::Sgd;
use pipestale::pipeline::{
    Feed, LastResult, NativeWorkerBackend, Occupancy, Pipeline, ThreadedOptions, ThreadedPipeline,
    TrainEvent, WorkerBackend, WorkerStage,
};
use pipestale::pool::{PoolStats, TensorPool};
use pipestale::tensor::{IntTensor, Tensor};
use pipestale::util::rng::Pcg32;

/// Pre-gather n mini-batches so scheduler and threaded runs consume
/// byte-identical feeds.
fn make_batches(meta: &ConfigMeta, n: usize) -> (Vec<(Tensor, IntTensor)>, Dataset) {
    let spec = SyntheticSpec { train: 256, test: 64, noise: 0.8, seed: 7 };
    let (train, test) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let mut batcher = Batcher::new(train.len(), meta.batch, 5);
    let batches = (0..n)
        .map(|_| {
            let idxs = batcher.next_indices().to_vec();
            train.gather(&idxs)
        })
        .collect();
    (batches, test)
}

/// The scheduler-runtime reference: continuous feed (+ drain) for the
/// pipelined schedule, or cycle+drain per batch for single-in-flight.
fn scheduler_run(
    meta: &ConfigMeta,
    batches: &[(Tensor, IntTensor)],
    seed: u64,
    single: bool,
) -> (Vec<TrainEvent>, ModelParams) {
    let params = ModelParams::init(&meta.partitions, seed).unwrap();
    let optims = pipestale::train::build_optims(meta, batches.len() as u64, 1.0);
    let exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
    let mut pipe = Pipeline::new(exec, meta.batch);
    let mut events = Vec::new();
    for (b, (x, labels)) in batches.iter().enumerate() {
        let feed = Feed {
            batch_id: b as u64,
            seed: batch_seed(seed, b as u64),
            x: x.clone(),
            labels: labels.clone(),
        };
        if let Some(e) = pipe.cycle(Some(feed)).unwrap() {
            events.push(e);
        }
        if single {
            events.extend(pipe.drain().unwrap());
        }
    }
    events.extend(pipe.drain().unwrap());
    (events, pipe.exec.params_snapshot())
}

fn threaded_run_with<B: WorkerBackend>(
    backend: B,
    meta: &ConfigMeta,
    batches: &[(Tensor, IntTensor)],
    seed: u64,
    occupancy: Occupancy,
) -> Result<(Vec<TrainEvent>, ModelParams)> {
    let params = ModelParams::init(&meta.partitions, seed)?;
    let optims = pipestale::train::build_optims(meta, batches.len() as u64, 1.0);
    let opts = ThreadedOptions { occupancy, stall_timeout: Duration::from_secs(30), ..Default::default() };
    let mut pipe = ThreadedPipeline::launch_with(backend, meta, params, optims, opts)?;
    let (events, _wall) =
        pipe.train(batches.len() as u64, seed, |b| Ok(batches[b as usize].clone()))?;
    let trained = pipe.shutdown()?;
    Ok((events, trained))
}

fn assert_params_eq(a: &ModelParams, b: &ModelParams) {
    assert_eq!(a.partitions.len(), b.partitions.len());
    for (i, (x, y)) in a.partitions.iter().zip(&b.partitions).enumerate() {
        assert_eq!(x.version, y.version, "partition {i}: update count must match");
        assert_eq!(x.params.len(), y.params.len(), "partition {i}");
        for (j, (t, u)) in x.params.iter().zip(&y.params).enumerate() {
            assert_eq!(t.data(), u.data(), "partition {i} param {j} must be bitwise equal");
        }
        for (j, (t, u)) in x.state.iter().zip(&y.state).enumerate() {
            assert_eq!(t.data(), u.data(), "partition {i} state {j} must be bitwise equal");
        }
    }
}

fn params_differ(a: &ModelParams, b: &ModelParams) -> bool {
    a.partitions
        .iter()
        .zip(&b.partitions)
        .any(|(x, y)| x.params.iter().zip(&y.params).any(|(t, u)| t.data() != u.data()))
}

/// Event-for-event comparison; `cycle` is runtime-relative (the
/// scheduler counts global cycles, the threaded runtime has none and
/// records the batch id), so it is deliberately excluded.
fn assert_events_eq(a: &[TrainEvent], b: &[TrainEvent]) {
    assert_eq!(a.len(), b.len(), "event counts must match");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.batch_id, y.batch_id, "batch id order must match");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "batch {}: loss bits", x.batch_id);
        assert_eq!(x.correct.to_bits(), y.correct.to_bits(), "batch {}: correct", x.batch_id);
        assert_eq!(x.batch_size, y.batch_size);
    }
}

// ---------------------------------------------------------------------------
// Equivalence: emergent staleness == simulated staleness, bitwise.
// ---------------------------------------------------------------------------

#[test]
fn single_inflight_threaded_is_bitwise_equal_to_scheduler() {
    for name in ["native_lenet_small", "native_lenet_small_4s"] {
        let meta = native_config(name).unwrap();
        let (batches, _) = make_batches(&meta, 8);
        let (se, sp) = scheduler_run(&meta, &batches, 21, true);
        let (te, tp) =
            threaded_run_with(NativeWorkerBackend, &meta, &batches, 21, Occupancy::Single)
                .unwrap();
        assert_eq!(te.len(), 8, "{name}");
        assert_events_eq(&te, &se);
        assert_params_eq(&tp, &sp);
    }
}

#[test]
fn full_occupancy_threaded_reproduces_scheduler_schedule() {
    // K batches genuinely in flight across P concurrent workers: the
    // emergent schedule must replay the scheduler's staleness pattern
    // event-for-event, down to the final weight bits.
    for name in ["native_lenet_small", "native_lenet_small_4s"] {
        let meta = native_config(name).unwrap();
        let (batches, _) = make_batches(&meta, 24);
        let (se, sp) = scheduler_run(&meta, &batches, 33, false);
        let (te, tp) =
            threaded_run_with(NativeWorkerBackend, &meta, &batches, 33, Occupancy::Full).unwrap();
        assert_eq!(te.len(), 24, "{name}");
        assert_events_eq(&te, &se);
        assert_params_eq(&tp, &sp);
        // ...and the staleness is real: the concurrent run must NOT
        // match the zero-staleness (sequential) trajectory.
        let (_, seq) = scheduler_run(&meta, &batches, 33, true);
        assert!(params_differ(&tp, &seq), "{name}: stale schedule must diverge from sequential");
    }
}

#[test]
fn threaded_native_resnet_matches_scheduler_bitwise() {
    // The block IR under real concurrency: a P=4 residual network
    // (stride-2 transitions, projection shortcuts, per-block BN state)
    // must stay bitwise-equivalent between runtimes, single- AND
    // K-in-flight — BN state handoff across block-edge partition
    // boundaries included.
    let meta = native_config("native_resnet_small_4s").unwrap();
    let (batches, _) = make_batches(&meta, 6);
    let (se, sp) = scheduler_run(&meta, &batches, 31, true);
    let (te, tp) =
        threaded_run_with(NativeWorkerBackend, &meta, &batches, 31, Occupancy::Single).unwrap();
    assert_eq!(te.len(), 6);
    assert_events_eq(&te, &se);
    assert_params_eq(&tp, &sp);

    let (fe, fp) = scheduler_run(&meta, &batches, 31, false);
    let (tfe, tfp) =
        threaded_run_with(NativeWorkerBackend, &meta, &batches, 31, Occupancy::Full).unwrap();
    assert_events_eq(&tfe, &fe);
    assert_params_eq(&tfp, &fp);
    // and the stale schedule genuinely diverges from sequential
    assert!(params_differ(&fp, &sp), "resnet stale schedule must diverge from sequential");
}

// ---------------------------------------------------------------------------
// End-to-end through the train driver (--runtime threaded --backend native).
// ---------------------------------------------------------------------------

fn native_rc(mode: Mode, iters: u64) -> RunConfig {
    let mut rc = RunConfig::new("native_lenet_small");
    rc.backend = Backend::Native;
    rc.runtime = RuntimeKind::Threaded;
    rc.mode = mode;
    rc.iters = iters;
    rc.train_size = 512;
    rc.test_size = 96;
    rc.noise = 0.8;
    rc
}

#[test]
fn train_run_threaded_native_trains_lenet_end_to_end() {
    let res = pipestale::train::run(&native_rc(Mode::Pipelined, 60)).unwrap();
    assert_eq!(res.runtime, "threaded");
    assert_eq!(res.recorder.train.len(), 60, "every fed batch retires exactly once");
    let early: f64 =
        res.recorder.train[..10].iter().map(|(_, l, _)| *l as f64).sum::<f64>() / 10.0;
    assert!(res.final_train_loss < early, "loss did not fall: {} vs {early}", res.final_train_loss);
    assert!(res.final_accuracy > 0.2, "acc {} (chance 0.1)", res.final_accuracy);
}

#[test]
fn train_run_threaded_sequential_matches_scheduler_run_bitwise() {
    // Same RunConfig, only the runtime differs: single-in-flight
    // threaded training must be indistinguishable from the scheduler
    // runtime — identical loss curve, identical final accuracy.
    let mut sched = native_rc(Mode::Sequential, 12);
    sched.runtime = RuntimeKind::Scheduler;
    let a = pipestale::train::run(&sched).unwrap();
    let b = pipestale::train::run(&native_rc(Mode::Sequential, 12)).unwrap();
    assert_eq!(a.recorder.train, b.recorder.train, "loss curves must be bitwise identical");
    assert_eq!(a.final_accuracy, b.final_accuracy);
}

#[test]
fn threaded_runtime_rejects_unsupported_shapes() {
    // Hybrid needs a mid-run drain only the scheduler performs.
    let mut rc = native_rc(Mode::Hybrid, 10);
    rc.pipelined_iters = 5;
    assert!(pipestale::train::run(&rc).is_err());
    // Mid-run eval is a scheduler-runtime feature.
    let mut rc = native_rc(Mode::Pipelined, 10);
    rc.eval_every = 2;
    assert!(pipestale::train::run(&rc).is_err());
    // train() is one-shot per launch (the drain marker ends the feed).
    let meta = native_config("native_lenet_small").unwrap();
    let (batches, _) = make_batches(&meta, 2);
    let params = ModelParams::init(&meta.partitions, 1).unwrap();
    let optims = pipestale::train::build_optims(&meta, 2, 1.0);
    let mut pipe = ThreadedPipeline::launch_native(&meta, params, optims).unwrap();
    pipe.train(2, 1, |b| Ok(batches[b as usize].clone())).unwrap();
    let err = pipe.train(1, 1, |b| Ok(batches[b as usize].clone())).unwrap_err();
    assert!(err.to_string().contains("once per launch"), "{err}");
    let trained = pipe.shutdown().unwrap();
    assert!(trained.all_finite());
}

// ---------------------------------------------------------------------------
// Stress/soak: jittered workers, long run, strict accounting.
// ---------------------------------------------------------------------------

/// Native stage with randomized per-op sleep, de-synchronizing worker
/// threads so message arrival order varies wildly across runs while
/// the schedule-driven op order must not.
#[derive(Clone)]
struct JitterBackend {
    seed: u64,
}

struct JitterStage {
    inner: NativePartition,
    rng: Pcg32,
}

impl JitterStage {
    fn nap(&mut self) {
        std::thread::sleep(Duration::from_micros(self.rng.below(400) as u64));
    }
}

impl WorkerBackend for JitterBackend {
    type Stage = JitterStage;

    fn make_stage(
        &self,
        meta: &ConfigMeta,
        idx: usize,
        params: PartitionParams,
        optim: Sgd,
    ) -> Result<JitterStage> {
        let inner = NativeWorkerBackend.make_stage(meta, idx, params, optim)?;
        Ok(JitterStage { inner, rng: Pcg32::new(self.seed, idx as u64) })
    }
}

impl WorkerStage for JitterStage {
    fn forward(&mut self, _seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        self.nap();
        self.inner.stage_forward(carry)
    }

    fn last(&mut self, _seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult> {
        self.nap();
        self.inner.stage_last(carry, labels)
    }

    fn backward(&mut self, _seed: i32, ci: &[Tensor], go: &[Tensor]) -> Result<Vec<Tensor>> {
        self.nap();
        self.inner.stage_backward(ci, go)
    }

    fn into_params(self) -> PartitionParams {
        WorkerStage::into_params(self.inner)
    }
}

#[test]
fn stress_soak_p4_with_jitter_keeps_strict_accounting() {
    // 200+ iterations at P=4 with per-worker sleep jitter. The
    // coordinator's ledger enforces no lost/duplicated TrainEvent and
    // monotone retire order (train() errors otherwise); the stall
    // guard turns any deadlock into an error instead of a hang; and
    // the run must still be bitwise-deterministic despite the jitter.
    let meta = native_config("native_lenet_small_4s").unwrap();
    let (batches, _) = make_batches(&meta, 210);
    let (events, trained) =
        threaded_run_with(JitterBackend { seed: 0x717 }, &meta, &batches, 9, Occupancy::Full)
            .unwrap();
    assert_eq!(events.len(), 210);
    for (i, e) in events.iter().enumerate() {
        assert_eq!(e.batch_id, i as u64);
    }
    assert!(trained.all_finite());
    for part in &trained.partitions {
        assert_eq!(part.version, 210, "every partition updates once per batch");
    }
    // jitter changes timing, never results: replay matches the clean run
    let (_, reference) = scheduler_run(&meta, &batches, 9, false);
    assert_params_eq(&trained, &reference);
}

// ---------------------------------------------------------------------------
// Fault injection: a failing worker must not strand its peers.
// ---------------------------------------------------------------------------

/// Fails a chosen worker's backward after `fail_after` calls.
#[derive(Clone)]
struct FailingBackend {
    fail_worker: usize,
    fail_after: u32,
}

struct FailingStage {
    inner: NativePartition,
    armed: bool,
    fail_after: u32,
    calls: u32,
}

impl WorkerBackend for FailingBackend {
    type Stage = FailingStage;

    fn make_stage(
        &self,
        meta: &ConfigMeta,
        idx: usize,
        params: PartitionParams,
        optim: Sgd,
    ) -> Result<FailingStage> {
        let inner = NativeWorkerBackend.make_stage(meta, idx, params, optim)?;
        Ok(FailingStage {
            inner,
            armed: idx == self.fail_worker,
            fail_after: self.fail_after,
            calls: 0,
        })
    }
}

impl WorkerStage for FailingStage {
    fn forward(&mut self, _seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        self.inner.stage_forward(carry)
    }

    fn last(&mut self, _seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult> {
        self.inner.stage_last(carry, labels)
    }

    fn backward(&mut self, _seed: i32, ci: &[Tensor], go: &[Tensor]) -> Result<Vec<Tensor>> {
        if self.armed {
            self.calls += 1;
            if self.calls > self.fail_after {
                anyhow::bail!("injected fault after {} backwards", self.fail_after);
            }
        }
        self.inner.stage_backward(ci, go)
    }

    fn into_params(self) -> PartitionParams {
        WorkerStage::into_params(self.inner)
    }
}

#[test]
fn worker_fatal_propagates_shutdown_and_surfaces_original_error() {
    // Regression: a worker Fatal used to leave peers parked forever on
    // their inboxes. Now the failing worker raises the shared shutdown
    // flag, every peer unparks, and the original error surfaces.
    let meta = native_config("native_lenet_small_4s").unwrap();
    let (batches, _) = make_batches(&meta, 40);
    let t0 = Instant::now();
    let err = threaded_run_with(
        FailingBackend { fail_worker: 1, fail_after: 3 },
        &meta,
        &batches,
        5,
        Occupancy::Full,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected fault"), "original error must surface: {msg}");
    assert!(msg.contains("worker 1"), "failing worker must be identified: {msg}");
    // No stranded threads: everything (including joins on drop) is
    // fast — nowhere near the 30s stall guard, let alone a hang.
    assert!(t0.elapsed() < Duration::from_secs(25), "shutdown must not stall");
}

#[test]
fn stage_construction_failure_surfaces_at_first_train() {
    /// Backend that cannot build one partition at all.
    #[derive(Clone)]
    struct BrokenBackend;
    impl WorkerBackend for BrokenBackend {
        type Stage = NativePartition;
        fn make_stage(
            &self,
            meta: &ConfigMeta,
            idx: usize,
            params: PartitionParams,
            optim: Sgd,
        ) -> Result<NativePartition> {
            if idx == 2 {
                anyhow::bail!("no accelerator for partition {idx}");
            }
            NativeWorkerBackend.make_stage(meta, idx, params, optim)
        }
    }
    let meta = native_config("native_lenet_small_4s").unwrap();
    let (batches, _) = make_batches(&meta, 4);
    let err = threaded_run_with(BrokenBackend, &meta, &batches, 5, Occupancy::Full).unwrap_err();
    assert!(format!("{err:#}").contains("no accelerator"), "{err:#}");
}

// ---------------------------------------------------------------------------
// TensorPool under real cross-thread traffic.
// ---------------------------------------------------------------------------

/// Probes each worker's scoped pool: a mid-run snapshot (after warmup)
/// and a final one, published for the test to compare.
#[derive(Clone)]
struct PoolProbeBackend {
    snap_at: u32,
    out: Arc<Mutex<Vec<(usize, PoolStats, PoolStats)>>>,
}

struct PoolProbeStage {
    inner: NativePartition,
    idx: usize,
    ops: u32,
    snap_at: u32,
    mid: Option<PoolStats>,
    out: Arc<Mutex<Vec<(usize, PoolStats, PoolStats)>>>,
}

impl PoolProbeStage {
    fn tick(&mut self) {
        self.ops += 1;
        if self.ops == self.snap_at {
            self.mid = Some(TensorPool::current().stats());
        }
    }
}

impl WorkerBackend for PoolProbeBackend {
    type Stage = PoolProbeStage;

    fn make_stage(
        &self,
        meta: &ConfigMeta,
        idx: usize,
        params: PartitionParams,
        optim: Sgd,
    ) -> Result<PoolProbeStage> {
        let inner = NativeWorkerBackend.make_stage(meta, idx, params, optim)?;
        Ok(PoolProbeStage {
            inner,
            idx,
            ops: 0,
            snap_at: self.snap_at,
            mid: None,
            out: Arc::clone(&self.out),
        })
    }
}

impl WorkerStage for PoolProbeStage {
    fn forward(&mut self, _seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        let r = self.inner.stage_forward(carry)?;
        self.tick();
        Ok(r)
    }

    fn last(&mut self, _seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult> {
        let r = self.inner.stage_last(carry, labels)?;
        self.tick();
        Ok(r)
    }

    fn backward(&mut self, _seed: i32, ci: &[Tensor], go: &[Tensor]) -> Result<Vec<Tensor>> {
        let r = self.inner.stage_backward(ci, go)?;
        self.tick();
        Ok(r)
    }

    fn into_params(self) -> PartitionParams {
        let end = TensorPool::current().stats();
        let mid = self.mid.expect("snap_at must be below the worker's total op count");
        self.out.lock().unwrap().push((self.idx, mid, end));
        WorkerStage::into_params(self.inner)
    }
}

#[test]
fn tensor_pool_steady_state_is_allocation_free_across_threads() {
    // Tensors produced in one worker's scoped pool migrate to
    // neighbours over the channel registers and are dropped there;
    // each buffer must return to its issuing ("home") pool so that,
    // after warmup, no worker performs a single fresh backing-store
    // allocation — the zero-copy data plane's contract, now under
    // genuine cross-thread traffic.
    let meta = native_config("native_lenet_small").unwrap();
    let (batches, _) = make_batches(&meta, 120);
    let out = Arc::new(Mutex::new(Vec::new()));
    let backend = PoolProbeBackend { snap_at: 80, out: Arc::clone(&out) };
    let (events, trained) =
        threaded_run_with(backend, &meta, &batches, 13, Occupancy::Full).unwrap();
    assert_eq!(events.len(), 120);
    assert!(trained.all_finite());

    let probes = out.lock().unwrap();
    assert_eq!(probes.len(), meta.partitions.len(), "every worker must report");
    for (idx, mid, end) in probes.iter() {
        assert_eq!(
            end.fresh_allocs, mid.fresh_allocs,
            "worker {idx}: fresh pool allocations after warmup (mid {mid:?} -> end {end:?})"
        );
        assert!(
            end.reuses > mid.reuses,
            "worker {idx}: steady state must be served from the shelf ({mid:?} -> {end:?})"
        );
    }
}
