//! Golden-fixture corpus tests: the generators in `data::fixtures`
//! write byte-exact MNIST IDX / CIFAR-10 binary files into a scratch
//! directory, and the loaders must round-trip them back to the
//! generated ground truth bitwise. The malformed variants must each
//! fail with an error naming the offending field. Nothing binary is
//! checked into git — every file here is generated into a tempdir and
//! removed, and a guard test scans the source tree to keep it that way.

use std::path::PathBuf;

use pipestale::data::fixtures::{
    self, write_cifar_bad_label, write_cifar_bad_size, write_idx_bad_dims, write_idx_bad_label,
    write_idx_short_body, write_idx_truncated_header, write_idx_wrong_magic,
};
use pipestale::data::{
    load_cifar10_bin, load_cifar10_dir_stream, load_idx_images, load_idx_labels, load_mnist,
    load_mnist_stream,
};

fn scratch(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("fixt_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    std::fs::create_dir_all(&p).unwrap();
    p
}

// ---------------------------------------------------------------------------
// Round-trip: serialized files parse back to the ground truth bitwise.
// ---------------------------------------------------------------------------

#[test]
fn mnist_fixture_round_trips_byte_exact() {
    let dir = scratch("mnist_rt");
    let (tr, te) = fixtures::write_mnist_fixture(&dir, 30, 10, 11).unwrap();

    let stream = load_mnist_stream(
        &dir.join("train-images-idx3-ubyte"),
        &dir.join("train-labels-idx1-ubyte"),
        "fixture-train",
    )
    .unwrap();
    assert_eq!(stream.len(), 30);
    assert_eq!(stream.input_shape, vec![28, 28, 1]);
    assert_eq!(stream.shards().len(), 1);
    assert_eq!(stream.shards()[0].name, "train-images-idx3-ubyte");

    // Every parsed pixel must equal bytes[k]/255 - 0.5 bitwise, and
    // every label must match the generated ground truth.
    let eager = stream.to_eager();
    assert_eq!(eager.images.len(), tr.images.len());
    for k in 0..tr.images.len() {
        assert_eq!(eager.images[k], tr.expected_f32(k), "train pixel {k}");
    }
    for (i, &l) in tr.labels.iter().enumerate() {
        assert_eq!(eager.labels[i], l as i32, "train label {i}");
    }

    // The eager wrapper agrees with the streaming path on the test split.
    let test = load_mnist(
        &dir.join("t10k-images-idx3-ubyte"),
        &dir.join("t10k-labels-idx1-ubyte"),
        "fixture-test",
    )
    .unwrap();
    assert_eq!(test.len(), 10);
    for k in 0..te.images.len() {
        assert_eq!(test.images[k], te.expected_f32(k), "test pixel {k}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cifar_fixture_round_trips_byte_exact() {
    let dir = scratch("cifar_rt");
    let (tr, te) = fixtures::write_cifar_fixture(&dir, 20, 10, 11).unwrap();

    let (train, test) = load_cifar10_dir_stream(&dir).unwrap();
    assert_eq!(train.len(), 20);
    assert_eq!(test.len(), 10);
    assert_eq!(train.input_shape, vec![32, 32, 3]);

    // Two shards (the writer splits train across data_batch_1/2) with
    // abutting index ranges.
    assert_eq!(train.shards().len(), 2);
    assert_eq!(train.shard_of(9).name, "data_batch_1.bin");
    assert_eq!(train.shard_of(10).name, "data_batch_2.bin");

    // The parser must undo the writer's HWC -> CHW transpose exactly:
    // parsed HWC pixel k == ground-truth HWC byte k, normalized.
    let eager = train.to_eager();
    for k in 0..tr.images.len() {
        assert_eq!(eager.images[k], tr.expected_f32(k), "train pixel {k}");
    }
    for (i, &l) in tr.labels.iter().enumerate() {
        assert_eq!(eager.labels[i], l as i32, "train label {i}");
    }
    let eager_test = test.to_eager();
    for k in 0..te.images.len() {
        assert_eq!(eager_test.images[k], te.expected_f32(k), "test pixel {k}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Malformed variants: every corruption fails naming the offending field.
// ---------------------------------------------------------------------------

#[test]
fn malformed_idx_variants_name_the_offending_field() {
    let dir = scratch("idx_bad");
    let p = dir.join("f");

    write_idx_truncated_header(&p).unwrap();
    let e = load_idx_images(&p).unwrap_err().to_string();
    assert!(e.contains("header"), "truncated header: {e}");

    write_idx_wrong_magic(&p).unwrap();
    let e = load_idx_images(&p).unwrap_err().to_string();
    assert!(e.contains("magic"), "wrong magic: {e}");

    write_idx_bad_dims(&p).unwrap();
    let e = load_idx_images(&p).unwrap_err().to_string();
    assert!(e.contains("dims"), "bad dims: {e}");

    write_idx_short_body(&p).unwrap();
    let e = load_idx_images(&p).unwrap_err().to_string();
    assert!(e.contains("body"), "short body: {e}");

    write_idx_bad_label(&p).unwrap();
    let e = load_idx_labels(&p).unwrap_err().to_string();
    assert!(e.contains("label 37"), "bad label: {e}");
    assert!(e.contains("record 2"), "bad label record index: {e}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_cifar_variants_name_the_offending_field() {
    let dir = scratch("cifar_bad");
    let p = dir.join("f.bin");

    write_cifar_bad_size(&p).unwrap();
    let e = load_cifar10_bin(&p).unwrap_err().to_string();
    assert!(e.contains("record"), "bad size: {e}");

    write_cifar_bad_label(&p).unwrap();
    let e = load_cifar10_bin(&p).unwrap_err().to_string();
    assert!(e.contains("label 11"), "bad label: {e}");
    assert!(e.contains("record 1"), "bad label record index: {e}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_fixture_dataset_is_an_error() {
    let dir = scratch("fixt_unknown");
    let e = fixtures::write_fixture("svhn", &dir, 4, 2, 1).unwrap_err().to_string();
    assert!(e.contains("svhn"), "{e}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Repo hygiene: the fixture corpus is generated, never committed.
// ---------------------------------------------------------------------------

#[test]
fn no_fixture_blobs_in_the_source_tree() {
    // The crate root (rust/) must not contain any materialized dataset
    // files — tests and CI generate them into scratch directories.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut stack = vec![root];
    while let Some(dir) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&dir) else { continue };
        for entry in entries.flatten() {
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().into_owned();
            if path.is_dir() {
                if name != "target" && name != ".git" {
                    stack.push(path);
                }
                continue;
            }
            assert!(
                !name.ends_with("-ubyte") && !name.starts_with("data_batch_")
                    && name != "test_batch.bin",
                "dataset blob checked into the source tree: {}",
                path.display()
            );
        }
    }
}
