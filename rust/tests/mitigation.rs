//! Staleness-mitigation equivalence suite (`--staleness-fix`,
//! DESIGN.md §9), all offline on the native backend.
//!
//! The ladder of claims, sharpest first:
//!
//! * a **flat-loop serial oracle** — a single-threaded replay of the
//!   paired-mapping schedule's exact per-partition op order — lands
//!   bitwise where the cycle-accurate scheduler AND the threaded
//!   runtime land, under every fix (the schedule, not the runtime,
//!   determines the arithmetic);
//! * the production stash ring is bitwise equal to a transparent
//!   external reimplementation (explicit clone-per-forward FIFOs
//!   driven through the raw `stage_*_with` primitives);
//! * every fix is a **bitwise no-op at staleness 0**: sequential runs
//!   under stash/predict/correct equal the fix-free run exactly, on
//!   both runtimes (fixes measure staleness at run time, so they stand
//!   down without special-casing);
//! * mid-training evaluation leaves the trajectory bitwise unchanged
//!   under every fix (eval purity);
//! * checkpoint-restart recovery stays bitwise-invisible under every
//!   fix (segment boundaries are drained, rings restart empty);
//! * the stash ring's observed high-water marks match the analytic
//!   memory model in `memory::stash_ring_costs` exactly.

use std::path::PathBuf;
use std::time::Duration;

use pipestale::backend::{native_config, NativeExecutor};
use pipestale::config::{Backend, Mode, OnFailure, RunConfig, RuntimeKind};
use pipestale::data::{batch_seed, load_or_synthesize, Batcher, SyntheticSpec};
use pipestale::memory::stash_ring_costs;
use pipestale::meta::ConfigMeta;
use pipestale::model::{checkpoint, ModelParams};
use pipestale::pipeline::{
    Feed, FixKind, NativeWorkerBackend, Occupancy, Pipeline, StageExecutor, ThreadedOptions,
    ThreadedPipeline,
};
use pipestale::tensor::{IntTensor, Tensor};
use pipestale::train::{build_optims, TrainResult};

// ---------------------------------------------------------------------------
// Shared fixtures.
// ---------------------------------------------------------------------------

/// A deterministic batch stream for a config: the same (x, labels)
/// list drives the oracle, the scheduler and the threaded runtime.
fn make_batches(meta: &ConfigMeta, n: usize, seed: u64) -> Vec<(Tensor, IntTensor)> {
    let spec = SyntheticSpec { train: 96, test: 16, noise: 0.8, seed: seed ^ 0x5eed_da7a };
    let (train, _) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let mut batcher = Batcher::new(train.len(), meta.batch, seed ^ 0xba7c4);
    (0..n).map(|_| train.gather(&batcher.next_indices().to_vec())).collect()
}

fn assert_params_eq(a: &ModelParams, b: &ModelParams, what: &str) {
    assert_eq!(a.partitions.len(), b.partitions.len(), "{what}");
    for (i, (x, y)) in a.partitions.iter().zip(&b.partitions).enumerate() {
        assert_eq!(x.version, y.version, "{what}: partition {i} update count");
        for (j, (t, u)) in x.params.iter().zip(&y.params).enumerate() {
            assert_eq!(t.data(), u.data(), "{what}: partition {i} param {j} must be bitwise equal");
        }
        for (j, (t, u)) in x.state.iter().zip(&y.state).enumerate() {
            assert_eq!(t.data(), u.data(), "{what}: partition {i} state {j} must be bitwise equal");
        }
    }
}

fn fresh_path(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("mitig_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    std::fs::remove_file(&p).ok();
    p
}

// ---------------------------------------------------------------------------
// The three runners. Identical init (same seed -> same weights, same
// optimizers) and identical batch streams; only the execution engine
// differs.
// ---------------------------------------------------------------------------

/// Flat-loop serial oracle: replays the schedule's timing as plain
/// loops over the raw per-partition primitives. Batch `b` hits
/// partition `p`'s forward at cycle `b + p`, the fused last stage at
/// cycle `b + P-1`, and `p`'s backward at cycle `b + 2(P-1) - p`;
/// within a cycle forwards run (ascending) before backwards
/// (descending), exactly like `Pipeline::cycle`. Per-partition op
/// order — the only thing that matters for weight state — is therefore
/// identical to both production runtimes.
///
/// `external_stash = true` keeps the production fix uninstalled and
/// instead maintains explicit per-partition FIFOs of cloned weights,
/// driving `stage_forward_with`/`stage_backward_with` directly: a
/// transparent reimplementation of stashing that the production ring
/// must match bitwise.
fn oracle_run(
    meta: &ConfigMeta,
    batches: &[(Tensor, IntTensor)],
    seed: u64,
    fix: FixKind,
    external_stash: bool,
) -> ModelParams {
    assert!(!external_stash || fix == FixKind::Stash);
    let params = ModelParams::init(&meta.partitions, seed).unwrap();
    let optims = build_optims(meta, batches.len() as u64, 1.0);
    let mut exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
    if !external_stash {
        exec.set_staleness_fix(fix).unwrap();
    }

    let p_total = exec.parts.len();
    assert!(p_total >= 2, "oracle needs a pipelined split");
    let n = batches.len();
    // [p][b] slots for carries crossing cycles.
    let mut fwd_out: Vec<Vec<Option<Vec<Tensor>>>> = vec![vec![None; n]; p_total - 1];
    let mut carry_in: Vec<Vec<Option<Vec<Tensor>>>> = vec![vec![None; n]; p_total - 1];
    let mut gcarry: Vec<Vec<Option<Vec<Tensor>>>> = vec![vec![None; n]; p_total - 1];
    let mut stash: Vec<std::collections::VecDeque<Vec<Tensor>>> =
        (0..p_total - 1).map(|_| Default::default()).collect();

    for c in 0..n + 2 * (p_total - 1) {
        // forwards, ascending partitions
        for p in 0..p_total - 1 {
            if c < p || c - p >= n {
                continue;
            }
            let b = c - p;
            let carry = if p == 0 {
                vec![batches[b].0.clone()]
            } else {
                fwd_out[p - 1][b].take().unwrap()
            };
            let out = if external_stash {
                stash[p].push_back(exec.parts[p].params.params.clone());
                exec.parts[p].stage_forward_with(&carry, None).unwrap()
            } else {
                exec.parts[p].stage_forward(&carry).unwrap()
            };
            fwd_out[p][b] = Some(out);
            carry_in[p][b] = Some(carry);
        }
        // fused last stage
        if c >= p_total - 1 && c - (p_total - 1) < n {
            let b = c - (p_total - 1);
            let carry = fwd_out[p_total - 2][b].take().unwrap();
            let res = exec.parts[p_total - 1].stage_last(&carry, &batches[b].1).unwrap();
            gcarry[p_total - 2][b] = Some(res.gcarry_in);
        }
        // backwards, descending partitions
        for p in (0..p_total - 1).rev() {
            let shift = 2 * (p_total - 1) - p;
            if c < shift || c - shift >= n {
                continue;
            }
            let b = c - shift;
            let cin = carry_in[p][b].take().unwrap();
            let g = gcarry[p][b].take().unwrap();
            let gin = if external_stash {
                let over = stash[p].pop_front().expect("external stash underflow");
                exec.parts[p].stage_backward_with(&cin, &g, Some(&over), 1.0).unwrap()
            } else {
                exec.parts[p].stage_backward(&cin, &g).unwrap()
            };
            if p > 0 {
                gcarry[p - 1][b] = Some(gin);
            }
        }
    }
    for s in &stash {
        assert!(s.is_empty(), "external stash must drain with the pipeline");
    }
    exec.params_snapshot()
}

/// The cycle-accurate scheduler on the native backend.
fn scheduler_run(
    meta: &ConfigMeta,
    batches: &[(Tensor, IntTensor)],
    seed: u64,
    fix: FixKind,
) -> ModelParams {
    let params = ModelParams::init(&meta.partitions, seed).unwrap();
    let optims = build_optims(meta, batches.len() as u64, 1.0);
    let mut exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
    exec.set_staleness_fix(fix).unwrap();
    let mut pipe = Pipeline::new(exec, meta.batch);
    for (b, (x, labels)) in batches.iter().enumerate() {
        let feed = Feed {
            batch_id: b as u64,
            seed: batch_seed(seed, b as u64),
            x: x.clone(),
            labels: labels.clone(),
        };
        pipe.cycle(Some(feed)).unwrap();
    }
    pipe.drain().unwrap();
    for st in pipe.exec.fix_stats() {
        assert_eq!(st.ring_len, 0, "fix state must be empty after drain");
    }
    pipe.exec.params_snapshot()
}

/// The thread-per-partition runtime on the native backend.
fn threaded_run(
    meta: &ConfigMeta,
    batches: &[(Tensor, IntTensor)],
    seed: u64,
    fix: FixKind,
) -> ModelParams {
    let params = ModelParams::init(&meta.partitions, seed).unwrap();
    let optims = build_optims(meta, batches.len() as u64, 1.0);
    let opts = ThreadedOptions {
        occupancy: Occupancy::Full,
        stall_timeout: Duration::from_secs(30),
        staleness_fix: fix,
    };
    let mut pipe =
        ThreadedPipeline::launch_with(NativeWorkerBackend, meta, params, optims, opts).unwrap();
    pipe.train(batches.len() as u64, seed, |b| Ok(batches[b as usize].clone())).unwrap();
    pipe.shutdown().unwrap()
}

// ---------------------------------------------------------------------------
// Oracle <-> scheduler <-> threaded, per fix.
// ---------------------------------------------------------------------------

fn assert_three_way(config: &str, n: usize, seed: u64) {
    let meta = native_config(config).unwrap();
    let batches = make_batches(&meta, n, seed);
    for fix in FixKind::all() {
        let oracle = oracle_run(&meta, &batches, seed, fix, false);
        let sched = scheduler_run(&meta, &batches, seed, fix);
        let thr = threaded_run(&meta, &batches, seed, fix);
        assert_params_eq(&oracle, &sched, &format!("{config}/{}: oracle vs scheduler", fix.name()));
        assert_params_eq(&sched, &thr, &format!("{config}/{}: scheduler vs threaded", fix.name()));
    }
}

#[test]
fn oracle_scheduler_threaded_agree_per_fix_lenet_p2() {
    assert_three_way("native_lenet_small", 10, 11);
}

#[test]
fn oracle_scheduler_threaded_agree_per_fix_lenet_p4() {
    assert_three_way("native_lenet_small_4s", 12, 17);
}

#[test]
fn oracle_scheduler_threaded_agree_per_fix_resnet_p4() {
    // Residual blocks + BN state cross the same seam; P=4 keeps the
    // deep-split degrees (6/4/2) in play.
    assert_three_way("native_resnet_small_4s", 10, 23);
}

#[test]
fn production_stash_ring_matches_external_reimplementation() {
    // The defining stash claim at full pipeline scale: the pool-backed
    // production ring is bitwise the obvious clone-per-forward FIFO.
    for (config, n, seed) in
        [("native_lenet_small_4s", 12, 29u64), ("native_resnet_small", 10, 31u64)]
    {
        let meta = native_config(config).unwrap();
        let batches = make_batches(&meta, n, seed);
        let production = oracle_run(&meta, &batches, seed, FixKind::Stash, false);
        let external = oracle_run(&meta, &batches, seed, FixKind::Stash, true);
        assert_params_eq(&production, &external, &format!("{config}: production vs external stash"));
    }
}

#[test]
fn stash_differs_from_baseline_once_weights_are_stale() {
    // Sanity check that the suite has teeth: under full occupancy the
    // stashed backward really changes the arithmetic.
    let meta = native_config("native_lenet_small_4s").unwrap();
    let batches = make_batches(&meta, 12, 37);
    let none = scheduler_run(&meta, &batches, 37, FixKind::None);
    let stash = scheduler_run(&meta, &batches, 37, FixKind::Stash);
    let differ = none
        .partitions
        .iter()
        .zip(&stash.partitions)
        .any(|(a, b)| a.params.iter().zip(&b.params).any(|(t, u)| t.data() != u.data()));
    assert!(differ, "stash must alter stale-partition training");
}

// ---------------------------------------------------------------------------
// Staleness 0: every fix stands down bitwise.
// ---------------------------------------------------------------------------

fn rc_for(config: &str, runtime: RuntimeKind, mode: Mode, iters: u64) -> RunConfig {
    let mut rc = RunConfig::new(config);
    rc.backend = Backend::Native;
    rc.runtime = runtime;
    rc.mode = mode;
    rc.iters = iters;
    rc.train_size = 128;
    rc.test_size = 32;
    rc.noise = 0.8;
    rc.restart_backoff_ms = 1;
    rc
}

/// Run to completion, reading the final weights back through
/// `--save-checkpoint` (the bitwise ground truth).
fn run_saving(rc: &mut RunConfig, tag: &str) -> (TrainResult, ModelParams) {
    let out = fresh_path(&format!("{tag}_final"));
    rc.save_to = Some(out.clone());
    let res = pipestale::train::run(rc).unwrap();
    let (params, at) = checkpoint::load(&out).unwrap();
    assert_eq!(at, rc.iters);
    std::fs::remove_file(&out).ok();
    (res, params)
}

#[test]
fn every_fix_is_bitwise_noop_in_sequential_mode() {
    for runtime in [RuntimeKind::Scheduler, RuntimeKind::Threaded] {
        let mut base = rc_for("native_lenet_small_4s", runtime, Mode::Sequential, 8);
        let (bres, bparams) = run_saving(&mut base, &format!("noop_base_{}", runtime.name()));
        for fix in [FixKind::Stash, FixKind::Predict, FixKind::Correct] {
            let mut rc = rc_for("native_lenet_small_4s", runtime, Mode::Sequential, 8);
            rc.staleness_fix = fix;
            let (res, params) =
                run_saving(&mut rc, &format!("noop_{}_{}", fix.name(), runtime.name()));
            assert_eq!(
                res.recorder.train,
                bres.recorder.train,
                "{}/{}: sequential loss curve must be bitwise identical",
                runtime.name(),
                fix.name()
            );
            assert_params_eq(
                &params,
                &bparams,
                &format!("{}/{}: sequential weights", runtime.name(), fix.name()),
            );
        }
    }
}

#[test]
fn every_fix_is_bitwise_noop_in_hybrid_tail() {
    // The hybrid switch drains the pipe; the sequential tail then runs
    // at staleness 0, where predict/correct must not perturb a single
    // bit relative to... themselves with a different fix? No: relative
    // to the fix-free hybrid run *after the same pipelined prefix* the
    // trajectories already diverged. The sharp claim is prefix-free:
    // pipelined_iters = 0 makes the whole hybrid run a sequential run,
    // which must equal Mode::Sequential bitwise under every fix.
    let mut seq = rc_for("native_lenet_small_4s", RuntimeKind::Scheduler, Mode::Sequential, 8);
    let (_, sparams) = run_saving(&mut seq, "hybrid_seq");
    for fix in FixKind::all() {
        let mut rc = rc_for("native_lenet_small_4s", RuntimeKind::Scheduler, Mode::Hybrid, 8);
        rc.pipelined_iters = 0;
        rc.staleness_fix = fix;
        let (_, params) = run_saving(&mut rc, &format!("hybrid_{}", fix.name()));
        assert_params_eq(&params, &sparams, &format!("hybrid-0/{}", fix.name()));
    }
}

// ---------------------------------------------------------------------------
// Eval purity: mid-training evaluation never touches the trajectory.
// ---------------------------------------------------------------------------

#[test]
fn midtrain_eval_leaves_trajectory_bitwise_unchanged_under_every_fix() {
    for fix in FixKind::all() {
        let mut plain = rc_for("native_lenet_small_4s", RuntimeKind::Scheduler, Mode::Pipelined, 9);
        plain.staleness_fix = fix;
        let (pres, pparams) = run_saving(&mut plain, &format!("evalp_plain_{}", fix.name()));

        let mut evald = rc_for("native_lenet_small_4s", RuntimeKind::Scheduler, Mode::Pipelined, 9);
        evald.staleness_fix = fix;
        evald.eval_every = 3;
        let (eres, eparams) = run_saving(&mut evald, &format!("evalp_eval_{}", fix.name()));

        assert_eq!(
            pres.recorder.train,
            eres.recorder.train,
            "{}: eval must not perturb the loss curve",
            fix.name()
        );
        assert_params_eq(&pparams, &eparams, &format!("eval purity under {}", fix.name()));
        assert!(eres.recorder.evals.len() > pres.recorder.evals.len(), "eval points were taken");
    }
}

// ---------------------------------------------------------------------------
// Checkpoint-restart: recovery stays bitwise-invisible under every fix.
// ---------------------------------------------------------------------------

#[test]
fn checkpoint_restart_recovery_is_bitwise_invisible_under_every_fix() {
    // Same fault geometry as the resilience suite's core test: stage 1
    // dies at op 16, inside the second 6-feed segment, after the iter-6
    // checkpoint exists. Segment boundaries are drained, so every fix's
    // ring restarts empty and recovery must stay bitwise-invisible.
    for fix in [FixKind::Stash, FixKind::Predict, FixKind::Correct] {
        let mut faulted = rc_for("native_lenet_small_4s", RuntimeKind::Threaded, Mode::Pipelined, 18);
        faulted.staleness_fix = fix;
        faulted.ckpt_every = 6;
        faulted.ckpt_dir = Some(fresh_path(&format!("ckpt_{}_faulted", fix.name())));
        faulted.on_failure = OnFailure::Restart;
        faulted.fault_plan = Some("panic@1:16".to_string());
        let (fres, fparams) = run_saving(&mut faulted, &format!("ckpt_{}_f", fix.name()));

        let mut clean = rc_for("native_lenet_small_4s", RuntimeKind::Threaded, Mode::Pipelined, 18);
        clean.staleness_fix = fix;
        clean.ckpt_every = 6;
        clean.ckpt_dir = Some(fresh_path(&format!("ckpt_{}_clean", fix.name())));
        let (cres, cparams) = run_saving(&mut clean, &format!("ckpt_{}_c", fix.name()));

        assert_eq!(fres.restarts, 1, "{}: exactly one recovery", fix.name());
        assert!(!fres.degraded);
        assert_eq!(
            fres.recorder.train,
            cres.recorder.train,
            "{}: recovered loss curve must be bitwise identical",
            fix.name()
        );
        assert_params_eq(&fparams, &cparams, &format!("checkpoint-restart under {}", fix.name()));
        std::fs::remove_dir_all(faulted.ckpt_dir.unwrap()).ok();
        std::fs::remove_dir_all(clean.ckpt_dir.unwrap()).ok();
    }
}

// ---------------------------------------------------------------------------
// Memory accounting: observed ring marks == analytic model, exactly.
// ---------------------------------------------------------------------------

#[test]
fn stash_ring_high_water_matches_memory_model_exactly() {
    // Enough feeds for every partition to reach full occupancy
    // (deepest window is degree+1 = 7 at P=4).
    let meta = native_config("native_lenet_small_4s").unwrap();
    let batches = make_batches(&meta, 16, 41);
    let params = ModelParams::init(&meta.partitions, 41).unwrap();
    let optims = build_optims(&meta, batches.len() as u64, 1.0);
    let mut exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
    exec.set_staleness_fix(FixKind::Stash).unwrap();
    let mut pipe = Pipeline::new(exec, meta.batch);
    for (b, (x, labels)) in batches.iter().enumerate() {
        let feed = Feed {
            batch_id: b as u64,
            seed: batch_seed(41, b as u64),
            x: x.clone(),
            labels: labels.clone(),
        };
        pipe.cycle(Some(feed)).unwrap();
    }
    pipe.drain().unwrap();

    let stats = pipe.exec.fix_stats();
    let costs = stash_ring_costs(&meta);
    assert_eq!(stats.len(), costs.len());
    for (st, cost) in stats.iter().zip(&costs) {
        assert_eq!(st.kind, FixKind::Stash);
        assert_eq!(st.ring_len, 0, "partition {}: drained ring must be empty", cost.partition);
        assert_eq!(
            st.ring_high_water, cost.ring_slots,
            "partition {}: observed ring high-water vs analytic slots",
            cost.partition
        );
        assert_eq!(
            st.stashed_bytes_high_water as f64, cost.ring_bytes,
            "partition {}: observed stash bytes vs analytic ring bytes",
            cost.partition
        );
    }
}

#[test]
fn predict_and_correct_track_inflight_depth_without_stashing_bytes() {
    let meta = native_config("native_lenet_small_4s").unwrap();
    let batches = make_batches(&meta, 16, 43);
    for fix in [FixKind::Predict, FixKind::Correct] {
        let params = ModelParams::init(&meta.partitions, 43).unwrap();
        let optims = build_optims(&meta, batches.len() as u64, 1.0);
        let mut exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
        exec.set_staleness_fix(fix).unwrap();
        let mut pipe = Pipeline::new(exec, meta.batch);
        for (b, (x, labels)) in batches.iter().enumerate() {
            let feed = Feed {
                batch_id: b as u64,
                seed: batch_seed(43, b as u64),
                x: x.clone(),
                labels: labels.clone(),
            };
            pipe.cycle(Some(feed)).unwrap();
        }
        pipe.drain().unwrap();
        for (st, cost) in pipe.exec.fix_stats().iter().zip(stash_ring_costs(&meta)) {
            assert_eq!(st.ring_len, 0, "{}: drained", fix.name());
            assert_eq!(st.ring_high_water, cost.ring_slots, "{}: in-flight depth", fix.name());
            assert_eq!(st.stashed_bytes_high_water, 0, "{}: stashes no weights", fix.name());
        }
    }
}
