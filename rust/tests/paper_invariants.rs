//! Cross-config invariants tying the artifact set to the paper's tables.
//!
//! The `native_*` tests run the same accounting against the in-crate
//! native config manifest, so the paper's §3 staleness/memory math is
//! exercised even when no artifacts are built.

use pipestale::backend::native_config;
use pipestale::memory::MemoryReport;
use pipestale::meta::ConfigMeta;
use pipestale::pipeline::perfsim::{
    analytic_costs, simulate_nonpipelined, simulate_pipelined, CommModel, Mapping,
};
use pipestale::pipeline::StalenessReport;
use pipestale::util::skip_marker;

fn root() -> std::path::PathBuf {
    pipestale::artifacts_root()
}

fn load(name: &str) -> ConfigMeta {
    ConfigMeta::load_named(&root(), name).unwrap_or_else(|e| panic!("{name}: {e}"))
}

#[test]
fn table1_ppvs_present_with_correct_stage_counts() {
    if !pipestale::artifacts_present() { skip_marker("artifacts not built"); return; }
    // (config, expected paper stages, expected PPV)
    let grid: &[(&str, usize, &[usize])] = &[
        ("lenet5_4s", 4, &[1]),
        ("lenet5_6s", 6, &[1, 2]),
        ("lenet5_8s", 8, &[1, 2, 3]),
        ("lenet5_10s", 10, &[1, 2, 3, 4]),
        ("alexnet_4s", 4, &[1]),
        ("alexnet_6s", 6, &[1, 2]),
        ("alexnet_8s", 8, &[1, 2, 3]),
        ("vgg16_4s", 4, &[2]),
        ("vgg16_6s", 6, &[2, 4]),
        ("vgg16_8s", 8, &[2, 4, 7]),
        ("vgg16_10s", 10, &[2, 4, 7, 10]),
        ("resnet20_4s", 4, &[7]),
        ("resnet20_6s", 6, &[7, 13]),
        ("resnet20_8s", 8, &[7, 13, 19]),
    ];
    for (name, stages, ppv) in grid {
        let m = load(name);
        assert_eq!(m.paper_stages(), *stages, "{name}");
        assert_eq!(m.ppv, ppv.to_vec(), "{name}");
    }
}

#[test]
fn table3_fine_grained_set_is_complete() {
    if !pipestale::artifacts_present() { skip_marker("artifacts not built"); return; }
    for ns in [8usize, 10, 12, 14, 16, 18, 20] {
        let m = load(&format!("resnet20_fine{ns}"));
        assert_eq!(m.paper_stages(), ns);
    }
}

#[test]
fn fig6_slide_positions_cover_the_network() {
    if !pipestale::artifacts_present() { skip_marker("artifacts not built"); return; }
    let mut prev = 0.0;
    for p in [3usize, 5, 7, 9, 11, 13, 15, 17, 19] {
        let m = load(&format!("resnet20_slide{p}"));
        assert_eq!(m.ppv, vec![p]);
        let frac = m.stale_weight_fraction();
        assert!(frac > prev, "slide{p}: {frac} <= {prev}");
        prev = frac;
        // constant degree of 2 for the single stale partition
        assert_eq!(m.degree_of_staleness(1), 2);
    }
    assert!(prev > 0.9, "last slide should have ~all weights stale: {prev}");
}

#[test]
fn table5_resnet_family_loads_and_speedup_grows_with_depth() {
    if !pipestale::artifacts_present() { skip_marker("artifacts not built"); return; }
    // DES with the GTX1060 roofline cost model (paper's testbed): deeper
    // ResNets have a higher compute-to-communication ratio, so the
    // projected speedup grows toward 2.0 under the paired 2-worker
    // mapping — Table 5's trend (1.23X .. 1.82X).
    let comm = CommModel::default();
    let mut prev = 0.0;
    for name in ["resnet20_4s", "resnet56_4s", "resnet110_4s", "resnet224_4s", "resnet362_4s"] {
        let m = load(name);
        assert_eq!(m.partitions.len(), 2, "{name} should be 4-stage (K=1)");
        let costs = pipestale::pipeline::perfsim::gtx1060_costs(&m);
        let s = simulate_nonpipelined(&costs, 200)
            / simulate_pipelined(&costs, &comm, Mapping::Paired, 200);
        assert!(s > 1.0 && s <= 2.0 + 1e-9, "{name}: speedup {s}");
        assert!(s >= prev - 0.02, "{name}: speedup {s} fell from {prev}");
        prev = prev.max(s);
    }
    assert!(prev > 1.5, "deepest ResNet should exceed 1.5x: {prev}");
    // the analytic flops-only model also yields sane (1..2] speedups
    let m = load("resnet110_4s");
    let costs = analytic_costs(&m, 50e9);
    let s = simulate_nonpipelined(&costs, 100)
        / simulate_pipelined(&costs, &CommModel::free(), Mapping::Paired, 100);
    assert!(s > 1.0 && s <= 2.0 + 1e-9, "{s}");
}

#[test]
fn table6_memory_reports_for_all_depths() {
    if !pipestale::artifacts_present() { skip_marker("artifacts not built"); return; }
    for d in [20usize, 56, 110, 224, 362] {
        let m = load(&format!("resnet{d}_mem"));
        let r = MemoryReport::from_meta(&m);
        assert!(r.weight_bytes > 0.0 && r.activations_per_sample > 0.0);
        assert!(r.increase_paper_style_per_sample > 0.0, "resnet{d}");
    }
}

#[test]
fn staleness_reports_consistent_across_all_configs() {
    if !pipestale::artifacts_present() { skip_marker("artifacts not built"); return; }
    for entry in std::fs::read_dir(root()).unwrap() {
        let dir = entry.unwrap().path();
        if !dir.join("meta.json").exists() {
            continue;
        }
        let m = ConfigMeta::load(&dir).unwrap();
        let r = StalenessReport::from_meta(&m);
        // degrees strictly decrease by 2 to zero
        for (i, p) in r.partitions.iter().enumerate() {
            assert_eq!(p.degree, 2 * (m.ppv.len() - i), "{}", m.config);
        }
        assert!(r.stale_weight_fraction >= 0.0 && r.stale_weight_fraction < 1.0);
        // param accounting: partition sums == layer sums
        let by_part: usize = m.partitions.iter().map(|p| p.param_count).sum();
        let by_layer: usize = m.layers.iter().map(|l| l.param_count).sum();
        assert_eq!(by_part, by_layer, "{}", m.config);
    }
}

#[test]
fn native_table1_lenet_row_matches_paper() {
    // Table 1's LeNet-5 row (PPVs for 4/6/8/10 stages), artifact-free.
    let grid: &[(&str, usize, &[usize])] = &[
        ("lenet5_4s", 4, &[1]),
        ("lenet5_6s", 6, &[1, 2]),
        ("lenet5_8s", 8, &[1, 2, 3]),
        ("lenet5_10s", 10, &[1, 2, 3, 4]),
    ];
    let mut prev_stale = 0.0;
    for (name, stages, ppv) in grid {
        let m = native_config(name).unwrap();
        assert_eq!(m.paper_stages(), *stages, "{name}");
        assert_eq!(m.ppv, ppv.to_vec(), "{name}");
        // more registers in the prefix -> strictly more stale weights
        let f = m.stale_weight_fraction();
        assert!(f > prev_stale, "{name}: {f} <= {prev_stale}");
        prev_stale = f;
    }
}

#[test]
fn native_staleness_reports_consistent() {
    for name in pipestale::backend::native_config_names() {
        let m = native_config(name).unwrap();
        let r = StalenessReport::from_meta(&m);
        // degrees strictly decrease by 2 to zero (paper §3)
        for (i, p) in r.partitions.iter().enumerate() {
            assert_eq!(p.degree, 2 * (m.ppv.len() - i), "{name}");
        }
        assert!(r.stale_weight_fraction >= 0.0 && r.stale_weight_fraction < 1.0);
        // param accounting: partition sums == layer sums
        let by_part: usize = m.partitions.iter().map(|p| p.param_count).sum();
        let by_layer: usize = m.layers.iter().map(|l| l.param_count).sum();
        assert_eq!(by_part, by_layer, "{name}");
    }
}

#[test]
fn native_memory_and_perfsim_models_accept_native_meta() {
    // The Table-6 memory model and the DES cost model consume ConfigMeta
    // only — the native manifest must satisfy both.
    let m = native_config("lenet5_8s").unwrap();
    let r = MemoryReport::from_meta(&m);
    assert!(r.weight_bytes > 0.0 && r.activations_per_sample > 0.0);
    assert!(r.increase_paper_style_per_sample > 0.0);
    let costs = analytic_costs(&m, 50e9);
    let comm = CommModel::free();
    let s = simulate_nonpipelined(&costs, 100)
        / simulate_pipelined(&costs, &comm, Mapping::Paired, 100);
    assert!(s > 1.0 && s <= m.partitions.len() as f64 + 1e-9, "{s}");
}

#[test]
fn hybrid_config_matches_paper_ppv() {
    if !pipestale::artifacts_present() { skip_marker("artifacts not built"); return; }
    let m = load("resnet20_hybrid");
    assert_eq!(m.ppv, vec![5, 12, 17]);
    assert_eq!(m.paper_stages(), 8);
}
