//! §Perf acceptance tests: pooled storage safety, fused-kernel
//! equivalence, and the zero-allocation steady-state cycle.
//!
//! All pool-stats assertions run under a `PoolScope`, which installs a
//! private pool for the current thread — parallel test threads cannot
//! perturb the counters.

use pipestale::backend::kernels::{self, ActKind};
use pipestale::optim::{kernel, Schedule, Sgd};
use pipestale::pipeline::mock::MockExecutor;
use pipestale::pipeline::{Feed, Pipeline};
use pipestale::pool::{PoolScope, PoolStats};
use pipestale::tensor::{IntTensor, Tensor};
use pipestale::util::prop;
use pipestale::util::rng::Pcg32;

/// Tests that can dispatch into the shared GEMM worker pool serialize
/// on this lock: unlike the `PoolScope`-isolated caller pools, the
/// workers' pool counters are process-global, so concurrent GEMM work
/// from a parallel test thread would perturb the cross-worker probe.
static GEMM_POOL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

// ---------------------------------------------------------------------
// Pool safety: recycled buffers never leak stale data through the
// public tensor constructors.
// ---------------------------------------------------------------------

#[test]
fn prop_recycled_buffers_never_expose_stale_data() {
    prop::check(
        0x5EED_900,
        60,
        |rng| {
            let len = 1 + rng.below(512) as usize;
            let seed = rng.next_u64();
            (len, seed)
        },
        |&(len, seed)| {
            if len == 0 {
                return Ok(()); // shrinker artifact: empty tensors hold no data
            }
            let scope = PoolScope::new();
            let pool = scope.pool().clone();
            let mut rng = Pcg32::seeded(seed);

            // Dirty a buffer of this size class, then recycle it.
            let junk = Tensor::filled(&[len], f32::from_bits(0xDEAD_BEEF) + rng.normal());
            drop(junk);
            if pool.stats().recycled != 1 {
                return Err(format!("buffer was not recycled: {:?}", pool.stats()));
            }

            // zeros() must fully zero a recycled buffer.
            let z = Tensor::zeros(&[len]);
            if !z.data().iter().all(|&v| v == 0.0) {
                return Err("zeros() exposed stale data".into());
            }
            drop(z);

            // ones()/filled() must fully overwrite.
            let o = Tensor::ones(&[len]);
            if !o.data().iter().all(|&v| v == 1.0) {
                return Err("ones() exposed stale data".into());
            }
            drop(o);

            // from_literal must copy exactly the literal's contents.
            let src: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let expect = src.clone();
            let lit = Tensor::from_vec(&[len], src).unwrap().to_literal().unwrap();
            let round = Tensor::from_literal(&lit, &[len]).unwrap();
            if round.data() != expect.as_slice() {
                return Err("from_literal exposed stale data".into());
            }

            // The reuse path must actually have been exercised.
            if pool.stats().reuses == 0 {
                return Err(format!("pool never reused: {:?}", pool.stats()));
            }
            Ok(())
        },
    );
}

#[test]
fn clone_is_shared_until_mutated() {
    let a = Tensor::filled(&[256], 4.0);
    let b = a.clone();
    assert!(a.shares_storage(&b), "clone must not deep-copy");
    let mut c = b.clone();
    c.data_mut()[7] = -4.0;
    assert!(!c.shares_storage(&a), "mutation must unshare");
    assert_eq!(a.data()[7], 4.0);
}

// ---------------------------------------------------------------------
// Fused SGD kernel: bitwise equivalence with the pre-fusion scalar
// loops across momentum / Nesterov / weight-decay combinations.
// ---------------------------------------------------------------------

#[test]
fn prop_fused_sgd_matches_reference_bitwise() {
    prop::check(
        0x0097_1D,
        60,
        |rng| {
            let len = 1 + rng.below(300) as usize;
            let mode = rng.below(6) as usize;
            let seed = rng.next_u64();
            (len, mode, seed)
        },
        |&(len, mode, seed)| {
            // (momentum, nesterov, weight decay) grid
            let (mu, nesterov, wd) = match mode {
                0 => (0.0, false, 0.0),
                1 => (0.0, false, 5e-4),
                2 => (0.9, false, 0.0),
                3 => (0.9, false, 1e-4),
                4 => (0.9, true, 0.0),
                _ => (0.9, true, 5e-4),
            };
            let mut rng = Pcg32::seeded(seed);
            let init: Vec<f32> = (0..len).map(|_| rng.normal()).collect();

            let mut opt = Sgd::new(Schedule::Const { base: 0.05 }, mu, nesterov, wd);
            let mut fused = vec![Tensor::from_vec(&[len], init.clone()).unwrap()];
            let mut p_ref = init;
            let mut v_ref = vec![0.0f32; len];
            let lr = 0.05f64 as f32;

            for step in 0..4 {
                let g: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
                let gt = Tensor::from_vec(&[len], g.clone()).unwrap();
                opt.step(step, &mut fused, std::slice::from_ref(&gt))
                    .map_err(|e| e.to_string())?;
                kernel::reference_update(&mut p_ref, &g, &mut v_ref, lr, mu, nesterov, wd);
                for (i, (a, b)) in fused[0].data().iter().zip(&p_ref).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "step {step} elem {i}: fused {a} ({:#x}) != reference {b} ({:#x}) \
                             [mu={mu} nesterov={nesterov} wd={wd}]",
                            a.to_bits(),
                            b.to_bits()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Zero-allocation steady state: a warm P=4 pipeline cycle allocates no
// tensor backing stores (acceptance criterion of the §Perf tentpole).
// ---------------------------------------------------------------------

#[test]
fn steady_state_cycle_allocates_no_backing_stores() {
    let scope = PoolScope::new();
    let pool = scope.pool().clone();
    let mut pipe = Pipeline::new(MockExecutor::new(4), 1);
    let mut b = 0u64;
    let mut cycle = |pipe: &mut Pipeline<MockExecutor>| {
        let f = Feed {
            batch_id: b,
            seed: b as i32,
            x: Tensor::filled(&[1], b as f32),
            labels: IntTensor::from_vec(&[1], vec![0]).unwrap(),
        };
        pipe.cycle(Some(f)).unwrap();
        b += 1;
    };

    // Warmup: fill the pipe and prime every size class.
    for _ in 0..50 {
        cycle(&mut pipe);
    }
    let warm = pool.stats();
    assert!(warm.reuses > 0, "pool must be serving reuses after warmup: {warm:?}");

    // Steady state: no fresh backing-store allocations over 200 cycles.
    for _ in 0..200 {
        cycle(&mut pipe);
    }
    let steady = pool.stats();
    assert_eq!(
        steady.fresh_allocs, warm.fresh_allocs,
        "steady-state cycles must not allocate backing stores \
         (warm {warm:?} vs steady {steady:?})"
    );
    assert!(steady.reuses > warm.reuses, "steady-state cycles must hit the pool");

    // And the pipeline still retires everything correctly.
    let events = pipe.drain().unwrap();
    assert!(!events.is_empty());
    assert!(pipe.is_drained());
}

#[test]
fn gemm_kernel_scratch_reaches_zero_alloc_steady_state() {
    // The GEMM lowering leases all its scratch (packing panels, im2col
    // buffers, preactivation gradients) from the pool at a fixed set of
    // sizes per model, so a warm training step must perform zero fresh
    // backing-store allocations — the same acceptance criterion the
    // scheduler cycle meets, now extended to the compute kernels.
    let _guard = GEMM_POOL_LOCK.lock().unwrap();
    let scope = PoolScope::new();
    let pool = scope.pool().clone();
    let mut rng = Pcg32::seeded(0x6E77);
    let (n, h, w, cin, cout, k) = (2usize, 8usize, 8usize, 3usize, 4usize, 3usize);
    let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.normal()).collect();
    let wgt: Vec<f32> = (0..k * k * cin * cout).map(|_| rng.normal()).collect();
    let (din, dout) = (n * h * w * cin / n, 10);
    let dwgt: Vec<f32> = (0..din * dout).map(|_| rng.normal()).collect();
    let dbias: Vec<f32> = (0..dout).map(|_| rng.normal()).collect();

    let mut conv_y = vec![0.0; n * h * w * cout];
    let mut conv_dx = vec![0.0; x.len()];
    let mut conv_dw = vec![0.0; wgt.len()];
    let mut fc_y = vec![0.0; n * dout];
    let mut fc_dx = vec![0.0; n * din];
    let mut fc_dw = vec![0.0; din * dout];
    let mut fc_db = vec![0.0; dout];
    let mut step = || {
        kernels::conv2d_forward(&x, n, h, w, cin, &wgt, k, cout, 1, true, None, &mut conv_y);
        conv_dx.fill(0.0);
        conv_dw.fill(0.0);
        kernels::conv2d_backward(
            &x,
            n,
            h,
            w,
            cin,
            &wgt,
            k,
            cout,
            1,
            true,
            &conv_y,
            &mut conv_dx,
            &mut conv_dw,
            None,
        );
        kernels::dense_forward(&x, n, din, &dwgt, &dbias, dout, ActKind::Tanh, &mut fc_y);
        fc_dx.fill(0.0);
        fc_dw.fill(0.0);
        fc_db.fill(0.0);
        kernels::dense_backward(
            &x,
            n,
            din,
            &dwgt,
            dout,
            ActKind::Tanh,
            &fc_y,
            &fc_y,
            &mut fc_dx,
            &mut fc_dw,
            &mut fc_db,
        );
    };

    step(); // warmup primes every scratch size class
    let warm = pool.stats();
    for _ in 0..20 {
        step();
    }
    let delta = pool.stats().delta(&warm);
    assert_eq!(
        delta.fresh_allocs, 0,
        "warm GEMM kernels must lease all scratch from the pool: {delta:?}"
    );
    assert!(delta.reuses > 0, "steady-state kernels must hit the pool: {delta:?}");
}

#[test]
fn threaded_gemm_scratch_stays_allocation_free_across_workers() {
    // Cross-worker extension of the probe above: with GEMM threads > 1
    // each worker leases its own packing panels from its thread-local
    // pool, so a warm multithreaded sgemm must stay allocation-free on
    // the caller pool AND on every worker pool.
    use pipestale::backend::gemm::sgemm_with;
    use pipestale::backend::{simd, threadpool};

    let _guard = GEMM_POOL_LOCK.lock().unwrap();
    let scope = PoolScope::new();
    let pool = scope.pool().clone();
    let mut rng = Pcg32::seeded(0x7A11);
    // 200x300 C = a 4x3 macro-tile grid, enough tiles for 3 workers.
    let (m, n, k) = (200usize, 300usize, 64usize);
    let threads = 3usize;
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let mut c = vec![0.0f32; m * n];

    // Warmup spawns the workers and primes every pool's size classes.
    sgemm_with(simd::detected(), threads, false, false, m, n, k, &a, &b, false, &mut c);
    let caller_warm = pool.stats();
    let workers_warm = threadpool::worker_pool_stats();

    for _ in 0..10 {
        sgemm_with(simd::detected(), threads, false, false, m, n, k, &a, &b, false, &mut c);
    }

    let caller_delta = pool.stats().delta(&caller_warm);
    assert_eq!(
        caller_delta.fresh_allocs, 0,
        "warm threaded GEMM must lease caller scratch from the pool: {caller_delta:?}"
    );
    let workers_now = threadpool::worker_pool_stats();
    // The same thread count reuses the warmup's workers, so the pool
    // roster is stable across the steady-state loop.
    assert_eq!(workers_now.len(), workers_warm.len(), "no new workers mid-probe");
    let worker_delta = workers_now
        .iter()
        .zip(&workers_warm)
        .map(|(now, warm)| now.delta(warm))
        .fold(PoolStats::default(), |acc, d| acc.merge(&d));
    assert_eq!(
        worker_delta.fresh_allocs, 0,
        "warm worker pools must stay allocation-free: {worker_delta:?}"
    );
    assert!(
        caller_delta.reuses + worker_delta.reuses > 0,
        "steady-state threaded GEMM must hit the pools: {caller_delta:?} {worker_delta:?}"
    );
}

#[test]
fn disabled_pool_allocates_every_cycle() {
    // Control for the test above: with recycling off, the same loop
    // must allocate continuously — proving the counter actually
    // measures the cycle's allocations.
    let scope = PoolScope::new();
    let pool = scope.pool().clone();
    pool.set_enabled(false);
    let mut pipe = Pipeline::new(MockExecutor::new(4), 1);
    for b in 0..50u64 {
        let f = Feed {
            batch_id: b,
            seed: b as i32,
            x: Tensor::filled(&[1], b as f32),
            labels: IntTensor::from_vec(&[1], vec![0]).unwrap(),
        };
        pipe.cycle(Some(f)).unwrap();
    }
    let mid = pool.stats().fresh_allocs;
    for b in 50..100u64 {
        let f = Feed {
            batch_id: b,
            seed: b as i32,
            x: Tensor::filled(&[1], b as f32),
            labels: IntTensor::from_vec(&[1], vec![0]).unwrap(),
        };
        pipe.cycle(Some(f)).unwrap();
    }
    assert!(pool.stats().fresh_allocs > mid, "disabled pool must keep allocating");
}

// ---------------------------------------------------------------------
// Sequential schedule equivalence is untouched by the zero-copy
// refactor: one batch through a drained pipe still matches
// sequential_step exactly (guards against aliasing bugs in the shared
// storage — a CoW mistake would corrupt one of the two traces).
// ---------------------------------------------------------------------

#[test]
fn refactored_cycle_preserves_schedule_semantics() {
    let p = 3;
    let mut a = Pipeline::new(MockExecutor::new(p), 1);
    let mut bpipe = Pipeline::new(MockExecutor::new(p), 1);
    let feed = |b: u64| Feed {
        batch_id: b,
        seed: b as i32,
        x: Tensor::filled(&[1], b as f32),
        labels: IntTensor::from_vec(&[1], vec![0]).unwrap(),
    };
    a.sequential_step(feed(0)).unwrap();
    bpipe.cycle(Some(feed(0))).unwrap();
    bpipe.drain().unwrap();
    assert_eq!(a.exec.trace, bpipe.exec.trace);
}
