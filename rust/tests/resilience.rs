//! Fault-tolerance test suite: deterministic fault injection against
//! the threaded runtime's checkpoint-restart supervisor (DESIGN.md §8).
//!
//! The core claims under test, all offline on the native backend:
//!
//! * a worker panic mid-run tears the pipeline down, restores the
//!   newest valid rotating checkpoint, replays the data stream, and
//!   finishes with weights and a loss curve **bitwise equal** to the
//!   same run without the fault (same `--ckpt-every` segmentation);
//! * a hung stage is detected by the heartbeat watchdog and either
//!   fails fast (`--on-failure fail`) or restarts; a slow-but-ticking
//!   stage is never flagged;
//! * exhausting the retry budget under `--on-failure degrade` finishes
//!   the run single-occupancy, bitwise equal to a sequential run;
//! * corrupt or truncated checkpoints are detected (trailing checksum)
//!   and skipped in favor of an older valid one, costing recomputation
//!   rather than the run.

use std::path::PathBuf;

use pipestale::backend::native_config;
use pipestale::config::{Backend, Mode, OnFailure, RunConfig, RuntimeKind};
use pipestale::data::{load_or_synthesize, SyntheticSpec};
use pipestale::model::checkpoint::{self, CheckpointStore};
use pipestale::model::ModelParams;
use pipestale::train::TrainResult;

/// A P=4 threaded-native run config, small enough for CI.
fn rc4(mode: Mode, iters: u64) -> RunConfig {
    let mut rc = RunConfig::new("native_lenet_small_4s");
    rc.backend = Backend::Native;
    rc.runtime = RuntimeKind::Threaded;
    rc.mode = mode;
    rc.iters = iters;
    rc.train_size = 256;
    rc.test_size = 48;
    rc.noise = 0.8;
    rc.stall_timeout_ms = 30_000;
    rc.restart_backoff_ms = 1; // keep recovery tests fast
    rc
}

/// Fresh per-test scratch path (removed first: earlier aborted runs of
/// the same pid must not leak checkpoints into this one).
fn fresh_path(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("resil_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    std::fs::remove_file(&p).ok();
    p
}

/// Run to completion and read back the final weights via `--save-to`
/// (the checkpoint file is the bitwise ground truth for comparisons).
fn run_saving(rc: &mut RunConfig, tag: &str) -> (TrainResult, ModelParams) {
    let out = fresh_path(&format!("{tag}_final"));
    rc.save_to = Some(out.clone());
    let res = pipestale::train::run(rc).unwrap();
    let (params, at) = checkpoint::load(&out).unwrap();
    assert_eq!(at, rc.iters);
    std::fs::remove_file(&out).ok();
    (res, params)
}

fn assert_params_eq(a: &ModelParams, b: &ModelParams) {
    assert_eq!(a.partitions.len(), b.partitions.len());
    for (i, (x, y)) in a.partitions.iter().zip(&b.partitions).enumerate() {
        assert_eq!(x.version, y.version, "partition {i}: update count must match");
        for (j, (t, u)) in x.params.iter().zip(&y.params).enumerate() {
            assert_eq!(t.data(), u.data(), "partition {i} param {j} must be bitwise equal");
        }
        for (j, (t, u)) in x.state.iter().zip(&y.state).enumerate() {
            assert_eq!(t.data(), u.data(), "partition {i} state {j} must be bitwise equal");
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpoint-restart: recovery is bitwise-invisible in the results.
// ---------------------------------------------------------------------------

#[test]
fn panic_mid_run_recovers_from_checkpoint_and_completes() {
    // Stage 1 runs 12 ops per 6-feed segment, so op 16 lands in the
    // second segment — after the iter-6 checkpoint exists. The
    // supervisor must restore it (not restart from scratch) and finish.
    let mut faulted = rc4(Mode::Pipelined, 18);
    faulted.ckpt_every = 6;
    faulted.ckpt_dir = Some(fresh_path("panic_ckpts"));
    faulted.on_failure = OnFailure::Restart;
    faulted.fault_plan = Some("panic@1:16".to_string());
    let (fres, fparams) = run_saving(&mut faulted, "panic_faulted");

    let mut clean = rc4(Mode::Pipelined, 18);
    clean.ckpt_every = 6;
    clean.ckpt_dir = Some(fresh_path("panic_ckpts_clean"));
    let (cres, cparams) = run_saving(&mut clean, "panic_clean");

    assert_eq!(fres.restarts, 1, "exactly one recovery");
    assert!(!fres.degraded);
    assert_eq!(fres.recorder.train, cres.recorder.train, "loss curve must be bitwise identical");
    assert_eq!(fres.final_accuracy, cres.final_accuracy);
    assert_params_eq(&fparams, &cparams);
    std::fs::remove_dir_all(faulted.ckpt_dir.unwrap()).ok();
    std::fs::remove_dir_all(clean.ckpt_dir.unwrap()).ok();
}

#[test]
fn sequential_recovery_bitwise_equals_uninterrupted() {
    // Single-occupancy variant of the same claim: stage 2 runs 8 ops
    // per 4-feed segment, so op 10 fails the second segment.
    let mut faulted = rc4(Mode::Sequential, 12);
    faulted.ckpt_every = 4;
    faulted.ckpt_dir = Some(fresh_path("seq_ckpts"));
    faulted.on_failure = OnFailure::Restart;
    faulted.fault_plan = Some("panic@2:10".to_string());
    let (fres, fparams) = run_saving(&mut faulted, "seq_faulted");

    let mut clean = rc4(Mode::Sequential, 12);
    clean.ckpt_every = 4;
    clean.ckpt_dir = Some(fresh_path("seq_ckpts_clean"));
    let (cres, cparams) = run_saving(&mut clean, "seq_clean");

    assert_eq!(fres.restarts, 1);
    assert_eq!(fres.recorder.train, cres.recorder.train);
    assert_params_eq(&fparams, &cparams);
    std::fs::remove_dir_all(faulted.ckpt_dir.unwrap()).ok();
    std::fs::remove_dir_all(clean.ckpt_dir.unwrap()).ok();
}

#[test]
fn degrade_finishes_single_occupancy_bitwise_equal_to_sequential() {
    // Two panics on stage 1 against a budget of one: attempt 1 dies at
    // op 4, attempt 2 dies at op 5 (counters persist across restarts),
    // and the supervisor degrades. With no checkpoint store the whole
    // run then re-runs single-occupancy from scratch — which must be
    // bitwise the plain sequential run.
    let mut faulted = rc4(Mode::Pipelined, 10);
    faulted.on_failure = OnFailure::Degrade;
    faulted.max_restarts = 1;
    faulted.fault_plan = Some("panic@1:4;panic@1:5".to_string());
    let (fres, fparams) = run_saving(&mut faulted, "degrade_faulted");

    let mut seq = rc4(Mode::Sequential, 10);
    let (sres, sparams) = run_saving(&mut seq, "degrade_seq");

    assert!(fres.degraded, "budget exhaustion must degrade");
    assert_eq!(fres.restarts, 2);
    assert_eq!(fres.recorder.train, sres.recorder.train);
    assert_eq!(fres.final_accuracy, sres.final_accuracy);
    assert_params_eq(&fparams, &sparams);
}

#[test]
fn restart_budget_exhaustion_fails_without_degrade() {
    // Same double fault, but under `restart` the second budget overrun
    // must surface as an error, not a degraded completion.
    let mut rc = rc4(Mode::Pipelined, 10);
    rc.on_failure = OnFailure::Restart;
    rc.max_restarts = 1;
    rc.fault_plan = Some("panic@1:4;panic@1:5".to_string());
    let err = pipestale::train::run(&rc).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("retry budget"), "unexpected error: {msg}");
}

// ---------------------------------------------------------------------------
// Watchdog: hung vs slow stages.
// ---------------------------------------------------------------------------

#[test]
fn stall_beyond_watchdog_fails_fast_under_fail_policy() {
    let mut rc = rc4(Mode::Pipelined, 8);
    rc.stall_timeout_ms = 300;
    rc.fault_plan = Some("stall@2:6:3000".to_string());
    let err = pipestale::train::run(&rc).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("hung"), "watchdog must flag the hung stage: {msg}");
    assert!(msg.contains("stage 2"), "the stalled stage is named: {msg}");
}

#[test]
fn stalled_stage_recovers_under_restart_policy() {
    // The stall fires once; after the watchdog kills the generation,
    // the relaunch runs clean from scratch (no checkpoint store).
    let mut faulted = rc4(Mode::Pipelined, 6);
    faulted.stall_timeout_ms = 200;
    faulted.on_failure = OnFailure::Restart;
    faulted.max_restarts = 2;
    faulted.fault_plan = Some("stall@0:2:1500".to_string());
    let (fres, fparams) = run_saving(&mut faulted, "stall_faulted");

    let mut clean = rc4(Mode::Pipelined, 6);
    let (cres, cparams) = run_saving(&mut clean, "stall_clean");

    assert_eq!(fres.restarts, 1);
    assert_eq!(fres.recorder.train, cres.recorder.train);
    assert_params_eq(&fparams, &cparams);
}

#[test]
fn delay_below_watchdog_is_tolerated_not_flagged() {
    // A slow-but-ticking stage: the watchdog must not fire, the run
    // must not restart, and the delay must not perturb the arithmetic.
    let mut slow = rc4(Mode::Pipelined, 8);
    slow.stall_timeout_ms = 5_000;
    slow.on_failure = OnFailure::Restart;
    slow.fault_plan = Some("delay@1:3:50".to_string());
    let (sres, sparams) = run_saving(&mut slow, "delay_slow");

    let mut clean = rc4(Mode::Pipelined, 8);
    let (cres, cparams) = run_saving(&mut clean, "delay_clean");

    assert_eq!(sres.restarts, 0, "a slow stage is not a failure");
    assert!(!sres.degraded);
    assert_eq!(sres.recorder.train, cres.recorder.train);
    assert_params_eq(&sparams, &cparams);
}

// ---------------------------------------------------------------------------
// Corruption: detected, skipped, healed.
// ---------------------------------------------------------------------------

#[test]
fn corrupt_newest_checkpoint_skipped_on_dir_restore() {
    // Run A leaves rotating checkpoints at iters 3 and 6. Damaging the
    // newest one by hand simulates a torn write that slipped past
    // rename (e.g. media corruption); a rerun over the same store must
    // skip it (trailing checksum), restore iter 3, replay 3..9, and
    // land bitwise where run A did.
    let dir = fresh_path("skip_ckpts");
    let mut a = rc4(Mode::Sequential, 9);
    a.ckpt_every = 3;
    a.ckpt_dir = Some(dir.clone());
    let (ares, aparams) = run_saving(&mut a, "skip_a");
    assert_eq!(ares.recorder.train.len(), 9);

    let store = CheckpointStore::open(&dir, 3).unwrap();
    let iters: Vec<u64> = store.list().iter().map(|(i, _)| *i).collect();
    assert_eq!(iters, vec![3, 6]);
    let newest = store.path_for(6);
    let mut bytes = std::fs::read(&newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&newest, &bytes).unwrap();

    let mut b = rc4(Mode::Sequential, 9);
    b.ckpt_every = 3;
    b.ckpt_dir = Some(dir.clone());
    let (bres, bparams) = run_saving(&mut b, "skip_b");

    // Only iters 3..9 re-ran, and they match run A's tail exactly.
    assert_eq!(bres.recorder.train.len(), 6);
    assert_eq!(bres.recorder.train[..], ares.recorder.train[3..]);
    assert_params_eq(&bparams, &aparams);
    // The rerun re-saved iter 6 over the damaged file, healing the
    // store: the newest checkpoint is valid again.
    let healed = store.newest_valid(None).expect("a valid checkpoint must exist");
    assert_eq!(healed.1, 6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn injected_corruption_falls_back_past_damaged_checkpoint() {
    // `corrupt@0` damages the very first save (iter 3); the later panic
    // then forces a restore that finds no valid checkpoint at all and
    // correctly falls back to scratch — completing bitwise equal to the
    // clean segmented run, with the re-saved iter-3 checkpoint valid.
    let mut faulted = rc4(Mode::Sequential, 9);
    faulted.ckpt_every = 3;
    faulted.ckpt_dir = Some(fresh_path("heal_ckpts"));
    faulted.on_failure = OnFailure::Restart;
    faulted.fault_plan = Some("corrupt@0;panic@0:10".to_string());
    let (fres, fparams) = run_saving(&mut faulted, "heal_faulted");

    let mut clean = rc4(Mode::Sequential, 9);
    clean.ckpt_every = 3;
    clean.ckpt_dir = Some(fresh_path("heal_ckpts_clean"));
    let (cres, cparams) = run_saving(&mut clean, "heal_clean");

    assert_eq!(fres.restarts, 1);
    assert_eq!(fres.recorder.train, cres.recorder.train);
    assert_params_eq(&fparams, &cparams);
    let store = CheckpointStore::open(faulted.ckpt_dir.as_ref().unwrap(), 3).unwrap();
    assert!(store.newest_valid(None).is_some(), "the store must heal after the rerun");
    std::fs::remove_dir_all(faulted.ckpt_dir.unwrap()).ok();
    std::fs::remove_dir_all(clean.ckpt_dir.unwrap()).ok();
}

// ---------------------------------------------------------------------------
// Scheduler-runtime periodic checkpoints + flag guards.
// ---------------------------------------------------------------------------

#[test]
fn scheduler_periodic_checkpoints_rotate_and_dir_resume_skips_truncated() {
    let dir = fresh_path("sched_ckpts");
    let mut rc = RunConfig::new("native_lenet_small");
    rc.backend = Backend::Native;
    rc.runtime = RuntimeKind::Scheduler;
    rc.mode = Mode::Sequential;
    rc.iters = 10;
    rc.train_size = 256;
    rc.test_size = 48;
    rc.noise = 0.8;
    rc.ckpt_every = 2;
    rc.ckpt_keep = 2;
    rc.ckpt_dir = Some(dir.clone());
    pipestale::train::run(&rc).unwrap();

    // Saves happened at 2,4,6,8; rotation keeps the newest two.
    let store = CheckpointStore::open(&dir, 2).unwrap();
    let iters: Vec<u64> = store.list().iter().map(|(i, _)| *i).collect();
    assert_eq!(iters, vec![6, 8]);

    // Truncate the newest: dir-resume must fall back to iter 6.
    let newest = store.path_for(8);
    let bytes = std::fs::read(&newest).unwrap();
    std::fs::write(&newest, &bytes[..bytes.len() / 3]).unwrap();
    let (restored, at) = store.newest_valid(None).expect("iter 6 is still valid");
    assert_eq!(at, 6);
    let meta = native_config("native_lenet_small").unwrap();
    checkpoint::validate(&restored, &meta).unwrap();

    // And the train driver takes the same path through --resume-from.
    let mut resumed = RunConfig::new("native_lenet_small");
    resumed.backend = Backend::Native;
    resumed.runtime = RuntimeKind::Scheduler;
    resumed.mode = Mode::Sequential;
    resumed.iters = 2;
    resumed.train_size = 256;
    resumed.test_size = 48;
    resumed.noise = 0.8;
    resumed.resume_from = Some(dir.clone());
    pipestale::train::run(&resumed).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fault_tolerance_flags_are_guarded() {
    // Fault injection and supervision are threaded-runtime features.
    let mut rc = RunConfig::new("native_lenet_small");
    rc.backend = Backend::Native;
    rc.runtime = RuntimeKind::Scheduler;
    rc.iters = 2;
    rc.fault_plan = Some("panic@0:0".to_string());
    let msg = format!("{:#}", pipestale::train::run(&rc).unwrap_err());
    assert!(msg.contains("threaded"), "{msg}");

    rc.fault_plan = None;
    rc.on_failure = OnFailure::Restart;
    let msg = format!("{:#}", pipestale::train::run(&rc).unwrap_err());
    assert!(msg.contains("threaded"), "{msg}");

    // Periodic checkpoints need somewhere to go.
    rc.on_failure = OnFailure::Fail;
    rc.ckpt_every = 5;
    let msg = format!("{:#}", pipestale::train::run(&rc).unwrap_err());
    assert!(msg.contains("ckpt-dir"), "{msg}");

    // A malformed plan is rejected up front, not mid-run.
    let mut rc = rc4(Mode::Pipelined, 2);
    rc.fault_plan = Some("frobnicate@1:2".to_string());
    let msg = format!("{:#}", pipestale::train::run(&rc).unwrap_err());
    assert!(msg.contains("fault"), "{msg}");
}

// ---------------------------------------------------------------------------
// train_range: the replay primitive under the supervisor.
// ---------------------------------------------------------------------------

#[test]
fn train_range_feeds_absolute_batch_ids() {
    use pipestale::optim::Sgd;
    use pipestale::pipeline::ThreadedPipeline;

    let meta = native_config("native_lenet_small").unwrap();
    let spec = SyntheticSpec { train: 128, test: 32, noise: 0.8, seed: 7 };
    let (train, _) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let idxs: Vec<usize> = (0..meta.batch).collect();
    let batch = train.gather(&idxs);

    let params = ModelParams::init(&meta.partitions, 11).unwrap();
    let optims: Vec<Sgd> = pipestale::train::build_optims(&meta, 6, 1.0);
    let mut pipe = ThreadedPipeline::launch_native(&meta, params, optims).unwrap();
    let mut fed_ids = Vec::new();
    let (events, _) = pipe
        .train_range(3, 6, 11, |b| {
            fed_ids.push(b);
            Ok(batch.clone())
        })
        .unwrap();
    pipe.shutdown().unwrap();

    assert_eq!(fed_ids, vec![3, 4, 5], "the feed closure sees absolute ids");
    let got: Vec<u64> = events.iter().map(|e| e.batch_id).collect();
    assert_eq!(got, vec![3, 4, 5], "events carry the absolute ids too");
}
