//! Profile-guided auto-partitioner test suite (DESIGN.md §10):
//!
//! * the bottleneck-minimizing DP in `perfsim::solve_partition` is
//!   *exact* — it matches exhaustive search over every contiguous
//!   partition on small arrays — and deterministic across runs and
//!   across threads, including on tied inputs;
//! * degenerate shapes (P=1, P=num_blocks, P>num_blocks, empty or
//!   non-finite costs) behave or error cleanly;
//! * `profile::auto_native_meta` synthesizes a *valid* native
//!   partition contract: cuts snap to block edges, every partition's
//!   op list builds, and the predicted bottleneck is never worse than
//!   the hand-tabulated manifest PPV's;
//! * `--partition auto` training is bitwise deterministic run-to-run
//!   on both runtimes, the two runtimes agree with each other, and an
//!   auto-partitioned pipeline is event-for-event bitwise identical to
//!   a manual pipeline built from the same PPV (auto changes *where
//!   the cuts go*, never the arithmetic);
//! * the threaded runtime's per-stage busy counters — the emergent
//!   side of the predicted-vs-emergent contract — cover every stage.

use pipestale::backend::{native_config, native_config_with_ppv, partition_nodes};
use pipestale::config::{Backend, Mode, PartitionMode, RunConfig, RuntimeKind};
use pipestale::data::{load_or_synthesize, Batcher, SyntheticSpec};
use pipestale::meta::ConfigMeta;
use pipestale::model::ModelParams;
use pipestale::pipeline::perfsim::{solve_partition, stage_costs_of};
use pipestale::pipeline::{ThreadedPipeline, TrainEvent};
use pipestale::profile::{auto_native_meta, CostProfile, REFERENCE_FLOPS_PER_S};
use pipestale::tensor::{IntTensor, Tensor};

// ---------------------------------------------------------------------------
// Solver: exactness, determinism, degenerate shapes.
// ---------------------------------------------------------------------------

/// Deterministic small-integer costs (exact as f64, so brute-force and
/// DP segment sums are bit-identical and comparable with `==`).
fn lcg_costs(n: usize, seed: u64) -> Vec<f64> {
    let mut state = seed;
    (0..n)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) % 17) as f64
        })
        .collect()
}

/// Minimal bottleneck over *every* contiguous p-way partition, by
/// exhaustive enumeration of cut sets (n <= 8 keeps this tiny).
fn brute_force_bottleneck(costs: &[f64], p: usize) -> f64 {
    let n = costs.len();
    let mut prefix = vec![0.0f64; n + 1];
    for (i, c) in costs.iter().enumerate() {
        prefix[i + 1] = prefix[i] + c;
    }
    let mut best = f64::INFINITY;
    // Each bit b of `mask` = a cut after block b+1.
    for mask in 0u32..(1 << (n - 1)) {
        if mask.count_ones() as usize != p - 1 {
            continue;
        }
        let mut bounds = vec![0usize];
        for b in 0..n - 1 {
            if mask & (1 << b) != 0 {
                bounds.push(b + 1);
            }
        }
        bounds.push(n);
        let bottleneck = bounds
            .windows(2)
            .map(|w| prefix[w[1]] - prefix[w[0]])
            .fold(0.0f64, f64::max);
        if bottleneck < best {
            best = bottleneck;
        }
    }
    best
}

#[test]
fn solver_matches_exhaustive_search_on_small_arrays() {
    for n in 1..=8usize {
        for variant in 0..4u64 {
            let costs = lcg_costs(n, 0x9e37_79b9 ^ ((n as u64) << 8) ^ variant);
            for p in 1..=n {
                let sol = solve_partition(&costs, p).unwrap();
                let best = brute_force_bottleneck(&costs, p);
                assert_eq!(
                    sol.bottleneck, best,
                    "n={n} p={p} costs={costs:?}: DP bottleneck must equal exhaustive search"
                );
                // The returned PPV must itself realize that bottleneck.
                assert_eq!(sol.ppv.len(), p - 1);
                assert!(sol.ppv.windows(2).all(|w| w[0] < w[1]), "ppv {:?}", sol.ppv);
                assert!(sol.ppv.iter().all(|&c| c >= 1 && c < n), "ppv {:?}", sol.ppv);
                let stages = stage_costs_of(&costs, &sol.ppv);
                assert_eq!(stages, sol.stage_costs);
                assert_eq!(stages.iter().cloned().fold(0.0f64, f64::max), best);
            }
        }
    }
}

#[test]
fn solver_is_deterministic_across_runs_and_threads() {
    // Tied inputs are where a sloppy tie-break would wander: every cut
    // placement of an all-equal array at p=3 has several optima.
    let tied: Vec<f64> = vec![2.0; 9];
    let mixed = vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0];
    for costs in [tied, mixed] {
        let reference = solve_partition(&costs, 3).unwrap();
        for _ in 0..10 {
            assert_eq!(solve_partition(&costs, 3).unwrap(), reference, "run-to-run drift");
        }
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let c = costs.clone();
                std::thread::spawn(move || solve_partition(&c, 3).unwrap())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), reference, "cross-thread drift");
        }
    }
}

#[test]
fn solver_degenerate_shapes_behave_and_error_cleanly() {
    let costs = [3.0, 1.0, 2.0, 2.0];
    // P=1: no cuts, bottleneck is the whole model, speedup 1.
    let whole = solve_partition(&costs, 1).unwrap();
    assert!(whole.ppv.is_empty());
    assert_eq!(whole.bottleneck, 8.0);
    assert_eq!(whole.predicted_speedup, 1.0);
    // P=num_blocks: every block its own stage.
    let each = solve_partition(&costs, 4).unwrap();
    assert_eq!(each.ppv, vec![1, 2, 3]);
    assert_eq!(each.bottleneck, 3.0);
    // P>num_blocks, P=0, empty and non-finite inputs all error.
    assert!(solve_partition(&costs, 5).is_err());
    assert!(solve_partition(&costs, 0).is_err());
    assert!(solve_partition(&[], 1).is_err());
    assert!(solve_partition(&[1.0, f64::NAN], 1).is_err());
    assert!(solve_partition(&[1.0, -1.0], 1).is_err());
    // And through the profile API: more stages than model blocks.
    let meta = native_config("native_lenet_small").unwrap();
    let prof = CostProfile::analytic(&meta, REFERENCE_FLOPS_PER_S).unwrap();
    assert!(prof.solve(meta.num_layers + 1).is_err());
}

// ---------------------------------------------------------------------------
// Auto-partitioned metas: valid contracts, no worse than the manifest.
// ---------------------------------------------------------------------------

#[test]
fn auto_meta_snaps_to_block_edges_and_builds_every_partition() {
    for config in ["native_resnet20_4s", "native_resnet_small_4s", "native_lenet_small_4s"] {
        let manual = native_config(config).unwrap();
        let (meta, sol) = auto_native_meta(config).unwrap();
        assert_eq!(meta.partitions.len(), manual.partitions.len(), "{config}: stage count");
        assert_eq!(meta.ppv, sol.ppv, "{config}: meta must carry the solver's PPV");
        assert!(meta.ppv.windows(2).all(|w| w[0] < w[1]), "{config}: {:?}", meta.ppv);
        assert!(
            meta.ppv.iter().all(|&c| c >= 1 && c < meta.num_layers),
            "{config}: cuts {:?} must be block edges in 1..{}",
            meta.ppv,
            meta.num_layers
        );
        // Partitions tile 1..=num_layers contiguously and every op
        // list builds against the model graph.
        let mut next_lo = 1;
        for pm in &meta.partitions {
            assert_eq!(pm.layer_lo, next_lo, "{config}: partition {} range", pm.index);
            assert!(pm.layer_hi >= pm.layer_lo);
            next_lo = pm.layer_hi + 1;
            // partition_nodes itself cross-checks the op stack against
            // the recorded param/state contract — success IS the test.
            let nodes = partition_nodes(&meta, pm).unwrap();
            assert!(!nodes.is_empty(), "{config}: partition {} has no ops", pm.index);
        }
        assert_eq!(next_lo, meta.num_layers + 1, "{config}: partitions must cover the model");
    }
}

#[test]
fn auto_predicted_bottleneck_no_worse_than_manifest_ppv() {
    for config in ["native_resnet20_4s", "native_lenet_small_4s", "lenet5_8s"] {
        let manual = native_config(config).unwrap();
        let prof = CostProfile::analytic(&manual, REFERENCE_FLOPS_PER_S).unwrap();
        let (_, sol) = auto_native_meta(config).unwrap();
        let manual_bottleneck = stage_costs_of(&prof.block_totals(), &manual.ppv)
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        assert!(
            sol.bottleneck <= manual_bottleneck + 1e-12,
            "{config}: auto bottleneck {} must be <= manual {}",
            sol.bottleneck,
            manual_bottleneck
        );
    }
}

// ---------------------------------------------------------------------------
// --partition auto end to end: determinism on both runtimes.
// ---------------------------------------------------------------------------

fn auto_rc(runtime: RuntimeKind, iters: u64) -> RunConfig {
    let mut rc = RunConfig::new("native_lenet_small_4s");
    rc.backend = Backend::Native;
    rc.runtime = runtime;
    rc.mode = Mode::Pipelined;
    rc.partition = PartitionMode::Auto;
    rc.iters = iters;
    rc.train_size = 256;
    rc.test_size = 48;
    rc.noise = 0.8;
    rc
}

#[test]
fn auto_partition_training_is_bitwise_deterministic_on_both_runtimes() {
    let mut per_runtime = Vec::new();
    for runtime in [RuntimeKind::Scheduler, RuntimeKind::Threaded] {
        let a = pipestale::train::run(&auto_rc(runtime, 16)).unwrap();
        let b = pipestale::train::run(&auto_rc(runtime, 16)).unwrap();
        assert_eq!(
            a.recorder.train,
            b.recorder.train,
            "{}: --partition auto must be bitwise repeatable",
            runtime.name()
        );
        assert_eq!(
            a.final_accuracy.to_bits(),
            b.final_accuracy.to_bits(),
            "{}: final accuracy must be bitwise repeatable",
            runtime.name()
        );
        per_runtime.push(a);
    }
    // The auto partition is resolved before either runtime starts, so
    // the cross-runtime bitwise-equivalence guarantee carries over.
    assert_eq!(
        per_runtime[0].recorder.train, per_runtime[1].recorder.train,
        "scheduler and threaded runtimes must agree under --partition auto"
    );
    assert_eq!(per_runtime[0].final_accuracy.to_bits(), per_runtime[1].final_accuracy.to_bits());
}

// ---------------------------------------------------------------------------
// Auto meta == manual meta at the same PPV, event for event.
// ---------------------------------------------------------------------------

fn threaded_events(meta: &ConfigMeta, batches: &[(Tensor, IntTensor)]) -> Vec<TrainEvent> {
    let params = ModelParams::init(&meta.partitions, 11).unwrap();
    let optims = pipestale::train::build_optims(meta, batches.len() as u64, 1.0);
    let mut pipe = ThreadedPipeline::launch_native(meta, params, optims).unwrap();
    let (events, _) =
        pipe.train(batches.len() as u64, 11, |b| Ok(batches[b as usize].clone())).unwrap();
    pipe.shutdown().unwrap();
    events
}

#[test]
fn auto_meta_matches_manual_twin_event_for_event() {
    let config = "native_resnet20_4s";
    let (auto_meta, sol) = auto_native_meta(config).unwrap();
    let twin = native_config_with_ppv(config, Some(&sol.ppv)).unwrap();
    let spec = SyntheticSpec { train: 96, test: 16, noise: 0.8, seed: 5 };
    let (train_ds, _) = load_or_synthesize(&auto_meta.dataset, None, &spec).unwrap();
    let mut batcher = Batcher::new(train_ds.len(), auto_meta.batch, 5);
    let batches: Vec<(Tensor, IntTensor)> =
        (0..10).map(|_| train_ds.gather(&batcher.next_indices().to_vec())).collect();
    let a = threaded_events(&auto_meta, &batches);
    let b = threaded_events(&twin, &batches);
    assert_eq!(a.len(), batches.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.batch_id, y.batch_id);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "batch {}: loss", x.batch_id);
        assert_eq!(x.correct.to_bits(), y.correct.to_bits(), "batch {}: correct", x.batch_id);
    }
}

// ---------------------------------------------------------------------------
// Emergent busy counters.
// ---------------------------------------------------------------------------

#[test]
fn stage_busy_seconds_cover_every_stage() {
    let meta = native_config("native_lenet_small_4s").unwrap();
    let spec = SyntheticSpec { train: 96, test: 16, noise: 0.8, seed: 9 };
    let (train_ds, _) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let mut batcher = Batcher::new(train_ds.len(), meta.batch, 9);
    let params = ModelParams::init(&meta.partitions, 9).unwrap();
    let optims = pipestale::train::build_optims(&meta, 8, 1.0);
    let mut pipe = ThreadedPipeline::launch_native(&meta, params, optims).unwrap();
    let (events, _) =
        pipe.train(8, 9, |_| Ok(train_ds.gather(&batcher.next_indices().to_vec()))).unwrap();
    assert_eq!(events.len(), 8);
    let busy = pipe.stage_busy_seconds();
    pipe.shutdown().unwrap();
    assert_eq!(busy.len(), meta.partitions.len());
    for (i, b) in busy.iter().enumerate() {
        assert!(b.is_finite() && *b > 0.0, "stage {i}: busy {b} must be positive");
    }
}
