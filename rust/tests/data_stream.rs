//! Determinism battery for the streaming ingestion subsystem
//! (DESIGN.md §11), run against real-format fixture files on disk:
//!
//! * prefetching with 1/2/4 worker threads is **bitwise identical** to
//!   synchronous iteration, on both the LeNet (MNIST IDX) and ResNet
//!   (CIFAR-10 binary) fixtures, with augmentation on;
//! * a stream resumed mid-epoch with `start = n` replays the
//!   interrupted stream bitwise, including augmentation draws across
//!   an epoch boundary — the contract checkpoint-restart leans on;
//! * a worker panic mid-run under `--on-failure restart` with real
//!   files, augmentation, and prefetch recovers to a loss curve and
//!   final weights bitwise equal to the unfaulted run;
//! * the steady-state ingest path is zero-alloc: once warm, neither
//!   the caller's pool nor any prefetch worker's pool sees a fresh
//!   heap allocation (merged `PoolStats` delta);
//! * e2e smoke: training on a generated fixture dataset learns (loss
//!   falls) and the scheduler and threaded runtimes produce bitwise
//!   identical final weights.

use std::path::PathBuf;
use std::sync::Arc;

use pipestale::config::{Backend, Mode, OnFailure, RunConfig, RuntimeKind};
use pipestale::data::fixtures;
use pipestale::data::{
    load_cifar10_dir_stream, load_mnist_stream, Augment, BatchStream, StreamDataset, StreamOptions,
};
use pipestale::model::checkpoint;
use pipestale::model::ModelParams;
use pipestale::pool::{PoolScope, PoolStats};
use pipestale::train::TrainResult;

/// Fresh per-test scratch path (removed first: earlier aborted runs of
/// the same pid must not leak files into this one).
fn fresh_path(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("dstream_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    std::fs::remove_file(&p).ok();
    p
}

/// Write a fixture dataset and load its train split back through the
/// real on-disk format (IDX or CIFAR binary), so every test below
/// exercises the raw-byte decode paths, not the synthetic wrapper.
fn fixture_stream(dataset: &str, name: &str, train: usize, test: usize) -> Arc<StreamDataset> {
    let dir = fresh_path(name);
    fixtures::write_fixture(dataset, &dir, train, test, 11).unwrap();
    let ds = match dataset {
        "mnist" => load_mnist_stream(
            &dir.join("train-images-idx3-ubyte"),
            &dir.join("train-labels-idx1-ubyte"),
            "mnist-train",
        )
        .unwrap(),
        _ => load_cifar10_dir_stream(&dir).unwrap().0,
    };
    std::fs::remove_dir_all(&dir).ok();
    Arc::new(ds)
}

/// Run to completion and read back the final weights via `--save-to`
/// (the checkpoint file is the bitwise ground truth for comparisons).
fn run_saving(rc: &mut RunConfig, tag: &str) -> (TrainResult, ModelParams) {
    let out = fresh_path(&format!("{tag}_final"));
    rc.save_to = Some(out.clone());
    let res = pipestale::train::run(rc).unwrap();
    let (params, at) = checkpoint::load(&out).unwrap();
    assert_eq!(at, rc.iters);
    std::fs::remove_file(&out).ok();
    (res, params)
}

fn assert_params_eq(a: &ModelParams, b: &ModelParams) {
    assert_eq!(a.partitions.len(), b.partitions.len());
    for (i, (x, y)) in a.partitions.iter().zip(&b.partitions).enumerate() {
        assert_eq!(x.version, y.version, "partition {i}: update count must match");
        for (j, (t, u)) in x.params.iter().zip(&y.params).enumerate() {
            assert_eq!(t.data(), u.data(), "partition {i} param {j} must be bitwise equal");
        }
        for (j, (t, u)) in x.state.iter().zip(&y.state).enumerate() {
            assert_eq!(t.data(), u.data(), "partition {i} state {j} must be bitwise equal");
        }
    }
}

// ---------------------------------------------------------------------------
// Prefetch thread count is a pure perf axis: bitwise-identical output.
// ---------------------------------------------------------------------------

#[test]
fn prefetch_1_2_4_threads_bitwise_equal_sync_on_both_fixtures() {
    for dataset in ["mnist", "cifar10"] {
        let ds = fixture_stream(dataset, &format!("sweep_{dataset}"), 48, 16);
        let mut opts = StreamOptions::plain(8, 13, 77);
        opts.augment = Augment::standard(dataset);
        for threads in [1usize, 2, 4] {
            let mut o = opts.clone();
            o.threads = threads;
            let mut pre = BatchStream::new(Arc::clone(&ds), o).unwrap();
            let mut sync = BatchStream::new(Arc::clone(&ds), opts.clone()).unwrap();
            // 48/8 = 6 batches/epoch; 15 batches cross two reshuffles.
            for b in 0..15 {
                let (sx, sy) = sync.next_batch().unwrap();
                let (px, py) = pre.next_batch().unwrap();
                assert_eq!(
                    sx.data(),
                    px.data(),
                    "{dataset}: prefetch({threads}) batch {b} diverged from sync"
                );
                assert_eq!(sy.data, py.data, "{dataset}: labels diverged at batch {b}");
            }
            assert_eq!(pre.worker_pool_stats().len(), threads);
            assert_eq!(sync.batches_per_epoch(), pre.batches_per_epoch());
        }
    }
}

#[test]
fn midepoch_resume_replays_the_stream_bitwise_on_both_fixtures() {
    for dataset in ["mnist", "cifar10"] {
        let ds = fixture_stream(dataset, &format!("resume_{dataset}"), 40, 8);
        let mut opts = StreamOptions::plain(8, 5, 21);
        opts.augment = Augment::standard(dataset);
        let mut full = BatchStream::new(Arc::clone(&ds), opts.clone()).unwrap();
        // 40/8 = 5 batches/epoch: skipping 7 crosses the reshuffle and
        // the per-epoch augmentation reseed.
        for _ in 0..7 {
            full.next_batch().unwrap();
        }
        for threads in [2usize, 4] {
            let mut o = opts.clone();
            o.start = 7;
            o.threads = threads;
            let mut resumed = BatchStream::new(Arc::clone(&ds), o).unwrap();
            let mut replay = opts.clone();
            replay.start = 7;
            let mut replay = BatchStream::new(Arc::clone(&ds), replay).unwrap();
            for b in 0..5 {
                let (ax, ay) = replay.next_batch().unwrap();
                let (bx, by) = resumed.next_batch().unwrap();
                assert_eq!(ax.data(), bx.data(), "{dataset}: resume({threads}) batch {b}");
                assert_eq!(ay.data, by.data);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Steady-state ingest is zero-alloc once the pools are warm.
// ---------------------------------------------------------------------------

#[test]
fn sync_streaming_is_zero_alloc_once_warm() {
    let ds = fixture_stream("cifar10", "zalloc_sync", 48, 8);
    let scope = PoolScope::new();
    let mut opts = StreamOptions::plain(8, 3, 9);
    opts.augment = Augment::standard("cifar10");
    let mut stream = BatchStream::new(ds, opts).unwrap();
    for _ in 0..6 {
        stream.next_batch().unwrap();
    }
    let before = scope.pool().stats();
    for _ in 0..12 {
        stream.next_batch().unwrap();
    }
    let d = scope.pool().stats().delta(&before);
    assert_eq!(d.fresh_allocs, 0, "steady-state sync decode must not allocate: {d:?}");
    assert!(d.reuses > 0, "the probe must actually exercise the pool: {d:?}");
}

#[test]
fn prefetch_streaming_is_zero_alloc_once_warm_across_workers() {
    let ds = fixture_stream("cifar10", "zalloc_pre", 48, 8);
    let scope = PoolScope::new();
    let mut opts = StreamOptions::plain(8, 3, 9);
    opts.augment = Augment::standard("cifar10");
    opts.threads = 2;
    let mut stream = BatchStream::new(ds, opts).unwrap();
    // Warm every worker's shelves: depth (= 2*threads) batches may be
    // in flight, so each worker needs a few cycles to stop allocating.
    for _ in 0..16 {
        stream.next_batch().unwrap();
    }
    let merged = |stream: &BatchStream, scope: &PoolScope| {
        stream
            .worker_pool_stats()
            .iter()
            .fold(scope.pool().stats(), |acc, s| acc.merge(s))
    };
    let before = merged(&stream, &scope);
    for _ in 0..16 {
        stream.next_batch().unwrap();
    }
    let d = merged(&stream, &scope).delta(&before);
    assert_eq!(
        d.fresh_allocs, 0,
        "steady-state prefetch decode must not allocate on any worker: {d:?}"
    );
    assert!(d.reuses > 0, "the probe must actually exercise the pools: {d:?}");
}

// ---------------------------------------------------------------------------
// Train-level: real files + augment + prefetch through both runtimes.
// ---------------------------------------------------------------------------

/// A P=4 run config over a fixture dataset directory, with the full
/// streaming data plane on (augmentation + 2 prefetch workers).
fn rc_stream(config: &str, iters: u64, data_dir: &std::path::Path) -> RunConfig {
    let mut rc = RunConfig::new(config);
    rc.backend = Backend::Native;
    rc.runtime = RuntimeKind::Threaded;
    rc.mode = Mode::Pipelined;
    rc.iters = iters;
    rc.data_dir = Some(data_dir.to_path_buf());
    rc.augment = true;
    rc.prefetch = 2;
    rc.stall_timeout_ms = 30_000;
    rc.restart_backoff_ms = 1;
    rc
}

#[test]
fn e2e_smoke_fixture_training_learns_and_runtimes_agree_bitwise() {
    let dir = fresh_path("e2e_mnist");
    fixtures::write_mnist_fixture(&dir, 256, 64, 3).unwrap();

    let mut threaded = rc_stream("native_lenet_small_4s", 200, &dir);
    let (tres, tparams) = run_saving(&mut threaded, "e2e_threaded");

    let mut scheduler = rc_stream("native_lenet_small_4s", 200, &dir);
    scheduler.runtime = RuntimeKind::Scheduler;
    scheduler.prefetch = 0; // the scheduler feeds synchronously
    let (sres, sparams) = run_saving(&mut scheduler, "e2e_scheduler");

    // Same data plane, same seeds: the runtimes must agree bitwise.
    assert_params_eq(&tparams, &sparams);
    assert_eq!(tres.final_accuracy, sres.final_accuracy);

    // And the run must actually learn on the fixture set: mean loss
    // over the first 20 iterations vs the last 20.
    let losses: Vec<f32> = tres.recorder.train.iter().map(|&(_, l, _)| l).collect();
    assert!(losses.len() >= 40, "expected a full loss curve, got {}", losses.len());
    let head: f32 = losses[..20].iter().sum::<f32>() / 20.0;
    let tail: f32 = losses[losses.len() - 20..].iter().sum::<f32>() / 20.0;
    assert!(
        tail < head,
        "loss must fall on the fixture dataset (first-20 mean {head}, last-20 mean {tail})"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resnet_cifar_fixture_runtimes_agree_bitwise() {
    // The ResNet leg of the acceptance box: CIFAR fixture files through
    // the record-decode path, augmentation + prefetch on, both runtimes.
    let dir = fresh_path("e2e_cifar");
    fixtures::write_cifar_fixture(&dir, 64, 16, 7).unwrap();

    let mut threaded = rc_stream("native_resnet_small_4s", 12, &dir);
    let (tres, tparams) = run_saving(&mut threaded, "resnet_threaded");

    let mut scheduler = rc_stream("native_resnet_small_4s", 12, &dir);
    scheduler.runtime = RuntimeKind::Scheduler;
    scheduler.prefetch = 0;
    let (sres, sparams) = run_saving(&mut scheduler, "resnet_scheduler");

    assert_params_eq(&tparams, &sparams);
    assert_eq!(tres.final_accuracy, sres.final_accuracy);
    assert!(
        tres.recorder.train.iter().all(|&(_, l, _)| l.is_finite()),
        "loss must stay finite on the CIFAR fixture"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn restart_replay_with_streaming_augment_prefetch_is_bitwise_invisible() {
    // Checkpoint-restart over the full data plane: real MNIST files,
    // augmentation, 2 prefetch workers. Stage 1 runs 12 ops per 6-feed
    // segment, so op 16 panics in the second segment — the supervisor
    // restores the iter-6 checkpoint and replays the stream (fresh
    // BatchStream with start=6), which must be bitwise-invisible.
    let dir = fresh_path("restart_mnist");
    fixtures::write_mnist_fixture(&dir, 256, 48, 3).unwrap();

    let mut faulted = rc_stream("native_lenet_small_4s", 18, &dir);
    faulted.ckpt_every = 6;
    faulted.ckpt_dir = Some(fresh_path("restart_ckpts"));
    faulted.on_failure = OnFailure::Restart;
    faulted.fault_plan = Some("panic@1:16".to_string());
    let (fres, fparams) = run_saving(&mut faulted, "stream_faulted");

    let mut clean = rc_stream("native_lenet_small_4s", 18, &dir);
    clean.ckpt_every = 6;
    clean.ckpt_dir = Some(fresh_path("restart_ckpts_clean"));
    let (cres, cparams) = run_saving(&mut clean, "stream_clean");

    assert_eq!(fres.restarts, 1, "exactly one recovery");
    assert!(!fres.degraded);
    assert_eq!(fres.recorder.train, cres.recorder.train, "loss curve must be bitwise identical");
    assert_eq!(fres.final_accuracy, cres.final_accuracy);
    assert_params_eq(&fparams, &cparams);
    std::fs::remove_dir_all(faulted.ckpt_dir.unwrap()).ok();
    std::fs::remove_dir_all(clean.ckpt_dir.unwrap()).ok();
    std::fs::remove_dir_all(&dir).ok();
}
