//! Integration tests over both compute backends.
//!
//! The `*_native` tests run the full training paths — pipelined,
//! sequential, hybrid, checkpointing, evaluation — on the pure-Rust
//! `NativeExecutor` and therefore execute everywhere, with no artifacts
//! and no XLA. The XLA twins of the same scenarios need
//! `make artifacts` + a real PJRT backend and skip gracefully otherwise.

use pipestale::backend::{native_config, NativeExecutor};
use pipestale::config::{Backend, Mode, RunConfig};
use pipestale::data::{batch_seed, load_or_synthesize, Batcher, SyntheticSpec};
use pipestale::meta::ConfigMeta;
use pipestale::model::ModelParams;
use pipestale::pipeline::{Feed, Pipeline, XlaExecutor};
use pipestale::runtime::Runtime;
use pipestale::tensor::Tensor;
use pipestale::util::skip_marker;

fn quick_rc(mode: Mode, iters: u64) -> RunConfig {
    let mut rc = RunConfig::new("quickstart_lenet");
    rc.mode = mode;
    rc.iters = iters;
    rc.train_size = 512;
    rc.test_size = 128;
    rc.noise = 1.2;
    rc
}

#[test]
fn pipelined_training_learns() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    let res = pipestale::train::run(&quick_rc(Mode::Pipelined, 120)).unwrap();
    assert!(res.final_accuracy > 0.5, "acc {}", res.final_accuracy);
    // loss decreased vs the first few batches
    let early: f64 = res.recorder.train[..10]
        .iter()
        .map(|(_, l, _)| *l as f64)
        .sum::<f64>()
        / 10.0;
    assert!(res.final_train_loss < early, "{} vs {early}", res.final_train_loss);
    // every fed batch retired exactly once
    assert_eq!(res.recorder.train.len(), 120);
    let mut ids: Vec<u64> = res.recorder.train.iter().map(|(b, _, _)| *b).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..120).collect::<Vec<_>>());
}

#[test]
fn sequential_training_learns() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    let res = pipestale::train::run(&quick_rc(Mode::Sequential, 80)).unwrap();
    assert!(res.final_accuracy > 0.5, "acc {}", res.final_accuracy);
}

#[test]
fn hybrid_switches_and_learns() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    let mut rc = quick_rc(Mode::Hybrid, 100);
    rc.pipelined_iters = 60;
    let res = pipestale::train::run(&rc).unwrap();
    assert!(res.final_accuracy > 0.5, "acc {}", res.final_accuracy);
    assert_eq!(res.recorder.train.len(), 100);
}

#[test]
fn single_inflight_pipelined_equals_sequential_on_xla() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    // With one batch in flight staleness is zero: cycle+drain must leave
    // the weights bit-identical to sequential_step.
    let root = pipestale::artifacts_root();
    let meta = ConfigMeta::load_named(&root, "quickstart_lenet").unwrap();
    let runtime = Runtime::cpu().unwrap();
    let spec = SyntheticSpec { train: 64, test: 32, noise: 1.0, seed: 5 };
    let (ds, _) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let mut batcher = Batcher::new(ds.len(), meta.batch, 1);
    let idxs = batcher.next_indices().to_vec();
    let (x, labels) = ds.gather(&idxs);

    let mk_pipe = |runtime: &Runtime| {
        let params = ModelParams::init(&meta.partitions, 7).unwrap();
        let optims = pipestale::train::build_optims(&meta, 10, 1.0);
        let exec = XlaExecutor::new(runtime, meta.clone(), params, optims).unwrap();
        Pipeline::new(exec, meta.batch)
    };
    let feed = || Feed {
        batch_id: 0,
        seed: batch_seed(3, 0),
        x: x.clone(),
        labels: labels.clone(),
    };

    let mut a = mk_pipe(&runtime);
    a.sequential_step(feed()).unwrap();
    let mut b = mk_pipe(&runtime);
    b.cycle(Some(feed())).unwrap();
    b.drain().unwrap();

    let pa = a.exec.params_snapshot();
    let pb = b.exec.params_snapshot();
    for (x, y) in pa.partitions.iter().zip(pb.partitions.iter()) {
        for (t, u) in x.params.iter().zip(y.params.iter()) {
            assert_eq!(t.data(), u.data());
        }
        for (t, u) in x.state.iter().zip(y.state.iter()) {
            assert_eq!(t.data(), u.data());
        }
    }
}

#[test]
fn eval_is_deterministic_and_training_changes_weights() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    let root = pipestale::artifacts_root();
    let meta = ConfigMeta::load_named(&root, "quickstart_lenet").unwrap();
    let runtime = Runtime::cpu().unwrap();
    let params = ModelParams::init(&meta.partitions, 9).unwrap();
    let before = params.clone();
    let optims = pipestale::train::build_optims(&meta, 10, 1.0);
    let exec = XlaExecutor::new(&runtime, meta.clone(), params, optims).unwrap();
    let mut pipe = Pipeline::new(exec, meta.batch);

    let spec = SyntheticSpec { train: 64, test: 64, noise: 1.0, seed: 2 };
    let (train_ds, test_ds) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();

    let a1 = pipestale::train::evaluate(&mut pipe, &test_ds, meta.batch).unwrap();
    let a2 = pipestale::train::evaluate(&mut pipe, &test_ds, meta.batch).unwrap();
    assert_eq!(a1, a2, "eval must be deterministic");

    let mut batcher = Batcher::new(train_ds.len(), meta.batch, 3);
    for b in 0..3u64 {
        let idxs = batcher.next_indices().to_vec();
        let (x, labels) = train_ds.gather(&idxs);
        pipe.sequential_step(Feed { batch_id: b, seed: batch_seed(1, b), x, labels }).unwrap();
    }
    let after = pipe.exec.params_snapshot();
    let changed = before
        .partitions
        .iter()
        .zip(after.partitions.iter())
        .any(|(x, y)| x.params.iter().zip(y.params.iter()).any(|(t, u)| t.data() != u.data()));
    assert!(changed, "training must move weights");
    assert!(after.all_finite());
}

#[test]
fn stale_pipelined_diverges_from_sequential_weights() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    // With many batches in flight the pipelined run must NOT be
    // bit-identical to sequential (stale gradients are actually used).
    let mut rc_a = quick_rc(Mode::Pipelined, 30);
    let mut rc_b = quick_rc(Mode::Sequential, 30);
    rc_a.eval_every = 0;
    rc_b.eval_every = 0;
    let a = pipestale::train::run(&rc_a).unwrap();
    let b = pipestale::train::run(&rc_b).unwrap();
    // same data/seed, different schedule: losses at the tail differ
    let la: Vec<f32> = a.recorder.train.iter().rev().take(5).map(|(_, l, _)| *l).collect();
    let lb: Vec<f32> = b.recorder.train.iter().rev().take(5).map(|(_, l, _)| *l).collect();
    assert_ne!(la, lb, "stale weights should alter the trajectory");
}

#[test]
fn threaded_pipeline_trains_and_collects_weights() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    let root = pipestale::artifacts_root();
    let meta = ConfigMeta::load_named(&root, "quickstart_lenet").unwrap();
    let spec = SyntheticSpec { train: 128, test: 64, noise: 1.0, seed: 11 };
    let (train_ds, test_ds) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let params = ModelParams::init(&meta.partitions, 21).unwrap();
    let optims = pipestale::train::build_optims(&meta, 40, 1.0);

    let mut pipe =
        pipestale::pipeline::threaded::ThreadedPipeline::launch(&meta, params, optims).unwrap();
    let mut batcher = Batcher::new(train_ds.len(), meta.batch, 5);
    let (events, _wall) = pipe
        .train(40, 42, |_| {
            let idxs = batcher.next_indices().to_vec();
            Ok(train_ds.gather(&idxs))
        })
        .unwrap();
    assert_eq!(events.len(), 40);
    let trained = pipe.shutdown().unwrap();
    assert!(trained.all_finite());

    // eval the reassembled model
    let runtime = Runtime::cpu().unwrap();
    let optims = pipestale::train::build_optims(&meta, 40, 1.0);
    let exec = XlaExecutor::new(&runtime, meta.clone(), trained, optims).unwrap();
    let mut single = Pipeline::new(exec, meta.batch);
    let acc = pipestale::train::evaluate(&mut single, &test_ds, meta.batch).unwrap();
    assert!(acc > 0.3, "threaded-trained acc {acc}");
}

#[test]
fn multi_tensor_carry_config_runs() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    // resnet20_4s PPV (7) cuts at a block boundary; run a few pipelined
    // iterations to exercise BN state + residual carries end to end.
    let mut rc = RunConfig::new("resnet20_4s");
    rc.mode = Mode::Pipelined;
    rc.iters = 12;
    rc.train_size = 128;
    rc.test_size = 64;
    rc.noise = 1.5;
    let res = pipestale::train::run(&rc).unwrap();
    assert_eq!(res.recorder.train.len(), 12);
    assert!(res.final_train_loss.is_finite());
}

fn _assert_tensor_finite(t: &Tensor) {
    assert!(t.is_finite());
}

#[test]
fn cross_process_hybrid_via_checkpoint() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    // Paper §4 hybrid split across "processes": pipelined prefix saved to
    // a checkpoint, non-pipelined tail resumed from it. The tail must
    // train (loss keeps falling) and end above-chance.
    let ckpt = std::env::temp_dir().join(format!("hybrid_{}.ckpt", std::process::id()));
    let mut prefix = quick_rc(Mode::Pipelined, 60);
    prefix.save_to = Some(ckpt.clone());
    let a = pipestale::train::run(&prefix).unwrap();

    let mut tail = quick_rc(Mode::Sequential, 40);
    tail.resume_from = Some(ckpt.clone());
    let b = pipestale::train::run(&tail).unwrap();
    assert!(b.final_accuracy >= a.final_accuracy - 0.05,
            "tail regressed: {} -> {}", a.final_accuracy, b.final_accuracy);
    assert!(b.final_accuracy > 0.5);
    std::fs::remove_file(&ckpt).ok();
}

// ---------------------------------------------------------------------------
// Native-backend ports: the same paper scenarios, executed unconditionally.
// ---------------------------------------------------------------------------

/// Small native config (narrow LeNet, batch 16) so the suite stays fast.
fn native_rc(mode: Mode, iters: u64) -> RunConfig {
    let mut rc = RunConfig::new("native_lenet_small");
    rc.backend = Backend::Native;
    rc.mode = mode;
    rc.iters = iters;
    rc.train_size = 512;
    rc.test_size = 96;
    rc.noise = 0.8;
    rc
}

#[test]
fn native_pipelined_training_learns() {
    // Run through Backend::Auto: this config has no artifacts, so Auto
    // must resolve to the native executor on every machine — covering
    // the auto-dispatch path end to end.
    let mut rc = native_rc(Mode::Pipelined, 80);
    rc.backend = Backend::Auto;
    let res = pipestale::train::run(&rc).unwrap();
    // loss decreased vs the first few batches (chance-level CE is ln 10)
    let early: f64 =
        res.recorder.train[..10].iter().map(|(_, l, _)| *l as f64).sum::<f64>() / 10.0;
    assert!(
        res.final_train_loss < early,
        "loss did not fall: {} vs {early}",
        res.final_train_loss
    );
    assert!(res.final_accuracy > 0.25, "acc {} (chance 0.1)", res.final_accuracy);
    // every fed batch retired exactly once
    assert_eq!(res.recorder.train.len(), 80);
    let mut ids: Vec<u64> = res.recorder.train.iter().map(|(b, _, _)| *b).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..80).collect::<Vec<_>>());
}

#[test]
fn native_sequential_training_learns() {
    let res = pipestale::train::run(&native_rc(Mode::Sequential, 60)).unwrap();
    let early: f64 =
        res.recorder.train[..10].iter().map(|(_, l, _)| *l as f64).sum::<f64>() / 10.0;
    assert!(res.final_train_loss < early, "{} vs {early}", res.final_train_loss);
    assert!(res.final_accuracy > 0.25, "acc {}", res.final_accuracy);
}

#[test]
fn native_hybrid_switches_and_learns() {
    let mut rc = native_rc(Mode::Hybrid, 60);
    rc.pipelined_iters = 30;
    let res = pipestale::train::run(&rc).unwrap();
    assert_eq!(res.recorder.train.len(), 60);
    let early: f64 =
        res.recorder.train[..10].iter().map(|(_, l, _)| *l as f64).sum::<f64>() / 10.0;
    assert!(res.final_train_loss < early, "{} vs {early}", res.final_train_loss);
    assert!(res.final_train_loss.is_finite());
}

#[test]
fn single_inflight_pipelined_equals_sequential_on_native() {
    // With one batch in flight staleness is zero: cycle+drain must leave
    // the weights bit-identical to sequential_step.
    let meta = native_config("native_lenet_small").unwrap();
    let spec = SyntheticSpec { train: 64, test: 32, noise: 1.0, seed: 5 };
    let (ds, _) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let mut batcher = Batcher::new(ds.len(), meta.batch, 1);
    let idxs = batcher.next_indices().to_vec();
    let (x, labels) = ds.gather(&idxs);

    let mk_pipe = || {
        let params = ModelParams::init(&meta.partitions, 7).unwrap();
        let optims = pipestale::train::build_optims(&meta, 10, 1.0);
        let exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
        Pipeline::new(exec, meta.batch)
    };
    let feed =
        || Feed { batch_id: 0, seed: batch_seed(3, 0), x: x.clone(), labels: labels.clone() };

    let mut a = mk_pipe();
    a.sequential_step(feed()).unwrap();
    let mut b = mk_pipe();
    b.cycle(Some(feed())).unwrap();
    b.drain().unwrap();

    let pa = a.exec.params_snapshot();
    let pb = b.exec.params_snapshot();
    assert_eq!(pa.partitions.len(), pb.partitions.len());
    for (x, y) in pa.partitions.iter().zip(pb.partitions.iter()) {
        for (t, u) in x.params.iter().zip(y.params.iter()) {
            assert_eq!(t.data(), u.data(), "weights must be bit-identical");
        }
        for (t, u) in x.state.iter().zip(y.state.iter()) {
            assert_eq!(t.data(), u.data(), "state must be bit-identical");
        }
    }
}

#[test]
fn stale_pipelined_diverges_from_sequential_weights_native() {
    // With many batches in flight the pipelined run must NOT match
    // sequential bit-for-bit: stale gradients are actually used.
    let a = pipestale::train::run(&native_rc(Mode::Pipelined, 25)).unwrap();
    let b = pipestale::train::run(&native_rc(Mode::Sequential, 25)).unwrap();
    let la: Vec<f32> = a.recorder.train.iter().rev().take(5).map(|(_, l, _)| *l).collect();
    let lb: Vec<f32> = b.recorder.train.iter().rev().take(5).map(|(_, l, _)| *l).collect();
    assert_ne!(la, lb, "stale weights should alter the trajectory");
}

#[test]
fn native_eval_is_deterministic_and_training_changes_weights() {
    let meta = native_config("native_lenet_small").unwrap();
    let params = ModelParams::init(&meta.partitions, 9).unwrap();
    let before = params.clone();
    let optims = pipestale::train::build_optims(&meta, 10, 1.0);
    let exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
    let mut pipe = Pipeline::new(exec, meta.batch);

    let spec = SyntheticSpec { train: 64, test: 64, noise: 1.0, seed: 2 };
    let (train_ds, test_ds) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();

    let a1 = pipestale::train::evaluate(&mut pipe, &test_ds, meta.batch).unwrap();
    let a2 = pipestale::train::evaluate(&mut pipe, &test_ds, meta.batch).unwrap();
    assert_eq!(a1, a2, "eval must be deterministic");

    let mut batcher = Batcher::new(train_ds.len(), meta.batch, 3);
    for b in 0..3u64 {
        let idxs = batcher.next_indices().to_vec();
        let (x, labels) = train_ds.gather(&idxs);
        pipe.sequential_step(Feed { batch_id: b, seed: batch_seed(1, b), x, labels }).unwrap();
    }
    let after = pipe.exec.params_snapshot();
    let changed = before
        .partitions
        .iter()
        .zip(after.partitions.iter())
        .any(|(x, y)| x.params.iter().zip(y.params.iter()).any(|(t, u)| t.data() != u.data()));
    assert!(changed, "training must move weights");
    assert!(after.all_finite());
}

#[test]
fn evaluate_scores_the_test_set_remainder() {
    // Regression: evaluate() used to drop the `len % batch` tail. With
    // all-zero weights the model predicts class 0 for every sample, so
    // accuracy over a balanced 50-sample set (5 zeros) is exactly 5/50 —
    // a tail-dropping evaluate (48 scored, 5 zeros) would report 5/48.
    let meta = native_config("native_lenet_small").unwrap();
    assert_eq!(meta.batch, 16);
    let mut params = ModelParams::init(&meta.partitions, 1).unwrap();
    for p in &mut params.partitions {
        for t in &mut p.params {
            t.data_mut().fill(0.0);
        }
    }
    let optims = pipestale::train::build_optims(&meta, 1, 1.0);
    let exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
    let mut pipe = Pipeline::new(exec, meta.batch);
    let spec = SyntheticSpec { train: 32, test: 50, noise: 0.5, seed: 3 };
    let (_, test_ds) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    assert_eq!(test_ds.len() % meta.batch, 2, "test fixture must have a tail");
    let acc = pipestale::train::evaluate(&mut pipe, &test_ds, meta.batch).unwrap();
    assert!((acc - 0.1).abs() < 1e-9, "tail samples must be scored: {acc}");
}

#[test]
fn native_cross_process_hybrid_via_checkpoint() {
    // Paper §4 hybrid split across "processes": pipelined prefix saved
    // to a checkpoint, non-pipelined tail resumed from it on the native
    // backend. The tail must start from trained weights (first losses
    // well below the chance-level ln(10) ≈ 2.30 a fresh init produces).
    let ckpt = std::env::temp_dir().join(format!("native_hybrid_{}.ckpt", std::process::id()));
    let mut prefix = native_rc(Mode::Pipelined, 60);
    prefix.save_to = Some(ckpt.clone());
    pipestale::train::run(&prefix).unwrap();

    let mut tail = native_rc(Mode::Sequential, 25);
    tail.resume_from = Some(ckpt.clone());
    let b = pipestale::train::run(&tail).unwrap();
    assert_eq!(b.recorder.train.len(), 25);
    let resumed_early: f64 =
        b.recorder.train[..5].iter().map(|(_, l, _)| *l as f64).sum::<f64>() / 5.0;
    assert!(resumed_early < 2.25, "resumed run started from scratch? loss {resumed_early}");
    std::fs::remove_file(&ckpt).ok();
}

// ---------------------------------------------------------------------------
// Native ResNet ports: the paper's residual-network scenarios on the
// block-structured IR — no artifacts, no Python, synthetic CIFAR.
// ---------------------------------------------------------------------------

/// Narrow ResNet fixture (resnet8 at width 0.25, batch 8).
fn native_resnet_rc(config: &str, mode: Mode, iters: u64) -> RunConfig {
    let mut rc = RunConfig::new(config);
    rc.backend = Backend::Native;
    rc.mode = mode;
    rc.iters = iters;
    rc.train_size = 160;
    rc.test_size = 40;
    rc.noise = 0.6;
    rc
}

#[test]
fn native_resnet_pipelined_training_learns() {
    // Deep pipelining (P=4, three block-edge cuts) over residual
    // blocks: training must make progress and retire every batch once.
    let res = pipestale::train::run(&native_resnet_rc(
        "native_resnet_small_4s",
        Mode::Pipelined,
        40,
    ))
    .unwrap();
    assert_eq!(res.recorder.train.len(), 40);
    let mut ids: Vec<u64> = res.recorder.train.iter().map(|(b, _, _)| *b).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..40).collect::<Vec<_>>());
    let early: f64 =
        res.recorder.train[..10].iter().map(|(_, l, _)| *l as f64).sum::<f64>() / 10.0;
    let late: f64 = res.recorder.train.iter().rev().take(10).map(|(_, l, _)| *l as f64).sum::<f64>()
        / 10.0;
    assert!(late.is_finite() && late < early, "loss did not fall: {late} vs {early}");
    assert!(res.final_accuracy.is_finite());
}

#[test]
fn single_inflight_pipelined_equals_sequential_on_native_resnet() {
    // Zero staleness must be bit-exact on residual blocks too: the
    // projection shortcut and per-block BN state make this a much
    // sharper equivalence than LeNet's plain op chain.
    let meta = native_config("native_resnet_small").unwrap();
    let spec = SyntheticSpec { train: 32, test: 16, noise: 1.0, seed: 5 };
    let (ds, _) = load_or_synthesize(&meta.dataset, None, &spec).unwrap();
    let mut batcher = Batcher::new(ds.len(), meta.batch, 1);
    let idxs = batcher.next_indices().to_vec();
    let (x, labels) = ds.gather(&idxs);

    let mk_pipe = || {
        let params = ModelParams::init(&meta.partitions, 7).unwrap();
        let optims = pipestale::train::build_optims(&meta, 10, 1.0);
        let exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
        Pipeline::new(exec, meta.batch)
    };
    let feed =
        || Feed { batch_id: 0, seed: batch_seed(3, 0), x: x.clone(), labels: labels.clone() };

    let mut a = mk_pipe();
    a.sequential_step(feed()).unwrap();
    let mut b = mk_pipe();
    b.cycle(Some(feed())).unwrap();
    b.drain().unwrap();

    let pa = a.exec.params_snapshot();
    let pb = b.exec.params_snapshot();
    for (x, y) in pa.partitions.iter().zip(pb.partitions.iter()) {
        for (t, u) in x.params.iter().zip(y.params.iter()) {
            assert_eq!(t.data(), u.data(), "weights must be bit-identical");
        }
        for (t, u) in x.state.iter().zip(y.state.iter()) {
            assert_eq!(t.data(), u.data(), "BN state must be bit-identical");
        }
    }
}

#[test]
fn stale_pipelined_diverges_from_sequential_weights_native_resnet() {
    let a = pipestale::train::run(&native_resnet_rc(
        "native_resnet_small_4s",
        Mode::Pipelined,
        12,
    ))
    .unwrap();
    let b = pipestale::train::run(&native_resnet_rc(
        "native_resnet_small_4s",
        Mode::Sequential,
        12,
    ))
    .unwrap();
    let la: Vec<f32> = a.recorder.train.iter().rev().take(5).map(|(_, l, _)| *l).collect();
    let lb: Vec<f32> = b.recorder.train.iter().rev().take(5).map(|(_, l, _)| *l).collect();
    assert_ne!(la, lb, "stale weights should alter the resnet trajectory");
}

#[test]
fn native_resnet_hybrid_switches_and_trains() {
    let mut rc = native_resnet_rc("native_resnet_small_4s", Mode::Hybrid, 16);
    rc.pipelined_iters = 8;
    let res = pipestale::train::run(&rc).unwrap();
    assert_eq!(res.recorder.train.len(), 16);
    assert!(res.final_train_loss.is_finite());
}

#[test]
fn native_resnet_hybrid_checkpoint_crosses_block_boundary() {
    // Cross-process hybrid on the deep split: the partition boundary
    // sits right after the first stride-2 block, so partition 2 opens
    // with the g2b0 transition block — the checkpoint must carry that
    // block's conv/BN params AND its projection-shortcut params in the
    // second partition intact.
    let ckpt =
        std::env::temp_dir().join(format!("native_resnet_hybrid_{}.ckpt", std::process::id()));
    let mut prefix = native_resnet_rc("native_resnet_small_deep", Mode::Pipelined, 10);
    prefix.save_to = Some(ckpt.clone());
    pipestale::train::run(&prefix).unwrap();

    // the checkpoint round-trips and validates against the synthesized
    // block-structured meta
    let meta = native_config("native_resnet_small_deep").unwrap();
    let (params, at) = pipestale::model::checkpoint::load(&ckpt).unwrap();
    assert_eq!(at, 10);
    pipestale::model::checkpoint::validate(&params, &meta).unwrap();
    assert!(params.all_finite());

    let mut tail = native_resnet_rc("native_resnet_small_deep", Mode::Sequential, 6);
    tail.resume_from = Some(ckpt.clone());
    let b = pipestale::train::run(&tail).unwrap();
    assert_eq!(b.recorder.train.len(), 6);
    assert!(b.final_train_loss.is_finite());
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn native_checkpoint_rejects_wrong_config() {
    let ckpt = std::env::temp_dir().join(format!("native_wrongcfg_{}.ckpt", std::process::id()));
    let mut rc = native_rc(Mode::Sequential, 2);
    rc.save_to = Some(ckpt.clone());
    pipestale::train::run(&rc).unwrap();

    // quickstart_lenet is full-width: every tensor shape differs.
    let mut other = RunConfig::new("quickstart_lenet");
    other.backend = Backend::Native;
    other.iters = 2;
    other.train_size = 64;
    other.test_size = 32;
    other.resume_from = Some(ckpt.clone());
    assert!(pipestale::train::run(&other).is_err());
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn explicit_xla_backend_fails_loudly_on_stub() {
    // --backend xla with the stub linked must error, not silently fall
    // back to native (the user asked for a specific substrate).
    if pipestale::xla_ready() {
        skip_marker("real XLA backend present");
        return;
    }
    let mut rc = native_rc(Mode::Sequential, 2);
    rc.backend = Backend::Xla;
    assert!(pipestale::train::run(&rc).is_err());
}

#[test]
fn checkpoint_rejects_wrong_config() {
    if !pipestale::xla_ready() { skip_marker("needs artifacts + real XLA backend"); return; }
    let ckpt = std::env::temp_dir().join(format!("wrongcfg_{}.ckpt", std::process::id()));
    let mut rc = quick_rc(Mode::Sequential, 2);
    rc.save_to = Some(ckpt.clone());
    pipestale::train::run(&rc).unwrap();

    let mut other = RunConfig::new("resnet20_4s");
    other.iters = 2;
    other.train_size = 64;
    other.test_size = 32;
    other.resume_from = Some(ckpt.clone());
    assert!(pipestale::train::run(&other).is_err());
    std::fs::remove_file(&ckpt).ok();
}
