//! Finite-difference gradient checks for every native kernel and node.
//!
//! Each analytic backward pass (conv2d incl. strided, dense,
//! batch-norm, max-pool, activations, global-avg-pool, softmax-CE, and
//! the block IR's residual add / projection shortcut) is verified
//! against central finite differences of a random-projection loss
//! `L = sum(proj * y)`, seeded via `util::rng::Pcg32` so every run
//! draws the same inputs. Kink-prone inputs (relu preactivations,
//! pooling window ties) are kept away from their nondifferentiable
//! points *by construction*, not by luck — residual-block checks use
//! tanh activations inside the block for the same reason — so the
//! checks are deterministic.
//!
//! Since the GEMM lowering, the conv2d/dense kernels under test here
//! ARE the im2col+GEMM paths, so every FD check below also validates
//! the lowering analytically; the `gemm_*_matches_reference_*` tests
//! additionally pin the lowering against the retained pre-GEMM loop
//! kernels (`reference_*`) to 1e-4 relative tolerance across the
//! geometry classes the model zoo uses.

use pipestale::backend::{ActKind, NativeNode, NativeOp, Shortcut};
use pipestale::backend::kernels;
use pipestale::tensor::Tensor;
use pipestale::util::rng::Pcg32;

const EPS: f32 = 1e-2;

fn randn(rng: &mut Pcg32, shape: &[usize], scale: f32) -> Tensor {
    let data = (0..shape.iter().product::<usize>()).map(|_| rng.normal() * scale).collect();
    Tensor::from_vec(shape, data).unwrap()
}

/// Uniform values bounded away from zero: |v| in [lo, lo+span).
fn rand_off_zero(rng: &mut Pcg32, shape: &[usize], lo: f32, span: f32) -> Tensor {
    let data = (0..shape.iter().product::<usize>())
        .map(|_| {
            let mag = lo + rng.next_f32() * span;
            if rng.next_f32() < 0.5 {
                -mag
            } else {
                mag
            }
        })
        .collect();
    Tensor::from_vec(shape, data).unwrap()
}

/// Distinct values with pairwise gaps >= 0.1 (a shuffled ramp), so a
/// +-EPS perturbation can never flip a max-pool argmax.
fn rand_distinct(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
    let n = shape.iter().product::<usize>();
    let mut vals: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
    rng.shuffle(&mut vals);
    Tensor::from_vec(shape, vals).unwrap()
}

/// `sum(proj * y)` in f64, with y from a training-mode forward.
fn proj_loss(
    node: &NativeNode,
    params: &[Tensor],
    state: &[Tensor],
    x: &Tensor,
    proj: &[f32],
) -> f64 {
    let (y, _, _) = node.train_forward(params, state, x).unwrap();
    y.data().iter().zip(proj).map(|(&a, &b)| a as f64 * b as f64).sum()
}

fn assert_close(what: &str, idx: usize, fd: f64, analytic: f32) {
    let an = analytic as f64;
    let tol = 1e-2 + 2e-2 * an.abs().max(fd.abs());
    assert!(
        (fd - an).abs() <= tol,
        "{what}[{idx}]: finite-diff {fd:.6} vs analytic {an:.6}"
    );
}

/// Check d(proj·y)/dx and d(proj·y)/dparam against finite differences,
/// for any IR node (a plain op or a whole residual block).
fn fd_check_node(node: &NativeNode, params: &[Tensor], state: &[Tensor], x: &Tensor, seed: u64) {
    let (y, cache, _) = node.train_forward(params, state, x).unwrap();
    let mut rng = Pcg32::seeded(seed ^ 0x9d2c_5680);
    let proj: Vec<f32> = (0..y.numel()).map(|_| rng.normal()).collect();
    let proj_t = Tensor::from_vec(y.shape.as_slice(), proj.clone()).unwrap();
    let (dx, dparams) = node.backward(params, &cache, &proj_t).unwrap();
    assert_eq!(dparams.len(), params.len(), "{}: grad arity", node.name());

    for i in 0..x.numel() {
        let mut xp = x.clone();
        xp.data_mut()[i] += EPS;
        let mut xm = x.clone();
        xm.data_mut()[i] -= EPS;
        let fd = (proj_loss(node, params, state, &xp, &proj)
            - proj_loss(node, params, state, &xm, &proj))
            / (2.0 * EPS as f64);
        assert_close(&format!("{}/dx", node.name()), i, fd, dx.data()[i]);
    }
    for (pi, dp) in dparams.iter().enumerate() {
        for i in 0..params[pi].numel() {
            let mut pp: Vec<Tensor> = params.to_vec();
            pp[pi].data_mut()[i] += EPS;
            let mut pm: Vec<Tensor> = params.to_vec();
            pm[pi].data_mut()[i] -= EPS;
            let fd = (proj_loss(node, &pp, state, x, &proj)
                - proj_loss(node, &pm, state, x, &proj))
                / (2.0 * EPS as f64);
            assert_close(&format!("{}/dparam{pi}", node.name()), i, fd, dp.data()[i]);
        }
    }
}

/// Plain-op convenience wrapper over `fd_check_node`.
fn fd_check_op(op: &NativeOp, params: &[Tensor], state: &[Tensor], x: &Tensor, seed: u64) {
    fd_check_node(&NativeNode::Op(op.clone()), params, state, x, seed);
}

#[test]
fn fd_conv2d_same_stride1() {
    let mut rng = Pcg32::seeded(101);
    let op = NativeOp::conv("c", 2, 3, 3, 1, true, true);
    let x = randn(&mut rng, &[2, 5, 5, 2], 1.0);
    let params = vec![randn(&mut rng, &[3, 3, 2, 3], 0.5), randn(&mut rng, &[3], 0.5)];
    fd_check_op(&op, &params, &[], &x, 101);
}

#[test]
fn fd_conv2d_same_stride2() {
    let mut rng = Pcg32::seeded(102);
    let op = NativeOp::conv("c", 1, 2, 3, 2, true, true);
    let x = randn(&mut rng, &[1, 6, 6, 1], 1.0);
    let params = vec![randn(&mut rng, &[3, 3, 1, 2], 0.5), randn(&mut rng, &[2], 0.5)];
    fd_check_op(&op, &params, &[], &x, 102);
}

#[test]
fn fd_conv2d_valid_no_bias() {
    let mut rng = Pcg32::seeded(103);
    let op = NativeOp::conv("c", 2, 2, 3, 1, false, false);
    let x = randn(&mut rng, &[2, 5, 5, 2], 1.0);
    let params = vec![randn(&mut rng, &[3, 3, 2, 2], 0.5)];
    fd_check_op(&op, &params, &[], &x, 103);
}

#[test]
fn fd_conv2d_valid_stride2() {
    // Strided conv backward over VALID padding: (7-3)/2+1 = 3 output
    // rows, so windows overlap-free — a distinct indexing path from the
    // SAME-padded stride-2 case above.
    let mut rng = Pcg32::seeded(104);
    let op = NativeOp::conv("c", 2, 2, 3, 2, false, true);
    let x = randn(&mut rng, &[1, 7, 7, 2], 1.0);
    let params = vec![randn(&mut rng, &[3, 3, 2, 2], 0.5), randn(&mut rng, &[2], 0.5)];
    fd_check_op(&op, &params, &[], &x, 104);
}

#[test]
fn fd_conv2d_projection_1x1_stride2() {
    // The projection-shortcut geometry: 1x1 kernel, stride 2, SAME (no
    // padding needed), channel widening, no bias.
    let mut rng = Pcg32::seeded(105);
    let op = NativeOp::conv("proj", 2, 4, 1, 2, true, false);
    let x = randn(&mut rng, &[2, 6, 6, 2], 1.0);
    let params = vec![randn(&mut rng, &[1, 1, 2, 4], 0.5)];
    fd_check_op(&op, &params, &[], &x, 105);
}

#[test]
fn fd_dense_linear_and_tanh() {
    for (seed, act) in [(201u64, ActKind::None), (202, ActKind::Tanh)] {
        let mut rng = Pcg32::seeded(seed);
        let op = NativeOp::dense("d", 6, 5, act);
        let x = randn(&mut rng, &[4, 6], 0.8);
        let params = vec![randn(&mut rng, &[6, 5], 0.5), randn(&mut rng, &[5], 0.5)];
        fd_check_op(&op, &params, &[], &x, seed);
    }
}

#[test]
fn fd_dense_relu_away_from_kink() {
    // |x| <= 0.2, |w| <= 0.3 bounds |x.w| by 6*0.2*0.3 = 0.36 < 0.5, and
    // biases of +-1 then keep every preactivation at least 0.5 from the
    // relu kink — an EPS perturbation cannot cross it.
    let mut rng = Pcg32::seeded(203);
    let op = NativeOp::dense("d", 6, 4, ActKind::Relu);
    let x = {
        let data = (0..4 * 6).map(|_| rng.uniform(-0.2, 0.2)).collect();
        Tensor::from_vec(&[4, 6], data).unwrap()
    };
    let w = {
        let data = (0..6 * 4).map(|_| rng.uniform(-0.3, 0.3)).collect();
        Tensor::from_vec(&[6, 4], data).unwrap()
    };
    let b = Tensor::from_vec(&[4], vec![1.0, -1.0, 1.0, -1.0]).unwrap();
    fd_check_op(&op, &[w, b], &[], &x, 203);
}

#[test]
fn fd_batchnorm_through_batch_stats() {
    let mut rng = Pcg32::seeded(301);
    let op = NativeOp::batch_norm("bn", 3);
    // NHWC: rows = 2*2*2 = 8 per channel
    let x = randn(&mut rng, &[2, 2, 2, 3], 1.0);
    let params = vec![randn(&mut rng, &[3], 0.5), randn(&mut rng, &[3], 0.5)];
    let state = vec![Tensor::zeros(&[3]), Tensor::ones(&[3])];
    fd_check_op(&op, &params, &state, &x, 301);
}

#[test]
fn fd_maxpool() {
    let mut rng = Pcg32::seeded(401);
    let op = NativeOp::max_pool("p", 2);
    let x = rand_distinct(&mut rng, &[2, 4, 4, 2]);
    fd_check_op(&op, &[], &[], &x, 401);
}

#[test]
fn fd_act_relu_and_tanh() {
    let mut rng = Pcg32::seeded(501);
    let x_relu = rand_off_zero(&mut rng, &[3, 7], 0.1, 0.9);
    fd_check_op(&NativeOp::act("r", ActKind::Relu), &[], &[], &x_relu, 501);
    let x_tanh = randn(&mut rng, &[3, 7], 1.0);
    fd_check_op(&NativeOp::act("t", ActKind::Tanh), &[], &[], &x_tanh, 502);
}

#[test]
fn fd_global_avg_pool() {
    let mut rng = Pcg32::seeded(601);
    let x = randn(&mut rng, &[2, 3, 3, 4], 1.0);
    fd_check_op(&NativeOp::global_avg_pool("g"), &[], &[], &x, 601);
}

#[test]
fn fd_softmax_cross_entropy() {
    let (n, classes) = (5usize, 7usize);
    let mut rng = Pcg32::seeded(701);
    let logits: Vec<f32> = (0..n * classes).map(|_| rng.normal()).collect();
    let labels: Vec<i32> = (0..n).map(|_| rng.below(classes as u32) as i32).collect();
    let (_, _, dlogits) = kernels::softmax_xent(&logits, n, classes, &labels);
    for i in 0..logits.len() {
        let mut lp = logits.clone();
        lp[i] += EPS;
        let mut lm = logits.clone();
        lm[i] -= EPS;
        let (loss_p, _, _) = kernels::softmax_xent(&lp, n, classes, &labels);
        let (loss_m, _, _) = kernels::softmax_xent(&lm, n, classes, &labels);
        let fd = (loss_p as f64 - loss_m as f64) / (2.0 * EPS as f64);
        assert_close("softmax_xent/dlogits", i, fd, dlogits[i]);
    }
}

#[test]
fn fd_resblock_identity_shortcut() {
    // A full basic block with identity shortcut: the residual add must
    // fan the gradient into both the conv/BN main branch and the skip.
    // tanh (not relu) inside the block keeps the check kink-free.
    let mut rng = Pcg32::seeded(901);
    let node = NativeNode::block(
        "b",
        vec![
            NativeOp::conv("b/conv1", 3, 3, 3, 1, true, false),
            NativeOp::batch_norm("b/bn1", 3),
            NativeOp::act("b/a1", ActKind::Tanh),
            NativeOp::conv("b/conv2", 3, 3, 3, 1, true, false),
            NativeOp::batch_norm("b/bn2", 3),
        ],
        Shortcut::Identity,
    );
    let x = randn(&mut rng, &[2, 4, 4, 3], 1.0);
    let params = vec![
        randn(&mut rng, &[3, 3, 3, 3], 0.4),
        randn(&mut rng, &[3], 0.5), // bn1 gamma
        randn(&mut rng, &[3], 0.5), // bn1 beta
        randn(&mut rng, &[3, 3, 3, 3], 0.4),
        randn(&mut rng, &[3], 0.5), // bn2 gamma
        randn(&mut rng, &[3], 0.5), // bn2 beta
    ];
    let state = vec![
        Tensor::zeros(&[3]),
        Tensor::ones(&[3]),
        Tensor::zeros(&[3]),
        Tensor::ones(&[3]),
    ];
    fd_check_node(&node, &params, &state, &x, 901);
}

#[test]
fn fd_resblock_projection_shortcut_stride2() {
    // A strided transition block: main branch downsamples 6x6 -> 3x3
    // and widens 3 -> 4 channels; the 1x1 stride-2 projection conv + BN
    // must receive its own gradients through the residual add.
    let mut rng = Pcg32::seeded(902);
    let node = NativeNode::block(
        "t",
        vec![
            NativeOp::conv("t/conv1", 3, 4, 3, 2, true, false),
            NativeOp::batch_norm("t/bn1", 4),
            NativeOp::act("t/a1", ActKind::Tanh),
            NativeOp::conv("t/conv2", 4, 4, 3, 1, true, false),
            NativeOp::batch_norm("t/bn2", 4),
        ],
        Shortcut::projection("t", 3, 4, 2),
    );
    let x = randn(&mut rng, &[1, 6, 6, 3], 1.0);
    let params = vec![
        randn(&mut rng, &[3, 3, 3, 4], 0.4),
        randn(&mut rng, &[4], 0.5),
        randn(&mut rng, &[4], 0.5),
        randn(&mut rng, &[3, 3, 4, 4], 0.4),
        randn(&mut rng, &[4], 0.5),
        randn(&mut rng, &[4], 0.5),
        randn(&mut rng, &[1, 1, 3, 4], 0.5), // projection conv
        randn(&mut rng, &[4], 0.5),          // projection BN gamma
        randn(&mut rng, &[4], 0.5),          // projection BN beta
    ];
    let state = vec![
        Tensor::zeros(&[4]),
        Tensor::ones(&[4]),
        Tensor::zeros(&[4]),
        Tensor::ones(&[4]),
        Tensor::zeros(&[4]),
        Tensor::ones(&[4]),
    ];
    fd_check_node(&node, &params, &state, &x, 902);
}

fn rel_close(what: &str, got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        let bound = tol * (1.0 + b.abs());
        assert!((a - b).abs() <= bound, "{what}[{i}]: gemm {a} vs reference {b}");
    }
}

#[test]
fn gemm_conv_matches_reference_across_geometries() {
    // Every conv geometry class the model zoo uses: LeNet SAME/VALID
    // 5x5, ResNet SAME 3x3 (stride 1 and 2), and the 1x1 stride-2
    // projection shortcut. Forward and full backward (dx/dw/db) must
    // match the retained loop kernels within 1e-4 relative tolerance.
    let cases: &[(&str, usize, usize, usize, usize, usize, usize, usize, bool, bool)] = &[
        // (tag, n, h, w, cin, cout, k, stride, same, bias)
        ("lenet-c1", 2, 8, 8, 1, 6, 5, 1, true, true),
        ("lenet-c2", 2, 9, 9, 3, 4, 5, 1, false, true),
        ("resnet-stem", 2, 8, 8, 3, 4, 3, 1, true, false),
        ("resnet-trans", 1, 8, 8, 4, 6, 3, 2, true, false),
        ("valid-s2", 1, 7, 7, 2, 3, 3, 2, false, true),
        ("proj-1x1-s2", 2, 6, 6, 3, 5, 1, 2, true, false),
    ];
    for &(tag, n, h, w, cin, cout, k, stride, same, bias) in cases {
        let mut rng = Pcg32::seeded(0xC0DE ^ tag.len() as u64);
        let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.normal()).collect();
        let wgt: Vec<f32> = (0..k * k * cin * cout).map(|_| rng.normal() * 0.5).collect();
        let b: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let bias_ref = bias.then_some(b.as_slice());
        let (oh, ow, _, _) = kernels::conv_out_dims(h, w, k, stride, same).unwrap();
        let out_len = n * oh * ow * cout;

        let mut y = vec![0.0; out_len];
        let mut yr = vec![0.0; out_len];
        kernels::conv2d_forward(&x, n, h, w, cin, &wgt, k, cout, stride, same, bias_ref, &mut y);
        kernels::reference_conv2d_forward(
            &x,
            n,
            h,
            w,
            cin,
            &wgt,
            k,
            cout,
            stride,
            same,
            bias_ref,
            &mut yr,
        );
        rel_close(&format!("{tag}/fwd"), &y, &yr, 1e-4);

        let dy: Vec<f32> = (0..out_len).map(|_| rng.normal()).collect();
        let (mut dx, mut dxr) = (vec![0.0; x.len()], vec![0.0; x.len()]);
        let (mut dw, mut dwr) = (vec![0.0; wgt.len()], vec![0.0; wgt.len()]);
        let (mut db, mut dbr) = (vec![0.0; cout], vec![0.0; cout]);
        kernels::conv2d_backward(
            &x,
            n,
            h,
            w,
            cin,
            &wgt,
            k,
            cout,
            stride,
            same,
            &dy,
            &mut dx,
            &mut dw,
            bias.then_some(db.as_mut_slice()),
        );
        kernels::reference_conv2d_backward(
            &x,
            n,
            h,
            w,
            cin,
            &wgt,
            k,
            cout,
            stride,
            same,
            &dy,
            &mut dxr,
            &mut dwr,
            bias.then_some(dbr.as_mut_slice()),
        );
        rel_close(&format!("{tag}/dx"), &dx, &dxr, 1e-4);
        rel_close(&format!("{tag}/dw"), &dw, &dwr, 1e-4);
        if bias {
            rel_close(&format!("{tag}/db"), &db, &dbr, 1e-4);
        }
    }
}

#[test]
fn gemm_kernels_are_bitwise_deterministic_run_to_run() {
    // The blocked summation order depends only on the problem shape,
    // so repeating a kernel call must reproduce every bit — the
    // property the pipeline equivalence invariants stand on.
    let mut rng = Pcg32::seeded(0xD17E);
    let (n, h, w, cin, cout, k) = (2, 9, 9, 3, 5, 3);
    let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.normal()).collect();
    let wgt: Vec<f32> = (0..k * k * cin * cout).map(|_| rng.normal()).collect();
    let (oh, ow, _, _) = kernels::conv_out_dims(h, w, k, 1, true).unwrap();
    let out_len = n * oh * ow * cout;
    let dy: Vec<f32> = (0..out_len).map(|_| rng.normal()).collect();

    let run = || {
        let mut y = vec![0.0; out_len];
        kernels::conv2d_forward(&x, n, h, w, cin, &wgt, k, cout, 1, true, None, &mut y);
        let mut dx = vec![0.0; x.len()];
        let mut dw = vec![0.0; wgt.len()];
        kernels::conv2d_backward(
            &x,
            n,
            h,
            w,
            cin,
            &wgt,
            k,
            cout,
            1,
            true,
            &dy,
            &mut dx,
            &mut dw,
            None,
        );
        (y, dx, dw)
    };
    let (y1, dx1, dw1) = run();
    let (y2, dx2, dw2) = run();
    for (a, b) in y1.iter().zip(&y2).chain(dx1.iter().zip(&dx2)).chain(dw1.iter().zip(&dw2)) {
        assert_eq!(a.to_bits(), b.to_bits(), "kernel results must be bitwise reproducible");
    }
}

#[test]
fn simd_and_threaded_gemm_match_the_scalar_oracle_across_zoo_geometries() {
    // The tentpole parity suite: for every GEMM shape the model zoo's
    // conv/dense lowerings produce, the detected SIMD micro-kernel and
    // the threaded driver must match the scalar 1-thread oracle within
    // the documented 1e-4 relative tolerance (in fact they match
    // bitwise — the no-FMA / static-tiling design — but this suite
    // pins only the documented contract so a future FMA kernel fails
    // loudly here rather than silently drifting).
    use pipestale::backend::gemm::sgemm_with;
    use pipestale::backend::simd::{detected, Micro};

    // (m, n, k) per zoo conv case: m = n_batch*oh*ow, n = cout,
    // k = kh*kw*cin (the im2col lowering), plus the dense head shapes.
    let conv_cases: &[(&str, usize, usize, usize, usize, usize, usize, usize, bool)] = &[
        // (tag, n, h, w, cin, cout, k, stride, same)
        ("lenet-c1", 2, 8, 8, 1, 6, 5, 1, true),
        ("lenet-c2", 2, 9, 9, 3, 4, 5, 1, false),
        ("resnet-stem", 2, 8, 8, 3, 4, 3, 1, true),
        ("resnet-trans", 1, 8, 8, 4, 6, 3, 2, true),
        ("valid-s2", 1, 7, 7, 2, 3, 3, 2, false),
        ("proj-1x1-s2", 2, 6, 6, 3, 5, 1, 2, true),
    ];
    let mut shapes: Vec<(String, usize, usize, usize)> = Vec::new();
    for &(tag, n, h, w, cin, cout, kk, stride, same) in conv_cases {
        let (oh, ow, _, _) = kernels::conv_out_dims(h, w, kk, stride, same).unwrap();
        shapes.push((tag.to_string(), n * oh * ow, cout, kk * kk * cin));
    }
    // dense heads: lenet fc1/fc2/logits-ish and a batch GEMM.
    for &(m, n, k) in &[(2usize, 120usize, 400usize), (2, 84, 120), (16, 10, 84)] {
        shapes.push((format!("dense-{m}x{n}x{k}"), m, n, k));
    }

    for (tag, m, n, k) in shapes {
        let mut rng = Pcg32::seeded(0x51D ^ (m * 31 + n * 7 + k) as u64);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
        let mut oracle = vec![0.0f32; m * n];
        sgemm_with(Micro::Scalar, 1, false, false, m, n, k, &a, &b, false, &mut oracle);
        for (label, micro, threads) in [
            ("simd-1t", detected(), 1usize),
            ("scalar-3t", Micro::Scalar, 3),
            ("simd-3t", detected(), 3),
        ] {
            let mut got = vec![0.0f32; m * n];
            sgemm_with(micro, threads, false, false, m, n, k, &a, &b, false, &mut got);
            rel_close(&format!("{tag}/{label}"), &got, &oracle, 1e-4);
        }
    }
}

#[test]
fn threaded_gemm_is_bitwise_deterministic_at_fixed_thread_count() {
    // Run-to-run determinism with real worker threads in play: the
    // static tile partition makes the summation order a function of
    // (m, n, k) alone, so repeated threaded calls — racing against
    // whatever else the test harness runs — reproduce every bit.
    use pipestale::backend::gemm::sgemm_with;
    use pipestale::backend::simd::detected;

    let mut rng = Pcg32::seeded(0xB175);
    let (m, n, k) = (150, 260, 300);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let threads = 3;
    let run = || {
        let mut c = vec![0.0f32; m * n];
        sgemm_with(detected(), threads, false, false, m, n, k, &a, &b, false, &mut c);
        c
    };
    let c1 = run();
    for round in 0..3 {
        let c2 = run();
        for (i, (x, y)) in c2.iter().zip(&c1).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "round {round} elem {i}: {x} vs {y}");
        }
    }
    // And the 1-thread threaded path equals the N-thread one exactly.
    let mut c1t = vec![0.0f32; m * n];
    sgemm_with(detected(), 1, false, false, m, n, k, &a, &b, false, &mut c1t);
    for (i, (x, y)) in c1t.iter().zip(&c1).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "1t vs {threads}t elem {i}");
    }
}

#[test]
fn conv_gradients_are_translation_consistent() {
    // A conv is linear in x: doubling x must double dw exactly.
    let mut rng = Pcg32::seeded(801);
    let op = NativeOp::conv("c", 1, 2, 3, 1, true, true);
    let x = randn(&mut rng, &[1, 4, 4, 1], 1.0);
    let params = vec![randn(&mut rng, &[3, 3, 1, 2], 0.5), Tensor::zeros(&[2])];
    let (y, cache, _) = op.train_forward(&params, &[], &x).unwrap();
    let dy = Tensor::ones(y.shape.as_slice());
    let (_, g1) = op.backward(&params, &cache, &dy).unwrap();
    let mut x2 = x.clone();
    for v in x2.data_mut() {
        *v *= 2.0;
    }
    let (_, cache2, _) = op.train_forward(&params, &[], &x2).unwrap();
    let (_, g2) = op.backward(&params, &cache2, &dy).unwrap();
    for (a, b) in g1[0].data().iter().zip(g2[0].data()) {
        assert!((2.0 * a - b).abs() < 1e-4, "{a} vs {b}");
    }
}
