//! Training driver: wires data, executor, scheduler and metrics into the
//! three schedules the paper evaluates (pipelined / non-pipelined /
//! hybrid), plus the eval loop.

pub mod metrics;

use anyhow::{Context, Result};

use crate::config::{Mode, RunConfig};
use crate::data::{batch_seed, load_or_synthesize, Batcher, Dataset, SyntheticSpec};
use crate::meta::ConfigMeta;
use crate::model::ModelParams;
use crate::optim::{paper_schedule, Sgd};
use crate::pipeline::{Feed, HybridSchedule, Phase, Pipeline, StageExecutor, XlaExecutor};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub use metrics::{EvalPoint, Recorder};

#[derive(Debug, Clone)]
pub struct TrainResult {
    pub config: String,
    pub mode: String,
    pub iters: u64,
    pub final_accuracy: f64,
    pub final_train_loss: f64,
    pub wall_seconds: f64,
    pub recorder: Recorder,
}

/// Build per-partition optimizers with the paper's hyperparameters;
/// non-final (stale) partitions get `stale_lr_scale` (Table 7).
pub fn build_optims(meta: &ConfigMeta, total_iters: u64, stale_lr_scale: f64) -> Vec<Sgd> {
    let (sched, mom, nesterov, wd) = paper_schedule(&meta.model, total_iters as usize);
    (0..meta.partitions.len())
        .map(|p| {
            let o = Sgd::new(sched.clone(), mom, nesterov, wd);
            if p + 1 < meta.partitions.len() {
                o.with_lr_scale(stale_lr_scale as f32)
            } else {
                o
            }
        })
        .collect()
}

/// Top-1 accuracy over the test set (floor(len/batch) full batches).
pub fn evaluate<E: StageExecutor>(
    pipe: &mut Pipeline<E>,
    ds: &Dataset,
    batch: usize,
) -> Result<f64> {
    let n_batches = ds.len() / batch;
    anyhow::ensure!(n_batches > 0, "test set smaller than a batch");
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in 0..n_batches {
        let idxs: Vec<usize> = (b * batch..(b + 1) * batch).collect();
        let (x, labels) = ds.gather(&idxs);
        let logits = pipe.eval_forward(x)?;
        correct += count_correct(&logits, &labels.data, batch);
        total += batch;
    }
    Ok(correct as f64 / total as f64)
}

pub fn count_correct(logits: &Tensor, labels: &[i32], batch: usize) -> usize {
    let classes = logits.numel() / batch;
    let mut correct = 0;
    for i in 0..batch {
        let row = &logits.data()[i * classes..(i + 1) * classes];
        let mut best = 0usize;
        for (j, v) in row.iter().enumerate() {
            if *v > row[best] {
                best = j;
            }
        }
        if best as i32 == labels[i] {
            correct += 1;
        }
    }
    correct
}

/// Run a full training experiment per the RunConfig.
pub fn run(rc: &RunConfig) -> Result<TrainResult> {
    let meta = ConfigMeta::load_named(&crate::artifacts_root(), &rc.config)
        .with_context(|| format!("loading config {}", rc.config))?;
    let runtime = Runtime::cpu()?;
    run_with_runtime(rc, &meta, &runtime)
}

/// Variant that reuses an existing runtime/artifacts (benches share one
/// PJRT client across many runs).
pub fn run_with_runtime(rc: &RunConfig, meta: &ConfigMeta, runtime: &Runtime) -> Result<TrainResult> {
    let spec = SyntheticSpec {
        train: rc.train_size,
        test: rc.test_size,
        noise: rc.noise as f32,
        seed: rc.seed ^ 0x5eed_da7a,
    };
    let (train_ds, test_ds) =
        load_or_synthesize(&meta.dataset, rc.data_dir.as_deref(), &spec)?;
    anyhow::ensure!(
        train_ds.input_shape == meta.input_shape,
        "dataset shape {:?} vs model input {:?}",
        train_ds.input_shape,
        meta.input_shape
    );

    let params = match &rc.resume_from {
        Some(path) => {
            let (p, at) = crate::model::checkpoint::load(path)?;
            crate::model::checkpoint::validate(&p, meta)?;
            log::info!("resumed weights from {} (saved at iter {at})", path.display());
            p
        }
        None => ModelParams::init(&meta.partitions, rc.seed)?,
    };
    let optims = build_optims(meta, rc.iters, rc.stale_lr_scale);
    let exec = XlaExecutor::new(runtime, meta.clone(), params, optims)?;
    let mut pipe = Pipeline::new(exec, meta.batch);
    let mut batcher = Batcher::new(train_ds.len(), meta.batch, rc.seed ^ 0xba7c4);

    let schedule = match rc.mode {
        Mode::Pipelined => HybridSchedule::all_pipelined(rc.iters),
        Mode::Sequential => HybridSchedule::all_sequential(rc.iters),
        Mode::Hybrid => HybridSchedule::new(rc.pipelined_iters, rc.iters),
    };

    let mut rec = Recorder::new();
    let start = std::time::Instant::now();
    let mut fed = 0u64;

    log::info!(
        "train {}: mode={} iters={} batch={} P={} stages={} %stale={:.1}",
        meta.config,
        rc.mode.name(),
        rc.iters,
        meta.batch,
        meta.partitions.len(),
        meta.paper_stages(),
        100.0 * meta.stale_weight_fraction()
    );

    while fed < rc.iters {
        let phase = schedule.phase(fed);
        if phase == Phase::DrainThenSequential {
            for e in pipe.drain()? {
                rec.train_event(&e);
            }
            log::info!("hybrid switch at iter {fed}: pipeline drained");
        }
        let idxs = batcher.next_indices().to_vec();
        let (x, labels) = train_ds.gather(&idxs);
        let feed = Feed { batch_id: fed, seed: batch_seed(rc.seed, fed), x, labels };
        match phase {
            Phase::Pipelined => {
                if let Some(e) = pipe.cycle(Some(feed))? {
                    rec.train_event(&e);
                }
            }
            _ => {
                let e = pipe.sequential_step(feed)?;
                rec.train_event(&e);
            }
        }
        fed += 1;
        if rc.eval_every > 0 && fed % rc.eval_every == 0 {
            // NOTE: in pipelined mode some batches are still in flight;
            // eval reflects the weights as of this cycle, like the
            // paper's periodic tests during training.
            let acc = evaluate(&mut pipe, &test_ds, meta.batch)?;
            rec.eval_point(fed, acc);
            log::info!("iter {fed}: test acc {:.2}%", 100.0 * acc);
        }
    }
    for e in pipe.drain()? {
        rec.train_event(&e);
    }
    let final_accuracy = evaluate(&mut pipe, &test_ds, meta.batch)?;
    rec.eval_point(rc.iters, final_accuracy);
    if let Some(path) = &rc.save_to {
        crate::model::checkpoint::save(path, &pipe.exec.params_snapshot(), rc.iters)?;
        log::info!("saved checkpoint to {}", path.display());
    }
    let wall = start.elapsed().as_secs_f64();

    Ok(TrainResult {
        config: meta.config.clone(),
        mode: rc.mode.name().to_string(),
        iters: rc.iters,
        final_accuracy,
        final_train_loss: rec.recent_loss(50),
        wall_seconds: wall,
        recorder: rec,
    })
}
