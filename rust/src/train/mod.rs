//! Training driver: wires data, executor, scheduler and metrics into the
//! three schedules the paper evaluates (pipelined / non-pipelined /
//! hybrid), plus the eval loop.
//!
//! The driver is generic over the compute backend AND the runtime:
//! `run` dispatches on `RunConfig::backend` between the XLA executor
//! (AOT artifacts + PJRT) and the native pure-Rust executor (no
//! artifacts, no Python step) — `Backend::Auto` picks XLA when
//! `xla_ready()` and native otherwise — and on `RunConfig::runtime`
//! between the cycle-accurate scheduler and the thread-per-partition
//! runtime, orthogonally (DESIGN.md §4 matrix).

pub mod metrics;

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::backend::NativeExecutor;
use crate::config::{Backend, Mode, OnFailure, PartitionMode, RunConfig, RuntimeKind};
use crate::data::{
    batch_seed, load_streaming, Augment, BatchStream, Dataset, StreamDataset, StreamOptions,
    SyntheticSpec,
};
use crate::meta::ConfigMeta;
use crate::model::checkpoint::CheckpointStore;
use crate::model::ModelParams;
use crate::optim::{paper_schedule, Sgd};
use crate::pipeline::{
    EventLedger, FaultInjector, FaultPlan, FaultyWorkerBackend, Feed, HybridSchedule,
    NativeWorkerBackend, Occupancy, Phase, Pipeline, StageExecutor, ThreadedOptions,
    ThreadedPipeline, TrainEvent, WorkerBackend, XlaExecutor, XlaWorkerBackend,
};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub use metrics::{EvalPoint, Recorder};

#[derive(Debug, Clone)]
pub struct TrainResult {
    pub config: String,
    pub mode: String,
    pub runtime: String,
    pub iters: u64,
    pub final_accuracy: f64,
    pub final_train_loss: f64,
    pub wall_seconds: f64,
    /// Worker failures the threaded supervisor recovered from
    /// (0 on the scheduler runtime and on clean runs).
    pub restarts: u32,
    /// True when the retry budget ran out and the run finished
    /// single-occupancy under `--on-failure degrade`.
    pub degraded: bool,
    pub recorder: Recorder,
}

/// Build per-partition optimizers with the paper's hyperparameters;
/// non-final (stale) partitions get `stale_lr_scale` (Table 7).
pub fn build_optims(meta: &ConfigMeta, total_iters: u64, stale_lr_scale: f64) -> Vec<Sgd> {
    let (sched, mom, nesterov, wd) = paper_schedule(&meta.model, total_iters as usize);
    (0..meta.partitions.len())
        .map(|p| {
            let o = Sgd::new(sched.clone(), mom, nesterov, wd);
            if p + 1 < meta.partitions.len() {
                o.with_lr_scale(stale_lr_scale as f32)
            } else {
                o
            }
        })
        .collect()
}

/// Top-1 accuracy over the *whole* test set. Stage programs have a
/// static batch size, so the `len % batch` remainder is padded up to a
/// full batch (repeating the first tail sample) and only the real
/// samples are scored — no silently dropped tail.
pub fn evaluate<E: StageExecutor>(
    pipe: &mut Pipeline<E>,
    ds: &Dataset,
    batch: usize,
) -> Result<f64> {
    anyhow::ensure!(batch > 0, "evaluate: zero batch size");
    anyhow::ensure!(!ds.is_empty(), "evaluate: empty test set");
    let mut correct = 0usize;
    let mut scored = 0usize;
    while scored < ds.len() {
        let real = (ds.len() - scored).min(batch);
        let mut idxs: Vec<usize> = (scored..scored + real).collect();
        idxs.resize(batch, idxs[0]); // pad to the static batch size
        let (x, labels) = ds.gather(&idxs);
        let logits = pipe.eval_forward(x)?;
        correct += count_correct_rows(&logits, &labels.data, batch, real);
        scored += real;
    }
    Ok(correct as f64 / scored as f64)
}

/// Count argmax==label over the first `rows` of a `[batch, classes]`
/// logits tensor (ties resolve to the first maximum).
pub fn count_correct_rows(logits: &Tensor, labels: &[i32], batch: usize, rows: usize) -> usize {
    let classes = logits.numel() / batch;
    let mut correct = 0;
    for i in 0..rows {
        let row = &logits.data()[i * classes..(i + 1) * classes];
        let mut best = 0usize;
        for (j, v) in row.iter().enumerate() {
            if *v > row[best] {
                best = j;
            }
        }
        if best as i32 == labels[i] {
            correct += 1;
        }
    }
    correct
}

pub fn count_correct(logits: &Tensor, labels: &[i32], batch: usize) -> usize {
    count_correct_rows(logits, labels, batch, batch)
}

/// True when this specific config has a recorded artifact contract —
/// the per-config half of the `Backend::Auto` resolution rule (shared
/// with examples that pick a config before building a RunConfig).
pub fn artifact_meta_exists(name: &str) -> bool {
    crate::artifacts_root().join(name).join("meta.json").exists()
}

/// Resolve the meta for a config on the native backend: a built
/// artifact meta.json takes precedence (so artifact configs run
/// natively too, against the recorded contract) and a corrupt one is an
/// error — only a genuinely absent artifact falls back to the in-crate
/// native manifest.
pub fn load_native_meta(name: &str) -> Result<ConfigMeta> {
    if artifact_meta_exists(name) {
        return ConfigMeta::load_named(&crate::artifacts_root(), name);
    }
    crate::backend::native_config(name)
}

/// Resolve the meta for a run under the partition axis: `manual` loads
/// the recorded contract (artifact meta.json or native manifest),
/// `auto` synthesizes the profile-guided PPV through
/// [`crate::profile::auto_native_meta`] — same stage count as the
/// manifest, cuts rebalanced by the analytic cost model, so the run is
/// still bitwise deterministic. Auto is native-only: XLA stage programs
/// are AOT-compiled against the recorded PPV and cannot serve a
/// re-partitioned contract.
pub fn resolve_meta(config: &str, partition: PartitionMode, use_xla: bool) -> Result<ConfigMeta> {
    match partition {
        PartitionMode::Manual => {
            if use_xla {
                ConfigMeta::load_named(&crate::artifacts_root(), config)
                    .with_context(|| format!("loading config {config}"))
            } else {
                load_native_meta(config)
                    .with_context(|| format!("resolving native config {config}"))
            }
        }
        PartitionMode::Auto => {
            anyhow::ensure!(
                !use_xla,
                "--partition auto re-synthesizes the partition contract and needs the native \
                 backend (XLA stage programs are compiled against the recorded PPV); rerun \
                 with --backend native"
            );
            let (meta, sol) = crate::profile::auto_native_meta(config)?;
            log::info!(
                "auto partition for {config}: PPV {:?} (predicted bottleneck {:.3e}s, \
                 imbalance {:.3}, speedup {:.2}x)",
                meta.ppv,
                sol.bottleneck,
                sol.imbalance,
                sol.predicted_speedup
            );
            Ok(meta)
        }
    }
}

/// Resolve `Backend::Auto`: XLA only when the runtime is ready AND
/// this config's artifacts exist; native-only built-ins (e.g.
/// `native_lenet_small`) therefore run everywhere under the default.
/// `--partition auto` pins the resolution to native — auto-partitioning
/// re-synthesizes the contract, which only the native backend can serve
/// (an explicit `--backend xla` + auto is an error in `resolve_meta`).
fn resolve_xla(rc: &RunConfig) -> bool {
    match rc.backend {
        Backend::Xla => true,
        Backend::Native => false,
        Backend::Auto => {
            rc.partition == PartitionMode::Manual
                && crate::xla_ready()
                && artifact_meta_exists(&rc.config)
        }
    }
}

/// Run a full training experiment per the RunConfig, on whichever
/// backend and runtime it selects (the two axes are orthogonal).
pub fn run(rc: &RunConfig) -> Result<TrainResult> {
    if rc.runtime == RuntimeKind::Scheduler {
        anyhow::ensure!(
            rc.fault_plan.is_none(),
            "--fault-plan injects worker faults: use --runtime threaded"
        );
        anyhow::ensure!(
            rc.on_failure == OnFailure::Fail,
            "--on-failure {} supervises worker threads: use --runtime threaded",
            rc.on_failure.name()
        );
    }
    anyhow::ensure!(
        rc.ckpt_every == 0 || rc.ckpt_dir.is_some(),
        "--ckpt-every needs --ckpt-dir for the rotating checkpoint files"
    );
    match rc.runtime {
        RuntimeKind::Scheduler => run_scheduler(rc),
        RuntimeKind::Threaded => run_threaded(rc),
    }
}

/// Open the rotating checkpoint store when the config asks for one.
fn checkpoint_store(rc: &RunConfig) -> Result<Option<CheckpointStore>> {
    match &rc.ckpt_dir {
        Some(dir) => Ok(Some(CheckpointStore::open(dir, rc.ckpt_keep)?)),
        None => Ok(None),
    }
}

/// Scheduler-runtime dispatch over the backend axis.
fn run_scheduler(rc: &RunConfig) -> Result<TrainResult> {
    if resolve_xla(rc) {
        let meta = resolve_meta(&rc.config, rc.partition, true)?;
        let runtime = Runtime::cpu()?;
        run_with_runtime(rc, &meta, &runtime)
    } else {
        run_native(rc)
    }
}

/// Threaded-runtime driver: one worker thread per partition over
/// whichever backend the config resolves to. Pipelined mode runs the
/// paper's full-occupancy concurrent schedule; sequential mode runs
/// single-in-flight (bitwise-equal to the scheduler runtime's
/// sequential training). Training runs under the checkpoint-restart
/// supervisor (DESIGN.md §8): periodic rotating checkpoints, restart
/// from the newest valid one on worker failure, optional degradation
/// to single occupancy when the retry budget runs out. Evaluation
/// happens once, at the end, on a scheduler pipeline rebuilt from the
/// returned weights.
pub fn run_threaded(rc: &RunConfig) -> Result<TrainResult> {
    let occupancy = match rc.mode {
        Mode::Pipelined => Occupancy::Full,
        Mode::Sequential => Occupancy::Single,
        Mode::Hybrid => {
            anyhow::bail!("hybrid schedule needs a mid-run drain: use --runtime scheduler")
        }
    };
    anyhow::ensure!(
        rc.eval_every == 0,
        "threaded runtime evaluates at the end only; rerun with --eval-every 0"
    );
    let use_xla = resolve_xla(rc);
    let meta = resolve_meta(&rc.config, rc.partition, use_xla)?;
    let (train_ds, test_ds) = build_datasets(rc, &meta)?;
    let plan = match &rc.fault_plan {
        Some(text) => FaultPlan::parse(text).context("parsing --fault-plan")?,
        None => FaultPlan::default(),
    };
    if !plan.faults.is_empty() {
        log::warn!("fault plan armed: {plan}");
    }
    let injector = Arc::new(FaultInjector::new(plan));
    let store = checkpoint_store(rc)?;

    log::info!(
        "train {} [threaded]: mode={} iters={} batch={} P={} on_failure={}",
        meta.config,
        rc.mode.name(),
        rc.iters,
        meta.batch,
        meta.partitions.len(),
        rc.on_failure.name()
    );
    let outcome = if use_xla {
        supervise_threaded(XlaWorkerBackend, rc, &meta, &train_ds, &injector, store.as_ref(), occupancy)?
    } else {
        supervise_threaded(
            NativeWorkerBackend,
            rc,
            &meta,
            &train_ds,
            &injector,
            store.as_ref(),
            occupancy,
        )?
    };
    let trained = outcome.params;

    let mut rec = Recorder::new();
    for e in &outcome.events {
        rec.train_event(e);
    }
    if let Some(path) = &rc.save_to {
        crate::model::checkpoint::save(path, &trained, rc.iters)?;
        log::info!("saved checkpoint to {}", path.display());
    }
    // Final eval on a scheduler pipeline over the same backend.
    let optims = build_optims(&meta, rc.iters, rc.stale_lr_scale);
    let final_accuracy = if use_xla {
        let runtime = Runtime::cpu()?;
        let exec = XlaExecutor::new(&runtime, meta.clone(), trained, optims)?;
        evaluate(&mut Pipeline::new(exec, meta.batch), &test_ds, meta.batch)?
    } else {
        let exec = NativeExecutor::new(meta.clone(), trained, optims)?;
        evaluate(&mut Pipeline::new(exec, meta.batch), &test_ds, meta.batch)?
    };
    rec.eval_point(rc.iters, final_accuracy);

    Ok(TrainResult {
        config: meta.config.clone(),
        mode: rc.mode.name().to_string(),
        runtime: rc.runtime.name().to_string(),
        iters: rc.iters,
        final_accuracy,
        final_train_loss: rec.recent_loss(50),
        wall_seconds: outcome.wall,
        restarts: outcome.restarts,
        degraded: outcome.degraded,
        recorder: rec,
    })
}

/// What the threaded supervisor hands back after the run completes.
struct SuperviseOutcome {
    events: Vec<TrainEvent>,
    params: ModelParams,
    wall: f64,
    restarts: u32,
    degraded: bool,
}

/// First iteration of the segment after `at` (segments are
/// `ckpt_every`-sized; 0 means one segment spanning the whole run).
fn segment_end(at: u64, every: u64, iters: u64) -> u64 {
    if every == 0 {
        iters
    } else {
        (at + every).min(iters)
    }
}

/// Where a (re)started generation picks up: the newest valid rotating
/// checkpoint when one exists, the configured initial weights at batch
/// 0 otherwise. Corrupt or truncated files in the store are skipped by
/// `newest_valid`, so a damaged newest checkpoint costs one segment of
/// recomputation, not the run.
fn restore_point(
    rc: &RunConfig,
    meta: &ConfigMeta,
    store: Option<&CheckpointStore>,
) -> Result<(ModelParams, u64)> {
    if let Some(store) = store {
        if let Some((params, at)) = store.newest_valid(Some(meta)) {
            log::info!("restored checkpoint at iter {at} from {}", store.dir().display());
            return Ok((params, at));
        }
    }
    Ok((initial_params(rc, meta)?, 0))
}

/// The checkpoint-restart supervisor (DESIGN.md §8). Training runs in
/// `ckpt_every`-sized segments; each segment is one pipeline
/// *generation* — launch, `train_range(at..end)` with absolute batch
/// ids and a replayed data stream, drain, collect weights, checkpoint.
/// Segment boundaries are drained, so a checkpoint is never torn and a
/// restarted segment recomputes exactly the batches the failed
/// generation owed: a run with mid-train failures is bitwise the
/// segmented run without them.
///
/// On failure: tear down, back off (capped exponential), restore the
/// newest valid checkpoint, rewind the event log to it, relaunch. The
/// per-segment retry budget `max_restarts` bounds livelock on a
/// persistent fault; exhausting it fails the run (`Restart`) or — once
/// — drops to single occupancy for the remainder (`Degrade`), trading
/// pipeline speedup for the sequential schedule's sturdier footprint.
#[allow(clippy::too_many_arguments)]
fn supervise_threaded<B: WorkerBackend>(
    backend: B,
    rc: &RunConfig,
    meta: &ConfigMeta,
    train_ds: &Arc<StreamDataset>,
    injector: &Arc<FaultInjector>,
    store: Option<&CheckpointStore>,
    occupancy: Occupancy,
) -> Result<SuperviseOutcome> {
    let mut occupancy = occupancy;
    let (mut params, mut at) = restore_point(rc, meta, store)?;
    let mut events: Vec<TrainEvent> = Vec::new();
    let mut wall = 0.0f64;
    let mut restarts = 0u32;
    let mut degraded = false;
    let mut budget_used = 0u32;
    let stall_timeout = Duration::from_millis(rc.stall_timeout_ms.max(1));

    while at < rc.iters {
        let end = segment_end(at, rc.ckpt_every, rc.iters);
        let attempt = run_segment(
            &backend, rc, meta, train_ds, injector, &params, at, end, occupancy, stall_timeout,
        );
        match attempt {
            Ok((ev, w, trained)) => {
                events.extend(ev);
                wall += w;
                params = trained;
                at = end;
                budget_used = 0;
                if let Some(store) = store {
                    if rc.ckpt_every > 0 && at < rc.iters {
                        let path = store.save(&params, at)?;
                        injector.after_checkpoint(&path)?;
                        log::info!("checkpointed iter {at} to {}", path.display());
                    }
                }
            }
            Err(e) => {
                if rc.on_failure == OnFailure::Fail {
                    return Err(e);
                }
                budget_used += 1;
                restarts += 1;
                if budget_used > rc.max_restarts {
                    if rc.on_failure == OnFailure::Degrade && !degraded {
                        degraded = true;
                        occupancy = Occupancy::Single;
                        budget_used = 0;
                        log::warn!(
                            "retry budget ({}) exhausted; degrading to single occupancy: {e:#}",
                            rc.max_restarts
                        );
                    } else {
                        return Err(e)
                            .with_context(|| format!("retry budget ({}) exhausted", rc.max_restarts));
                    }
                } else {
                    log::warn!(
                        "worker failure (restart {budget_used}/{}): {e:#}",
                        rc.max_restarts
                    );
                }
                let exp = budget_used.saturating_sub(1).min(6);
                let backoff = rc.restart_backoff_ms.saturating_mul(1u64 << exp).min(10_000);
                if backoff > 0 {
                    std::thread::sleep(Duration::from_millis(backoff));
                }
                let (p, a) = restore_point(rc, meta, store)?;
                params = p;
                at = a;
                // The restore may land before segments we already hold
                // events for (a damaged newer checkpoint was skipped):
                // drop them — the replayed segments re-produce them.
                events.retain(|ev| ev.batch_id < at);
            }
        }
    }
    Ok(SuperviseOutcome { events, params, wall, restarts, degraded })
}

/// One pipeline generation: launch fresh workers on `params`, replay
/// the deterministic data stream up to `at`, train `at..end`, drain and
/// hand the weights back. Everything a restart needs to redo lives in
/// here; everything it must not redo (event log, checkpoints, fired
/// faults) lives with the supervisor.
#[allow(clippy::too_many_arguments)]
fn run_segment<B: WorkerBackend>(
    backend: &B,
    rc: &RunConfig,
    meta: &ConfigMeta,
    train_ds: &Arc<StreamDataset>,
    injector: &Arc<FaultInjector>,
    params: &ModelParams,
    at: u64,
    end: u64,
    occupancy: Occupancy,
    stall_timeout: Duration,
) -> Result<(Vec<TrainEvent>, f64, ModelParams)> {
    let optims = build_optims(meta, rc.iters, rc.stale_lr_scale);
    let opts = ThreadedOptions { occupancy, stall_timeout, staleness_fix: rc.staleness_fix };
    let faulty = FaultyWorkerBackend::new(backend.clone(), Arc::clone(injector));
    let mut pipe = ThreadedPipeline::launch_with(faulty, meta, params.clone(), optims, opts)?;
    // `start: at` replays the deterministic shuffle (and per-sample
    // augmentation draws) up to the restore point — the stream a
    // restarted generation sees is bitwise the one the failed
    // generation would have fed.
    let mut stream = BatchStream::new(Arc::clone(train_ds), stream_options(rc, meta, at))?;
    let (ev, w) = pipe.train_range(at, end, rc.seed, |_| stream.next_batch())?;
    let trained = pipe.shutdown()?;
    Ok((ev, w, trained))
}

/// XLA-backend variant that reuses an existing runtime/artifacts
/// (benches share one PJRT client across many runs).
pub fn run_with_runtime(
    rc: &RunConfig,
    meta: &ConfigMeta,
    runtime: &Runtime,
) -> Result<TrainResult> {
    let (train_ds, test_ds) = build_datasets(rc, meta)?;
    let params = initial_params(rc, meta)?;
    let optims = build_optims(meta, rc.iters, rc.stale_lr_scale);
    let exec = XlaExecutor::new(runtime, meta.clone(), params, optims)?;
    train_loop(rc, meta, exec, &train_ds, &test_ds)
}

/// Native-backend variant: pure-Rust kernels, no artifacts required.
pub fn run_native(rc: &RunConfig) -> Result<TrainResult> {
    let meta = resolve_meta(&rc.config, rc.partition, false)?;
    let (train_ds, test_ds) = build_datasets(rc, &meta)?;
    let params = initial_params(rc, &meta)?;
    let optims = build_optims(&meta, rc.iters, rc.stale_lr_scale);
    let exec = NativeExecutor::new(meta.clone(), params, optims)?;
    train_loop(rc, &meta, exec, &train_ds, &test_ds)
}

fn build_datasets(rc: &RunConfig, meta: &ConfigMeta) -> Result<(Arc<StreamDataset>, Dataset)> {
    let spec = SyntheticSpec {
        train: rc.train_size,
        test: rc.test_size,
        noise: rc.noise as f32,
        seed: rc.seed ^ 0x5eed_da7a,
    };
    let (train_ds, test_ds) = load_streaming(&meta.dataset, rc.data_dir.as_deref(), &spec)?;
    anyhow::ensure!(
        train_ds.input_shape == meta.input_shape,
        "dataset shape {:?} vs model input {:?}",
        train_ds.input_shape,
        meta.input_shape
    );
    Ok((Arc::new(train_ds), test_ds))
}

/// Stream configuration for a training run (or a segment of one,
/// replayed from batch `start`). The shuffle seed matches the
/// pre-streaming `Batcher` salt, so legacy runs replay bitwise; the
/// augmentation seed is the run seed itself, keyed per (epoch, sample)
/// inside the stream.
fn stream_options(rc: &RunConfig, meta: &ConfigMeta, start: u64) -> StreamOptions {
    StreamOptions {
        batch: meta.batch,
        shuffle_seed: rc.seed ^ 0xba7c4,
        aug_seed: rc.seed,
        start,
        augment: if rc.augment { Augment::standard(&meta.dataset) } else { Augment::none() },
        threads: rc.prefetch,
        depth: 0,
    }
}

/// The run's starting weights: `--resume-from` a checkpoint file, or a
/// checkpoint *directory* (rotating store: the newest valid file wins
/// and damaged ones are skipped), or seeded random init.
fn initial_params(rc: &RunConfig, meta: &ConfigMeta) -> Result<ModelParams> {
    match &rc.resume_from {
        Some(path) if path.is_dir() => {
            let store = CheckpointStore::open(path, rc.ckpt_keep)?;
            let (p, at) = store.newest_valid(Some(meta)).ok_or_else(|| {
                anyhow!("no valid checkpoint to resume from in {}", path.display())
            })?;
            log::info!(
                "resumed weights from {} (newest valid, saved at iter {at})",
                path.display()
            );
            Ok(p)
        }
        Some(path) => {
            let (p, at) = crate::model::checkpoint::load(path)?;
            crate::model::checkpoint::validate(&p, meta)?;
            log::info!("resumed weights from {} (saved at iter {at})", path.display());
            Ok(p)
        }
        None => ModelParams::init(&meta.partitions, rc.seed),
    }
}

/// The backend-agnostic training loop: any `StageExecutor` plugged into
/// the cycle-accurate pipeline, with the paper's schedule switching.
fn train_loop<E: StageExecutor>(
    rc: &RunConfig,
    meta: &ConfigMeta,
    mut exec: E,
    train_ds: &Arc<StreamDataset>,
    test_ds: &Dataset,
) -> Result<TrainResult> {
    // Freshly built executor = drained pipeline, the one safe moment to
    // install a mitigation (its per-partition state must start empty).
    exec.set_staleness_fix(rc.staleness_fix)?;
    let mut pipe = Pipeline::new(exec, meta.batch);
    let mut stream = BatchStream::new(Arc::clone(train_ds), stream_options(rc, meta, 0))?;

    let schedule = match rc.mode {
        Mode::Pipelined => HybridSchedule::all_pipelined(rc.iters),
        Mode::Sequential => HybridSchedule::all_sequential(rc.iters),
        Mode::Hybrid => HybridSchedule::new(rc.pipelined_iters, rc.iters),
    };

    let mut rec = Recorder::new();
    // Same event accounting the threaded coordinator enforces: every
    // fed batch produces exactly one event, in batch order.
    let mut ledger = EventLedger::new();
    // Periodic rotating checkpoints (crash-resumable via
    // `--resume-from <dir>`). NOTE: in pipelined mode each checkpoint
    // drains the pipe first — a consistent snapshot, at the cost of a
    // refill and the staleness blip that implies (like the hybrid
    // switch, and like the threaded runtime's segment boundaries).
    let store = checkpoint_store(rc)?;
    let start = std::time::Instant::now();
    let mut fed = 0u64;

    log::info!(
        "train {}: mode={} iters={} batch={} P={} stages={} %stale={:.1}",
        meta.config,
        rc.mode.name(),
        rc.iters,
        meta.batch,
        meta.partitions.len(),
        meta.paper_stages(),
        100.0 * meta.stale_weight_fraction()
    );

    while fed < rc.iters {
        let phase = schedule.phase(fed);
        if phase == Phase::DrainThenSequential {
            for e in pipe.drain()? {
                ledger.record(e.clone())?;
                rec.train_event(&e);
            }
            log::info!("hybrid switch at iter {fed}: pipeline drained");
        }
        let (x, labels) = stream.next_batch()?;
        let feed = Feed { batch_id: fed, seed: batch_seed(rc.seed, fed), x, labels };
        match phase {
            Phase::Pipelined => {
                if let Some(e) = pipe.cycle(Some(feed))? {
                    ledger.record(e.clone())?;
                    rec.train_event(&e);
                }
            }
            _ => {
                let e = pipe.sequential_step(feed)?;
                ledger.record(e.clone())?;
                rec.train_event(&e);
            }
        }
        fed += 1;
        if let Some(store) = &store {
            if rc.ckpt_every > 0 && fed % rc.ckpt_every == 0 && fed < rc.iters {
                for e in pipe.drain()? {
                    ledger.record(e.clone())?;
                    rec.train_event(&e);
                }
                let path = store.save(&pipe.exec.params_snapshot(), fed)?;
                log::info!("checkpointed iter {fed} to {}", path.display());
            }
        }
        if rc.eval_every > 0 && fed % rc.eval_every == 0 {
            // NOTE: in pipelined mode some batches are still in flight;
            // eval reflects the weights as of this cycle, like the
            // paper's periodic tests during training.
            let acc = evaluate(&mut pipe, test_ds, meta.batch)?;
            rec.eval_point(fed, acc);
            log::info!("iter {fed}: test acc {:.2}%", 100.0 * acc);
        }
    }
    for e in pipe.drain()? {
        ledger.record(e.clone())?;
        rec.train_event(&e);
    }
    ledger.expect_complete(rc.iters)?;
    let final_accuracy = evaluate(&mut pipe, test_ds, meta.batch)?;
    rec.eval_point(rc.iters, final_accuracy);
    if let Some(path) = &rc.save_to {
        crate::model::checkpoint::save(path, &pipe.exec.params_snapshot(), rc.iters)?;
        log::info!("saved checkpoint to {}", path.display());
    }
    let wall = start.elapsed().as_secs_f64();

    Ok(TrainResult {
        config: meta.config.clone(),
        mode: rc.mode.name().to_string(),
        runtime: rc.runtime.name().to_string(),
        iters: rc.iters,
        final_accuracy,
        final_train_loss: rec.recent_loss(50),
        wall_seconds: wall,
        restarts: 0,
        degraded: false,
        recorder: rec,
    })
}
