//! Training metrics: loss/accuracy curves, CSV/JSON export.

use crate::pipeline::TrainEvent;
use crate::util::json::{self, Json};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalPoint {
    pub iter: u64,
    pub accuracy: f64,
}

#[derive(Debug, Clone, Default)]
pub struct Recorder {
    /// (batch_id, loss, batch_accuracy)
    pub train: Vec<(u64, f32, f32)>,
    pub evals: Vec<EvalPoint>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn train_event(&mut self, e: &TrainEvent) {
        let acc = if e.batch_size > 0 { e.correct / e.batch_size as f32 } else { 0.0 };
        self.train.push((e.batch_id, e.loss, acc));
    }

    pub fn eval_point(&mut self, iter: u64, accuracy: f64) {
        self.evals.push(EvalPoint { iter, accuracy });
    }

    /// Mean loss over the last `n` retired batches.
    pub fn recent_loss(&self, n: usize) -> f64 {
        if self.train.is_empty() {
            return f64::NAN;
        }
        let tail = &self.train[self.train.len().saturating_sub(n)..];
        tail.iter().map(|(_, l, _)| *l as f64).sum::<f64>() / tail.len() as f64
    }

    /// Mean batch accuracy over the last `n` retired batches.
    pub fn recent_train_acc(&self, n: usize) -> f64 {
        if self.train.is_empty() {
            return f64::NAN;
        }
        let tail = &self.train[self.train.len().saturating_sub(n)..];
        tail.iter().map(|(_, _, a)| *a as f64).sum::<f64>() / tail.len() as f64
    }

    pub fn best_eval(&self) -> Option<EvalPoint> {
        self.evals
            .iter()
            .copied()
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
    }

    /// losses as CSV: iter,loss,batch_acc
    pub fn train_csv(&self) -> String {
        let mut out = String::from("iter,loss,batch_acc");
        for (i, l, a) in &self.train {
            out.push_str(&format!("\n{i},{l},{a}"));
        }
        out
    }

    /// eval curve as CSV: iter,test_acc
    pub fn eval_csv(&self) -> String {
        let mut out = String::from("iter,test_acc");
        for e in &self.evals {
            out.push_str(&format!("\n{},{}", e.iter, e.accuracy));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            json::obj(vec![
                                ("iter", json::num(e.iter as f64)),
                                ("acc", json::num(e.accuracy)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("final_loss", json::num(self.recent_loss(50))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(b: u64, loss: f32, correct: f32) -> TrainEvent {
        TrainEvent { batch_id: b, loss, correct, batch_size: 10, cycle: b }
    }

    #[test]
    fn records_and_summarizes() {
        let mut r = Recorder::new();
        for b in 0..10 {
            r.train_event(&ev(b, 2.0 - b as f32 * 0.1, b as f32));
        }
        assert_eq!(r.train.len(), 10);
        assert!(r.recent_loss(5) < 2.0);
        assert!((r.recent_train_acc(1) - 0.9).abs() < 1e-6);
        r.eval_point(10, 0.5);
        r.eval_point(20, 0.7);
        assert_eq!(r.best_eval().unwrap().iter, 20);
    }

    #[test]
    fn csv_shapes() {
        let mut r = Recorder::new();
        r.train_event(&ev(0, 1.5, 3.0));
        r.eval_point(1, 0.25);
        assert_eq!(r.train_csv().lines().count(), 2);
        assert!(r.eval_csv().contains("1,0.25"));
        assert!(r.to_json().to_string().contains("evals"));
    }

    #[test]
    fn empty_recorder_is_nan() {
        let r = Recorder::new();
        assert!(r.recent_loss(5).is_nan());
        assert!(r.best_eval().is_none());
    }
}
