//! Parsed `artifacts/<config>/meta.json` — the L2→L3 contract.
//!
//! The AOT driver (python/compile/aot.py) records, per partition, the
//! exact positional layout of every stage program's inputs and outputs,
//! parameter/state initialization specs, carry shapes, and the per-layer
//! data (param counts, activation sizes, FLOPs) behind the staleness and
//! memory models.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub fan_in: usize,
}

#[derive(Debug, Clone)]
pub struct StateSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
}

#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    pub param_count: usize,
    pub carry_elems_per_sample: usize,
    pub flops_per_sample: u64,
}

#[derive(Debug, Clone)]
pub struct PartitionMeta {
    pub index: usize,
    pub layer_lo: usize,
    pub layer_hi: usize,
    pub param_count: usize,
    pub params: Vec<ParamSpec>,
    pub state: Vec<StateSpec>,
    pub carry_in: Vec<Vec<usize>>,
    pub carry_out: Vec<Vec<usize>>,
    pub programs: BTreeMap<String, String>,
}

impl PartitionMeta {
    pub fn is_last(&self) -> bool {
        self.programs.contains_key("last")
    }
}

#[derive(Debug, Clone)]
pub struct ConfigMeta {
    pub dir: PathBuf,
    pub config: String,
    pub model: String,
    pub width_mult: f64,
    pub batch: usize,
    pub dataset: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub num_layers: usize,
    pub ppv: Vec<usize>,
    pub meta_only: bool,
    pub layers: Vec<LayerMeta>,
    pub partitions: Vec<PartitionMeta>,
}

impl ConfigMeta {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(dir, &j)
    }

    /// Load `artifacts/<name>` relative to a root (default `artifacts/`).
    pub fn load_named(root: &Path, name: &str) -> Result<Self> {
        Self::load(&root.join(name))
    }

    fn from_json(dir: &Path, j: &Json) -> Result<Self> {
        let gs = |k: &str| -> Result<String> {
            Ok(j.get(k).and_then(Json::as_str).ok_or_else(|| anyhow!("meta missing {k}"))?.to_string())
        };
        let gu = |k: &str| -> Result<usize> {
            j.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("meta missing {k}"))
        };

        let layers = j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("meta missing layers"))?
            .iter()
            .map(|l| -> Result<LayerMeta> {
                Ok(LayerMeta {
                    name: l.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                    param_count: l.get("param_count").and_then(Json::as_usize).unwrap_or(0),
                    carry_elems_per_sample: l
                        .get("carry_elems_per_sample")
                        .and_then(Json::as_usize)
                        .unwrap_or(0),
                    flops_per_sample: l
                        .get("flops_per_sample")
                        .and_then(Json::as_i64)
                        .unwrap_or(0) as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let partitions = j
            .get("partitions")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("meta missing partitions"))?
            .iter()
            .map(|p| parse_partition(p))
            .collect::<Result<Vec<_>>>()?;

        let meta = ConfigMeta {
            dir: dir.to_path_buf(),
            config: gs("config")?,
            model: gs("model")?,
            width_mult: j.get("width_mult").and_then(Json::as_f64).unwrap_or(1.0),
            batch: gu("batch")?,
            dataset: gs("dataset")?,
            input_shape: j
                .get("input_shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("meta missing input_shape"))?,
            num_classes: gu("num_classes")?,
            num_layers: gu("num_layers")?,
            ppv: j
                .get("ppv")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("meta missing ppv"))?,
            meta_only: j.get("meta_only").and_then(Json::as_bool).unwrap_or(false),
            layers,
            partitions,
        };
        meta.validate()?;
        Ok(meta)
    }

    fn validate(&self) -> Result<()> {
        if self.partitions.len() != self.ppv.len() + 1 {
            bail!("{}: {} partitions but ppv {:?}", self.config, self.partitions.len(), self.ppv);
        }
        if self.layers.len() != self.num_layers {
            bail!("{}: layer metadata arity mismatch", self.config);
        }
        // PPV well-formedness: strictly increasing cuts, each inside
        // 1..num_layers. models.rs re-checks this for native built-ins,
        // but artifact meta.json files must be rejected uniformly at
        // load too — a malformed PPV otherwise surfaces much later as a
        // bogus staleness degree or a panicking layer slice.
        if let Some(w) = self.ppv.windows(2).find(|w| w[0] >= w[1]) {
            bail!(
                "{}: PPV {:?} is not strictly increasing (cut {} then {})",
                self.config,
                self.ppv,
                w[0],
                w[1]
            );
        }
        if let Some(&bad) = self.ppv.iter().find(|&&c| c < 1 || c >= self.num_layers) {
            bail!(
                "{}: PPV cut {bad} out of bounds for {} layers (cuts must lie in 1..{})",
                self.config,
                self.num_layers,
                self.num_layers
            );
        }
        for (a, b) in self.partitions.iter().zip(self.partitions.iter().skip(1)) {
            if a.carry_out != b.carry_in {
                bail!("carry chain mismatch between partitions {} and {}", a.index, b.index);
            }
            if a.layer_hi + 1 != b.layer_lo {
                bail!("layer range gap between partitions {} and {}", a.index, b.index);
            }
        }
        let last = self.partitions.last().unwrap();
        if !last.is_last() {
            bail!("{}: final partition lacks fused last program", self.config);
        }
        Ok(())
    }

    /// Number of pipeline register pairs (K).
    pub fn num_registers(&self) -> usize {
        self.ppv.len()
    }

    /// Paper stage count: 2K + 2 (K+1 forward + K+1 backward stages).
    pub fn paper_stages(&self) -> usize {
        2 * self.ppv.len() + 2
    }

    /// Paper §3: percentage of stale weights = sum_{i<=K} N_i / sum N_i.
    pub fn stale_weight_fraction(&self) -> f64 {
        let total: usize = self.partitions.iter().map(|p| p.param_count).sum();
        if total == 0 {
            return 0.0;
        }
        let stale: usize = self
            .partitions
            .iter()
            .take(self.partitions.len() - 1)
            .map(|p| p.param_count)
            .sum();
        stale as f64 / total as f64
    }

    /// Paper §3: degree of staleness of partition i (1-based) = 2(K-i+1).
    pub fn degree_of_staleness(&self, partition_index: usize) -> usize {
        let k = self.num_registers();
        2 * (k + 1 - partition_index)
    }

    pub fn total_params(&self) -> usize {
        self.partitions.iter().map(|p| p.param_count).sum()
    }

    pub fn program_path(&self, part: &PartitionMeta, which: &str) -> Result<PathBuf> {
        let f = part
            .programs
            .get(which)
            .ok_or_else(|| anyhow!("partition {} has no {which} program", part.index))?;
        Ok(self.dir.join(f))
    }
}

fn parse_partition(p: &Json) -> Result<PartitionMeta> {
    let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
        p.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("partition missing {key}"))?
            .iter()
            .map(|s| s.as_usize_vec().ok_or_else(|| anyhow!("bad shape in {key}")))
            .collect()
    };
    let params = p
        .get("params")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("partition missing params"))?
        .iter()
        .map(|s| -> Result<ParamSpec> {
            Ok(ParamSpec {
                name: s.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                shape: s.get("shape").and_then(Json::as_usize_vec).unwrap_or_default(),
                init: s.get("init").and_then(Json::as_str).unwrap_or("zeros").to_string(),
                fan_in: s.get("fan_in").and_then(Json::as_usize).unwrap_or(0),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let state = p
        .get("state")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("partition missing state"))?
        .iter()
        .map(|s| -> Result<StateSpec> {
            Ok(StateSpec {
                name: s.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                shape: s.get("shape").and_then(Json::as_usize_vec).unwrap_or_default(),
                init: s.get("init").and_then(Json::as_str).unwrap_or("zeros").to_string(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let programs = p
        .get("programs")
        .and_then(|v| match v {
            Json::Obj(m) => Some(m),
            _ => None,
        })
        .ok_or_else(|| anyhow!("partition missing programs"))?
        .iter()
        .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
        .collect();

    Ok(PartitionMeta {
        index: p.get("index").and_then(Json::as_usize).unwrap_or(0),
        layer_lo: p.get("layer_lo").and_then(Json::as_usize).unwrap_or(0),
        layer_hi: p.get("layer_hi").and_then(Json::as_usize).unwrap_or(0),
        param_count: p.get("param_count").and_then(Json::as_usize).unwrap_or(0),
        params,
        state,
        carry_in: shapes("carry_in")?,
        carry_out: shapes("carry_out")?,
        programs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_quickstart_meta() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let m = ConfigMeta::load_named(&artifacts_root(), "quickstart_lenet").unwrap();
        assert_eq!(m.model, "lenet5");
        assert_eq!(m.num_layers, 5);
        assert_eq!(m.partitions.len(), 2);
        assert!(m.partitions[1].is_last());
        assert_eq!(m.batch, 32);
        assert_eq!(m.input_shape, vec![28, 28, 1]);
    }

    #[test]
    fn staleness_accounting_matches_paper_definitions() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let m = ConfigMeta::load_named(&artifacts_root(), "resnet20_fine8").unwrap();
        // K=3 registers -> 8 paper stages; degrees 2K..2 for partitions 1..K
        assert_eq!(m.paper_stages(), 8);
        assert_eq!(m.degree_of_staleness(1), 6);
        assert_eq!(m.degree_of_staleness(3), 2);
        assert_eq!(m.degree_of_staleness(4), 0);
        let f = m.stale_weight_fraction();
        assert!(f > 0.0 && f < 1.0);
    }

    #[test]
    fn carry_chain_validated() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let m = ConfigMeta::load_named(&artifacts_root(), "resnet20_4s").unwrap();
        for (a, b) in m.partitions.iter().zip(m.partitions.iter().skip(1)) {
            assert_eq!(a.carry_out, b.carry_in);
        }
        assert_eq!(m.total_params(), m.layers.iter().map(|l| l.param_count).sum());
    }

    #[test]
    fn slide_fraction_monotone() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        // Fig 6 premise: %stale grows with the slide position.
        let mut prev = 0.0;
        for p in [3usize, 9, 15, 19] {
            let m = ConfigMeta::load_named(&artifacts_root(), &format!("resnet20_slide{p}")).unwrap();
            let f = m.stale_weight_fraction();
            assert!(f > prev, "p={p} f={f} prev={prev}");
            prev = f;
        }
    }

    /// Minimal hand-written meta.json (3 layers, 3 single-layer
    /// partitions) with a substitutable PPV — no artifacts needed.
    fn mini_meta(ppv: &str) -> String {
        format!(
            r#"{{
  "config": "mini", "model": "toy", "batch": 2, "dataset": "synthetic",
  "input_shape": [4], "num_classes": 2, "num_layers": 3, "ppv": {ppv},
  "meta_only": true,
  "layers": [
    {{"name": "l1", "param_count": 0, "carry_elems_per_sample": 3, "flops_per_sample": 10}},
    {{"name": "l2", "param_count": 0, "carry_elems_per_sample": 2, "flops_per_sample": 10}},
    {{"name": "l3", "param_count": 0, "carry_elems_per_sample": 2, "flops_per_sample": 10}}
  ],
  "partitions": [
    {{"index": 1, "layer_lo": 1, "layer_hi": 1, "param_count": 0, "params": [], "state": [],
      "carry_in": [[2, 4]], "carry_out": [[2, 3]],
      "programs": {{"fwd": "f", "bwd": "b", "fwd_eval": "e"}}}},
    {{"index": 2, "layer_lo": 2, "layer_hi": 2, "param_count": 0, "params": [], "state": [],
      "carry_in": [[2, 3]], "carry_out": [[2, 2]],
      "programs": {{"fwd": "f", "bwd": "b", "fwd_eval": "e"}}}},
    {{"index": 3, "layer_lo": 3, "layer_hi": 3, "param_count": 0, "params": [], "state": [],
      "carry_in": [[2, 2]], "carry_out": [[2, 2]],
      "programs": {{"last": "l", "last_eval": "le"}}}}
  ]
}}"#
        )
    }

    #[test]
    fn ppv_monotonicity_and_bounds_rejected_at_load() {
        let dir = std::env::temp_dir().join(format!("pipestale_meta_ppv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let write = |ppv: &str| std::fs::write(dir.join("meta.json"), mini_meta(ppv)).unwrap();
        // A well-formed PPV loads.
        write("[1, 2]");
        let m = ConfigMeta::load(&dir).unwrap();
        assert_eq!(m.ppv, vec![1, 2]);
        // Regression: all of these passed the arity-only validation —
        // non-strict, decreasing, and out-of-bounds cuts (cuts must lie
        // in 1..num_layers) now fail uniformly at load.
        for bad in ["[2, 2]", "[2, 1]", "[0, 2]", "[1, 3]"] {
            write(bad);
            let err = ConfigMeta::load(&dir).unwrap_err().to_string();
            assert!(err.contains("PPV"), "{bad}: {err}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn meta_only_configs_load() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let m = ConfigMeta::load_named(&artifacts_root(), "resnet362_mem").unwrap();
        assert!(m.meta_only);
        assert_eq!(m.num_layers, 362);
    }
}
