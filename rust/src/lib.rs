//! pipestale — pipelined backpropagation training with stale weights.
//!
//! A Rust + JAX + Pallas reproduction of Zhang & Abdelrahman (2019),
//! *Pipelined Training with Stale Weights of Deep Convolutional Neural
//! Networks*. The Rust coordinator (this crate) owns weights, schedules
//! the cycle-accurate pipeline of Figure 4, and executes AOT-compiled XLA
//! stage programs via PJRT; Python/JAX/Pallas run only at build time.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured results.
//!
//! Public items must carry doc comments (`missing_docs` warns, and CI
//! builds docs with `RUSTDOCFLAGS="-D warnings"`). Modules not yet
//! brought up to that bar carry an explicit `#[allow(missing_docs)]`
//! below — shrink that list, never grow it.

#![warn(missing_docs)]

pub mod backend;
#[allow(missing_docs)]
pub mod config;
#[allow(missing_docs)]
pub mod data;
#[allow(missing_docs)]
pub mod memory;
#[allow(missing_docs)]
pub mod meta;
#[allow(missing_docs)]
pub mod model;
pub mod optim;
pub mod pipeline;
pub mod pool;
pub mod profile;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod tensor;
#[allow(missing_docs)]
pub mod train;
#[allow(missing_docs)]
pub mod util;

use std::path::PathBuf;

/// Default artifacts root: $PIPESTALE_ARTIFACTS or <crate>/artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("PIPESTALE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// True when the AOT artifact set has been built (`make artifacts`).
/// Artifact-dependent tests and benches skip gracefully when absent.
pub fn artifacts_present() -> bool {
    artifacts_root().join("quickstart_lenet").join("meta.json").exists()
}

/// True when both the artifacts and a real (non-stub) XLA backend are
/// available, i.e. stage programs can actually compile and run.
pub fn xla_ready() -> bool {
    runtime::backend_available() && artifacts_present()
}

/// Default results dir for bench/table outputs. Creation failures are
/// surfaced (not swallowed): callers writing results will also fail, and
/// the log line explains why.
pub fn results_root() -> PathBuf {
    let p = std::env::var("PIPESTALE_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results"));
    if let Err(e) = std::fs::create_dir_all(&p) {
        log::warn!("could not create results dir {}: {e}", p.display());
    }
    p
}
