//! Shared GEMM compute core: cache-blocked, register-tiled `sgemm`
//! with packed panels, plus the im2col/col2im lowering that turns
//! convolution into matrix multiplication.
//!
//! This is the hot path of every native training step. `kernels.rs`
//! routes conv2d forward (im2col + GEMM), conv2d backward (weight
//! gradient as a GEMM over the im2col buffer, input gradient as a GEMM
//! followed by col2im) and dense forward/backward through this one
//! core, so there is exactly one inner loop to optimize and one
//! floating-point summation order to reason about.
//!
//! # Blocking scheme (BLIS-style)
//!
//! The classic five-loop decomposition: C is computed in `MC x NC`
//! macro-tiles; for each `KC`-deep slice of the inner dimension, a
//! `KC x NC` panel of B and an `MC x KC` panel of A are *packed* into
//! contiguous scratch so the micro-kernel streams cache-resident,
//! unit-stride data. The micro-kernel itself computes an `MR x NR`
//! register tile with a single accumulator per output element — at
//! scalar, AVX2 or NEON width, selected at run time by
//! [`simd::detected`] (see `backend::simd` for the no-FMA bitwise
//! contract across kernels).
//!
//! # Threading
//!
//! With more than one configured GEMM thread
//! (`threadpool::configured_threads`), the `(jc, ic)` macro-tile grid
//! is partitioned *statically* — round-robin by flattened tile index —
//! over the slots of `backend::threadpool`, and each tile runs its
//! `pc` loop sequentially on whichever thread owns it. Different
//! threads write disjoint `MC x NC` tiles of C, so no synchronization
//! touches the inner loops, and — because assignment is by index, not
//! by timing — the work a tile's owner performs is identical at every
//! thread count.
//!
//! # Scratch lifecycle
//!
//! Each participating thread leases its own packing-panel pair from
//! *its* thread's [`TensorPool`] (`crate::pool`) at fixed sizes
//! `MC*KC` and `KC*NC` (GEMM pool workers install a thread-lifetime
//! pool scope; the calling thread uses its own, as before), and im2col
//! buffers are leased at the (finite, per-model) conv geometry sizes —
//! so after warmup a training step performs **zero heap allocations**
//! for GEMM scratch on every thread, verified by the pool-stats probes
//! in `tests/pool_and_kernel.rs` (including the cross-worker probe at
//! threads > 1; `backend::ops` accounts the footprint as
//! threads x panel-pair via [`pack_scratch_total`]). Recycled buffers
//! return with arbitrary contents; every packing routine fully
//! overwrites the region it reads back (zero-filling edge strips), so
//! no stale data can leak into a product.
//!
//! # Determinism
//!
//! The loop nest is fixed: for each output element the `k` products
//! are accumulated in ascending-`k` order within each `KC` block, and
//! the per-block partial sums are added to C in ascending block order.
//! Threading never splits `k` (the `pc` loop is sequential per tile)
//! and the SIMD kernels perform the identical per-element operation
//! sequence as the scalar oracle, so the summation order still depends
//! only on the problem shape `(m, n, k)` — never on timing, thread
//! count, ISA, or data. A given model step is therefore bitwise
//! reproducible run-to-run *and* across GEMM thread counts, which is
//! what keeps the pipeline-schedule equivalence invariants
//! (single-in-flight == sequential, threaded == scheduler) exact under
//! the GEMM lowering. For `k <= KC` the result is additionally bitwise
//! identical to a naive single-accumulator k-ordered loop.
//!
//! [`TensorPool`]: crate::pool::TensorPool

use super::simd::{self, Micro};
use super::threadpool;
use crate::pool;

/// Micro-kernel register-tile rows (accumulator tile is `MR x NR`).
pub const MR: usize = 4;
/// Micro-kernel register-tile columns.
pub const NR: usize = 8;
/// Macro-tile rows of A packed per panel (multiple of `MR`).
pub const MC: usize = 64;
/// Macro-tile columns of B packed per panel (multiple of `NR`).
pub const NC: usize = 128;
/// Inner-dimension depth of one packed panel pair.
pub const KC: usize = 256;

/// Scalars of pooled packing scratch one GEMM *thread* leases
/// (`MC*KC` for the A panel + `KC*NC` for the B panel), independent of
/// the problem size.
pub const fn pack_scratch_floats() -> usize {
    MC * KC + KC * NC
}

/// Scalars of pooled packing scratch a dispatched [`sgemm`] call may
/// lease across all participating threads — one panel pair per
/// configured GEMM thread (the worker-side pairs live in the workers'
/// own pools, but they are still part of the step's memory footprint).
/// Exposed so the op-level scratch accounting in `backend::ops` can
/// report a training step's pool footprint.
pub fn pack_scratch_total() -> usize {
    threadpool::configured_threads() * pack_scratch_floats()
}

/// Scalars of the im2col (or col2im) buffer for a conv lowering:
/// `n*oh*ow` rows of `k*k*cin` patch columns.
pub fn conv_cols_floats(n: usize, oh: usize, ow: usize, k: usize, cin: usize) -> usize {
    n * oh * ow * k * k * cin
}

#[inline(always)]
fn at(x: &[f32], trans: bool, rows: usize, cols: usize, r: usize, c: usize) -> f32 {
    // Logical (r, c) of a `rows x cols` matrix; `trans` means the
    // slice is stored as the transpose (`cols x rows`, row-major).
    debug_assert!(r < rows && c < cols);
    if trans {
        x[c * rows + r]
    } else {
        x[r * cols + c]
    }
}

/// Pack an `mc x kc` block of op(A) (rows `ic..`, cols `pc..`) into
/// MR-row strips: `ap[(strip*kc + l)*MR + r]`, zero-filling rows past
/// `mc` so edge strips multiply as zeros.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    a: &[f32],
    ta: bool,
    m: usize,
    k: usize,
    ic: usize,
    pc: usize,
    mc: usize,
    kc: usize,
    ap: &mut [f32],
) {
    let strips = (mc + MR - 1) / MR;
    for s in 0..strips {
        let row0 = ic + s * MR;
        let dst = &mut ap[s * kc * MR..(s * kc * MR) + kc * MR];
        for l in 0..kc {
            let cell = &mut dst[l * MR..l * MR + MR];
            for (r, out) in cell.iter_mut().enumerate() {
                let row = row0 + r;
                *out = if row < ic + mc { at(a, ta, m, k, row, pc + l) } else { 0.0 };
            }
        }
    }
}

/// Pack a `kc x nc` block of op(B) (rows `pc..`, cols `jc..`) into
/// NR-column strips: `bp[(strip*kc + l)*NR + c]`, zero-filling columns
/// past `nc`.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &[f32],
    tb: bool,
    k: usize,
    n: usize,
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
    bp: &mut [f32],
) {
    let strips = (nc + NR - 1) / NR;
    for s in 0..strips {
        let col0 = jc + s * NR;
        let dst = &mut bp[s * kc * NR..(s * kc * NR) + kc * NR];
        for l in 0..kc {
            let cell = &mut dst[l * NR..l * NR + NR];
            for (c, out) in cell.iter_mut().enumerate() {
                let col = col0 + c;
                *out = if col < jc + nc { at(b, tb, k, n, pc + l, col) } else { 0.0 };
            }
        }
    }
}

/// Macro-kernel over one packed panel pair: for each `MR x NR` register
/// tile, `acc[r][c] += sum_l a_panel[l*MR+r] * b_panel[l*NR+c]` with a
/// single accumulator per element (ascending-`l` order, computed by the
/// requested `simd` micro-kernel), then `C += acc` on the valid
/// sub-tile in ascending row, ascending column order.
///
/// Takes C as a raw pointer so the threaded driver can hand disjoint
/// macro-tiles of one C buffer to different threads.
///
/// # Safety
///
/// `c` must point to a live `f32` buffer of `c_len >= m*n` scalars, the
/// `(ic, jc, mc, nc)` tile must lie inside the logical `m x n` matrix,
/// and no other thread may concurrently touch this tile's elements
/// (rows `ic..ic+mc` x cols `jc..jc+nc`). Concurrent writes to
/// *disjoint* tiles of the same buffer are fine — that disjointness is
/// exactly what the threaded driver guarantees.
#[allow(clippy::too_many_arguments)]
unsafe fn macro_kernel_raw(
    micro: Micro,
    ap: &[f32],
    bp: &[f32],
    mc: usize,
    nc: usize,
    kc: usize,
    c: *mut f32,
    c_len: usize,
    ic: usize,
    jc: usize,
    n: usize,
) {
    let row_strips = (mc + MR - 1) / MR;
    let col_strips = (nc + NR - 1) / NR;
    for js in 0..col_strips {
        let b_panel = &bp[js * kc * NR..(js * kc * NR) + kc * NR];
        let col0 = jc + js * NR;
        let cols = NR.min(jc + nc - col0);
        for is in 0..row_strips {
            let a_panel = &ap[is * kc * MR..(is * kc * MR) + kc * MR];
            let row0 = ic + is * MR;
            let rows = MR.min(ic + mc - row0);
            let acc = simd::compute_tile(micro, a_panel, b_panel, kc);
            for (r, accr) in acc.iter().enumerate().take(rows) {
                let base = (row0 + r) * n + col0;
                debug_assert!(base + cols <= c_len);
                for (cc, &v) in accr[..cols].iter().enumerate() {
                    *c.add(base + cc) += v;
                }
            }
        }
    }
}

/// The scalar parity oracle: the original safe macro-kernel every
/// vectorized/threaded path is tested against.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    ap: &[f32],
    bp: &[f32],
    mc: usize,
    nc: usize,
    kc: usize,
    c: &mut [f32],
    ic: usize,
    jc: usize,
    n: usize,
) {
    macro_kernel_with(Micro::Scalar, ap, bp, mc, nc, kc, c, ic, jc, n)
}

/// Safe single-threaded wrapper over [`macro_kernel_raw`] with a
/// caller-chosen micro-kernel.
#[allow(clippy::too_many_arguments)]
fn macro_kernel_with(
    micro: Micro,
    ap: &[f32],
    bp: &[f32],
    mc: usize,
    nc: usize,
    kc: usize,
    c: &mut [f32],
    ic: usize,
    jc: usize,
    n: usize,
) {
    // SAFETY: the exclusive `&mut` borrow spans all of C, so no other
    // thread can touch any tile while this call runs.
    unsafe { macro_kernel_raw(micro, ap, bp, mc, nc, kc, c.as_mut_ptr(), c.len(), ic, jc, n) }
}

/// Single-precision GEMM: `C (+)= op(A) · op(B)` with row-major
/// operands.
///
/// * `op(A)` is the logical `m x k` left operand; with `ta == true`
///   the slice `a` is stored as its transpose (`k x m`, row-major).
/// * `op(B)` is the logical `k x n` right operand; `tb` likewise.
/// * `accumulate == false` overwrites `C` (`C = op(A)op(B)`);
///   `accumulate == true` adds into the caller's `C` — the path conv
///   bias init and gradient accumulation use.
///
/// Packing scratch is leased from each participating thread's tensor
/// pool and returned on exit; steady-state calls allocate nothing. The
/// summation order is fixed by `(m, n, k)` alone (see the module docs),
/// so results are bitwise reproducible — at any thread count and on
/// any detected micro-kernel.
///
/// This entry point auto-dispatches to [`simd::detected`] and
/// `threadpool::configured_threads`; use [`sgemm_with`] to pin both
/// axes explicitly (the parity suites and benches do).
///
/// ```
/// use pipestale::backend::gemm::sgemm;
/// // C = A (2x3) · B (3x2)
/// let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
/// let b = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0];
/// let mut c = [0.0f32; 4];
/// sgemm(false, false, 2, 2, 3, &a, &b, false, &mut c);
/// assert_eq!(c, [4.0, 5.0, 10.0, 11.0]);
/// ```
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    ta: bool,
    tb: bool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    accumulate: bool,
    c: &mut [f32],
) {
    let threads = threadpool::configured_threads();
    sgemm_with(simd::detected(), threads, ta, tb, m, n, k, a, b, accumulate, c)
}

/// [`sgemm`] with the micro-kernel and GEMM thread count pinned by the
/// caller instead of auto-detected. `threads <= 1` runs the serial
/// loop nest on the calling thread; `threads > 1` partitions the
/// macro-tile grid over the `backend::threadpool` workers (capped at
/// the tile count). Every combination returns bitwise-identical
/// results for a given `(m, n, k)` — that is the point of the design —
/// so this knob trades time, never bits.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_with(
    micro: Micro,
    threads: usize,
    ta: bool,
    tb: bool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    accumulate: bool,
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "sgemm: op(A) must hold m*k scalars");
    assert_eq!(b.len(), k * n, "sgemm: op(B) must hold k*n scalars");
    assert_eq!(c.len(), m * n, "sgemm: C must hold m*n scalars");
    if !accumulate {
        c.fill(0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if threads <= 1 {
        sgemm_serial(micro, ta, tb, m, n, k, a, b, c);
    } else {
        sgemm_tiled(micro, threads, ta, tb, m, n, k, a, b, c);
    }
}

/// The original single-threaded five-loop nest (jc -> pc -> ic), which
/// packs each B panel once per `(jc, pc)` and reuses it across the ic
/// sweep. C must already be zeroed/accumulation-ready.
#[allow(clippy::too_many_arguments)]
fn sgemm_serial(
    micro: Micro,
    ta: bool,
    tb: bool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    let mut ap = pool::acquire(MC * KC);
    let mut bp = pool::acquire(KC * NC);
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(b, tb, k, n, pc, jc, kc, nc, &mut bp);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                pack_a(a, ta, m, k, ic, pc, mc, kc, &mut ap);
                match micro {
                    Micro::Scalar => macro_kernel(&ap, &bp, mc, nc, kc, c, ic, jc, n),
                    other => macro_kernel_with(other, &ap, &bp, mc, nc, kc, c, ic, jc, n),
                }
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
}

/// Raw C pointer that may cross into pool worker threads.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: every worker writes only the macro-tiles the static
// round-robin partition assigns to its slot, and those tiles are
// pairwise disjoint regions of C (see `sgemm_tiled`); the caller
// blocks until all slots finish before the `&mut` borrow ends.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Threaded driver: the flattened `(jc, ic)` macro-tile grid is walked
/// round-robin by slot (`tile = slot, slot + t, ...`), each tile
/// running its full sequential `pc` loop on its owning thread. Static
/// assignment by index keeps every C element's summation order
/// identical to the serial nest — and to any other thread count — so
/// threading is bitwise invisible. C must already be
/// zeroed/accumulation-ready.
#[allow(clippy::too_many_arguments)]
fn sgemm_tiled(
    micro: Micro,
    threads: usize,
    ta: bool,
    tb: bool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    let it = (m + MC - 1) / MC;
    let jt = (n + NC - 1) / NC;
    let tiles = it * jt;
    let t = threads.min(tiles).max(1);
    let cp = SendPtr(c.as_mut_ptr());
    let c_len = c.len();
    threadpool::run(t, &|slot| {
        // Per-thread packing panels: slot 0 leases from the calling
        // thread's pool, workers from their own thread-lifetime pools,
        // so warm steady state allocates nothing anywhere.
        let mut ap = pool::acquire(MC * KC);
        let mut bp = pool::acquire(KC * NC);
        let mut tile = slot;
        while tile < tiles {
            let ic = (tile % it) * MC;
            let jc = (tile / it) * NC;
            let mc = MC.min(m - ic);
            let nc = NC.min(n - jc);
            let mut pc = 0;
            while pc < k {
                let kc = KC.min(k - pc);
                pack_b(b, tb, k, n, pc, jc, kc, nc, &mut bp);
                pack_a(a, ta, m, k, ic, pc, mc, kc, &mut ap);
                // SAFETY: tile indices are partitioned round-robin, so
                // exactly one slot ever touches the (ic, jc) tile, and
                // distinct tiles are disjoint in C; `threadpool::run`
                // returns only after every slot completes, keeping the
                // pointer live for all worker-side writes.
                unsafe {
                    macro_kernel_raw(micro, &ap, &bp, mc, nc, kc, cp.0, c_len, ic, jc, n);
                }
                pc += KC;
            }
            tile += t;
        }
    });
}

/// Lower an NHWC activation tensor to the im2col patch matrix:
/// row `(ni*oh + oy)*ow + ox` holds the `k*k*cin` input patch under
/// output pixel `(oy, ox)`, column-ordered `(ky*k + kx)*cin + ci` —
/// exactly the row-major flattening of an HWIO weight tensor, so
/// `conv(x, w) = im2col(x) · w` as a plain `[M, K] x [K, cout]` GEMM.
/// Padding cells are written as zeros; `cols` is fully overwritten.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    stride: usize,
    oh: usize,
    ow: usize,
    pt: usize,
    pl: usize,
    cols: &mut [f32],
) {
    let patch = k * k * cin;
    debug_assert_eq!(cols.len(), n * oh * ow * patch);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = &mut cols[((ni * oh + oy) * ow + ox) * patch..][..patch];
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pt as isize;
                    for kx in 0..k {
                        let dst = &mut row[(ky * k + kx) * cin..(ky * k + kx) * cin + cin];
                        let ix = (ox * stride + kx) as isize - pl as isize;
                        if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                            dst.fill(0.0);
                        } else {
                            let src = ((ni * h + iy as usize) * w + ix as usize) * cin;
                            dst.copy_from_slice(&x[src..src + cin]);
                        }
                    }
                }
            }
        }
    }
}

/// Adjoint of [`im2col`]: scatter-add the patch-matrix gradient back
/// onto the input layout (`dx += col2im(cols)`); entries that fell on
/// padding are dropped. `dx` is accumulated into, not overwritten —
/// callers zero it first, matching the conv-backward contract.
#[allow(clippy::too_many_arguments)]
pub fn col2im(
    cols: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    stride: usize,
    oh: usize,
    ow: usize,
    pt: usize,
    pl: usize,
    dx: &mut [f32],
) {
    let patch = k * k * cin;
    debug_assert_eq!(cols.len(), n * oh * ow * patch);
    debug_assert_eq!(dx.len(), n * h * w * cin);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = &cols[((ni * oh + oy) * ow + ox) * patch..][..patch];
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pt as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pl as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = &row[(ky * k + kx) * cin..(ky * k + kx) * cin + cin];
                        let base = ((ni * h + iy as usize) * w + ix as usize) * cin;
                        let dst = &mut dx[base..base + cin];
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d += v;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::PoolScope;
    use crate::util::rng::Pcg32;

    /// Naive k-ordered reference: one f32 accumulator per element.
    fn naive(ta: bool, tb: bool, m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for l in 0..k {
                    acc += at(a, ta, m, k, i, l) * at(b, tb, k, n, l, j);
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn randv(rng: &mut Pcg32, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn small_k_is_bitwise_equal_to_naive_k_order() {
        // k <= KC: a single packed panel pair, so the per-element
        // summation is exactly the naive ascending-k order.
        let mut rng = Pcg32::seeded(11);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (5, 9, 7), (70, 140, 37), (65, 129, 256)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let mut c = vec![0.0f32; m * n];
            sgemm(false, false, m, n, k, &a, &b, false, &mut c);
            let want = naive(false, false, m, n, k, &a, &b);
            for (i, (x, y)) in c.iter().zip(&want).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{n},{k}) elem {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn large_k_crosses_panel_boundary_within_tolerance() {
        // k > KC: partial sums per KC block; tolerance, not bitwise.
        let mut rng = Pcg32::seeded(12);
        let (m, n, k) = (17, 23, 2 * KC + 19);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        sgemm(false, false, m, n, k, &a, &b, false, &mut c);
        let want = naive(false, false, m, n, k, &a, &b);
        for (i, (x, y)) in c.iter().zip(&want).enumerate() {
            let tol = 1e-4 * (1.0 + y.abs());
            assert!((x - y).abs() <= tol, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn transposed_operands_match_naive() {
        let mut rng = Pcg32::seeded(13);
        let (m, n, k) = (13, 21, 30);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        for &(ta, tb) in &[(true, false), (false, true), (true, true)] {
            let mut c = vec![0.0f32; m * n];
            sgemm(ta, tb, m, n, k, &a, &b, false, &mut c);
            let want = naive(ta, tb, m, n, k, &a, &b);
            for (i, (x, y)) in c.iter().zip(&want).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "ta={ta} tb={tb} elem {i}");
            }
        }
    }

    #[test]
    fn accumulate_adds_onto_existing_c() {
        let mut rng = Pcg32::seeded(14);
        let (m, n, k) = (6, 10, 8);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bias = 0.5f32;
        let mut c = vec![bias; m * n];
        sgemm(false, false, m, n, k, &a, &b, true, &mut c);
        let want = naive(false, false, m, n, k, &a, &b);
        for (x, y) in c.iter().zip(&want) {
            assert_eq!(*x, bias + y, "accumulate must add exactly once onto C");
        }
        // overwrite mode ignores prior contents
        let mut c2 = vec![123.0f32; m * n];
        sgemm(false, false, m, n, k, &a, &b, false, &mut c2);
        assert_eq!(c2, want);
    }

    #[test]
    fn repeated_calls_are_bitwise_deterministic_and_allocation_free() {
        let scope = PoolScope::new();
        let pool = scope.pool().clone();
        let mut rng = Pcg32::seeded(15);
        let (m, n, k) = (48, 80, 300);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut c1 = vec![0.0f32; m * n];
        sgemm(false, false, m, n, k, &a, &b, false, &mut c1);
        let warm = pool.stats();
        let mut c2 = vec![0.0f32; m * n];
        for _ in 0..5 {
            sgemm(false, false, m, n, k, &a, &b, false, &mut c2);
        }
        let steady = pool.stats();
        assert_eq!(
            steady.fresh_allocs, warm.fresh_allocs,
            "warm sgemm calls must lease all scratch from the pool"
        );
        for (x, y) in c1.iter().zip(&c2) {
            assert_eq!(x.to_bits(), y.to_bits(), "same shape => same summation order");
        }
    }

    #[test]
    fn tiled_driver_is_bitwise_equal_to_serial_at_one_thread() {
        // Same bits despite a different packing schedule (per-tile
        // B packs instead of one per (jc, pc)): packing affects layout
        // only, never the per-element summation order.
        let mut rng = Pcg32::seeded(17);
        for &(m, n, k) in &[(1usize, 1usize, 3usize), (70, 140, 37), (65, 129, 300), (200, 30, 64)]
        {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let mut c_serial = vec![0.0f32; m * n];
            sgemm_serial(Micro::Scalar, false, false, m, n, k, &a, &b, &mut c_serial);
            let mut c_tiled = vec![0.0f32; m * n];
            sgemm_tiled(Micro::Scalar, 1, false, false, m, n, k, &a, &b, &mut c_tiled);
            for (i, (x, y)) in c_tiled.iter().zip(&c_serial).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{n},{k}) elem {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn multithreaded_sgemm_is_bitwise_equal_to_serial() {
        // The headline invariant: N GEMM threads == 1 thread == the
        // serial nest, to the bit, across edge geometries (multi-tile,
        // ragged edges, k crossing the KC panel boundary).
        let mut rng = Pcg32::seeded(18);
        for &(m, n, k) in
            &[(70usize, 140usize, 37usize), (200, 300, 64), (65, 129, 2 * KC + 19), (5, 400, 12)]
        {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let mut want = vec![0.0f32; m * n];
            sgemm_with(Micro::Scalar, 1, false, false, m, n, k, &a, &b, false, &mut want);
            for threads in [2usize, 3, 8] {
                let mut got = vec![0.0f32; m * n];
                sgemm_with(Micro::Scalar, threads, false, false, m, n, k, &a, &b, false, &mut got);
                for (i, (x, y)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "({m},{n},{k}) t={threads} elem {i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn multithreaded_accumulate_adds_exactly_once() {
        let mut rng = Pcg32::seeded(19);
        let (m, n, k) = (130, 150, 40);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut want = vec![0.25f32; m * n];
        sgemm_with(Micro::Scalar, 1, false, false, m, n, k, &a, &b, true, &mut want);
        let mut got = vec![0.25f32; m * n];
        sgemm_with(Micro::Scalar, 4, false, false, m, n, k, &a, &b, true, &mut got);
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn im2col_col2im_are_adjoint() {
        // <im2col(x), C> == <x, col2im(C)> for any C: the defining
        // property that makes col2im the correct conv input-gradient.
        let mut rng = Pcg32::seeded(16);
        let (n, h, w, cin, k, stride) = (2usize, 5usize, 4usize, 3usize, 3usize, 2usize);
        let (oh, ow, pt, pl) = (3, 2, 1, 1); // SAME-ish geometry with padding
        let x = randv(&mut rng, n * h * w * cin);
        let patch = k * k * cin;
        let mut cols = vec![0.0f32; n * oh * ow * patch];
        im2col(&x, n, h, w, cin, k, stride, oh, ow, pt, pl, &mut cols);
        let cmat = randv(&mut rng, cols.len());
        let lhs: f64 = cols.iter().zip(&cmat).map(|(&a, &b)| a as f64 * b as f64).sum();
        let mut back = vec![0.0f32; x.len()];
        col2im(&cmat, n, h, w, cin, k, stride, oh, ow, pt, pl, &mut back);
        let rhs: f64 = x.iter().zip(&back).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn scratch_accounting_helpers() {
        assert_eq!(pack_scratch_floats(), MC * KC + KC * NC);
        assert_eq!(conv_cols_floats(2, 4, 4, 3, 5), 2 * 16 * 9 * 5);
        assert_eq!(MC % MR, 0, "A macro-tile must hold whole row strips");
        assert_eq!(NC % NR, 0, "B macro-tile must hold whole column strips");
        // The dispatched footprint is one panel pair per GEMM thread.
        let total = pack_scratch_total();
        let threads = threadpool::configured_threads();
        assert_eq!(total, threads * pack_scratch_floats());
        assert!(total >= pack_scratch_floats());
    }
}
