//! Pure-Rust forward/backward compute kernels for the native backend.
//!
//! Each kernel mirrors the math of its JAX counterpart in
//! `python/compile/layers.py` / `python/compile/kernels/ref.py` (NHWC
//! activations, HWIO conv weights, biased batch-norm variance, XLA-style
//! SAME padding with `pad_before = total // 2`), so a native stage
//! computes the same function the AOT-compiled HLO program would — only
//! the backend differs, not the model. Backward passes are analytic and
//! finite-difference-checked in `tests/native_backend.rs`.
//!
//! Kernels operate on flat `&[f32]` buffers with explicit dimensions;
//! tensor plumbing (shapes, caches, parameter slicing) lives in
//! `backend::ops`.
//!
//! The conv2d and dense hot paths are **GEMM-lowered**: convolution
//! forward is im2col + one `[M, K] x [K, cout]` matrix product on the
//! shared [`gemm`](super::gemm) core, conv backward computes the
//! weight gradient as a GEMM over the im2col buffer and the input
//! gradient as a GEMM followed by col2im, and dense forward/backward
//! run through the same core. The pre-lowering nested loops are kept
//! verbatim as `reference_*` oracles: every GEMM path is differentially
//! tested against them (`tests/native_backend.rs`) and the micro bench
//! times the pairs. The core itself is SIMD-vectorized and
//! multithreaded (`backend::simd`, `backend::threadpool`) — every conv
//! and dense call here inherits both transparently via `gemm::sgemm`'s
//! runtime dispatch. Because the GEMM summation order is fixed by the
//! problem shape alone — the SIMD kernels replay the scalar op
//! sequence and threads split only whole macro-tiles — a training step
//! remains bitwise reproducible at any thread count on any host, which
//! is what keeps the pipeline equivalence invariants exact.

use anyhow::{ensure, Result};

use crate::pool;

use super::gemm;

/// Elementwise activation fused into `Dense` or standing alone (`Act`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActKind {
    /// Identity (no activation).
    None,
    /// Rectified linear unit: `max(0, x)`.
    Relu,
    /// Hyperbolic tangent.
    Tanh,
}

impl ActKind {
    /// Parse the layer-spec activation names used in `meta.json`.
    ///
    /// ```
    /// use pipestale::backend::ActKind;
    /// assert_eq!(ActKind::parse("relu"), Some(ActKind::Relu));
    /// assert_eq!(ActKind::parse("gelu"), None);
    /// ```
    pub fn parse(s: &str) -> Option<ActKind> {
        match s {
            "none" => Some(ActKind::None),
            "relu" => Some(ActKind::Relu),
            "tanh" => Some(ActKind::Tanh),
            _ => None,
        }
    }

    /// Apply in place.
    pub fn apply(self, y: &mut [f32]) {
        match self {
            ActKind::None => {}
            ActKind::Relu => {
                for v in y.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            ActKind::Tanh => {
                for v in y.iter_mut() {
                    *v = v.tanh();
                }
            }
        }
    }

    /// d act / d preactivation, expressed through the *output* value
    /// (valid for relu/tanh, which is all the model zoo uses).
    #[inline]
    pub fn grad_from_output(self, y: f32) -> f32 {
        match self {
            ActKind::None => 1.0,
            ActKind::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            ActKind::Tanh => 1.0 - y * y,
        }
    }
}

/// Output spatial dims + top/left padding for a square-kernel conv.
/// SAME matches XLA: `out = ceil(in/stride)`, `pad_before = total // 2`.
///
/// Checked: `stride == 0` and a VALID-padding input smaller than the
/// kernel are errors. The latter used to wrap (`(h - k) / stride + 1`
/// underflows in release builds) and yield garbage output shapes.
pub fn conv_out_dims(
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    same: bool,
) -> Result<(usize, usize, usize, usize)> {
    ensure!(stride >= 1, "conv: stride must be >= 1");
    ensure!(k >= 1 && h >= 1 && w >= 1, "conv: degenerate dims {h}x{w} kernel {k}");
    if !same {
        ensure!(
            h >= k && w >= k,
            "conv VALID: input {h}x{w} smaller than kernel {k}x{k}"
        );
    }
    Ok(conv_out_dims_unchecked(h, w, k, stride, same))
}

/// Unchecked variant for the inner kernels, which only ever see
/// dimensions already validated by `backend::ops`.
pub(crate) fn conv_out_dims_unchecked(
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    same: bool,
) -> (usize, usize, usize, usize) {
    if same {
        let oh = (h + stride - 1) / stride;
        let ow = (w + stride - 1) / stride;
        let pad_h = ((oh - 1) * stride + k).saturating_sub(h);
        let pad_w = ((ow - 1) * stride + k).saturating_sub(w);
        (oh, ow, pad_h / 2, pad_w / 2)
    } else {
        ((h - k) / stride + 1, (w - k) / stride + 1, 0, 0)
    }
}

/// Residual merge forward: `out = main + shortcut`, elementwise.
pub fn residual_add_forward(main: &[f32], shortcut: &[f32], out: &mut [f32]) {
    debug_assert_eq!(main.len(), shortcut.len());
    debug_assert_eq!(main.len(), out.len());
    for ((o, &a), &b) in out.iter_mut().zip(main).zip(shortcut) {
        *o = a + b;
    }
}

/// Residual merge backward: the add fans the incoming gradient out to
/// both branches unchanged (`d_main = d_shortcut = dy`).
pub fn residual_add_backward(dy: &[f32], d_main: &mut [f32], d_shortcut: &mut [f32]) {
    debug_assert_eq!(dy.len(), d_main.len());
    debug_assert_eq!(dy.len(), d_shortcut.len());
    d_main.copy_from_slice(dy);
    d_shortcut.copy_from_slice(dy);
}

/// 2-D convolution forward: x `[n,h,w,cin]`, wgt `[k,k,cin,cout]` (HWIO),
/// optional bias `[cout]`, out `[n,oh,ow,cout]` (fully overwritten).
///
/// GEMM-lowered: the patch matrix (`gemm::im2col`; skipped for 1×1
/// unpadded stride-1 convs, where the activations already are the
/// patch matrix) is multiplied against the row-major-flattened HWIO
/// weights on the blocked core, accumulating onto the bias-initialized
/// output. Matches [`reference_conv2d_forward`] to float tolerance:
///
/// ```
/// use pipestale::backend::kernels::{conv2d_forward, reference_conv2d_forward};
/// let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect(); // [1,4,4,1]
/// let w: Vec<f32> = (0..9).map(|i| i as f32 * 0.01).collect(); // [3,3,1,1]
/// let (mut y, mut r) = (vec![0.0; 16], vec![0.0; 16]);
/// conv2d_forward(&x, 1, 4, 4, 1, &w, 3, 1, 1, true, None, &mut y);
/// reference_conv2d_forward(&x, 1, 4, 4, 1, &w, 3, 1, 1, true, None, &mut r);
/// for (a, b) in y.iter().zip(&r) {
///     assert!((a - b).abs() < 1e-5);
/// }
/// ```
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wgt: &[f32],
    k: usize,
    cout: usize,
    stride: usize,
    same: bool,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let (oh, ow, pt, pl) = conv_out_dims_unchecked(h, w, k, stride, same);
    debug_assert_eq!(out.len(), n * oh * ow * cout);
    match bias {
        Some(b) => {
            for chunk in out.chunks_exact_mut(cout) {
                chunk.copy_from_slice(b);
            }
        }
        None => out.fill(0.0),
    }
    let m = n * oh * ow;
    let kk = k * k * cin;
    if k == 1 && stride == 1 && pt == 0 && pl == 0 {
        gemm::sgemm(false, false, m, cout, kk, x, wgt, true, out);
    } else {
        let mut cols = pool::acquire(m * kk);
        gemm::im2col(x, n, h, w, cin, k, stride, oh, ow, pt, pl, &mut cols);
        gemm::sgemm(false, false, m, cout, kk, &cols, wgt, true, out);
    }
}

/// Pre-lowering conv2d forward loops, kept verbatim as the
/// differential-test oracle and the "before" side of the micro bench.
/// Same contract as [`conv2d_forward`].
#[allow(clippy::too_many_arguments)]
pub fn reference_conv2d_forward(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wgt: &[f32],
    k: usize,
    cout: usize,
    stride: usize,
    same: bool,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let (oh, ow, pt, pl) = conv_out_dims_unchecked(h, w, k, stride, same);
    debug_assert_eq!(out.len(), n * oh * ow * cout);
    match bias {
        Some(b) => {
            for chunk in out.chunks_exact_mut(cout) {
                chunk.copy_from_slice(b);
            }
        }
        None => out.fill(0.0),
    }
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let obase = ((ni * oh + oy) * ow + ox) * cout;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pt as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pl as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let xbase = ((ni * h + iy as usize) * w + ix as usize) * cin;
                        let wbase = (ky * k + kx) * cin * cout;
                        for ci in 0..cin {
                            let xv = x[xbase + ci];
                            let wrow = &wgt[wbase + ci * cout..wbase + (ci + 1) * cout];
                            let orow = &mut out[obase..obase + cout];
                            for (o, &wv) in orow.iter_mut().zip(wrow) {
                                *o += xv * wv;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Conv backward: given dy `[n,oh,ow,cout]`, accumulate dx (zeroed by
/// caller), dw (zeroed), and optionally db (zeroed).
///
/// GEMM-lowered: `db` is the column sum of dy; `dw += cols^T · dy` is
/// one GEMM over the (recomputed) im2col buffer; the input gradient is
/// `dcols = dy · W^T` followed by the `gemm::col2im` scatter-add (for
/// 1×1 unpadded stride-1 convs both products hit `x`/`dx` directly).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wgt: &[f32],
    k: usize,
    cout: usize,
    stride: usize,
    same: bool,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    db: Option<&mut [f32]>,
) {
    let (oh, ow, pt, pl) = conv_out_dims_unchecked(h, w, k, stride, same);
    debug_assert_eq!(dy.len(), n * oh * ow * cout);
    debug_assert_eq!(dx.len(), x.len());
    debug_assert_eq!(dw.len(), wgt.len());
    if let Some(db) = db {
        for row in dy.chunks_exact(cout) {
            for (d, &g) in db.iter_mut().zip(row) {
                *d += g;
            }
        }
    }
    let m = n * oh * ow;
    let kk = k * k * cin;
    if k == 1 && stride == 1 && pt == 0 && pl == 0 {
        gemm::sgemm(true, false, kk, cout, m, x, dy, true, dw);
        gemm::sgemm(false, true, m, kk, cout, dy, wgt, true, dx);
    } else {
        let mut cols = pool::acquire(m * kk);
        gemm::im2col(x, n, h, w, cin, k, stride, oh, ow, pt, pl, &mut cols);
        gemm::sgemm(true, false, kk, cout, m, &cols, dy, true, dw);
        // Reuse the im2col lease for the input-gradient patch matrix:
        // sgemm with accumulate=false fully overwrites it.
        gemm::sgemm(false, true, m, kk, cout, dy, wgt, false, &mut cols);
        gemm::col2im(&cols, n, h, w, cin, k, stride, oh, ow, pt, pl, dx);
    }
}

/// Pre-lowering conv2d backward loops, kept verbatim as the
/// differential-test oracle and the "before" side of the micro bench.
/// Same contract as [`conv2d_backward`].
#[allow(clippy::too_many_arguments)]
pub fn reference_conv2d_backward(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wgt: &[f32],
    k: usize,
    cout: usize,
    stride: usize,
    same: bool,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    mut db: Option<&mut [f32]>,
) {
    let (oh, ow, pt, pl) = conv_out_dims_unchecked(h, w, k, stride, same);
    debug_assert_eq!(dy.len(), n * oh * ow * cout);
    debug_assert_eq!(dx.len(), x.len());
    debug_assert_eq!(dw.len(), wgt.len());
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let dyrow = &dy[((ni * oh + oy) * ow + ox) * cout..][..cout];
                if let Some(db) = db.as_deref_mut() {
                    for (d, &g) in db.iter_mut().zip(dyrow) {
                        *d += g;
                    }
                }
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pt as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pl as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let xbase = ((ni * h + iy as usize) * w + ix as usize) * cin;
                        let wbase = (ky * k + kx) * cin * cout;
                        for ci in 0..cin {
                            let xv = x[xbase + ci];
                            let wrow = &wgt[wbase + ci * cout..wbase + (ci + 1) * cout];
                            let dwrow = &mut dw[wbase + ci * cout..wbase + (ci + 1) * cout];
                            let mut acc = 0.0f32;
                            for co in 0..cout {
                                let g = dyrow[co];
                                acc += g * wrow[co];
                                dwrow[co] += g * xv;
                            }
                            dx[xbase + ci] += acc;
                        }
                    }
                }
            }
        }
    }
}

/// Dense forward: x `[n,din]`, wgt `[din,dout]`, bias `[dout]`,
/// y `[n,dout]` (fully overwritten, activation applied).
///
/// GEMM-lowered: one `[n, din] x [din, dout]` product accumulated onto
/// the bias-broadcast output, then the fused activation in place.
pub fn dense_forward(
    x: &[f32],
    n: usize,
    din: usize,
    wgt: &[f32],
    bias: &[f32],
    dout: usize,
    act: ActKind,
    y: &mut [f32],
) {
    debug_assert_eq!(y.len(), n * dout);
    for yrow in y.chunks_exact_mut(dout) {
        yrow.copy_from_slice(bias);
    }
    gemm::sgemm(false, false, n, dout, din, x, wgt, true, y);
    act.apply(y);
}

/// Pre-lowering dense forward loops, kept verbatim as the
/// differential-test oracle and the "before" side of the micro bench.
/// Same contract as [`dense_forward`].
pub fn reference_dense_forward(
    x: &[f32],
    n: usize,
    din: usize,
    wgt: &[f32],
    bias: &[f32],
    dout: usize,
    act: ActKind,
    y: &mut [f32],
) {
    debug_assert_eq!(y.len(), n * dout);
    for ni in 0..n {
        let yrow = &mut y[ni * dout..(ni + 1) * dout];
        yrow.copy_from_slice(bias);
        let xrow = &x[ni * din..(ni + 1) * din];
        for (di, &xv) in xrow.iter().enumerate() {
            let wrow = &wgt[di * dout..(di + 1) * dout];
            for (o, &wv) in yrow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
        act.apply(yrow);
    }
}

/// Dense backward: `y` is the *post-activation* forward output; dx/dw/db
/// must be zeroed by the caller.
///
/// GEMM-lowered: the preactivation gradient `dyp = dy * act'(y)` goes
/// into a pooled scratch buffer, `db` is its column sum, and the two
/// matrix gradients are `dw += x^T · dyp` and `dx += dyp · W^T`.
#[allow(clippy::too_many_arguments)]
pub fn dense_backward(
    x: &[f32],
    n: usize,
    din: usize,
    wgt: &[f32],
    dout: usize,
    act: ActKind,
    y: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
) {
    debug_assert_eq!(dy.len(), n * dout);
    let mut dyp = pool::acquire(n * dout);
    for ((p, &g), &yv) in dyp.iter_mut().zip(dy).zip(y) {
        *p = g * act.grad_from_output(yv);
    }
    for row in dyp.chunks_exact(dout) {
        for (d, &p) in db.iter_mut().zip(row) {
            *d += p;
        }
    }
    gemm::sgemm(true, false, din, dout, n, x, &dyp, true, dw);
    gemm::sgemm(false, true, n, din, dout, &dyp, wgt, true, dx);
}

/// Pre-lowering dense backward loops, kept verbatim as the
/// differential-test oracle and the "before" side of the micro bench.
/// Same contract as [`dense_backward`].
#[allow(clippy::too_many_arguments)]
pub fn reference_dense_backward(
    x: &[f32],
    n: usize,
    din: usize,
    wgt: &[f32],
    dout: usize,
    act: ActKind,
    y: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
) {
    let mut dyp = vec![0.0f32; dout];
    for ni in 0..n {
        let yrow = &y[ni * dout..(ni + 1) * dout];
        let dyrow = &dy[ni * dout..(ni + 1) * dout];
        for ((p, &g), &yv) in dyp.iter_mut().zip(dyrow).zip(yrow) {
            *p = g * act.grad_from_output(yv);
        }
        for (d, &p) in db.iter_mut().zip(&dyp) {
            *d += p;
        }
        let xrow = &x[ni * din..(ni + 1) * din];
        let dxrow = &mut dx[ni * din..(ni + 1) * din];
        for di in 0..din {
            let wrow = &wgt[di * dout..(di + 1) * dout];
            let dwrow = &mut dw[di * dout..(di + 1) * dout];
            let xv = xrow[di];
            let mut acc = 0.0f32;
            for ((&p, &wv), dwv) in dyp.iter().zip(wrow).zip(dwrow.iter_mut()) {
                acc += p * wv;
                *dwv += p * xv;
            }
            dxrow[di] += acc;
        }
    }
}

/// Max-pool forward (VALID padding): records the flat input index of each
/// window maximum for the backward scatter. Returns `(oh, ow)`.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_forward(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    y: &mut [f32],
    argmax: &mut [u32],
) -> (usize, usize) {
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    debug_assert_eq!(y.len(), n * oh * ow * c);
    debug_assert_eq!(argmax.len(), y.len());
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let obase = ((ni * oh + oy) * ow + ox) * c;
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0usize;
                    for ky in 0..k {
                        let iy = oy * stride + ky;
                        for kx in 0..k {
                            let ix = ox * stride + kx;
                            let idx = ((ni * h + iy) * w + ix) * c + ch;
                            let v = x[idx];
                            if v > best {
                                best = v;
                                best_idx = idx;
                            }
                        }
                    }
                    y[obase + ch] = best;
                    argmax[obase + ch] = best_idx as u32;
                }
            }
        }
    }
    (oh, ow)
}

/// Max-pool backward: scatter dy through the recorded argmax indices
/// (dx zeroed by caller).
pub fn maxpool_backward(dy: &[f32], argmax: &[u32], dx: &mut [f32]) {
    for (&g, &idx) in dy.iter().zip(argmax) {
        dx[idx as usize] += g;
    }
}

/// Batch-norm training forward over `rows` samples of `c` channels
/// (rows = N*H*W for conv activations, N for dense). Writes y and the
/// normalized activations `xhat`; returns per-channel
/// `(batch_mean, batch_var, inv_std)` (biased variance, like `jnp.var`).
#[allow(clippy::too_many_arguments)]
pub fn batchnorm_forward_train(
    x: &[f32],
    rows: usize,
    c: usize,
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
    y: &mut [f32],
    xhat: &mut [f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let m = rows as f32;
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    for row in x.chunks_exact(c) {
        for (s, &v) in mean.iter_mut().zip(row) {
            *s += v;
        }
    }
    for s in mean.iter_mut() {
        *s /= m;
    }
    for row in x.chunks_exact(c) {
        for ((s, &v), &mu) in var.iter_mut().zip(row).zip(&mean) {
            let d = v - mu;
            *s += d * d;
        }
    }
    for s in var.iter_mut() {
        *s /= m;
    }
    let inv_std: Vec<f32> = var.iter().map(|&v| 1.0 / (v + eps).sqrt()).collect();
    for ((yrow, xrow), hrow) in
        y.chunks_exact_mut(c).zip(x.chunks_exact(c)).zip(xhat.chunks_exact_mut(c))
    {
        for ch in 0..c {
            let h = (xrow[ch] - mean[ch]) * inv_std[ch];
            hrow[ch] = h;
            yrow[ch] = h * gamma[ch] + beta[ch];
        }
    }
    (mean, var, inv_std)
}

/// Batch-norm inference forward using running statistics.
#[allow(clippy::too_many_arguments)]
pub fn batchnorm_forward_eval(
    x: &[f32],
    c: usize,
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
    y: &mut [f32],
) {
    let inv_std: Vec<f32> = var.iter().map(|&v| 1.0 / (v + eps).sqrt()).collect();
    for (yrow, xrow) in y.chunks_exact_mut(c).zip(x.chunks_exact(c)) {
        for ch in 0..c {
            yrow[ch] = (xrow[ch] - mean[ch]) * inv_std[ch] * gamma[ch] + beta[ch];
        }
    }
}

/// Batch-norm backward through the batch statistics:
/// `dx = inv_std/m * (m*dxhat - sum(dxhat) - xhat*sum(dxhat*xhat))`.
/// dx/dgamma/dbeta are fully overwritten.
#[allow(clippy::too_many_arguments)]
pub fn batchnorm_backward(
    xhat: &[f32],
    inv_std: &[f32],
    gamma: &[f32],
    rows: usize,
    c: usize,
    dy: &[f32],
    dx: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    let m = rows as f32;
    dgamma.fill(0.0);
    dbeta.fill(0.0);
    // sums of dxhat and dxhat*xhat per channel (dxhat = dy * gamma)
    let mut s1 = vec![0.0f32; c];
    let mut s2 = vec![0.0f32; c];
    for (dyrow, hrow) in dy.chunks_exact(c).zip(xhat.chunks_exact(c)) {
        for ch in 0..c {
            let dh = dyrow[ch] * gamma[ch];
            s1[ch] += dh;
            s2[ch] += dh * hrow[ch];
            dgamma[ch] += dyrow[ch] * hrow[ch];
            dbeta[ch] += dyrow[ch];
        }
    }
    for ((dxrow, dyrow), hrow) in
        dx.chunks_exact_mut(c).zip(dy.chunks_exact(c)).zip(xhat.chunks_exact(c))
    {
        for ch in 0..c {
            let dh = dyrow[ch] * gamma[ch];
            dxrow[ch] = inv_std[ch] / m * (m * dh - s1[ch] - hrow[ch] * s2[ch]);
        }
    }
}

/// Global average pool forward: `[n,h,w,c] -> [n,c]`.
pub fn global_avg_pool_forward(x: &[f32], n: usize, h: usize, w: usize, c: usize, y: &mut [f32]) {
    let hw = (h * w) as f32;
    y.fill(0.0);
    for ni in 0..n {
        let yrow = &mut y[ni * c..(ni + 1) * c];
        for row in x[ni * h * w * c..(ni + 1) * h * w * c].chunks_exact(c) {
            for (o, &v) in yrow.iter_mut().zip(row) {
                *o += v;
            }
        }
        for o in yrow.iter_mut() {
            *o /= hw;
        }
    }
}

/// Global average pool backward (dx fully overwritten).
pub fn global_avg_pool_backward(
    dy: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    dx: &mut [f32],
) {
    let hw = (h * w) as f32;
    for ni in 0..n {
        let dyrow = &dy[ni * c..(ni + 1) * c];
        for row in dx[ni * h * w * c..(ni + 1) * h * w * c].chunks_exact_mut(c) {
            for (o, &g) in row.iter_mut().zip(dyrow) {
                *o = g / hw;
            }
        }
    }
}

/// Softmax cross-entropy over logits `[n,classes]` with integer labels:
/// returns `(mean_loss, correct_count, dlogits)` where
/// `dlogits = (softmax - onehot)/n` — the gradient of the mean loss,
/// mirroring `stages._loss_and_metrics` + its vjp. Argmax ties resolve
/// to the first maximum (like `jnp.argmax` and `train::count_correct`).
pub fn softmax_xent(
    logits: &[f32],
    n: usize,
    classes: usize,
    labels: &[i32],
) -> (f32, f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), n * classes);
    debug_assert_eq!(labels.len(), n);
    let mut dlogits = vec![0.0f32; n * classes];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for ni in 0..n {
        let row = &logits[ni * classes..(ni + 1) * classes];
        let mut maxv = row[0];
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > maxv {
                maxv = v;
                argmax = j;
            }
        }
        let label = labels[ni] as usize;
        if argmax == label {
            correct += 1;
        }
        let mut denom = 0.0f32;
        for &v in row {
            denom += (v - maxv).exp();
        }
        let log_denom = denom.ln();
        loss += (log_denom - (row[label] - maxv)) as f64;
        let drow = &mut dlogits[ni * classes..(ni + 1) * classes];
        for (j, &v) in row.iter().enumerate() {
            let p = (v - maxv).exp() / denom;
            drow[j] = (p - if j == label { 1.0 } else { 0.0 }) / n as f32;
        }
    }
    ((loss / n as f64) as f32, correct as f32, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randv(rng: &mut Pcg32, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal()).collect()
    }

    fn assert_rel_close(what: &str, got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            let bound = tol * (1.0 + b.abs());
            assert!((a - b).abs() <= bound, "{what}[{i}]: gemm {a} vs reference {b}");
        }
    }

    #[test]
    fn gemm_conv_1x1_fast_path_matches_reference() {
        // The projection-shortcut shape class with stride 1: the im2col
        // copy is skipped and x feeds the GEMM directly.
        let mut rng = Pcg32::seeded(21);
        let (n, h, w, cin, cout) = (2, 5, 5, 3, 4);
        let x = randv(&mut rng, n * h * w * cin);
        let wgt = randv(&mut rng, cin * cout);
        let bias = randv(&mut rng, cout);
        let mut y = vec![0.0; n * h * w * cout];
        let mut r = vec![0.0; n * h * w * cout];
        conv2d_forward(&x, n, h, w, cin, &wgt, 1, cout, 1, true, Some(&bias), &mut y);
        reference_conv2d_forward(&x, n, h, w, cin, &wgt, 1, cout, 1, true, Some(&bias), &mut r);
        assert_rel_close("conv1x1/fwd", &y, &r, 1e-4);

        let dy = randv(&mut rng, y.len());
        let (mut dx, mut dxr) = (vec![0.0; x.len()], vec![0.0; x.len()]);
        let (mut dw, mut dwr) = (vec![0.0; wgt.len()], vec![0.0; wgt.len()]);
        let (mut db, mut dbr) = (vec![0.0; cout], vec![0.0; cout]);
        conv2d_backward(
            &x,
            n,
            h,
            w,
            cin,
            &wgt,
            1,
            cout,
            1,
            true,
            &dy,
            &mut dx,
            &mut dw,
            Some(&mut db),
        );
        reference_conv2d_backward(
            &x,
            n,
            h,
            w,
            cin,
            &wgt,
            1,
            cout,
            1,
            true,
            &dy,
            &mut dxr,
            &mut dwr,
            Some(&mut dbr),
        );
        assert_rel_close("conv1x1/dx", &dx, &dxr, 1e-4);
        assert_rel_close("conv1x1/dw", &dw, &dwr, 1e-4);
        assert_rel_close("conv1x1/db", &db, &dbr, 1e-4);
    }

    #[test]
    fn gemm_dense_matches_reference() {
        let mut rng = Pcg32::seeded(22);
        let (n, din, dout) = (7, 300, 13); // din > KC exercises panel splits
        let x = randv(&mut rng, n * din);
        let wgt = randv(&mut rng, din * dout);
        let bias = randv(&mut rng, dout);
        for act in [ActKind::None, ActKind::Tanh] {
            let mut y = vec![0.0; n * dout];
            let mut r = vec![0.0; n * dout];
            dense_forward(&x, n, din, &wgt, &bias, dout, act, &mut y);
            reference_dense_forward(&x, n, din, &wgt, &bias, dout, act, &mut r);
            assert_rel_close("dense/fwd", &y, &r, 1e-4);

            let dy = randv(&mut rng, y.len());
            let (mut dx, mut dxr) = (vec![0.0; x.len()], vec![0.0; x.len()]);
            let (mut dw, mut dwr) = (vec![0.0; wgt.len()], vec![0.0; wgt.len()]);
            let (mut db, mut dbr) = (vec![0.0; dout], vec![0.0; dout]);
            dense_backward(&x, n, din, &wgt, dout, act, &y, &dy, &mut dx, &mut dw, &mut db);
            reference_dense_backward(
                &x,
                n,
                din,
                &wgt,
                dout,
                act,
                &r,
                &dy,
                &mut dxr,
                &mut dwr,
                &mut dbr,
            );
            assert_rel_close("dense/dx", &dx, &dxr, 1e-4);
            assert_rel_close("dense/dw", &dw, &dwr, 1e-4);
            assert_rel_close("dense/db", &db, &dbr, 1e-4);
        }
    }

    #[test]
    fn conv_out_dims_match_xla_conventions() {
        // SAME stride 1: shape preserved, pad (k-1)/2 on the before side.
        assert_eq!(conv_out_dims(28, 28, 5, 1, true).unwrap(), (28, 28, 2, 2));
        // SAME stride 2 on even input: ceil(32/2)=16.
        assert_eq!(conv_out_dims(32, 32, 3, 2, true).unwrap(), (16, 16, 0, 0));
        // VALID: (h-k)/s+1.
        assert_eq!(conv_out_dims(14, 14, 5, 1, false).unwrap(), (10, 10, 0, 0));
    }

    #[test]
    fn conv_out_dims_reject_underflow_and_zero_stride() {
        // Regression: VALID with h < k used to wrap ((h-k)/s+1 on usize)
        // in release builds and produce garbage shapes.
        let err = conv_out_dims(3, 3, 5, 1, false).unwrap_err().to_string();
        assert!(err.contains("smaller than kernel"), "{err}");
        assert!(conv_out_dims(5, 3, 5, 1, false).is_err(), "w < k must error too");
        // k == h is the smallest legal VALID input.
        assert_eq!(conv_out_dims(5, 5, 5, 1, false).unwrap(), (1, 1, 0, 0));
        // SAME tolerates small inputs (padding covers them)...
        assert_eq!(conv_out_dims(2, 2, 5, 1, true).unwrap().0, 2);
        // ...but nothing tolerates a zero stride or empty dims.
        assert!(conv_out_dims(8, 8, 3, 0, true).is_err());
        assert!(conv_out_dims(0, 8, 3, 1, true).is_err());
    }

    #[test]
    fn residual_add_roundtrip() {
        let main = [1.0f32, -2.0, 3.0];
        let shortcut = [0.5f32, 0.25, -1.0];
        let mut out = [0.0f32; 3];
        residual_add_forward(&main, &shortcut, &mut out);
        assert_eq!(out, [1.5, -1.75, 2.0]);
        let dy = [0.1f32, 0.2, 0.3];
        let mut dm = [0.0f32; 3];
        let mut ds = [9.0f32; 3];
        residual_add_backward(&dy, &mut dm, &mut ds);
        assert_eq!(dm, dy);
        assert_eq!(ds, dy);
    }

    #[test]
    fn conv_identity_kernel_passes_through() {
        // 1x1 kernel with identity channel map == copy.
        let x: Vec<f32> = (0..2 * 3 * 3 * 2).map(|i| i as f32).collect();
        let wgt = vec![1.0, 0.0, 0.0, 1.0]; // [1,1,2,2] identity
        let mut out = vec![0.0; x.len()];
        conv2d_forward(&x, 2, 3, 3, 2, &wgt, 1, 2, 1, true, None, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn conv_bias_is_added() {
        let x = vec![0.0; 1 * 2 * 2 * 1];
        let wgt = vec![0.0; 1]; // [1,1,1,1]
        let mut out = vec![9.0; 4];
        conv2d_forward(&x, 1, 2, 2, 1, &wgt, 1, 1, 1, true, Some(&[0.5]), &mut out);
        assert_eq!(out, vec![0.5; 4]);
    }

    #[test]
    fn dense_matches_manual_matmul() {
        // x [1,2] @ w [2,3] + b
        let x = vec![1.0, 2.0];
        let wgt = vec![1.0, 0.0, -1.0, 0.5, 2.0, 1.0];
        let b = vec![0.1, 0.2, 0.3];
        let mut y = vec![0.0; 3];
        dense_forward(&x, 1, 2, &wgt, &b, 3, ActKind::None, &mut y);
        assert!((y[0] - 2.1).abs() < 1e-6);
        assert!((y[1] - 4.2).abs() < 1e-6);
        assert!((y[2] - 1.3).abs() < 1e-6);
        let mut yr = vec![0.0; 3];
        dense_forward(&x, 1, 2, &wgt, &[-10.0, 0.0, 10.0], 3, ActKind::Relu, &mut yr);
        assert_eq!(yr[0], 0.0); // relu clamps
    }

    #[test]
    fn maxpool_picks_maxima_and_scatters_back() {
        // 1x4x4x1, 2x2 pool stride 2
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut y = vec![0.0; 4];
        let mut am = vec![0u32; 4];
        let (oh, ow) = maxpool_forward(&x, 1, 4, 4, 1, 2, 2, &mut y, &mut am);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(y, vec![5.0, 7.0, 13.0, 15.0]);
        let mut dx = vec![0.0; 16];
        maxpool_backward(&[1.0, 2.0, 3.0, 4.0], &am, &mut dx);
        assert_eq!(dx[5], 1.0);
        assert_eq!(dx[7], 2.0);
        assert_eq!(dx[13], 3.0);
        assert_eq!(dx[15], 4.0);
        assert_eq!(dx.iter().sum::<f32>(), 10.0);
    }

    #[test]
    fn batchnorm_train_normalizes() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // rows=4, c=1
        let mut y = vec![0.0; 4];
        let mut xhat = vec![0.0; 4];
        let (mean, var, _) =
            batchnorm_forward_train(&x, 4, 1, &[1.0], &[0.0], 1e-5, &mut y, &mut xhat);
        assert!((mean[0] - 2.5).abs() < 1e-6);
        assert!((var[0] - 1.25).abs() < 1e-6);
        let m: f32 = y.iter().sum::<f32>() / 4.0;
        assert!(m.abs() < 1e-6);
    }

    #[test]
    fn gap_averages_and_distributes() {
        let x: Vec<f32> = vec![1.0, 3.0, 5.0, 7.0]; // 1x2x2x1
        let mut y = vec![0.0; 1];
        global_avg_pool_forward(&x, 1, 2, 2, 1, &mut y);
        assert!((y[0] - 4.0).abs() < 1e-6);
        let mut dx = vec![0.0; 4];
        global_avg_pool_backward(&[1.0], 1, 2, 2, 1, &mut dx);
        assert!(dx.iter().all(|&v| (v - 0.25).abs() < 1e-7));
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        let (loss, correct, d) = softmax_xent(&[0.0; 8], 2, 4, &[1, 2]);
        assert!((loss - (4.0f32).ln()).abs() < 1e-5);
        // argmax ties resolve to index 0 -> neither label matches
        assert_eq!(correct, 0.0);
        // gradient rows sum to zero
        assert!(d[..4].iter().sum::<f32>().abs() < 1e-6);
        // gradient points away from the label
        assert!(d[1] < 0.0 && d[0] > 0.0);
    }

    #[test]
    fn softmax_xent_confident_correct_prediction() {
        let (loss, correct, _) = softmax_xent(&[10.0, -10.0, 0.0, 20.0], 2, 2, &[0, 1]);
        assert!(loss < 1e-3, "{loss}");
        assert_eq!(correct, 2.0);
    }
}
