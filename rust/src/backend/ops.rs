//! Native op zoo: the Rust twin of `python/compile/layers.py`.
//!
//! A partition's compute is a flat `Vec<NativeOp>`; each op transforms
//! the carry tensor and (for batch-norm) produces functional state
//! updates that the executor commits exactly where the XLA engine's
//! `take_state` would. `train_forward` records an `OpCache` so the
//! backward walk is analytic; `backward` consumes it and returns
//! `(dx, dparams)` with dparams positionally aligned to the op's
//! `param_specs` — the same ordering `meta.json` records and `Sgd::step`
//! zips against.
//!
//! Scope: the ops the LeNet-style configs need (conv / batch-norm /
//! activation / max-pool / global-avg-pool / flatten / dense). Residual
//! markers and dropout are XLA-only for now; `backend::models` refuses
//! to build models that use them.

use anyhow::{bail, ensure, Result};

use crate::meta::{ParamSpec, StateSpec};
use crate::tensor::Tensor;

use super::kernels::{self, ActKind};

/// One atomic native operation.
#[derive(Debug, Clone)]
pub struct NativeOp {
    pub name: String,
    pub kind: OpKind,
}

#[derive(Debug, Clone)]
pub enum OpKind {
    Conv { cin: usize, cout: usize, k: usize, stride: usize, same: bool, bias: bool },
    BatchNorm { c: usize, momentum: f32, eps: f32 },
    Act { kind: ActKind },
    MaxPool { k: usize, stride: usize },
    GlobalAvgPool,
    Flatten,
    Dense { din: usize, dout: usize, act: ActKind },
}

/// Saved forward intermediates for one op's backward pass.
#[derive(Debug, Clone)]
pub enum OpCache {
    Conv { x: Tensor },
    Dense { x: Tensor, y: Tensor },
    Act { y: Tensor },
    MaxPool { in_shape: Vec<usize>, argmax: Vec<u32> },
    BatchNorm { xhat: Tensor, inv_std: Vec<f32> },
    Gap { in_shape: Vec<usize> },
    Flatten { in_shape: Vec<usize> },
}

fn dims4(t: &Tensor) -> Result<(usize, usize, usize, usize)> {
    let s = t.shape.as_slice();
    ensure!(s.len() == 4, "expected NHWC tensor, got shape {:?}", s);
    Ok((s[0], s[1], s[2], s[3]))
}

fn dims2(t: &Tensor) -> Result<(usize, usize)> {
    let s = t.shape.as_slice();
    ensure!(s.len() == 2, "expected [N,D] tensor, got shape {:?}", s);
    Ok((s[0], s[1]))
}

impl NativeOp {
    pub fn conv(
        name: &str,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        same: bool,
        bias: bool,
    ) -> Self {
        NativeOp {
            name: name.to_string(),
            kind: OpKind::Conv { cin, cout, k, stride, same, bias },
        }
    }

    pub fn batch_norm(name: &str, c: usize) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::BatchNorm { c, momentum: 0.9, eps: 1e-5 } }
    }

    pub fn act(name: &str, kind: ActKind) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::Act { kind } }
    }

    pub fn max_pool(name: &str, k: usize) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::MaxPool { k, stride: k } }
    }

    pub fn global_avg_pool(name: &str) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::GlobalAvgPool }
    }

    pub fn flatten(name: &str) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::Flatten }
    }

    pub fn dense(name: &str, din: usize, dout: usize, act: ActKind) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::Dense { din, dout, act } }
    }

    /// Parameter specs, mirroring `layers.py::*.param_specs` exactly
    /// (names, shapes, init kinds, fan-in).
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        let p = |pname: &str| format!("{}/{}", self.name, pname);
        match &self.kind {
            OpKind::Conv { cin, cout, k, bias, .. } => {
                let mut specs = vec![ParamSpec {
                    name: p("w"),
                    shape: vec![*k, *k, *cin, *cout],
                    init: "he".to_string(),
                    fan_in: k * k * cin,
                }];
                if *bias {
                    specs.push(ParamSpec {
                        name: p("b"),
                        shape: vec![*cout],
                        init: "zeros".to_string(),
                        fan_in: 0,
                    });
                }
                specs
            }
            OpKind::BatchNorm { c, .. } => vec![
                ParamSpec { name: p("gamma"), shape: vec![*c], init: "ones".into(), fan_in: 0 },
                ParamSpec { name: p("beta"), shape: vec![*c], init: "zeros".into(), fan_in: 0 },
            ],
            OpKind::Dense { din, dout, .. } => vec![
                ParamSpec {
                    name: p("w"),
                    shape: vec![*din, *dout],
                    init: "glorot".into(),
                    fan_in: *din,
                },
                ParamSpec { name: p("b"), shape: vec![*dout], init: "zeros".into(), fan_in: 0 },
            ],
            _ => Vec::new(),
        }
    }

    pub fn state_specs(&self) -> Vec<StateSpec> {
        let p = |sname: &str| format!("{}/{}", self.name, sname);
        match &self.kind {
            OpKind::BatchNorm { c, .. } => vec![
                StateSpec { name: p("mean"), shape: vec![*c], init: "zeros".into() },
                StateSpec { name: p("var"), shape: vec![*c], init: "ones".into() },
            ],
            _ => Vec::new(),
        }
    }

    pub fn n_params(&self) -> usize {
        match &self.kind {
            OpKind::Conv { bias, .. } => 1 + usize::from(*bias),
            OpKind::BatchNorm { .. } | OpKind::Dense { .. } => 2,
            _ => 0,
        }
    }

    pub fn n_state(&self) -> usize {
        match &self.kind {
            OpKind::BatchNorm { .. } => 2,
            _ => 0,
        }
    }

    /// Carry shape out given the (batch-inclusive) carry shape in,
    /// mirroring `layers.py::*.out_shapes`.
    pub fn out_shape(&self, s: &[usize]) -> Result<Vec<usize>> {
        match &self.kind {
            OpKind::Conv { cin, cout, k, stride, same, .. } => {
                ensure!(s.len() == 4 && s[3] == *cin, "{}: bad input shape {:?}", self.name, s);
                let (oh, ow, _, _) = kernels::conv_out_dims(s[1], s[2], *k, *stride, *same);
                Ok(vec![s[0], oh, ow, *cout])
            }
            OpKind::BatchNorm { c, .. } => {
                ensure!(s.last() == Some(c), "{}: bad input shape {:?}", self.name, s);
                Ok(s.to_vec())
            }
            OpKind::Act { .. } => Ok(s.to_vec()),
            OpKind::MaxPool { k, stride } => {
                ensure!(s.len() == 4, "{}: bad input shape {:?}", self.name, s);
                Ok(vec![s[0], (s[1] - k) / stride + 1, (s[2] - k) / stride + 1, s[3]])
            }
            OpKind::GlobalAvgPool => {
                ensure!(s.len() == 4, "{}: bad input shape {:?}", self.name, s);
                Ok(vec![s[0], s[3]])
            }
            OpKind::Flatten => Ok(vec![s[0], s[1..].iter().product()]),
            OpKind::Dense { din, dout, .. } => {
                ensure!(s.len() == 2 && s[1] == *din, "{}: bad input shape {:?}", self.name, s);
                Ok(vec![s[0], *dout])
            }
        }
    }

    /// Forward-pass FLOPs for one sample (the perfsim cost model),
    /// mirroring `layers.py::*.flops_per_sample`.
    pub fn flops_per_sample(&self, s: &[usize]) -> Result<u64> {
        Ok(match &self.kind {
            OpKind::Conv { cin, cout, k, .. } => {
                let out = self.out_shape(s)?;
                (2 * out[1] * out[2] * k * k * cin * cout) as u64
            }
            OpKind::BatchNorm { .. } => 4 * s[1..].iter().product::<usize>() as u64,
            OpKind::Act { .. } => s[1..].iter().product::<usize>() as u64,
            OpKind::MaxPool { k, .. } => {
                let out = self.out_shape(s)?;
                (out[1] * out[2] * out[3] * k * k) as u64
            }
            OpKind::GlobalAvgPool => (s[1] * s[2] * s[3]) as u64,
            OpKind::Flatten => 0,
            OpKind::Dense { din, dout, .. } => (2 * din * dout) as u64,
        })
    }

    /// Training-mode forward: `(y, cache, new_state)`. `new_state` is
    /// positionally aligned with `state_specs` (empty for stateless ops);
    /// the caller decides whether to commit it (fwd/last do, the bwd
    /// recompute discards it — exactly the jax.vjp semantics).
    pub fn train_forward(
        &self,
        params: &[Tensor],
        state: &[Tensor],
        x: &Tensor,
    ) -> Result<(Tensor, OpCache, Vec<Tensor>)> {
        match &self.kind {
            OpKind::Conv { cin, cout, k, stride, same, bias } => {
                let (n, h, w, ci) = dims4(x)?;
                ensure!(ci == *cin, "{}: input has {} channels, want {}", self.name, ci, cin);
                let (oh, ow, _, _) = kernels::conv_out_dims(h, w, *k, *stride, *same);
                let mut y = Tensor::zeros(&[n, oh, ow, *cout]);
                let b = if *bias { Some(params[1].data()) } else { None };
                kernels::conv2d_forward(
                    x.data(),
                    n,
                    h,
                    w,
                    *cin,
                    params[0].data(),
                    *k,
                    *cout,
                    *stride,
                    *same,
                    b,
                    y.data_mut(),
                );
                Ok((y, OpCache::Conv { x: x.clone() }, Vec::new()))
            }
            OpKind::BatchNorm { c, momentum, eps } => {
                ensure!(x.shape.last() == Some(c), "{}: bad shape {:?}", self.name, x.shape);
                let rows = x.numel() / c;
                let mut y = Tensor::zeros(x.shape.as_slice());
                let mut xhat = Tensor::zeros(x.shape.as_slice());
                let (mean, var, inv_std) = kernels::batchnorm_forward_train(
                    x.data(),
                    rows,
                    *c,
                    params[0].data(),
                    params[1].data(),
                    *eps,
                    y.data_mut(),
                    xhat.data_mut(),
                );
                let m = *momentum;
                let mut new_mean = state[0].clone();
                for (o, &b) in new_mean.data_mut().iter_mut().zip(&mean) {
                    *o = m * *o + (1.0 - m) * b;
                }
                let mut new_var = state[1].clone();
                for (o, &b) in new_var.data_mut().iter_mut().zip(&var) {
                    *o = m * *o + (1.0 - m) * b;
                }
                Ok((y, OpCache::BatchNorm { xhat, inv_std }, vec![new_mean, new_var]))
            }
            OpKind::Act { kind } => {
                let mut y = x.clone();
                kind.apply(y.data_mut());
                let cache = OpCache::Act { y: y.clone() };
                Ok((y, cache, Vec::new()))
            }
            OpKind::MaxPool { k, stride } => {
                let (n, h, w, c) = dims4(x)?;
                let (oh, ow) = ((h - k) / stride + 1, (w - k) / stride + 1);
                let mut y = Tensor::zeros(&[n, oh, ow, c]);
                let mut argmax = vec![0u32; n * oh * ow * c];
                kernels::maxpool_forward(
                    x.data(),
                    n,
                    h,
                    w,
                    c,
                    *k,
                    *stride,
                    y.data_mut(),
                    &mut argmax,
                );
                Ok((
                    y,
                    OpCache::MaxPool { in_shape: x.shape.as_slice().to_vec(), argmax },
                    Vec::new(),
                ))
            }
            OpKind::GlobalAvgPool => {
                let (n, h, w, c) = dims4(x)?;
                let mut y = Tensor::zeros(&[n, c]);
                kernels::global_avg_pool_forward(x.data(), n, h, w, c, y.data_mut());
                Ok((y, OpCache::Gap { in_shape: x.shape.as_slice().to_vec() }, Vec::new()))
            }
            OpKind::Flatten => {
                let in_shape = x.shape.as_slice().to_vec();
                let y = x.reshape(&[in_shape[0], x.numel() / in_shape[0]])?;
                Ok((y, OpCache::Flatten { in_shape }, Vec::new()))
            }
            OpKind::Dense { din, dout, act } => {
                let (n, d) = dims2(x)?;
                ensure!(d == *din, "{}: input dim {} want {}", self.name, d, din);
                let mut y = Tensor::zeros(&[n, *dout]);
                kernels::dense_forward(
                    x.data(),
                    n,
                    *din,
                    params[0].data(),
                    params[1].data(),
                    *dout,
                    *act,
                    y.data_mut(),
                );
                Ok((Tensor::clone(&y), OpCache::Dense { x: x.clone(), y }, Vec::new()))
            }
        }
    }

    /// Inference-mode forward (batch-norm uses running stats; no cache,
    /// no state updates).
    pub fn eval_forward(&self, params: &[Tensor], state: &[Tensor], x: &Tensor) -> Result<Tensor> {
        match &self.kind {
            OpKind::BatchNorm { c, eps, .. } => {
                ensure!(x.shape.last() == Some(c), "{}: bad shape {:?}", self.name, x.shape);
                let mut y = Tensor::zeros(x.shape.as_slice());
                kernels::batchnorm_forward_eval(
                    x.data(),
                    *c,
                    params[0].data(),
                    params[1].data(),
                    state[0].data(),
                    state[1].data(),
                    *eps,
                    y.data_mut(),
                );
                Ok(y)
            }
            // every other op is train/eval-identical (no dropout here)
            _ => Ok(self.train_forward(params, state, x)?.0),
        }
    }

    /// Backward: `(dx, dparams)` with dparams aligned to `param_specs`.
    pub fn backward(
        &self,
        params: &[Tensor],
        cache: &OpCache,
        dy: &Tensor,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        match (&self.kind, cache) {
            (OpKind::Conv { cin, cout, k, stride, same, bias }, OpCache::Conv { x }) => {
                let (n, h, w, _) = dims4(x)?;
                let mut dx = Tensor::zeros(x.shape.as_slice());
                let mut dw = Tensor::zeros(params[0].shape.as_slice());
                let mut db = if *bias { Some(Tensor::zeros(&[*cout])) } else { None };
                kernels::conv2d_backward(
                    x.data(),
                    n,
                    h,
                    w,
                    *cin,
                    params[0].data(),
                    *k,
                    *cout,
                    *stride,
                    *same,
                    dy.data(),
                    dx.data_mut(),
                    dw.data_mut(),
                    db.as_mut().map(|t| t.data_mut()),
                );
                let mut grads = vec![dw];
                if let Some(db) = db {
                    grads.push(db);
                }
                Ok((dx, grads))
            }
            (OpKind::BatchNorm { c, .. }, OpCache::BatchNorm { xhat, inv_std }) => {
                let rows = xhat.numel() / c;
                let mut dx = Tensor::zeros(xhat.shape.as_slice());
                let mut dgamma = Tensor::zeros(&[*c]);
                let mut dbeta = Tensor::zeros(&[*c]);
                kernels::batchnorm_backward(
                    xhat.data(),
                    inv_std,
                    params[0].data(),
                    rows,
                    *c,
                    dy.data(),
                    dx.data_mut(),
                    dgamma.data_mut(),
                    dbeta.data_mut(),
                );
                Ok((dx, vec![dgamma, dbeta]))
            }
            (OpKind::Act { kind }, OpCache::Act { y }) => {
                let mut dx = dy.clone();
                for (g, &yv) in dx.data_mut().iter_mut().zip(y.data()) {
                    *g *= kind.grad_from_output(yv);
                }
                Ok((dx, Vec::new()))
            }
            (OpKind::MaxPool { .. }, OpCache::MaxPool { in_shape, argmax }) => {
                let mut dx = Tensor::zeros(in_shape);
                kernels::maxpool_backward(dy.data(), argmax, dx.data_mut());
                Ok((dx, Vec::new()))
            }
            (OpKind::GlobalAvgPool, OpCache::Gap { in_shape }) => {
                let mut dx = Tensor::zeros(in_shape);
                kernels::global_avg_pool_backward(
                    dy.data(),
                    in_shape[0],
                    in_shape[1],
                    in_shape[2],
                    in_shape[3],
                    dx.data_mut(),
                );
                Ok((dx, Vec::new()))
            }
            (OpKind::Flatten, OpCache::Flatten { in_shape }) => {
                Ok((dy.reshape(in_shape)?, Vec::new()))
            }
            (OpKind::Dense { din, dout, act }, OpCache::Dense { x, y }) => {
                let (n, _) = dims2(x)?;
                let mut dx = Tensor::zeros(x.shape.as_slice());
                let mut dw = Tensor::zeros(params[0].shape.as_slice());
                let mut db = Tensor::zeros(&[*dout]);
                kernels::dense_backward(
                    x.data(),
                    n,
                    *din,
                    params[0].data(),
                    *dout,
                    *act,
                    y.data(),
                    dy.data(),
                    dx.data_mut(),
                    dw.data_mut(),
                    db.data_mut(),
                );
                Ok((dx, vec![dw, db]))
            }
            _ => bail!("{}: cache/op kind mismatch in backward", self.name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_mirror_python_layer_zoo() {
        let conv = NativeOp::conv("conv1", 1, 6, 5, 1, true, true);
        let specs = conv.param_specs();
        assert_eq!(specs[0].name, "conv1/w");
        assert_eq!(specs[0].shape, vec![5, 5, 1, 6]);
        assert_eq!(specs[0].init, "he");
        assert_eq!(specs[0].fan_in, 25);
        assert_eq!(specs[1].name, "conv1/b");
        assert_eq!(conv.n_params(), 2);

        let bn = NativeOp::batch_norm("bn1", 8);
        assert_eq!(bn.param_specs()[0].init, "ones");
        assert_eq!(bn.state_specs()[1].name, "bn1/var");
        assert_eq!(bn.n_state(), 2);

        let fc = NativeOp::dense("fc1", 400, 120, ActKind::Tanh);
        assert_eq!(fc.param_specs()[0].init, "glorot");
        assert_eq!(fc.param_specs()[0].fan_in, 400);
    }

    #[test]
    fn lenet_shape_chain() {
        // The quickstart LeNet-5 carry chain, batch 32.
        let ops = [
            NativeOp::conv("conv1", 1, 6, 5, 1, true, true),
            NativeOp::act("act1", ActKind::Tanh),
            NativeOp::max_pool("pool1", 2),
            NativeOp::conv("conv2", 6, 16, 5, 1, false, true),
            NativeOp::act("act2", ActKind::Tanh),
            NativeOp::max_pool("pool2", 2),
            NativeOp::flatten("flat"),
            NativeOp::dense("fc1", 400, 120, ActKind::Tanh),
        ];
        let mut s = vec![32usize, 28, 28, 1];
        for op in &ops {
            s = op.out_shape(&s).unwrap();
        }
        assert_eq!(s, vec![32, 120]);
    }

    #[test]
    fn train_and_eval_forward_agree_without_state() {
        // tanh act has no state: train and eval paths must be identical.
        let op = NativeOp::act("a", ActKind::Tanh);
        let x = Tensor::from_vec(&[2, 3], vec![-1.0, 0.0, 1.0, 2.0, -2.0, 0.5]).unwrap();
        let (yt, _, st) = op.train_forward(&[], &[], &x).unwrap();
        let ye = op.eval_forward(&[], &[], &x).unwrap();
        assert_eq!(yt.data(), ye.data());
        assert!(st.is_empty());
    }

    #[test]
    fn batchnorm_train_updates_state_eval_uses_it() {
        let op = NativeOp::batch_norm("bn", 2);
        let params = vec![Tensor::ones(&[2]), Tensor::zeros(&[2])];
        let state = vec![Tensor::zeros(&[2]), Tensor::ones(&[2])];
        let x = Tensor::from_vec(&[3, 2], vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0]).unwrap();
        let (_, _, new_state) = op.train_forward(&params, &state, &x).unwrap();
        assert_eq!(new_state.len(), 2);
        // running mean moved toward the batch mean (momentum 0.9)
        assert!((new_state[0].data()[0] - 0.1 * 2.0).abs() < 1e-5);
        assert!((new_state[0].data()[1] - 0.1 * 20.0).abs() < 1e-4);
        // eval with the fresh state differs from eval with the old state
        let e_old = op.eval_forward(&params, &state, &x).unwrap();
        let e_new = op.eval_forward(&params, &new_state, &x).unwrap();
        assert_ne!(e_old.data(), e_new.data());
    }

    #[test]
    fn flatten_roundtrips_through_backward() {
        let op = NativeOp::flatten("flat");
        let x = Tensor::from_vec(&[2, 2, 2, 1], (0..8).map(|i| i as f32).collect()).unwrap();
        let (y, cache, _) = op.train_forward(&[], &[], &x).unwrap();
        assert_eq!(y.shape, vec![2, 4]);
        let (dx, grads) = op.backward(&[], &cache, &y).unwrap();
        assert_eq!(dx.shape, vec![2, 2, 2, 1]);
        assert_eq!(dx.data(), x.data());
        assert!(grads.is_empty());
    }
}
