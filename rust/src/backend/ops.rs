//! Native op zoo: the Rust twin of `python/compile/layers.py`, lifted
//! to a block-structured IR.
//!
//! A partition's compute is a `Vec<NativeNode>`. A node is either a
//! plain atomic `NativeOp` (conv / batch-norm / activation / max-pool /
//! global-avg-pool / flatten / dense) or a residual `ResBlock`: a main
//! op sequence plus a `Shortcut` (identity, or a strided 1×1 projection
//! conv + BN) merged by an elementwise add. Blocks are *atomic* with
//! respect to partitioning — the skip tensor never crosses a pipeline
//! register, so carries stay single-tensor (contrast the XLA side's
//! `ResStart`/`ResEnd`, which thread the skip through the register).
//!
//! Each node transforms the carry tensor and (for batch-norm) produces
//! functional state updates that the executor commits exactly where the
//! XLA engine's `take_state` would. `train_forward` records an
//! `OpCache` so the backward walk is analytic; `backward` consumes it
//! and returns `(dx, dparams)` with dparams positionally aligned to the
//! node's `param_specs` (a block's order is main ops, then shortcut
//! ops) — the same ordering `meta.json` records and `Sgd::step` zips
//! against. Dropout remains XLA-only; `backend::models` refuses to
//! build models that use it.

use anyhow::{bail, ensure, Result};

use crate::meta::{ParamSpec, StateSpec};
use crate::tensor::Tensor;

use super::gemm;
use super::kernels::{self, ActKind};

/// Canonical backward/forward FLOPs ratio of one training step: the
/// backward pass computes both the activation gradients and the weight
/// gradients, each roughly one forward's worth of work. The single
/// source for the `bwd ~= 2x fwd` convention shared by the analytic and
/// roofline cost models in [`crate::pipeline::perfsim`] and the
/// analytic profiler in [`crate::profile`] (previously hardcoded as
/// `2.0` in each).
pub const BWD_FLOPS_FACTOR: f64 = 2.0;

/// One atomic native operation.
#[derive(Debug, Clone)]
pub struct NativeOp {
    /// Layer-spec name; parameter/state spec names are derived from it.
    pub name: String,
    /// What the op computes (and its static geometry).
    pub kind: OpKind,
}

/// The op zoo: every atomic computation a native node can perform.
#[derive(Debug, Clone)]
pub enum OpKind {
    /// 2-D convolution (NHWC activations, HWIO weights).
    Conv {
        /// Input channels.
        cin: usize,
        /// Output channels.
        cout: usize,
        /// Square kernel size.
        k: usize,
        /// Spatial stride.
        stride: usize,
        /// XLA-style SAME padding when true, VALID when false.
        same: bool,
        /// Whether a `[cout]` bias is added.
        bias: bool,
    },
    /// Batch normalization over the trailing channel dimension.
    BatchNorm {
        /// Channel count.
        c: usize,
        /// Running-statistics momentum (0.9 everywhere in the zoo).
        momentum: f32,
        /// Variance epsilon.
        eps: f32,
    },
    /// Standalone elementwise activation.
    Act {
        /// Which activation.
        kind: ActKind,
    },
    /// Max pooling, VALID padding, argmax recorded for the backward
    /// scatter.
    MaxPool {
        /// Square window size.
        k: usize,
        /// Window stride (== `k` for the zoo's non-overlapping pools).
        stride: usize,
    },
    /// Global average pool `[n,h,w,c] -> [n,c]`.
    GlobalAvgPool,
    /// Collapse all non-batch dims (zero-copy reshape).
    Flatten,
    /// Fully-connected layer with fused activation.
    Dense {
        /// Input features.
        din: usize,
        /// Output features.
        dout: usize,
        /// Fused activation.
        act: ActKind,
    },
}

/// Saved forward intermediates for one node's backward pass.
#[derive(Debug, Clone)]
pub enum OpCache {
    /// Conv saves its input (im2col is recomputed on the backward).
    Conv {
        /// The forward input.
        x: Tensor,
    },
    /// Dense saves input and post-activation output.
    Dense {
        /// The forward input.
        x: Tensor,
        /// The post-activation output (activation gradients are
        /// expressed through it).
        y: Tensor,
    },
    /// Activations save only their output.
    Act {
        /// The post-activation output.
        y: Tensor,
    },
    /// Max-pool saves the argmax scatter map.
    MaxPool {
        /// Input shape (for the gradient tensor).
        in_shape: Vec<usize>,
        /// Flat input index of each window maximum.
        argmax: Vec<u32>,
    },
    /// Batch-norm saves the normalized activations and the inverse
    /// batch standard deviation.
    BatchNorm {
        /// Normalized activations.
        xhat: Tensor,
        /// Per-channel `1/sqrt(var + eps)`.
        inv_std: Vec<f32>,
    },
    /// Global-avg-pool needs only the input shape.
    Gap {
        /// Input shape (for the gradient tensor).
        in_shape: Vec<usize>,
    },
    /// Flatten needs only the input shape.
    Flatten {
        /// Input shape (for the gradient reshape).
        in_shape: Vec<usize>,
    },
    /// Residual block: per-op caches of both branches (shortcut empty
    /// for identity).
    Block {
        /// Main-branch caches, forward order.
        main: Vec<OpCache>,
        /// Shortcut-branch caches (empty for identity).
        shortcut: Vec<OpCache>,
    },
}

fn dims4(t: &Tensor) -> Result<(usize, usize, usize, usize)> {
    let s = t.shape.as_slice();
    ensure!(s.len() == 4, "expected NHWC tensor, got shape {:?}", s);
    Ok((s[0], s[1], s[2], s[3]))
}

fn dims2(t: &Tensor) -> Result<(usize, usize)> {
    let s = t.shape.as_slice();
    ensure!(s.len() == 2, "expected [N,D] tensor, got shape {:?}", s);
    Ok((s[0], s[1]))
}

impl NativeOp {
    /// Square-kernel 2-D convolution (see [`OpKind::Conv`]).
    pub fn conv(
        name: &str,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        same: bool,
        bias: bool,
    ) -> Self {
        NativeOp {
            name: name.to_string(),
            kind: OpKind::Conv { cin, cout, k, stride, same, bias },
        }
    }

    /// Batch norm with the zoo-wide momentum 0.9 and eps 1e-5.
    pub fn batch_norm(name: &str, c: usize) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::BatchNorm { c, momentum: 0.9, eps: 1e-5 } }
    }

    /// Standalone elementwise activation.
    pub fn act(name: &str, kind: ActKind) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::Act { kind } }
    }

    /// Non-overlapping max pool (stride == window).
    pub fn max_pool(name: &str, k: usize) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::MaxPool { k, stride: k } }
    }

    /// Global average pool.
    pub fn global_avg_pool(name: &str) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::GlobalAvgPool }
    }

    /// Flatten to `[n, features]`.
    pub fn flatten(name: &str) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::Flatten }
    }

    /// Fully-connected layer with fused activation.
    pub fn dense(name: &str, din: usize, dout: usize, act: ActKind) -> Self {
        NativeOp { name: name.to_string(), kind: OpKind::Dense { din, dout, act } }
    }

    /// Parameter specs, mirroring `layers.py::*.param_specs` exactly
    /// (names, shapes, init kinds, fan-in).
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        let p = |pname: &str| format!("{}/{}", self.name, pname);
        match &self.kind {
            OpKind::Conv { cin, cout, k, bias, .. } => {
                let mut specs = vec![ParamSpec {
                    name: p("w"),
                    shape: vec![*k, *k, *cin, *cout],
                    init: "he".to_string(),
                    fan_in: k * k * cin,
                }];
                if *bias {
                    specs.push(ParamSpec {
                        name: p("b"),
                        shape: vec![*cout],
                        init: "zeros".to_string(),
                        fan_in: 0,
                    });
                }
                specs
            }
            OpKind::BatchNorm { c, .. } => vec![
                ParamSpec { name: p("gamma"), shape: vec![*c], init: "ones".into(), fan_in: 0 },
                ParamSpec { name: p("beta"), shape: vec![*c], init: "zeros".into(), fan_in: 0 },
            ],
            OpKind::Dense { din, dout, .. } => vec![
                ParamSpec {
                    name: p("w"),
                    shape: vec![*din, *dout],
                    init: "glorot".into(),
                    fan_in: *din,
                },
                ParamSpec { name: p("b"), shape: vec![*dout], init: "zeros".into(), fan_in: 0 },
            ],
            _ => Vec::new(),
        }
    }

    /// Functional-state specs (batch-norm running statistics).
    pub fn state_specs(&self) -> Vec<StateSpec> {
        let p = |sname: &str| format!("{}/{}", self.name, sname);
        match &self.kind {
            OpKind::BatchNorm { c, .. } => vec![
                StateSpec { name: p("mean"), shape: vec![*c], init: "zeros".into() },
                StateSpec { name: p("var"), shape: vec![*c], init: "ones".into() },
            ],
            _ => Vec::new(),
        }
    }

    /// Number of parameter tensors this op consumes.
    pub fn n_params(&self) -> usize {
        match &self.kind {
            OpKind::Conv { bias, .. } => 1 + usize::from(*bias),
            OpKind::BatchNorm { .. } | OpKind::Dense { .. } => 2,
            _ => 0,
        }
    }

    /// Number of functional-state tensors this op consumes.
    pub fn n_state(&self) -> usize {
        match &self.kind {
            OpKind::BatchNorm { .. } => 2,
            _ => 0,
        }
    }

    /// Carry shape out given the (batch-inclusive) carry shape in,
    /// mirroring `layers.py::*.out_shapes`.
    pub fn out_shape(&self, s: &[usize]) -> Result<Vec<usize>> {
        match &self.kind {
            OpKind::Conv { cin, cout, k, stride, same, .. } => {
                ensure!(s.len() == 4 && s[3] == *cin, "{}: bad input shape {:?}", self.name, s);
                let (oh, ow, _, _) = kernels::conv_out_dims(s[1], s[2], *k, *stride, *same)
                    .map_err(|e| e.context(format!("{}: bad conv geometry", self.name)))?;
                Ok(vec![s[0], oh, ow, *cout])
            }
            OpKind::BatchNorm { c, .. } => {
                ensure!(s.last() == Some(c), "{}: bad input shape {:?}", self.name, s);
                Ok(s.to_vec())
            }
            OpKind::Act { .. } => Ok(s.to_vec()),
            OpKind::MaxPool { k, stride } => {
                ensure!(s.len() == 4, "{}: bad input shape {:?}", self.name, s);
                ensure!(
                    s[1] >= *k && s[2] >= *k && *stride >= 1,
                    "{}: pool window {k} does not fit input {:?}",
                    self.name,
                    s
                );
                Ok(vec![s[0], (s[1] - k) / stride + 1, (s[2] - k) / stride + 1, s[3]])
            }
            OpKind::GlobalAvgPool => {
                ensure!(s.len() == 4, "{}: bad input shape {:?}", self.name, s);
                Ok(vec![s[0], s[3]])
            }
            OpKind::Flatten => Ok(vec![s[0], s[1..].iter().product()]),
            OpKind::Dense { din, dout, .. } => {
                ensure!(s.len() == 2 && s[1] == *din, "{}: bad input shape {:?}", self.name, s);
                Ok(vec![s[0], *dout])
            }
        }
    }

    /// Forward-pass FLOPs for one sample (the perfsim cost model),
    /// mirroring `layers.py::*.flops_per_sample`.
    pub fn flops_per_sample(&self, s: &[usize]) -> Result<u64> {
        Ok(match &self.kind {
            OpKind::Conv { cin, cout, k, .. } => {
                let out = self.out_shape(s)?;
                (2 * out[1] * out[2] * k * k * cin * cout) as u64
            }
            OpKind::BatchNorm { .. } => 4 * s[1..].iter().product::<usize>() as u64,
            OpKind::Act { .. } => s[1..].iter().product::<usize>() as u64,
            OpKind::MaxPool { k, .. } => {
                let out = self.out_shape(s)?;
                (out[1] * out[2] * out[3] * k * k) as u64
            }
            OpKind::GlobalAvgPool => (s[1] * s[2] * s[3]) as u64,
            OpKind::Flatten => 0,
            OpKind::Dense { din, dout, .. } => (2 * din * dout) as u64,
        })
    }

    /// Pooled GEMM scratch (in f32 scalars) one training step of this
    /// op leases at batch-inclusive input shape `s`: the packing panels
    /// of every configured GEMM thread (the worker-side pairs live in
    /// the workers' own pools — `gemm::pack_scratch_total`) plus the
    /// im2col / preactivation-gradient buffer. The companion of
    /// [`NativeOp::flops_per_sample`] for the cost model — `flops`
    /// drives the perfsim timeline, `scratch_floats` bounds the pool
    /// footprint of the lowering (all of it recycled, so the
    /// steady-state step still allocates nothing on any thread).
    pub fn scratch_floats(&self, s: &[usize]) -> Result<usize> {
        Ok(match &self.kind {
            OpKind::Conv { cin, k, stride, .. } => {
                let out = self.out_shape(s)?;
                if *k == 1 && *stride == 1 {
                    // 1x1 stride-1 convs skip im2col entirely.
                    gemm::pack_scratch_total()
                } else {
                    gemm::conv_cols_floats(s[0], out[1], out[2], *k, *cin)
                        + gemm::pack_scratch_total()
                }
            }
            OpKind::Dense { dout, .. } => s[0] * dout + gemm::pack_scratch_total(),
            _ => 0,
        })
    }

    /// Training-mode forward: `(y, cache, new_state)`. `new_state` is
    /// positionally aligned with `state_specs` (empty for stateless ops);
    /// the caller decides whether to commit it (fwd/last do, the bwd
    /// recompute discards it — exactly the jax.vjp semantics).
    pub fn train_forward(
        &self,
        params: &[Tensor],
        state: &[Tensor],
        x: &Tensor,
    ) -> Result<(Tensor, OpCache, Vec<Tensor>)> {
        match &self.kind {
            OpKind::Conv { cin, cout, k, stride, same, bias } => {
                let (n, h, w, ci) = dims4(x)?;
                ensure!(ci == *cin, "{}: input has {} channels, want {}", self.name, ci, cin);
                let (oh, ow, _, _) = kernels::conv_out_dims(h, w, *k, *stride, *same)
                    .map_err(|e| e.context(format!("{}: bad conv geometry", self.name)))?;
                let mut y = Tensor::zeros(&[n, oh, ow, *cout]);
                let b = if *bias { Some(params[1].data()) } else { None };
                kernels::conv2d_forward(
                    x.data(),
                    n,
                    h,
                    w,
                    *cin,
                    params[0].data(),
                    *k,
                    *cout,
                    *stride,
                    *same,
                    b,
                    y.data_mut(),
                );
                Ok((y, OpCache::Conv { x: x.clone() }, Vec::new()))
            }
            OpKind::BatchNorm { c, momentum, eps } => {
                ensure!(x.shape.last() == Some(c), "{}: bad shape {:?}", self.name, x.shape);
                let rows = x.numel() / c;
                let mut y = Tensor::zeros(x.shape.as_slice());
                let mut xhat = Tensor::zeros(x.shape.as_slice());
                let (mean, var, inv_std) = kernels::batchnorm_forward_train(
                    x.data(),
                    rows,
                    *c,
                    params[0].data(),
                    params[1].data(),
                    *eps,
                    y.data_mut(),
                    xhat.data_mut(),
                );
                let m = *momentum;
                let mut new_mean = state[0].clone();
                for (o, &b) in new_mean.data_mut().iter_mut().zip(&mean) {
                    *o = m * *o + (1.0 - m) * b;
                }
                let mut new_var = state[1].clone();
                for (o, &b) in new_var.data_mut().iter_mut().zip(&var) {
                    *o = m * *o + (1.0 - m) * b;
                }
                Ok((y, OpCache::BatchNorm { xhat, inv_std }, vec![new_mean, new_var]))
            }
            OpKind::Act { kind } => {
                let mut y = x.clone();
                kind.apply(y.data_mut());
                let cache = OpCache::Act { y: y.clone() };
                Ok((y, cache, Vec::new()))
            }
            OpKind::MaxPool { k, stride } => {
                let (n, h, w, c) = dims4(x)?;
                ensure!(
                    h >= *k && w >= *k && *stride >= 1,
                    "{}: pool window {k} does not fit input {:?}",
                    self.name,
                    x.shape
                );
                let (oh, ow) = ((h - k) / stride + 1, (w - k) / stride + 1);
                let mut y = Tensor::zeros(&[n, oh, ow, c]);
                let mut argmax = vec![0u32; n * oh * ow * c];
                kernels::maxpool_forward(
                    x.data(),
                    n,
                    h,
                    w,
                    c,
                    *k,
                    *stride,
                    y.data_mut(),
                    &mut argmax,
                );
                Ok((
                    y,
                    OpCache::MaxPool { in_shape: x.shape.as_slice().to_vec(), argmax },
                    Vec::new(),
                ))
            }
            OpKind::GlobalAvgPool => {
                let (n, h, w, c) = dims4(x)?;
                let mut y = Tensor::zeros(&[n, c]);
                kernels::global_avg_pool_forward(x.data(), n, h, w, c, y.data_mut());
                Ok((y, OpCache::Gap { in_shape: x.shape.as_slice().to_vec() }, Vec::new()))
            }
            OpKind::Flatten => {
                let in_shape = x.shape.as_slice().to_vec();
                let y = x.reshape(&[in_shape[0], x.numel() / in_shape[0]])?;
                Ok((y, OpCache::Flatten { in_shape }, Vec::new()))
            }
            OpKind::Dense { din, dout, act } => {
                let (n, d) = dims2(x)?;
                ensure!(d == *din, "{}: input dim {} want {}", self.name, d, din);
                let mut y = Tensor::zeros(&[n, *dout]);
                kernels::dense_forward(
                    x.data(),
                    n,
                    *din,
                    params[0].data(),
                    params[1].data(),
                    *dout,
                    *act,
                    y.data_mut(),
                );
                Ok((Tensor::clone(&y), OpCache::Dense { x: x.clone(), y }, Vec::new()))
            }
        }
    }

    /// Inference-mode forward (batch-norm uses running stats; no cache,
    /// no state updates).
    pub fn eval_forward(&self, params: &[Tensor], state: &[Tensor], x: &Tensor) -> Result<Tensor> {
        match &self.kind {
            OpKind::BatchNorm { c, eps, .. } => {
                ensure!(x.shape.last() == Some(c), "{}: bad shape {:?}", self.name, x.shape);
                let mut y = Tensor::zeros(x.shape.as_slice());
                kernels::batchnorm_forward_eval(
                    x.data(),
                    *c,
                    params[0].data(),
                    params[1].data(),
                    state[0].data(),
                    state[1].data(),
                    *eps,
                    y.data_mut(),
                );
                Ok(y)
            }
            // every other op is train/eval-identical (no dropout here)
            _ => Ok(self.train_forward(params, state, x)?.0),
        }
    }

    /// Backward: `(dx, dparams)` with dparams aligned to `param_specs`.
    pub fn backward(
        &self,
        params: &[Tensor],
        cache: &OpCache,
        dy: &Tensor,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        match (&self.kind, cache) {
            (OpKind::Conv { cin, cout, k, stride, same, bias }, OpCache::Conv { x }) => {
                let (n, h, w, _) = dims4(x)?;
                let mut dx = Tensor::zeros(x.shape.as_slice());
                let mut dw = Tensor::zeros(params[0].shape.as_slice());
                let mut db = if *bias { Some(Tensor::zeros(&[*cout])) } else { None };
                kernels::conv2d_backward(
                    x.data(),
                    n,
                    h,
                    w,
                    *cin,
                    params[0].data(),
                    *k,
                    *cout,
                    *stride,
                    *same,
                    dy.data(),
                    dx.data_mut(),
                    dw.data_mut(),
                    db.as_mut().map(|t| t.data_mut()),
                );
                let mut grads = vec![dw];
                if let Some(db) = db {
                    grads.push(db);
                }
                Ok((dx, grads))
            }
            (OpKind::BatchNorm { c, .. }, OpCache::BatchNorm { xhat, inv_std }) => {
                let rows = xhat.numel() / c;
                let mut dx = Tensor::zeros(xhat.shape.as_slice());
                let mut dgamma = Tensor::zeros(&[*c]);
                let mut dbeta = Tensor::zeros(&[*c]);
                kernels::batchnorm_backward(
                    xhat.data(),
                    inv_std,
                    params[0].data(),
                    rows,
                    *c,
                    dy.data(),
                    dx.data_mut(),
                    dgamma.data_mut(),
                    dbeta.data_mut(),
                );
                Ok((dx, vec![dgamma, dbeta]))
            }
            (OpKind::Act { kind }, OpCache::Act { y }) => {
                let mut dx = dy.clone();
                for (g, &yv) in dx.data_mut().iter_mut().zip(y.data()) {
                    *g *= kind.grad_from_output(yv);
                }
                Ok((dx, Vec::new()))
            }
            (OpKind::MaxPool { .. }, OpCache::MaxPool { in_shape, argmax }) => {
                let mut dx = Tensor::zeros(in_shape);
                kernels::maxpool_backward(dy.data(), argmax, dx.data_mut());
                Ok((dx, Vec::new()))
            }
            (OpKind::GlobalAvgPool, OpCache::Gap { in_shape }) => {
                let mut dx = Tensor::zeros(in_shape);
                kernels::global_avg_pool_backward(
                    dy.data(),
                    in_shape[0],
                    in_shape[1],
                    in_shape[2],
                    in_shape[3],
                    dx.data_mut(),
                );
                Ok((dx, Vec::new()))
            }
            (OpKind::Flatten, OpCache::Flatten { in_shape }) => {
                Ok((dy.reshape(in_shape)?, Vec::new()))
            }
            (OpKind::Dense { din, dout, act }, OpCache::Dense { x, y }) => {
                let (n, _) = dims2(x)?;
                let mut dx = Tensor::zeros(x.shape.as_slice());
                let mut dw = Tensor::zeros(params[0].shape.as_slice());
                let mut db = Tensor::zeros(&[*dout]);
                kernels::dense_backward(
                    x.data(),
                    n,
                    *din,
                    params[0].data(),
                    *dout,
                    *act,
                    y.data(),
                    dy.data(),
                    dx.data_mut(),
                    dw.data_mut(),
                    db.data_mut(),
                );
                Ok((dx, vec![dw, db]))
            }
            _ => bail!("{}: cache/op kind mismatch in backward", self.name),
        }
    }
}

// ---------------------------------------------------------------------------
// Block-structured IR: plain ops and residual blocks as one node kind.
// ---------------------------------------------------------------------------

/// Shortcut branch of a residual block.
#[derive(Debug, Clone)]
pub enum Shortcut {
    /// `y = main(x) + x` — requires the main branch to preserve shape.
    Identity,
    /// Shape-aligning projection (He et al. option B): by convention a
    /// strided 1×1 conv + BN, but any op chain mapping the block input
    /// to the main branch's output shape is accepted.
    Projection(Vec<NativeOp>),
}

impl Shortcut {
    /// The standard projection shortcut: 1×1 conv (stride `stride`,
    /// no bias) + batch-norm, aligning `cin -> cout` across a
    /// (possibly strided) block transition.
    pub fn projection(tag: &str, cin: usize, cout: usize, stride: usize) -> Shortcut {
        Shortcut::Projection(vec![
            NativeOp::conv(&format!("{tag}/proj"), cin, cout, 1, stride, true, false),
            NativeOp::batch_norm(&format!("{tag}/projbn"), cout),
        ])
    }

    fn ops(&self) -> &[NativeOp] {
        match self {
            Shortcut::Identity => &[],
            Shortcut::Projection(ops) => ops,
        }
    }
}

/// A residual basic block: `y = main(x) + shortcut(x)`, merged by an
/// elementwise add. The whole block is one IR node, so a pipeline
/// partition boundary can never split it — carries stay single-tensor.
#[derive(Debug, Clone)]
pub struct ResBlock {
    /// Block name (spec names of branch ops are prefixed with it by
    /// the model builders).
    pub name: String,
    /// Main branch, forward order.
    pub main: Vec<NativeOp>,
    /// Skip branch.
    pub shortcut: Shortcut,
}

impl ResBlock {
    fn main_params(&self) -> usize {
        self.main.iter().map(NativeOp::n_params).sum()
    }

    fn main_state(&self) -> usize {
        self.main.iter().map(NativeOp::n_state).sum()
    }
}

/// One node of a partition's compute: a plain op or a residual block.
#[derive(Debug, Clone)]
pub enum NativeNode {
    /// A single atomic op.
    Op(NativeOp),
    /// A whole residual block (atomic w.r.t. partitioning).
    Block(ResBlock),
}

/// Training forward over an op chain, slicing `params`/`state`
/// positionally per op: `(y, caches, new_state)` with new_state
/// concatenated in `state_specs` order.
fn chain_train_forward(
    ops: &[NativeOp],
    params: &[Tensor],
    state: &[Tensor],
    x: &Tensor,
) -> Result<(Tensor, Vec<OpCache>, Vec<Tensor>)> {
    let (mut po, mut so) = (0usize, 0usize);
    let mut cur = x.clone();
    let mut caches = Vec::with_capacity(ops.len());
    let mut new_state = Vec::new();
    for op in ops {
        let (y, cache, ns) =
            op.train_forward(&params[po..po + op.n_params()], &state[so..so + op.n_state()], &cur)?;
        po += op.n_params();
        so += op.n_state();
        caches.push(cache);
        new_state.extend(ns);
        cur = y;
    }
    Ok((cur, caches, new_state))
}

/// Inference forward over an op chain (running BN statistics).
fn chain_eval_forward(
    ops: &[NativeOp],
    params: &[Tensor],
    state: &[Tensor],
    x: &Tensor,
) -> Result<Tensor> {
    let (mut po, mut so) = (0usize, 0usize);
    let mut cur = x.clone();
    for op in ops {
        cur =
            op.eval_forward(&params[po..po + op.n_params()], &state[so..so + op.n_state()], &cur)?;
        po += op.n_params();
        so += op.n_state();
    }
    Ok(cur)
}

/// Merge two branch outputs (or branch input-gradients) by elementwise
/// add, enforcing shape agreement — the block's single merge point for
/// forward, eval and backward.
fn merge_branches(name: &str, ym: &Tensor, ys: &Tensor) -> Result<Tensor> {
    ensure!(
        ym.shape == ys.shape,
        "{name}: residual add shape mismatch: main {:?} vs shortcut {:?}",
        ym.shape,
        ys.shape
    );
    let mut y = Tensor::zeros(ym.shape.as_slice());
    kernels::residual_add_forward(ym.data(), ys.data(), y.data_mut());
    Ok(y)
}

/// Backward over an op chain from its recorded caches: `(dx, grads)`
/// with grads concatenated in `param_specs` (forward) order.
fn chain_backward(
    ops: &[NativeOp],
    params: &[Tensor],
    caches: &[OpCache],
    dy: &Tensor,
) -> Result<(Tensor, Vec<Tensor>)> {
    ensure!(caches.len() == ops.len(), "chain backward: cache arity mismatch");
    let mut offsets = Vec::with_capacity(ops.len());
    let mut po = 0usize;
    for op in ops {
        offsets.push(po);
        po += op.n_params();
    }
    let mut per_op: Vec<Vec<Tensor>> = vec![Vec::new(); ops.len()];
    let mut g = dy.clone();
    for i in (0..ops.len()).rev() {
        let (dx, dparams) =
            ops[i].backward(&params[offsets[i]..offsets[i] + ops[i].n_params()], &caches[i], &g)?;
        per_op[i] = dparams;
        g = dx;
    }
    Ok((g, per_op.into_iter().flatten().collect()))
}

impl NativeNode {
    /// Wrap a plain op as a node.
    pub fn op(op: NativeOp) -> NativeNode {
        NativeNode::Op(op)
    }

    /// Build a residual block node.
    pub fn block(name: &str, main: Vec<NativeOp>, shortcut: Shortcut) -> NativeNode {
        NativeNode::Block(ResBlock { name: name.to_string(), main, shortcut })
    }

    /// The op or block name.
    pub fn name(&self) -> &str {
        match self {
            NativeNode::Op(op) => &op.name,
            NativeNode::Block(b) => &b.name,
        }
    }

    /// Parameter specs; a block's ordering is main ops then shortcut ops.
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        match self {
            NativeNode::Op(op) => op.param_specs(),
            NativeNode::Block(b) => b
                .main
                .iter()
                .chain(b.shortcut.ops())
                .flat_map(NativeOp::param_specs)
                .collect(),
        }
    }

    /// Functional-state specs; a block's ordering is main then shortcut.
    pub fn state_specs(&self) -> Vec<StateSpec> {
        match self {
            NativeNode::Op(op) => op.state_specs(),
            NativeNode::Block(b) => b
                .main
                .iter()
                .chain(b.shortcut.ops())
                .flat_map(NativeOp::state_specs)
                .collect(),
        }
    }

    /// Number of parameter tensors this node consumes.
    pub fn n_params(&self) -> usize {
        match self {
            NativeNode::Op(op) => op.n_params(),
            NativeNode::Block(b) => {
                b.main.iter().chain(b.shortcut.ops()).map(NativeOp::n_params).sum()
            }
        }
    }

    /// Number of functional-state tensors this node consumes.
    pub fn n_state(&self) -> usize {
        match self {
            NativeNode::Op(op) => op.n_state(),
            NativeNode::Block(b) => {
                b.main.iter().chain(b.shortcut.ops()).map(NativeOp::n_state).sum()
            }
        }
    }

    /// Carry shape out given the (batch-inclusive) carry shape in. For
    /// a block, both branches are walked and must agree — a shape
    /// mismatch at the residual add is a build-time error here, not a
    /// runtime panic.
    pub fn out_shape(&self, s: &[usize]) -> Result<Vec<usize>> {
        match self {
            NativeNode::Op(op) => op.out_shape(s),
            NativeNode::Block(b) => {
                let mut main = s.to_vec();
                for op in &b.main {
                    main = op.out_shape(&main)?;
                }
                let mut sc = s.to_vec();
                for op in b.shortcut.ops() {
                    sc = op.out_shape(&sc)?;
                }
                ensure!(
                    main == sc,
                    "{}: residual add shape mismatch: main {:?} vs shortcut {:?} \
                     (identity shortcuts need a shape-preserving main branch)",
                    b.name,
                    main,
                    sc
                );
                Ok(main)
            }
        }
    }

    /// Forward-pass FLOPs for one sample; a block adds both branches
    /// plus one elementwise add over the output.
    pub fn flops_per_sample(&self, s: &[usize]) -> Result<u64> {
        match self {
            NativeNode::Op(op) => op.flops_per_sample(s),
            NativeNode::Block(b) => {
                let mut flops = 0u64;
                let mut main = s.to_vec();
                for op in &b.main {
                    flops += op.flops_per_sample(&main)?;
                    main = op.out_shape(&main)?;
                }
                let mut sc = s.to_vec();
                for op in b.shortcut.ops() {
                    flops += op.flops_per_sample(&sc)?;
                    sc = op.out_shape(&sc)?;
                }
                Ok(flops + main[1..].iter().product::<usize>() as u64)
            }
        }
    }

    /// Peak pooled GEMM scratch (f32 scalars) across this node's ops at
    /// batch-inclusive input shape `s`. Per-op leases drop before the
    /// next op runs, so a chain's footprint is the max, not the sum.
    pub fn scratch_floats(&self, s: &[usize]) -> Result<usize> {
        match self {
            NativeNode::Op(op) => op.scratch_floats(s),
            NativeNode::Block(b) => {
                let mut peak = 0usize;
                let mut main = s.to_vec();
                for op in &b.main {
                    peak = peak.max(op.scratch_floats(&main)?);
                    main = op.out_shape(&main)?;
                }
                let mut sc = s.to_vec();
                for op in b.shortcut.ops() {
                    peak = peak.max(op.scratch_floats(&sc)?);
                    sc = op.out_shape(&sc)?;
                }
                Ok(peak)
            }
        }
    }

    /// Training-mode forward: `(y, cache, new_state)`, same contract as
    /// `NativeOp::train_forward` (new_state aligned to `state_specs`).
    pub fn train_forward(
        &self,
        params: &[Tensor],
        state: &[Tensor],
        x: &Tensor,
    ) -> Result<(Tensor, OpCache, Vec<Tensor>)> {
        match self {
            NativeNode::Op(op) => op.train_forward(params, state, x),
            NativeNode::Block(b) => {
                let (mp, ms) = (b.main_params(), b.main_state());
                let (ym, mcaches, mut new_state) =
                    chain_train_forward(&b.main, &params[..mp], &state[..ms], x)?;
                let sops = b.shortcut.ops();
                let (ys, scaches) = if sops.is_empty() {
                    (x.clone(), Vec::new())
                } else {
                    let (ys, sc, ss) =
                        chain_train_forward(sops, &params[mp..], &state[ms..], x)?;
                    new_state.extend(ss);
                    (ys, sc)
                };
                let y = merge_branches(&b.name, &ym, &ys)?;
                Ok((y, OpCache::Block { main: mcaches, shortcut: scaches }, new_state))
            }
        }
    }

    /// Inference-mode forward (running BN statistics; pure).
    pub fn eval_forward(&self, params: &[Tensor], state: &[Tensor], x: &Tensor) -> Result<Tensor> {
        match self {
            NativeNode::Op(op) => op.eval_forward(params, state, x),
            NativeNode::Block(b) => {
                let (mp, ms) = (b.main_params(), b.main_state());
                let ym = chain_eval_forward(&b.main, &params[..mp], &state[..ms], x)?;
                let sops = b.shortcut.ops();
                let ys = if sops.is_empty() {
                    x.clone()
                } else {
                    chain_eval_forward(sops, &params[mp..], &state[ms..], x)?
                };
                merge_branches(&b.name, &ym, &ys)
            }
        }
    }

    /// Backward: `(dx, dparams)` with dparams aligned to `param_specs`.
    /// The residual add fans `dy` into both branches; the block input
    /// gradient is the elementwise sum of the branch input gradients.
    pub fn backward(
        &self,
        params: &[Tensor],
        cache: &OpCache,
        dy: &Tensor,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        match (self, cache) {
            (NativeNode::Op(op), cache) => op.backward(params, cache, dy),
            (NativeNode::Block(b), OpCache::Block { main, shortcut }) => {
                // The add's backward fans dy into both branch seeds.
                let mut d_main = Tensor::zeros(dy.shape.as_slice());
                let mut d_sc = Tensor::zeros(dy.shape.as_slice());
                kernels::residual_add_backward(dy.data(), d_main.data_mut(), d_sc.data_mut());
                let mp = b.main_params();
                let (dxm, mut grads) = chain_backward(&b.main, &params[..mp], main, &d_main)?;
                let sops = b.shortcut.ops();
                let dxs = if sops.is_empty() {
                    d_sc
                } else {
                    let (dxs, gs) = chain_backward(sops, &params[mp..], shortcut, &d_sc)?;
                    grads.extend(gs);
                    dxs
                };
                let dx = merge_branches(&b.name, &dxm, &dxs)?;
                Ok((dx, grads))
            }
            (NativeNode::Block(b), _) => {
                bail!("{}: cache/node kind mismatch in backward", b.name)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_mirror_python_layer_zoo() {
        let conv = NativeOp::conv("conv1", 1, 6, 5, 1, true, true);
        let specs = conv.param_specs();
        assert_eq!(specs[0].name, "conv1/w");
        assert_eq!(specs[0].shape, vec![5, 5, 1, 6]);
        assert_eq!(specs[0].init, "he");
        assert_eq!(specs[0].fan_in, 25);
        assert_eq!(specs[1].name, "conv1/b");
        assert_eq!(conv.n_params(), 2);

        let bn = NativeOp::batch_norm("bn1", 8);
        assert_eq!(bn.param_specs()[0].init, "ones");
        assert_eq!(bn.state_specs()[1].name, "bn1/var");
        assert_eq!(bn.n_state(), 2);

        let fc = NativeOp::dense("fc1", 400, 120, ActKind::Tanh);
        assert_eq!(fc.param_specs()[0].init, "glorot");
        assert_eq!(fc.param_specs()[0].fan_in, 400);
    }

    #[test]
    fn lenet_shape_chain() {
        // The quickstart LeNet-5 carry chain, batch 32.
        let ops = [
            NativeOp::conv("conv1", 1, 6, 5, 1, true, true),
            NativeOp::act("act1", ActKind::Tanh),
            NativeOp::max_pool("pool1", 2),
            NativeOp::conv("conv2", 6, 16, 5, 1, false, true),
            NativeOp::act("act2", ActKind::Tanh),
            NativeOp::max_pool("pool2", 2),
            NativeOp::flatten("flat"),
            NativeOp::dense("fc1", 400, 120, ActKind::Tanh),
        ];
        let mut s = vec![32usize, 28, 28, 1];
        for op in &ops {
            s = op.out_shape(&s).unwrap();
        }
        assert_eq!(s, vec![32, 120]);
    }

    #[test]
    fn train_and_eval_forward_agree_without_state() {
        // tanh act has no state: train and eval paths must be identical.
        let op = NativeOp::act("a", ActKind::Tanh);
        let x = Tensor::from_vec(&[2, 3], vec![-1.0, 0.0, 1.0, 2.0, -2.0, 0.5]).unwrap();
        let (yt, _, st) = op.train_forward(&[], &[], &x).unwrap();
        let ye = op.eval_forward(&[], &[], &x).unwrap();
        assert_eq!(yt.data(), ye.data());
        assert!(st.is_empty());
    }

    #[test]
    fn batchnorm_train_updates_state_eval_uses_it() {
        let op = NativeOp::batch_norm("bn", 2);
        let params = vec![Tensor::ones(&[2]), Tensor::zeros(&[2])];
        let state = vec![Tensor::zeros(&[2]), Tensor::ones(&[2])];
        let x = Tensor::from_vec(&[3, 2], vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0]).unwrap();
        let (_, _, new_state) = op.train_forward(&params, &state, &x).unwrap();
        assert_eq!(new_state.len(), 2);
        // running mean moved toward the batch mean (momentum 0.9)
        assert!((new_state[0].data()[0] - 0.1 * 2.0).abs() < 1e-5);
        assert!((new_state[0].data()[1] - 0.1 * 20.0).abs() < 1e-4);
        // eval with the fresh state differs from eval with the old state
        let e_old = op.eval_forward(&params, &state, &x).unwrap();
        let e_new = op.eval_forward(&params, &new_state, &x).unwrap();
        assert_ne!(e_old.data(), e_new.data());
    }

    #[test]
    fn block_param_specs_order_main_then_shortcut() {
        let node = NativeNode::block(
            "g1b0",
            vec![
                NativeOp::conv("g1b0/conv1", 4, 8, 3, 2, true, false),
                NativeOp::batch_norm("g1b0/bn1", 8),
                NativeOp::act("g1b0/a1", ActKind::Relu),
                NativeOp::conv("g1b0/conv2", 8, 8, 3, 1, true, false),
                NativeOp::batch_norm("g1b0/bn2", 8),
            ],
            Shortcut::projection("g1b0", 4, 8, 2),
        );
        let names: Vec<String> = node.param_specs().iter().map(|s| s.name.clone()).collect();
        assert_eq!(
            names,
            vec![
                "g1b0/conv1/w",
                "g1b0/bn1/gamma",
                "g1b0/bn1/beta",
                "g1b0/conv2/w",
                "g1b0/bn2/gamma",
                "g1b0/bn2/beta",
                "g1b0/proj/w",
                "g1b0/projbn/gamma",
                "g1b0/projbn/beta",
            ]
        );
        assert_eq!(node.n_params(), 9);
        assert_eq!(node.n_state(), 6);
        // strided transition halves spatial dims, widens channels; both
        // branches agree on the output shape
        assert_eq!(node.out_shape(&[2, 8, 8, 4]).unwrap(), vec![2, 4, 4, 8]);
        assert!(node.flops_per_sample(&[1, 8, 8, 4]).unwrap() > 0);
    }

    #[test]
    fn scratch_accounting_tracks_the_gemm_lowering() {
        use crate::backend::gemm;
        // 3x3 conv: im2col buffer + per-thread packing panels.
        let conv = NativeOp::conv("c", 4, 8, 3, 1, true, false);
        let s = [2usize, 8, 8, 4];
        assert_eq!(
            conv.scratch_floats(&s).unwrap(),
            gemm::conv_cols_floats(2, 8, 8, 3, 4) + gemm::pack_scratch_total()
        );
        // 1x1 stride-1 conv skips im2col: panels only.
        let proj = NativeOp::conv("p", 4, 8, 1, 1, true, false);
        assert_eq!(proj.scratch_floats(&s).unwrap(), gemm::pack_scratch_total());
        // dense: preactivation-gradient buffer + panels.
        let fc = NativeOp::dense("f", 16, 10, ActKind::None);
        assert_eq!(fc.scratch_floats(&[2, 16]).unwrap(), 2 * 10 + gemm::pack_scratch_total());
        // shape-only ops lease nothing.
        assert_eq!(NativeOp::flatten("fl").scratch_floats(&s).unwrap(), 0);
        // a block's footprint is the per-op peak, not the sum.
        let node = NativeNode::block(
            "b",
            vec![
                NativeOp::conv("b/c1", 4, 4, 3, 1, true, false),
                NativeOp::conv("b/c2", 4, 4, 3, 1, true, false),
            ],
            Shortcut::Identity,
        );
        assert_eq!(
            node.scratch_floats(&s).unwrap(),
            gemm::conv_cols_floats(2, 8, 8, 3, 4) + gemm::pack_scratch_total()
        );
    }

    #[test]
    fn identity_block_shape_mismatch_is_a_build_error() {
        // main branch strides but the shortcut is identity: the
        // residual add cannot merge the branches.
        let node = NativeNode::block(
            "b",
            vec![NativeOp::conv("b/conv1", 4, 4, 3, 2, true, false)],
            Shortcut::Identity,
        );
        let err = node.out_shape(&[1, 8, 8, 4]).unwrap_err().to_string();
        assert!(err.contains("residual add shape mismatch"), "{err}");
    }

    #[test]
    fn residual_add_passes_identity_through_zero_main() {
        // Zeroed 1x1-conv main branch: y = 0 + x, and backward fans the
        // incoming gradient to both branches (dx = W^T dy + dy = dy).
        let node = NativeNode::block(
            "b",
            vec![NativeOp::conv("b/c", 2, 2, 1, 1, true, false)],
            Shortcut::Identity,
        );
        let x = Tensor::from_vec(&[1, 2, 2, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        let params = vec![Tensor::zeros(&[1, 1, 2, 2])];
        let (y, cache, ns) = node.train_forward(&params, &[], &x).unwrap();
        assert_eq!(y.data(), x.data());
        assert!(ns.is_empty());
        let dy = Tensor::ones(&[1, 2, 2, 2]);
        let (dx, grads) = node.backward(&params, &cache, &dy).unwrap();
        assert_eq!(dx.data(), dy.data());
        assert_eq!(grads.len(), 1);
        // the conv weight still receives dW = dy * x from its branch
        assert!(grads[0].data().iter().any(|&g| g != 0.0));
        // eval path agrees (no BN in this block)
        let ye = node.eval_forward(&params, &[], &x).unwrap();
        assert_eq!(ye.data(), y.data());
    }

    #[test]
    fn flatten_roundtrips_through_backward() {
        let op = NativeOp::flatten("flat");
        let x = Tensor::from_vec(&[2, 2, 2, 1], (0..8).map(|i| i as f32).collect()).unwrap();
        let (y, cache, _) = op.train_forward(&[], &[], &x).unwrap();
        assert_eq!(y.shape, vec![2, 4]);
        let (dx, grads) = op.backward(&[], &cache, &y).unwrap();
        assert_eq!(dx.shape, vec![2, 2, 2, 1]);
        assert_eq!(dx.data(), x.data());
        assert!(grads.is_empty());
    }
}
