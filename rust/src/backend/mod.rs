//! Native pure-Rust compute backend.
//!
//! `NativeExecutor` implements the same `StageExecutor` contract the
//! XLA engine does — `forward` / `last` / `backward` / `eval_forward`
//! with coordinator-owned weights and per-partition SGD — but computes
//! every stage with the in-crate kernels instead of AOT-compiled PJRT
//! programs. The scheduler, hybrid controller, train driver, evaluate
//! loop and checkpointing all run unchanged on either backend; only the
//! compute substrate differs. This is what lets the full pipelined-
//! training suite (convergence, single-in-flight equivalence, staleness
//! divergence) execute on any machine, offline, with no artifacts —
//! for the LeNet family and, via the block-structured IR
//! (`ops::NativeNode`), the paper's CIFAR-10 ResNets.
//!
//! Semantics mirrored from the stage programs (`python/compile/stages.py`):
//! * `forward` applies BN-state updates internally and never touches
//!   weights;
//! * `backward` *recomputes* the partition forward from the saved
//!   carry_in (the jax.vjp recompute), discards its state updates, and
//!   applies the weight update;
//! * the fused `last` stage does forward + softmax-CE + backward +
//!   update in one call (staleness 0 for the final partition);
//! * `eval_forward` uses running BN statistics and, on the last
//!   partition, returns logits.

pub mod gemm;
pub mod kernels;
pub mod models;
pub mod ops;
pub mod simd;
pub mod threadpool;

use anyhow::{anyhow, ensure, Result};

use crate::meta::{ConfigMeta, PartitionMeta};
use crate::model::{ModelParams, PartitionParams};
use crate::optim::Sgd;
use crate::pipeline::executor::{LastResult, StageExecutor, WorkerStage};
use crate::pipeline::mitigation::{fix_for, FixKind, FixStats, StalenessFix};
use crate::tensor::{IntTensor, Tensor};

pub use kernels::ActKind;
pub use models::{
    build_model, native_config, native_config_names, native_config_with_ppv, partition_nodes,
    supported_models,
};
pub use ops::{NativeNode, NativeOp, OpCache, ResBlock, Shortcut, BWD_FLOPS_FACTOR};

/// One partition's native compute: node stack (plain ops and whole
/// residual blocks) + weights + optimizer. Because blocks are atomic
/// nodes, a partition always holds complete blocks — the block IR's
/// partition-boundary rule.
pub struct NativePartition {
    /// The partition's recorded contract (layer range, carry shapes,
    /// param/state specs).
    pub meta: PartitionMeta,
    nodes: Vec<NativeNode>,
    /// Per-node (param, state) offsets into the flat partition vectors.
    offsets: Vec<(usize, usize)>,
    /// The partition's weights and functional state (the only copy
    /// during training — the paper's one-copy discipline).
    pub params: PartitionParams,
    /// Per-partition SGD optimizer (own LR scale, own velocity).
    pub optim: Sgd,
    /// Weight updates applied so far (`last`/`backward` calls) — the
    /// LR-schedule position. Seeded from `params.version` so a
    /// partition rebuilt from a checkpoint (or relaunched at a segment
    /// boundary) continues the schedule where it left off.
    pub update_count: usize,
    /// Active staleness mitigation (DESIGN.md §9); `none` by default,
    /// so plain runs are byte-for-byte the pre-mitigation code path.
    fix: Box<dyn StalenessFix>,
}

impl NativePartition {
    /// Build the native compute for partition `idx` of a config — the
    /// partition-splittable constructor the threaded runtime uses so
    /// each worker thread owns exactly one partition's weights. All
    /// fields are plain data (`Send`), so a partition can be built on
    /// the coordinator and moved to a worker, or built on the worker
    /// directly.
    pub fn for_partition(
        meta: &ConfigMeta,
        idx: usize,
        params: PartitionParams,
        optim: Sgd,
    ) -> Result<Self> {
        let pm = meta
            .partitions
            .get(idx)
            .ok_or_else(|| anyhow!("config {} has no partition {idx}", meta.config))?;
        let nodes = models::partition_nodes(meta, pm)?;
        NativePartition::new(pm.clone(), nodes, params, optim)
    }

    fn new(
        meta: PartitionMeta,
        nodes: Vec<NativeNode>,
        params: PartitionParams,
        optim: Sgd,
    ) -> Result<Self> {
        let mut po = 0usize;
        let mut so = 0usize;
        let mut offsets = Vec::with_capacity(nodes.len());
        for node in &nodes {
            offsets.push((po, so));
            po += node.n_params();
            so += node.n_state();
        }
        ensure!(
            po == params.params.len() && so == params.state.len(),
            "partition {}: node stack wants {po} params / {so} state, got {} / {}",
            meta.index,
            params.params.len(),
            params.state.len()
        );
        let update_count = params.version as usize;
        Ok(NativePartition {
            meta,
            nodes,
            offsets,
            params,
            optim,
            update_count,
            fix: fix_for(FixKind::None),
        })
    }

    /// Install a staleness fix (DESIGN.md §9). Must be called on a
    /// drained partition (no batch in flight): the fresh fix starts
    /// with an empty in-flight ring.
    pub fn set_staleness_fix(&mut self, kind: FixKind) {
        self.fix = fix_for(kind);
    }

    /// The active fix's observable counters (ring occupancy and
    /// high-water marks; memory-accounting tests).
    pub fn fix_stats(&self) -> FixStats {
        self.fix.stats()
    }

    fn node_params(&self, i: usize) -> &[Tensor] {
        let (po, _) = self.offsets[i];
        &self.params.params[po..po + self.nodes[i].n_params()]
    }

    /// Slice node `i`'s parameters out of an explicit flat vector (the
    /// live weights, a stashed version, or a predicted one).
    fn node_params_in<'a>(&self, flat: &'a [Tensor], i: usize) -> &'a [Tensor] {
        let (po, _) = self.offsets[i];
        &flat[po..po + self.nodes[i].n_params()]
    }

    fn node_state(&self, i: usize) -> &[Tensor] {
        let (_, so) = self.offsets[i];
        &self.params.state[so..so + self.nodes[i].n_state()]
    }

    /// Training forward walk over an explicit weight vector (`flat` is
    /// usually `self.params.params`; the mitigation hooks substitute a
    /// stashed or predicted version): `(output, caches, state_updates)`
    /// where state_updates pairs a state offset with the node's new
    /// state values (for a block, all its BN states concatenated in
    /// spec order).
    #[allow(clippy::type_complexity)]
    fn forward_train(
        &self,
        flat: &[Tensor],
        x: &Tensor,
    ) -> Result<(Tensor, Vec<OpCache>, Vec<(usize, Vec<Tensor>)>)> {
        let mut cur = x.clone();
        let mut caches = Vec::with_capacity(self.nodes.len());
        let mut updates = Vec::new();
        for i in 0..self.nodes.len() {
            let (y, cache, new_state) = self.nodes[i].train_forward(
                self.node_params_in(flat, i),
                self.node_state(i),
                &cur,
            )?;
            caches.push(cache);
            if !new_state.is_empty() {
                updates.push((self.offsets[i].1, new_state));
            }
            cur = y;
        }
        Ok((cur, caches, updates))
    }

    fn commit_state(&mut self, updates: Vec<(usize, Vec<Tensor>)>) {
        for (so, vals) in updates {
            for (j, t) in vals.into_iter().enumerate() {
                self.params.state[so + j] = t;
            }
        }
    }

    /// Backward walk from `dy` through the recorded caches, against the
    /// same explicit weight vector the forward used: `(gcarry_in,
    /// grads)` with grads aligned to `params.params`.
    fn backward_walk(
        &self,
        flat: &[Tensor],
        caches: &[OpCache],
        dy: Tensor,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        let mut grads: Vec<Option<Tensor>> = vec![None; self.params.params.len()];
        let mut g = dy;
        for i in (0..self.nodes.len()).rev() {
            let (dx, dparams) =
                self.nodes[i].backward(self.node_params_in(flat, i), &caches[i], &g)?;
            let (po, _) = self.offsets[i];
            for (j, dp) in dparams.into_iter().enumerate() {
                grads[po + j] = Some(dp);
            }
            g = dx;
        }
        let grads = grads
            .into_iter()
            .enumerate()
            .map(|(j, g)| g.ok_or_else(|| anyhow!("missing gradient for param {j}")))
            .collect::<Result<Vec<_>>>()?;
        Ok((g, grads))
    }

    fn apply_update(&mut self, grads: &[Tensor]) -> Result<()> {
        self.optim.step(self.update_count, &mut self.params.params, grads)?;
        self.update_count += 1;
        self.params.version += 1;
        Ok(())
    }

    fn single<'a>(carry: &'a [Tensor], what: &str) -> Result<&'a Tensor> {
        ensure!(carry.len() == 1, "native {what}: expected 1 carry tensor, got {}", carry.len());
        Ok(&carry[0])
    }

    /// Training forward of a non-last partition: commits BN-state
    /// updates, never touches weights. Engages the active staleness
    /// fix (stash push / weight prediction).
    pub fn stage_forward(&mut self, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        let over = self.fix.on_forward(&self.params.params, &self.optim, self.update_count)?;
        self.stage_forward_with(carry, over.as_deref())
    }

    /// The raw forward primitive under an explicit weight override
    /// (`None` = live weights) — the mitigation seam, public so the
    /// equivalence oracle in `tests/mitigation.rs` can drive it without
    /// the production ring.
    pub fn stage_forward_with(
        &mut self,
        carry: &[Tensor],
        over: Option<&[Tensor]>,
    ) -> Result<Vec<Tensor>> {
        ensure!(!self.meta.is_last(), "forward called on the last partition");
        if let Some(o) = over {
            ensure!(
                o.len() == self.params.params.len(),
                "weight override arity {} != {}",
                o.len(),
                self.params.params.len()
            );
        }
        let x = Self::single(carry, "forward")?.clone();
        let flat = over.unwrap_or(&self.params.params);
        let (y, _caches, updates) = self.forward_train(flat, &x)?;
        self.commit_state(updates);
        Ok(vec![y])
    }

    /// Fused last stage: forward + softmax-CE + backward + update in
    /// one call (staleness 0 for the final partition).
    pub fn stage_last(&mut self, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult> {
        ensure!(self.meta.is_last(), "stage_last called on a non-last partition");
        let x = Self::single(carry, "last")?.clone();
        let (logits, caches, updates) = self.forward_train(&self.params.params, &x)?;
        let n = logits.shape[0];
        let classes = logits.numel() / n;
        ensure!(
            labels.data.len() == n,
            "last: {} labels for batch of {n}",
            labels.data.len()
        );
        let (loss, correct, dlogits) =
            kernels::softmax_xent(logits.data(), n, classes, &labels.data);
        let dl = Tensor::from_vec(&[n, classes], dlogits)?;
        let (gcarry, grads) = self.backward_walk(&self.params.params, &caches, dl)?;
        self.commit_state(updates);
        self.apply_update(&grads)?;
        Ok(LastResult { loss, correct, gcarry_in: vec![gcarry] })
    }

    /// Backward of a non-last partition: recomputes the forward from
    /// the saved carry_in with the *current* (stale-by-schedule)
    /// weights per jax.vjp semantics — the recompute's BN-state
    /// updates are discarded — then applies the weight update. Engages
    /// the active staleness fix (stash pop / gradient damping).
    pub fn stage_backward(
        &mut self,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let plan = self.fix.on_backward(self.update_count)?;
        self.stage_backward_with(carry_in, gcarry_out, plan.params.as_deref(), plan.grad_scale)
    }

    /// The raw backward primitive: recompute under an explicit weight
    /// override (`None` = live weights), scale the weight gradients by
    /// `grad_scale` (`1.0` skips the multiply so the no-op is bitwise),
    /// then apply the update **to the live weights**. Public as the
    /// mitigation seam for the `tests/mitigation.rs` oracle.
    pub fn stage_backward_with(
        &mut self,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
        over: Option<&[Tensor]>,
        grad_scale: f32,
    ) -> Result<Vec<Tensor>> {
        if let Some(o) = over {
            ensure!(
                o.len() == self.params.params.len(),
                "weight override arity {} != {}",
                o.len(),
                self.params.params.len()
            );
        }
        let x = Self::single(carry_in, "backward")?.clone();
        let g = Self::single(gcarry_out, "backward grad")?.clone();
        let flat = over.unwrap_or(&self.params.params);
        let (_y, caches, _updates) = self.forward_train(flat, &x)?;
        let (gcarry_in, mut grads) = self.backward_walk(flat, &caches, g)?;
        if grad_scale != 1.0 {
            for gt in &mut grads {
                for v in gt.data_mut() {
                    *v *= grad_scale;
                }
            }
        }
        self.apply_update(&grads)?;
        Ok(vec![gcarry_in])
    }

    /// Eval-mode forward (running BN statistics; pure).
    pub fn stage_eval_forward(&self, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        let x = Self::single(carry, "eval_forward")?;
        let mut cur = x.clone();
        for i in 0..self.nodes.len() {
            cur = self.nodes[i].eval_forward(self.node_params(i), self.node_state(i), &cur)?;
        }
        Ok(vec![cur])
    }
}

/// The native backend's stage compute plugs directly into the threaded
/// runtime: one `NativePartition` per worker thread. Seeds are unused
/// (the native kernels have no dropout).
impl WorkerStage for NativePartition {
    fn forward(&mut self, _seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        self.stage_forward(carry)
    }

    fn last(&mut self, _seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult> {
        self.stage_last(carry, labels)
    }

    fn backward(
        &mut self,
        _seed: i32,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.stage_backward(carry_in, gcarry_out)
    }

    fn into_params(self) -> PartitionParams {
        self.params
    }

    fn set_staleness_fix(&mut self, kind: FixKind) -> Result<()> {
        NativePartition::set_staleness_fix(self, kind);
        Ok(())
    }
}

/// Artifact-free executor: the whole pipeline on in-crate kernels.
pub struct NativeExecutor {
    /// The full config contract this executor was built from.
    pub meta: ConfigMeta,
    /// One native compute unit per partition, in pipeline order.
    pub parts: Vec<NativePartition>,
}

impl NativeExecutor {
    /// Build the executor: one [`NativePartition`] per config
    /// partition, cross-validated against the recorded specs.
    pub fn new(meta: ConfigMeta, params: ModelParams, optims: Vec<Sgd>) -> Result<Self> {
        ensure!(
            optims.len() == meta.partitions.len(),
            "need one optimizer per partition"
        );
        ensure!(
            params.partitions.len() == meta.partitions.len(),
            "params/partitions arity mismatch"
        );
        let parts = params
            .partitions
            .into_iter()
            .zip(optims)
            .enumerate()
            .map(|(i, (pp, opt))| NativePartition::for_partition(&meta, i, pp, opt))
            .collect::<Result<Vec<_>>>()?;
        Ok(NativeExecutor { meta, parts })
    }

    /// Split the executor into its per-partition compute units (e.g. to
    /// hand each to a worker thread; every piece is `Send`).
    pub fn into_partitions(self) -> Vec<NativePartition> {
        self.parts
    }

    /// Snapshot the current weights (eval / checkpointing), like
    /// `XlaExecutor::params_snapshot`.
    pub fn params_snapshot(&self) -> ModelParams {
        ModelParams { partitions: self.parts.iter().map(|p| p.params.clone()).collect() }
    }

    /// Per-partition applied-update counts (schedule assertions).
    pub fn update_counts(&self) -> Vec<usize> {
        self.parts.iter().map(|p| p.update_count).collect()
    }

    /// Per-partition mitigation counters (ring occupancy, high-water
    /// marks) — the observable side of `--staleness-fix`, matched
    /// against `memory::stash_report` by the accounting tests.
    pub fn fix_stats(&self) -> Vec<FixStats> {
        self.parts.iter().map(NativePartition::fix_stats).collect()
    }
}

impl StageExecutor for NativeExecutor {
    fn num_partitions(&self) -> usize {
        self.parts.len()
    }

    fn forward(&mut self, p: usize, _seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        self.parts[p].stage_forward(carry)
    }

    fn last(&mut self, _seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult> {
        let p = self.parts.len() - 1;
        self.parts[p].stage_last(carry, labels)
    }

    fn backward(
        &mut self,
        p: usize,
        _seed: i32,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.parts[p].stage_backward(carry_in, gcarry_out)
    }

    fn eval_forward(&mut self, p: usize, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        self.parts[p].stage_eval_forward(carry)
    }

    fn params_snapshot(&self) -> ModelParams {
        NativeExecutor::params_snapshot(self)
    }

    fn set_staleness_fix(&mut self, kind: FixKind) -> Result<()> {
        for part in &mut self.parts {
            part.set_staleness_fix(kind);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{Feed, Pipeline};

    fn mk_exec(seed: u64) -> NativeExecutor {
        let meta = native_config("native_lenet_small").unwrap();
        let params = ModelParams::init(&meta.partitions, seed).unwrap();
        let optims = crate::train::build_optims(&meta, 10, 1.0);
        NativeExecutor::new(meta, params, optims).unwrap()
    }

    fn mk_feed(exec: &NativeExecutor, b: u64) -> Feed {
        let meta = &exec.meta;
        let spec = crate::data::SyntheticSpec { train: 32, test: 16, noise: 0.8, seed: 7 };
        let (ds, _) = crate::data::load_or_synthesize(&meta.dataset, None, &spec).unwrap();
        let idxs: Vec<usize> = (0..meta.batch).collect();
        let (x, labels) = ds.gather(&idxs);
        Feed { batch_id: b, seed: crate::data::batch_seed(1, b), x, labels }
    }

    #[test]
    fn executor_builds_and_snapshots() {
        let exec = mk_exec(3);
        assert_eq!(exec.num_partitions(), 2);
        let snap = NativeExecutor::params_snapshot(&exec);
        assert_eq!(snap.partitions.len(), 2);
        assert!(snap.all_finite());
        assert_eq!(exec.update_counts(), vec![0, 0]);
    }

    #[test]
    fn one_sequential_step_updates_every_partition_once() {
        let mut pipe = Pipeline::new(mk_exec(5), 16);
        let feed = mk_feed(&pipe.exec, 0);
        let before = NativeExecutor::params_snapshot(&pipe.exec);
        let e = pipe.sequential_step(feed).unwrap();
        assert!(e.loss.is_finite() && e.loss > 0.0);
        assert_eq!(pipe.exec.update_counts(), vec![1, 1]);
        let after = NativeExecutor::params_snapshot(&pipe.exec);
        assert!(after.all_finite());
        let moved = before
            .partitions
            .iter()
            .zip(&after.partitions)
            .all(|(a, b)| a.params.iter().zip(&b.params).any(|(t, u)| t.data() != u.data()));
        assert!(moved, "every partition's weights must move");
    }

    #[test]
    fn eval_forward_yields_logits_and_is_pure() {
        let mut pipe = Pipeline::new(mk_exec(9), 16);
        let feed = mk_feed(&pipe.exec, 0);
        let before = NativeExecutor::params_snapshot(&pipe.exec);
        let logits = pipe.eval_forward(feed.x.clone()).unwrap();
        assert_eq!(logits.shape, vec![16, 10]);
        assert!(logits.is_finite());
        let again = pipe.eval_forward(feed.x).unwrap();
        assert_eq!(logits.data(), again.data(), "eval must be deterministic");
        let after = NativeExecutor::params_snapshot(&pipe.exec);
        for (a, b) in before.partitions.iter().zip(&after.partitions) {
            for (t, u) in a.params.iter().zip(&b.params) {
                assert_eq!(t.data(), u.data(), "eval must not touch weights");
            }
            for (t, u) in a.state.iter().zip(&b.state) {
                assert_eq!(t.data(), u.data(), "eval must not touch state");
            }
        }
    }

    #[test]
    fn native_compute_is_send() {
        // The threaded runtime moves partitions (or the inputs to build
        // them) across worker threads; regression-guard the auto-traits.
        fn assert_send<T: Send>() {}
        assert_send::<NativeExecutor>();
        assert_send::<NativePartition>();
        assert_send::<crate::tensor::Tensor>();
        assert_send::<crate::tensor::IntTensor>();
        assert_send::<ModelParams>();
        assert_send::<PartitionParams>();
        assert_send::<Sgd>();
        assert_send::<ConfigMeta>();
    }

    #[test]
    fn executor_splits_into_partitions_that_compute_on_other_threads() {
        let exec = mk_exec(11);
        let meta = exec.meta.clone();
        let mut parts = exec.into_partitions();
        assert_eq!(parts.len(), 2);
        let mut p0 = parts.remove(0);
        let x = Tensor::zeros(
            &std::iter::once(meta.batch)
                .chain(meta.input_shape.iter().copied())
                .collect::<Vec<_>>(),
        );
        let out = std::thread::spawn(move || p0.stage_forward(&[x]).unwrap())
            .join()
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, meta.partitions[0].carry_out[0]);
    }

    #[test]
    fn native_resnet_sequential_step_updates_every_partition() {
        // The block IR end to end: a P=4 residual-network pipeline where
        // three partition boundaries sit on block edges.
        let meta = native_config("native_resnet_small_4s").unwrap();
        let params = ModelParams::init(&meta.partitions, 13).unwrap();
        let optims = crate::train::build_optims(&meta, 10, 1.0);
        let exec = NativeExecutor::new(meta.clone(), params, optims).unwrap();
        let mut pipe = Pipeline::new(exec, meta.batch);
        let spec = crate::data::SyntheticSpec { train: 32, test: 16, noise: 0.8, seed: 7 };
        let (ds, _) = crate::data::load_or_synthesize(&meta.dataset, None, &spec).unwrap();
        let idxs: Vec<usize> = (0..meta.batch).collect();
        let (x, labels) = ds.gather(&idxs);
        let before = NativeExecutor::params_snapshot(&pipe.exec);
        let e = pipe
            .sequential_step(Feed { batch_id: 0, seed: crate::data::batch_seed(1, 0), x, labels })
            .unwrap();
        assert!(e.loss.is_finite() && e.loss > 0.0);
        assert_eq!(pipe.exec.update_counts(), vec![1, 1, 1, 1]);
        let after = NativeExecutor::params_snapshot(&pipe.exec);
        assert!(after.all_finite());
        for (i, (a, b)) in before.partitions.iter().zip(&after.partitions).enumerate() {
            assert!(
                a.params.iter().zip(&b.params).any(|(t, u)| t.data() != u.data()),
                "partition {i} weights must move"
            );
        }
    }

    #[test]
    fn forward_rejects_last_partition_and_multi_carry() {
        let mut exec = mk_exec(1);
        let x = Tensor::zeros(&[16, 28, 28, 1]);
        let last_p = exec.num_partitions() - 1;
        assert!(exec.forward(last_p, 0, &[x.clone()]).is_err());
        assert!(exec.forward(0, 0, &[x.clone(), x]).is_err());
    }
}
