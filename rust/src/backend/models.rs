//! Native model zoo + artifact-free config generation.
//!
//! `build_model` mirrors `python/compile/models.py` for the LeNet
//! family (including the `_w` width-scaling rule with Python's banker's
//! rounding), so a native op stack produces the same parameter/state
//! specs and carry shapes the AOT pipeline records in `meta.json`, and
//! adds the paper's CIFAR-10 ResNet on the block-structured IR: one
//! `NativeNode::Block` per residual basic block, so the skip tensor
//! never crosses a pipeline register and every PPV falls on a block
//! edge by construction (the XLA side instead threads the skip through
//! the register via `ResStart`/`ResEnd` — a documented divergence).
//!
//! The zoo itself is `MODEL_ZOO`, the single source of truth for what
//! the native backend can build: `build_model`'s unsupported-model
//! error and the `NATIVE_MANIFEST` config table both derive from it,
//! so the supported list cannot go stale.
//!
//! `native_config` synthesizes a full `ConfigMeta` in memory — layer
//! metadata, partition specs, carry chains — for a built-in manifest of
//! LeNet and ResNet configs, so training, evaluation, checkpointing and
//! the paper's staleness accounting all run with **no Python step and
//! no artifacts directory**. `partition_nodes` then cross-validates the
//! generated (or artifact-loaded) meta against the native node stack:
//! any drift between the two worlds is an error, not silent divergence.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, ensure, Result};

use crate::meta::{ConfigMeta, LayerMeta, PartitionMeta};
use crate::tensor::numel;

use super::kernels::ActKind;
use super::ops::{NativeNode, NativeOp, Shortcut};

/// One paper-numbered layer: a pipeline register may follow it. Nodes
/// are plain ops or whole residual blocks — a partition boundary can
/// only fall *between* layers, hence only on block edges.
#[derive(Debug, Clone)]
pub struct NativeLayer {
    /// Paper-layer name (`l1`, `l2`, ...).
    pub name: String,
    /// The layer's compute, in forward order.
    pub nodes: Vec<NativeNode>,
}

/// A whole model as a flat layer list (the paper's PPV numbering).
#[derive(Debug, Clone)]
pub struct NativeModel {
    /// Zoo model name (`lenet5`, `resnet`, ...).
    pub name: String,
    /// Paper-numbered layers, forward order.
    pub layers: Vec<NativeLayer>,
    /// Per-sample input shape (H, W, C).
    pub input_shape: Vec<usize>,
    /// Output classes of the final dense head.
    pub num_classes: usize,
    /// Dataset the model trains on (`mnist` / `cifar10`).
    pub dataset: String,
}

/// Python's `round()` (banker's rounding), needed to mirror `_w` exactly.
fn round_half_even(x: f64) -> f64 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Width scaling, mirroring `models.py::_w`.
fn w_scale(c: usize, mult: f64) -> usize {
    if mult >= 1.0 {
        round_half_even(c as f64 * mult) as usize
    } else {
        (round_half_even(c as f64 * mult / 4.0) as usize * 4).max(4)
    }
}

/// LeNet-5 on MNIST (5 layers, tanh activations), mirroring
/// `models.py::lenet5`.
fn lenet5(width_mult: f64, num_classes: usize) -> NativeModel {
    let c1 = w_scale(6, width_mult);
    let c2 = w_scale(16, width_mult);
    let f1 = w_scale(120, width_mult);
    let f2 = w_scale(84, width_mult);
    let flat = 5 * 5 * c2;
    let layer = |name: &str, ops: Vec<NativeOp>| NativeLayer {
        name: name.to_string(),
        nodes: ops.into_iter().map(NativeNode::Op).collect(),
    };
    NativeModel {
        name: "lenet5".to_string(),
        layers: vec![
            layer(
                "l1",
                vec![
                    NativeOp::conv("conv1", 1, c1, 5, 1, true, true),
                    NativeOp::act("act1", ActKind::Tanh),
                    NativeOp::max_pool("pool1", 2),
                ],
            ),
            layer(
                "l2",
                vec![
                    NativeOp::conv("conv2", c1, c2, 5, 1, false, true),
                    NativeOp::act("act2", ActKind::Tanh),
                    NativeOp::max_pool("pool2", 2),
                ],
            ),
            layer(
                "l3",
                vec![
                    NativeOp::flatten("flat"),
                    NativeOp::dense("fc1", flat, f1, ActKind::Tanh),
                ],
            ),
            layer("l4", vec![NativeOp::dense("fc2", f1, f2, ActKind::Tanh)]),
            layer("l5", vec![NativeOp::dense("fc3", f2, num_classes, ActKind::None)]),
        ],
        input_shape: vec![28, 28, 1],
        num_classes,
        dataset: "mnist".to_string(),
    }
}

/// The paper's CIFAR-10 ResNet (He et al. 2016 basic blocks): a stem
/// conv + BN + relu, three stages of `mblocks` residual blocks (widths
/// 16/32/64 scaled by `width_mult`, stride-2 transitions with 1×1
/// projection shortcuts, option B), then a global-avg-pool + linear
/// head. Native paper-layer numbering: layer 1 = stem, one layer per
/// block (the post-add relu rides in the block's layer), final layer =
/// head — so a `resnet` model has `2 + 3*mblocks` pipeline layers.
fn resnet(name: &str, mblocks: usize, width_mult: f64, num_classes: usize) -> NativeModel {
    let widths = [w_scale(16, width_mult), w_scale(32, width_mult), w_scale(64, width_mult)];
    let mut layers = Vec::with_capacity(2 + 3 * mblocks);
    layers.push(NativeLayer {
        name: "l1".to_string(),
        nodes: vec![
            NativeNode::op(NativeOp::conv("conv0", 3, widths[0], 3, 1, true, false)),
            NativeNode::op(NativeOp::batch_norm("bn0", widths[0])),
            NativeNode::op(NativeOp::act("a0", ActKind::Relu)),
        ],
    });
    let mut cin = widths[0];
    let mut lnum = 2;
    for (g, &c) in widths.iter().enumerate() {
        for j in 0..mblocks {
            let stride = if g > 0 && j == 0 { 2 } else { 1 };
            let tag = format!("g{g}b{j}");
            let main = vec![
                NativeOp::conv(&format!("{tag}/conv1"), cin, c, 3, stride, true, false),
                NativeOp::batch_norm(&format!("{tag}/bn1"), c),
                NativeOp::act(&format!("{tag}/a1"), ActKind::Relu),
                NativeOp::conv(&format!("{tag}/conv2"), c, c, 3, 1, true, false),
                NativeOp::batch_norm(&format!("{tag}/bn2"), c),
            ];
            let shortcut = if stride != 1 || cin != c {
                Shortcut::projection(&tag, cin, c, stride)
            } else {
                Shortcut::Identity
            };
            layers.push(NativeLayer {
                name: format!("l{lnum}"),
                nodes: vec![
                    NativeNode::block(&tag, main, shortcut),
                    NativeNode::op(NativeOp::act(&format!("{tag}/a2"), ActKind::Relu)),
                ],
            });
            lnum += 1;
            cin = c;
        }
    }
    layers.push(NativeLayer {
        name: format!("l{lnum}"),
        nodes: vec![
            NativeNode::op(NativeOp::global_avg_pool("gap")),
            NativeNode::op(NativeOp::dense("fc", cin, num_classes, ActKind::None)),
        ],
    });
    NativeModel {
        name: name.to_string(),
        layers,
        input_shape: vec![32, 32, 3],
        num_classes,
        dataset: "cifar10".to_string(),
    }
}

/// `resnet`: the paper's ResNet-20 topology (3 blocks per stage).
fn paper_resnet(width_mult: f64, num_classes: usize) -> NativeModel {
    resnet("resnet", 3, width_mult, num_classes)
}

/// `resnet8`: one block per stage — the shallow CI/fixture variant.
fn resnet8(width_mult: f64, num_classes: usize) -> NativeModel {
    resnet("resnet8", 1, width_mult, num_classes)
}

/// The native model zoo — the ONE place a buildable model is declared.
/// `build_model`'s error message and `NATIVE_MANIFEST` validation both
/// derive from this table, so the "supported" list cannot go stale.
const MODEL_ZOO: &[(&str, fn(f64, usize) -> NativeModel)] = &[
    ("lenet5", lenet5),
    ("resnet", paper_resnet),
    ("resnet8", resnet8),
];

/// Model names the native backend can build.
pub fn supported_models() -> Vec<&'static str> {
    MODEL_ZOO.iter().map(|e| e.0).collect()
}

/// Build a native model by name. Models whose ops the native backend
/// does not implement (e.g. dropout) are rejected here, listing the
/// supported set straight from `MODEL_ZOO`.
pub fn build_model(name: &str, width_mult: f64, num_classes: usize) -> Result<NativeModel> {
    match MODEL_ZOO.iter().find(|e| e.0 == name) {
        Some((_, builder)) => Ok(builder(width_mult, num_classes)),
        None => bail!(
            "native backend has no model {name:?} (supported: {}); \
             use the XLA backend with AOT artifacts for the full zoo",
            supported_models().join(", ")
        ),
    }
}

impl NativeModel {
    /// Paper-layer count (the PPV numbering runs 1..=num_layers).
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Carry shape (batch-inclusive) after each layer; index i = after
    /// layer i+1 in paper numbering.
    pub fn carry_shapes_after(&self, batch: usize) -> Result<Vec<Vec<usize>>> {
        let mut shape: Vec<usize> = std::iter::once(batch)
            .chain(self.input_shape.iter().copied())
            .collect();
        let mut out = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            for node in &layer.nodes {
                shape = node.out_shape(&shape)?;
            }
            out.push(shape.clone());
        }
        Ok(out)
    }
}

/// The built-in native manifest: configs runnable with no artifacts,
/// as `(name, model, width_mult, ppv, batch)`. Names shared with
/// `python/compile/experiments.py` use the same
/// (model, width, PPV, batch), so a run is configured identically
/// whichever backend serves it. `native_lenet_small` is a narrow,
/// small-batch variant for fast native CI runs; `native_lenet_small_4s`
/// is its 4-partition split (PPV (1,2,3)), the P=4 fixture for the
/// threaded-runtime equivalence and stress suites.
///
/// The `native_resnet_*` entries are the paper's ResNet partitionings
/// on synthetic CIFAR-shaped (32,32,3) inputs, over the block IR
/// (blocks atomic, so every PPV cut is a block edge): an early-layer
/// split (`_small`, register after the stem), a deep split (`_deep`,
/// register after the first stride-2 block, so the second transition
/// block g2b0 opens partition 2), the P=4 hybrid fixture
/// (`_small_4s`), and the paper-depth ResNet-20 topology with Table
/// 4's deep-pipelining cut — PPV (5,12,17) in the paper's 20-layer
/// numbering, snapped to the nearest block edges in native numbering —
/// (`native_resnet20_4s`, narrow width for the 1-core testbed).
const NATIVE_MANIFEST: &[(&str, &str, f64, &[usize], usize)] = &[
    ("quickstart_lenet", "lenet5", 1.0, &[2], 32),
    ("lenet5_4s", "lenet5", 1.0, &[1], 64),
    ("lenet5_6s", "lenet5", 1.0, &[1, 2], 64),
    ("lenet5_8s", "lenet5", 1.0, &[1, 2, 3], 64),
    ("lenet5_10s", "lenet5", 1.0, &[1, 2, 3, 4], 64),
    ("native_lenet_small", "lenet5", 0.5, &[2], 16),
    ("native_lenet_small_4s", "lenet5", 0.5, &[1, 2, 3], 16),
    ("native_resnet_small", "resnet8", 0.25, &[1], 8),
    ("native_resnet_small_deep", "resnet8", 0.25, &[3], 8),
    ("native_resnet_small_4s", "resnet8", 0.25, &[1, 2, 3], 8),
    ("native_resnet20_4s", "resnet", 0.25, &[3, 6, 9], 8),
];

/// Returns `(model, width_mult, ppv, batch)` for a built-in config.
fn manifest(name: &str) -> Option<(&'static str, f64, Vec<usize>, usize)> {
    NATIVE_MANIFEST
        .iter()
        .find(|e| e.0 == name)
        .map(|&(_, model, width, ppv, batch)| (model, width, ppv.to_vec(), batch))
}

/// Names the native manifest can synthesize (for CLI listings/errors).
pub fn native_config_names() -> Vec<&'static str> {
    NATIVE_MANIFEST.iter().map(|e| e.0).collect()
}

/// Synthesize the full `ConfigMeta` for a built-in native config —
/// everything `aot.py::config_meta` would record, minus the HLO files.
///
/// ```
/// let meta = pipestale::backend::native_config("quickstart_lenet").unwrap();
/// assert_eq!(meta.model, "lenet5");
/// assert_eq!(meta.partitions.len(), 2);
/// assert_eq!(meta.total_params(), 61_706); // full-width LeNet-5
/// ```
pub fn native_config(name: &str) -> Result<ConfigMeta> {
    native_config_with_ppv(name, None)
}

/// Like [`native_config`], but with the manifest's hand-tabulated PPV
/// optionally replaced by `ppv_override` — the entry point of the
/// profile-guided auto-partitioner (`--partition auto`). The override
/// runs through exactly the same synthesis machinery as the manifest
/// PPV (bounds validation, per-layer metadata, carry/param/state specs
/// from the model IR), so [`partition_nodes`] cross-validation, memory
/// accounting, and checkpointing consume the result unchanged.
pub fn native_config_with_ppv(name: &str, ppv_override: Option<&[usize]>) -> Result<ConfigMeta> {
    let Some((model_name, width_mult, ppv, batch)) = manifest(name) else {
        bail!(
            "unknown native config {name:?}; built-ins: {} (or build artifacts for the full set)",
            native_config_names().join(", ")
        );
    };
    let ppv: Vec<usize> = match ppv_override {
        Some(over) => over.to_vec(),
        None => ppv,
    };
    let model = build_model(model_name, width_mult, 10)?;
    let num_layers = model.num_layers();
    ensure!(
        ppv.windows(2).all(|w| w[0] < w[1]) && ppv.iter().all(|&p| p >= 1 && p < num_layers),
        "PPV {ppv:?} invalid for {model_name} ({num_layers} layers)"
    );

    // Per-layer metadata (param counts, carry sizes, FLOPs).
    let after = model.carry_shapes_after(batch)?;
    let mut layers_meta = Vec::with_capacity(num_layers);
    let mut shape: Vec<usize> = std::iter::once(batch)
        .chain(model.input_shape.iter().copied())
        .collect();
    for (layer, out_shape) in model.layers.iter().zip(&after) {
        let mut flops = 0u64;
        let mut param_count = 0usize;
        for node in &layer.nodes {
            flops += node.flops_per_sample(&shape)?;
            param_count += node.param_specs().iter().map(|s| numel(&s.shape)).sum::<usize>();
            shape = node.out_shape(&shape)?;
        }
        layers_meta.push(LayerMeta {
            name: layer.name.clone(),
            param_count,
            carry_elems_per_sample: numel(&out_shape[1..]),
            flops_per_sample: flops,
        });
    }

    // Partitions: layer ranges [lo, hi] (1-based) from the PPV bounds.
    let mut bounds = vec![0usize];
    bounds.extend(ppv.iter().copied());
    bounds.push(num_layers);
    let n_parts = bounds.len() - 1;
    let mut partitions = Vec::with_capacity(n_parts);
    for i in 0..n_parts {
        let (lo, hi) = (bounds[i] + 1, bounds[i + 1]);
        let is_last = i == n_parts - 1;
        let layers = &model.layers[lo - 1..hi];
        let params: Vec<_> =
            layers.iter().flat_map(|l| l.nodes.iter().flat_map(|n| n.param_specs())).collect();
        let state: Vec<_> =
            layers.iter().flat_map(|l| l.nodes.iter().flat_map(|n| n.state_specs())).collect();
        let param_count = params.iter().map(|s| numel(&s.shape)).sum();
        let carry_in = if i == 0 {
            vec![std::iter::once(batch).chain(model.input_shape.iter().copied()).collect()]
        } else {
            vec![after[bounds[i] - 1].clone()]
        };
        let carry_out = if is_last {
            vec![vec![batch, model.num_classes]]
        } else {
            vec![after[bounds[i + 1] - 1].clone()]
        };
        let program_keys: &[&str] =
            if is_last { &["last", "last_eval"] } else { &["fwd", "bwd", "fwd_eval"] };
        let programs: BTreeMap<String, String> = program_keys
            .iter()
            .map(|k| (k.to_string(), format!("native://{k}")))
            .collect();
        partitions.push(PartitionMeta {
            index: i + 1,
            layer_lo: lo,
            layer_hi: hi,
            param_count,
            params,
            state,
            carry_in,
            carry_out,
            programs,
        });
    }

    Ok(ConfigMeta {
        dir: PathBuf::from(format!("native://{name}")),
        config: name.to_string(),
        model: model.name,
        width_mult,
        batch,
        dataset: model.dataset,
        input_shape: model.input_shape,
        num_classes: model.num_classes,
        num_layers,
        ppv,
        meta_only: false,
        layers: layers_meta,
        partitions,
    })
}

/// Build the native node stack for one partition of a config,
/// validating the generated nodes against the partition's recorded
/// specs. Works for both artifact-loaded and natively generated
/// `ConfigMeta`. Because residual blocks are whole nodes inside a
/// layer and a partition is a contiguous layer range, the cut is on a
/// block edge by construction — a block can never straddle partitions.
pub fn partition_nodes(meta: &ConfigMeta, part: &PartitionMeta) -> Result<Vec<NativeNode>> {
    let model = build_model(&meta.model, meta.width_mult, meta.num_classes)?;
    ensure!(
        part.layer_lo >= 1 && part.layer_hi <= model.num_layers() && part.layer_lo <= part.layer_hi,
        "partition {} layer range {}..{} out of bounds",
        part.index,
        part.layer_lo,
        part.layer_hi
    );
    ensure!(
        part.carry_in.len() == 1 && part.carry_out.len() == 1,
        "native backend supports single-tensor carries; partition {} has {}/{}",
        part.index,
        part.carry_in.len(),
        part.carry_out.len()
    );
    let nodes: Vec<NativeNode> = model.layers[part.layer_lo - 1..part.layer_hi]
        .iter()
        .flat_map(|l| l.nodes.iter().cloned())
        .collect();

    // Cross-check against the recorded contract: same params, same state.
    let specs: Vec<_> = nodes.iter().flat_map(|n| n.param_specs()).collect();
    ensure!(
        specs.len() == part.params.len(),
        "partition {}: native stack has {} params, meta records {}",
        part.index,
        specs.len(),
        part.params.len()
    );
    for (a, b) in specs.iter().zip(&part.params) {
        ensure!(
            a.name == b.name && a.shape == b.shape && a.init == b.init && a.fan_in == b.fan_in,
            "partition {}: param spec drift: native {:?}/{:?} vs meta {:?}/{:?}",
            part.index,
            a.name,
            a.shape,
            b.name,
            b.shape
        );
    }
    let sspecs: Vec<_> = nodes.iter().flat_map(|n| n.state_specs()).collect();
    ensure!(
        sspecs.len() == part.state.len(),
        "partition {}: native stack has {} state tensors, meta records {}",
        part.index,
        sspecs.len(),
        part.state.len()
    );
    for (a, b) in sspecs.iter().zip(&part.state) {
        ensure!(
            a.name == b.name && a.shape == b.shape,
            "partition {}: state spec drift: {:?} vs {:?}",
            part.index,
            a.name,
            b.name
        );
    }
    Ok(nodes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_scaling_matches_python_round() {
        // mult >= 1: plain banker's round
        assert_eq!(w_scale(6, 1.0), 6);
        assert_eq!(w_scale(16, 1.5), 24);
        // mult < 1: multiples of 4, floor 4, banker's tie-break
        assert_eq!(w_scale(6, 0.5), 4); // 0.75 -> 1 -> 4
        assert_eq!(w_scale(16, 0.5), 8); // 2.0 -> 8
        assert_eq!(w_scale(120, 0.5), 60); // 15.0 -> 60
        assert_eq!(w_scale(84, 0.5), 40); // 10.5 ties to even 10 -> 40
        assert_eq!(w_scale(4, 0.25), 4); // floor at 4
    }

    #[test]
    fn lenet_carry_chain_matches_paper_shapes() {
        let m = build_model("lenet5", 1.0, 10).unwrap();
        let after = m.carry_shapes_after(32).unwrap();
        assert_eq!(after[0], vec![32, 14, 14, 6]);
        assert_eq!(after[1], vec![32, 5, 5, 16]);
        assert_eq!(after[2], vec![32, 120]);
        assert_eq!(after[3], vec![32, 84]);
        assert_eq!(after[4], vec![32, 10]);
    }

    #[test]
    fn native_quickstart_meta_mirrors_artifact_contract() {
        // Same assertions meta.rs::loads_quickstart_meta makes against
        // the artifact-built meta.json — now artifact-free.
        let m = native_config("quickstart_lenet").unwrap();
        assert_eq!(m.model, "lenet5");
        assert_eq!(m.num_layers, 5);
        assert_eq!(m.partitions.len(), 2);
        assert!(m.partitions[1].is_last());
        assert!(!m.partitions[0].is_last());
        assert_eq!(m.batch, 32);
        assert_eq!(m.input_shape, vec![28, 28, 1]);
        // LeNet-5 full-width parameter count: 61,706
        assert_eq!(m.total_params(), 61_706);
        // carry chain is consistent
        for (a, b) in m.partitions.iter().zip(m.partitions.iter().skip(1)) {
            assert_eq!(a.carry_out, b.carry_in);
            assert_eq!(a.layer_hi + 1, b.layer_lo);
        }
        // layer accounting consistent with partition accounting
        let by_layer: usize = m.layers.iter().map(|l| l.param_count).sum();
        assert_eq!(by_layer, m.total_params());
    }

    #[test]
    fn native_table1_lenet_ppvs() {
        for (name, stages, ppv) in [
            ("lenet5_4s", 4usize, vec![1usize]),
            ("lenet5_6s", 6, vec![1, 2]),
            ("lenet5_8s", 8, vec![1, 2, 3]),
            ("lenet5_10s", 10, vec![1, 2, 3, 4]),
        ] {
            let m = native_config(name).unwrap();
            assert_eq!(m.paper_stages(), stages, "{name}");
            assert_eq!(m.ppv, ppv, "{name}");
            let f = m.stale_weight_fraction();
            assert!(f > 0.0 && f < 1.0, "{name}: {f}");
        }
    }

    #[test]
    fn native_small_4s_is_a_four_partition_split() {
        let m = native_config("native_lenet_small_4s").unwrap();
        assert_eq!(m.partitions.len(), 4);
        assert_eq!(m.batch, 16);
        assert!(m.partitions[3].is_last());
        // same model/width as native_lenet_small: identical weights from
        // the same seed (ModelParams::init walks one RNG stream)
        let small = native_config("native_lenet_small").unwrap();
        assert_eq!(m.total_params(), small.total_params());
        for (a, b) in m.partitions.iter().zip(m.partitions.iter().skip(1)) {
            assert_eq!(a.carry_out, b.carry_in);
        }
    }

    #[test]
    fn partition_nodes_validate_against_meta() {
        let m = native_config("quickstart_lenet").unwrap();
        let nodes0 = partition_nodes(&m, &m.partitions[0]).unwrap();
        let nodes1 = partition_nodes(&m, &m.partitions[1]).unwrap();
        assert_eq!(nodes0.len(), 6); // conv,act,pool x2
        assert_eq!(nodes1.len(), 4); // flatten,fc1,fc2,fc3
        // tampering with a recorded spec is caught
        let mut bad = m.partitions[0].clone();
        bad.params[0].shape = vec![3, 3, 1, 6];
        assert!(partition_nodes(&m, &bad).is_err());
    }

    #[test]
    fn unknown_configs_and_models_error_clearly() {
        let err = native_config("resnet20_4s").unwrap_err().to_string();
        assert!(err.contains("unknown native config"), "{err}");
        // the unsupported-model error derives its list from MODEL_ZOO
        let err = build_model("resnet362", 1.0, 10).unwrap_err().to_string();
        assert!(err.contains(&supported_models().join(", ")), "{err}");
    }

    #[test]
    fn model_zoo_is_the_single_source_of_truth() {
        // Every manifest entry must name a buildable zoo model, and
        // every zoo model must build + produce a consistent carry chain.
        for (cfg, model, width, _, batch) in NATIVE_MANIFEST {
            assert!(
                supported_models().contains(model),
                "config {cfg} references model {model} missing from MODEL_ZOO"
            );
            build_model(model, *width, 10).unwrap().carry_shapes_after(*batch).unwrap();
        }
        for (name, _) in MODEL_ZOO {
            let m = build_model(name, 1.0, 10).unwrap();
            assert_eq!(&m.name, name);
            assert_eq!(*m.carry_shapes_after(4).unwrap().last().unwrap(), vec![4, 10]);
        }
    }

    #[test]
    fn resnet_carry_chain_and_block_structure() {
        // resnet8 at width 0.25: stage widths 4/8/16, stride-2
        // transitions at g1/g2 with projection shortcuts.
        let m = build_model("resnet8", 0.25, 10).unwrap();
        assert_eq!(m.num_layers(), 5);
        assert_eq!(m.input_shape, vec![32, 32, 3]);
        assert_eq!(m.dataset, "cifar10");
        let after = m.carry_shapes_after(8).unwrap();
        assert_eq!(after[0], vec![8, 32, 32, 4]); // stem
        assert_eq!(after[1], vec![8, 32, 32, 4]); // g0b0 (identity shortcut)
        assert_eq!(after[2], vec![8, 16, 16, 8]); // g1b0 (stride 2, projection)
        assert_eq!(after[3], vec![8, 8, 8, 16]); // g2b0 (stride 2, projection)
        assert_eq!(after[4], vec![8, 10]); // gap + fc head
        // block layers are [Block, post-add relu]
        assert!(matches!(m.layers[1].nodes[0], NativeNode::Block(_)));
        assert!(matches!(m.layers[1].nodes[1], NativeNode::Op(_)));
        // paper-depth variant: 2 + 3*3 = 11 pipeline layers
        assert_eq!(build_model("resnet", 0.25, 10).unwrap().num_layers(), 11);
    }

    #[test]
    fn native_resnet_configs_synthesize_full_meta() {
        // Early split / deep split / P=4 hybrid fixture, all on
        // CIFAR-shaped inputs with consistent carry chains.
        for (name, parts) in [
            ("native_resnet_small", 2usize),
            ("native_resnet_small_deep", 2),
            ("native_resnet_small_4s", 4),
            ("native_resnet20_4s", 4),
        ] {
            let m = native_config(name).unwrap();
            assert_eq!(m.partitions.len(), parts, "{name}");
            assert_eq!(m.input_shape, vec![32, 32, 3], "{name}");
            assert_eq!(m.dataset, "cifar10", "{name}");
            assert!(m.partitions.last().unwrap().is_last(), "{name}");
            for (a, b) in m.partitions.iter().zip(m.partitions.iter().skip(1)) {
                assert_eq!(a.carry_out, b.carry_in, "{name}");
                assert_eq!(a.layer_hi + 1, b.layer_lo, "{name}");
            }
            let by_layer: usize = m.layers.iter().map(|l| l.param_count).sum();
            assert_eq!(by_layer, m.total_params(), "{name}");
            let f = m.stale_weight_fraction();
            assert!(f > 0.0 && f < 1.0, "{name}: {f}");
            // every partition's node stack validates against the meta
            for p in &m.partitions {
                partition_nodes(&m, p).unwrap();
            }
        }
        // exact parameter count of the narrow resnet8 fixture:
        // stem 116 + g0b0 304 + g1b0 944 + g2b0 3680 + head 170
        let m = native_config("native_resnet_small").unwrap();
        assert_eq!(m.total_params(), 5214);
        // the paper-topology fixture pipelines 8 stages (K=3)
        assert_eq!(native_config("native_resnet20_4s").unwrap().paper_stages(), 8);
    }
}
