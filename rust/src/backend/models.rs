//! Native model zoo + artifact-free config generation.
//!
//! `build_model` mirrors `python/compile/models.py` for the LeNet family
//! (including the `_w` width-scaling rule with Python's banker's
//! rounding), so a native op stack produces the same parameter/state
//! specs and carry shapes the AOT pipeline records in `meta.json`.
//!
//! `native_config` synthesizes a full `ConfigMeta` in memory — layer
//! metadata, partition specs, carry chains — for a built-in manifest of
//! LeNet configs, so training, evaluation, checkpointing and the paper's
//! staleness accounting all run with **no Python step and no artifacts
//! directory**. `partition_ops` then cross-validates the generated (or
//! artifact-loaded) meta against the native op stack: any drift between
//! the two worlds is an error, not silent divergence.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, ensure, Result};

use crate::meta::{ConfigMeta, LayerMeta, PartitionMeta};
use crate::tensor::numel;

use super::kernels::ActKind;
use super::ops::NativeOp;

/// One paper-numbered layer: a pipeline register may follow it.
#[derive(Debug, Clone)]
pub struct NativeLayer {
    pub name: String,
    pub ops: Vec<NativeOp>,
}

/// A whole model as a flat layer list (the paper's PPV numbering).
#[derive(Debug, Clone)]
pub struct NativeModel {
    pub name: String,
    pub layers: Vec<NativeLayer>,
    /// (H, W, C)
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub dataset: String,
}

/// Python's `round()` (banker's rounding), needed to mirror `_w` exactly.
fn round_half_even(x: f64) -> f64 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Width scaling, mirroring `models.py::_w`.
fn w_scale(c: usize, mult: f64) -> usize {
    if mult >= 1.0 {
        round_half_even(c as f64 * mult) as usize
    } else {
        (round_half_even(c as f64 * mult / 4.0) as usize * 4).max(4)
    }
}

/// LeNet-5 on MNIST (5 layers, tanh activations), mirroring
/// `models.py::lenet5`.
fn lenet5(width_mult: f64, num_classes: usize) -> NativeModel {
    let c1 = w_scale(6, width_mult);
    let c2 = w_scale(16, width_mult);
    let f1 = w_scale(120, width_mult);
    let f2 = w_scale(84, width_mult);
    let flat = 5 * 5 * c2;
    let layer = |name: &str, ops: Vec<NativeOp>| NativeLayer { name: name.to_string(), ops };
    NativeModel {
        name: "lenet5".to_string(),
        layers: vec![
            layer(
                "l1",
                vec![
                    NativeOp::conv("conv1", 1, c1, 5, 1, true, true),
                    NativeOp::act("act1", ActKind::Tanh),
                    NativeOp::max_pool("pool1", 2),
                ],
            ),
            layer(
                "l2",
                vec![
                    NativeOp::conv("conv2", c1, c2, 5, 1, false, true),
                    NativeOp::act("act2", ActKind::Tanh),
                    NativeOp::max_pool("pool2", 2),
                ],
            ),
            layer(
                "l3",
                vec![
                    NativeOp::flatten("flat"),
                    NativeOp::dense("fc1", flat, f1, ActKind::Tanh),
                ],
            ),
            layer("l4", vec![NativeOp::dense("fc2", f1, f2, ActKind::Tanh)]),
            layer("l5", vec![NativeOp::dense("fc3", f2, num_classes, ActKind::None)]),
        ],
        input_shape: vec![28, 28, 1],
        num_classes,
        dataset: "mnist".to_string(),
    }
}

/// Build a native model by name. Models whose ops the native backend
/// does not implement (residual blocks, dropout) are rejected here.
pub fn build_model(name: &str, width_mult: f64, num_classes: usize) -> Result<NativeModel> {
    match name {
        "lenet5" => Ok(lenet5(width_mult, num_classes)),
        other => bail!(
            "native backend has no model {other:?} (supported: lenet5); \
             use the XLA backend with AOT artifacts for the full zoo"
        ),
    }
}

impl NativeModel {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Carry shape (batch-inclusive) after each layer; index i = after
    /// layer i+1 in paper numbering.
    pub fn carry_shapes_after(&self, batch: usize) -> Result<Vec<Vec<usize>>> {
        let mut shape: Vec<usize> = std::iter::once(batch)
            .chain(self.input_shape.iter().copied())
            .collect();
        let mut out = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            for op in &layer.ops {
                shape = op.out_shape(&shape)?;
            }
            out.push(shape.clone());
        }
        Ok(out)
    }
}

/// The built-in native manifest: LeNet configs runnable with no
/// artifacts, as `(name, model, width_mult, ppv, batch)`. Names shared
/// with `python/compile/experiments.py` use the same
/// (model, width, PPV, batch), so a run is configured identically
/// whichever backend serves it. `native_lenet_small` is a narrow,
/// small-batch variant for fast native CI runs; `native_lenet_small_4s`
/// is its 4-partition split (PPV (1,2,3)), the P=4 fixture for the
/// threaded-runtime equivalence and stress suites.
const NATIVE_MANIFEST: &[(&str, &str, f64, &[usize], usize)] = &[
    ("quickstart_lenet", "lenet5", 1.0, &[2], 32),
    ("lenet5_4s", "lenet5", 1.0, &[1], 64),
    ("lenet5_6s", "lenet5", 1.0, &[1, 2], 64),
    ("lenet5_8s", "lenet5", 1.0, &[1, 2, 3], 64),
    ("lenet5_10s", "lenet5", 1.0, &[1, 2, 3, 4], 64),
    ("native_lenet_small", "lenet5", 0.5, &[2], 16),
    ("native_lenet_small_4s", "lenet5", 0.5, &[1, 2, 3], 16),
];

/// Returns `(model, width_mult, ppv, batch)` for a built-in config.
fn manifest(name: &str) -> Option<(&'static str, f64, Vec<usize>, usize)> {
    NATIVE_MANIFEST
        .iter()
        .find(|e| e.0 == name)
        .map(|&(_, model, width, ppv, batch)| (model, width, ppv.to_vec(), batch))
}

/// Names the native manifest can synthesize (for CLI listings/errors).
pub fn native_config_names() -> Vec<&'static str> {
    NATIVE_MANIFEST.iter().map(|e| e.0).collect()
}

/// Synthesize the full `ConfigMeta` for a built-in native config —
/// everything `aot.py::config_meta` would record, minus the HLO files.
pub fn native_config(name: &str) -> Result<ConfigMeta> {
    let Some((model_name, width_mult, ppv, batch)) = manifest(name) else {
        bail!(
            "unknown native config {name:?}; built-ins: {} (or build artifacts for the full set)",
            native_config_names().join(", ")
        );
    };
    let model = build_model(model_name, width_mult, 10)?;
    let num_layers = model.num_layers();
    ensure!(
        ppv.windows(2).all(|w| w[0] < w[1]) && ppv.iter().all(|&p| p >= 1 && p < num_layers),
        "PPV {ppv:?} invalid for {model_name} ({num_layers} layers)"
    );

    // Per-layer metadata (param counts, carry sizes, FLOPs).
    let after = model.carry_shapes_after(batch)?;
    let mut layers_meta = Vec::with_capacity(num_layers);
    let mut shape: Vec<usize> = std::iter::once(batch)
        .chain(model.input_shape.iter().copied())
        .collect();
    for (layer, out_shape) in model.layers.iter().zip(&after) {
        let mut flops = 0u64;
        let mut param_count = 0usize;
        for op in &layer.ops {
            flops += op.flops_per_sample(&shape)?;
            param_count += op.param_specs().iter().map(|s| numel(&s.shape)).sum::<usize>();
            shape = op.out_shape(&shape)?;
        }
        layers_meta.push(LayerMeta {
            name: layer.name.clone(),
            param_count,
            carry_elems_per_sample: numel(&out_shape[1..]),
            flops_per_sample: flops,
        });
    }

    // Partitions: layer ranges [lo, hi] (1-based) from the PPV bounds.
    let mut bounds = vec![0usize];
    bounds.extend(ppv.iter().copied());
    bounds.push(num_layers);
    let n_parts = bounds.len() - 1;
    let mut partitions = Vec::with_capacity(n_parts);
    for i in 0..n_parts {
        let (lo, hi) = (bounds[i] + 1, bounds[i + 1]);
        let is_last = i == n_parts - 1;
        let layers = &model.layers[lo - 1..hi];
        let params: Vec<_> =
            layers.iter().flat_map(|l| l.ops.iter().flat_map(|o| o.param_specs())).collect();
        let state: Vec<_> =
            layers.iter().flat_map(|l| l.ops.iter().flat_map(|o| o.state_specs())).collect();
        let param_count = params.iter().map(|s| numel(&s.shape)).sum();
        let carry_in = if i == 0 {
            vec![std::iter::once(batch).chain(model.input_shape.iter().copied()).collect()]
        } else {
            vec![after[bounds[i] - 1].clone()]
        };
        let carry_out = if is_last {
            vec![vec![batch, model.num_classes]]
        } else {
            vec![after[bounds[i + 1] - 1].clone()]
        };
        let program_keys: &[&str] =
            if is_last { &["last", "last_eval"] } else { &["fwd", "bwd", "fwd_eval"] };
        let programs: BTreeMap<String, String> = program_keys
            .iter()
            .map(|k| (k.to_string(), format!("native://{k}")))
            .collect();
        partitions.push(PartitionMeta {
            index: i + 1,
            layer_lo: lo,
            layer_hi: hi,
            param_count,
            params,
            state,
            carry_in,
            carry_out,
            programs,
        });
    }

    Ok(ConfigMeta {
        dir: PathBuf::from(format!("native://{name}")),
        config: name.to_string(),
        model: model.name,
        width_mult,
        batch,
        dataset: model.dataset,
        input_shape: model.input_shape,
        num_classes: model.num_classes,
        num_layers,
        ppv,
        meta_only: false,
        layers: layers_meta,
        partitions,
    })
}

/// Build the native op stack for one partition of a config, validating
/// the generated ops against the partition's recorded specs. Works for
/// both artifact-loaded and natively generated `ConfigMeta`.
pub fn partition_ops(meta: &ConfigMeta, part: &PartitionMeta) -> Result<Vec<NativeOp>> {
    let model = build_model(&meta.model, meta.width_mult, meta.num_classes)?;
    ensure!(
        part.layer_lo >= 1 && part.layer_hi <= model.num_layers() && part.layer_lo <= part.layer_hi,
        "partition {} layer range {}..{} out of bounds",
        part.index,
        part.layer_lo,
        part.layer_hi
    );
    ensure!(
        part.carry_in.len() == 1 && part.carry_out.len() == 1,
        "native backend supports single-tensor carries; partition {} has {}/{}",
        part.index,
        part.carry_in.len(),
        part.carry_out.len()
    );
    let ops: Vec<NativeOp> = model.layers[part.layer_lo - 1..part.layer_hi]
        .iter()
        .flat_map(|l| l.ops.iter().cloned())
        .collect();

    // Cross-check against the recorded contract: same params, same state.
    let specs: Vec<_> = ops.iter().flat_map(|o| o.param_specs()).collect();
    ensure!(
        specs.len() == part.params.len(),
        "partition {}: native stack has {} params, meta records {}",
        part.index,
        specs.len(),
        part.params.len()
    );
    for (a, b) in specs.iter().zip(&part.params) {
        ensure!(
            a.name == b.name && a.shape == b.shape && a.init == b.init && a.fan_in == b.fan_in,
            "partition {}: param spec drift: native {:?}/{:?} vs meta {:?}/{:?}",
            part.index,
            a.name,
            a.shape,
            b.name,
            b.shape
        );
    }
    let sspecs: Vec<_> = ops.iter().flat_map(|o| o.state_specs()).collect();
    ensure!(
        sspecs.len() == part.state.len(),
        "partition {}: native stack has {} state tensors, meta records {}",
        part.index,
        sspecs.len(),
        part.state.len()
    );
    for (a, b) in sspecs.iter().zip(&part.state) {
        ensure!(
            a.name == b.name && a.shape == b.shape,
            "partition {}: state spec drift: {:?} vs {:?}",
            part.index,
            a.name,
            b.name
        );
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_scaling_matches_python_round() {
        // mult >= 1: plain banker's round
        assert_eq!(w_scale(6, 1.0), 6);
        assert_eq!(w_scale(16, 1.5), 24);
        // mult < 1: multiples of 4, floor 4, banker's tie-break
        assert_eq!(w_scale(6, 0.5), 4); // 0.75 -> 1 -> 4
        assert_eq!(w_scale(16, 0.5), 8); // 2.0 -> 8
        assert_eq!(w_scale(120, 0.5), 60); // 15.0 -> 60
        assert_eq!(w_scale(84, 0.5), 40); // 10.5 ties to even 10 -> 40
        assert_eq!(w_scale(4, 0.25), 4); // floor at 4
    }

    #[test]
    fn lenet_carry_chain_matches_paper_shapes() {
        let m = build_model("lenet5", 1.0, 10).unwrap();
        let after = m.carry_shapes_after(32).unwrap();
        assert_eq!(after[0], vec![32, 14, 14, 6]);
        assert_eq!(after[1], vec![32, 5, 5, 16]);
        assert_eq!(after[2], vec![32, 120]);
        assert_eq!(after[3], vec![32, 84]);
        assert_eq!(after[4], vec![32, 10]);
    }

    #[test]
    fn native_quickstart_meta_mirrors_artifact_contract() {
        // Same assertions meta.rs::loads_quickstart_meta makes against
        // the artifact-built meta.json — now artifact-free.
        let m = native_config("quickstart_lenet").unwrap();
        assert_eq!(m.model, "lenet5");
        assert_eq!(m.num_layers, 5);
        assert_eq!(m.partitions.len(), 2);
        assert!(m.partitions[1].is_last());
        assert!(!m.partitions[0].is_last());
        assert_eq!(m.batch, 32);
        assert_eq!(m.input_shape, vec![28, 28, 1]);
        // LeNet-5 full-width parameter count: 61,706
        assert_eq!(m.total_params(), 61_706);
        // carry chain is consistent
        for (a, b) in m.partitions.iter().zip(m.partitions.iter().skip(1)) {
            assert_eq!(a.carry_out, b.carry_in);
            assert_eq!(a.layer_hi + 1, b.layer_lo);
        }
        // layer accounting consistent with partition accounting
        let by_layer: usize = m.layers.iter().map(|l| l.param_count).sum();
        assert_eq!(by_layer, m.total_params());
    }

    #[test]
    fn native_table1_lenet_ppvs() {
        for (name, stages, ppv) in [
            ("lenet5_4s", 4usize, vec![1usize]),
            ("lenet5_6s", 6, vec![1, 2]),
            ("lenet5_8s", 8, vec![1, 2, 3]),
            ("lenet5_10s", 10, vec![1, 2, 3, 4]),
        ] {
            let m = native_config(name).unwrap();
            assert_eq!(m.paper_stages(), stages, "{name}");
            assert_eq!(m.ppv, ppv, "{name}");
            let f = m.stale_weight_fraction();
            assert!(f > 0.0 && f < 1.0, "{name}: {f}");
        }
    }

    #[test]
    fn native_small_4s_is_a_four_partition_split() {
        let m = native_config("native_lenet_small_4s").unwrap();
        assert_eq!(m.partitions.len(), 4);
        assert_eq!(m.batch, 16);
        assert!(m.partitions[3].is_last());
        // same model/width as native_lenet_small: identical weights from
        // the same seed (ModelParams::init walks one RNG stream)
        let small = native_config("native_lenet_small").unwrap();
        assert_eq!(m.total_params(), small.total_params());
        for (a, b) in m.partitions.iter().zip(m.partitions.iter().skip(1)) {
            assert_eq!(a.carry_out, b.carry_in);
        }
    }

    #[test]
    fn partition_ops_validate_against_meta() {
        let m = native_config("quickstart_lenet").unwrap();
        let ops0 = partition_ops(&m, &m.partitions[0]).unwrap();
        let ops1 = partition_ops(&m, &m.partitions[1]).unwrap();
        assert_eq!(ops0.len(), 6); // conv,act,pool x2
        assert_eq!(ops1.len(), 4); // flatten,fc1,fc2,fc3
        // tampering with a recorded spec is caught
        let mut bad = m.partitions[0].clone();
        bad.params[0].shape = vec![3, 3, 1, 6];
        assert!(partition_ops(&m, &bad).is_err());
    }

    #[test]
    fn unknown_configs_and_models_error_clearly() {
        let err = native_config("resnet20_4s").unwrap_err().to_string();
        assert!(err.contains("unknown native config"), "{err}");
        assert!(build_model("resnet20", 1.0, 10).is_err());
    }
}
