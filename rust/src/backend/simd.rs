//! SIMD micro-kernels for the GEMM core (`backend::gemm`).
//!
//! The blocked `sgemm` driver computes C one `MR x NR` register tile at
//! a time from a packed panel pair. This module supplies the tile
//! computation at three ISA levels — portable scalar loops (the parity
//! oracle), AVX2 (x86_64), and NEON (aarch64) — selected at run time by
//! CPU feature detection, never at compile time, so one binary runs
//! everywhere and picks the fastest kernel the machine supports.
//!
//! # Bitwise contract
//!
//! Every implementation performs the *identical* per-element operation
//! sequence: for ascending `l`, `acc[r][c] += a[l][r] * b[l][c]` as a
//! separate IEEE-754 multiply then add — deliberately **no FMA
//! contraction**, which would change the rounding. Element-wise, the
//! vector kernels are therefore bitwise-identical to the scalar oracle,
//! which is what keeps a fixed model step reproducible bit-for-bit no
//! matter which kernel the host machine detects. The cross-kernel
//! parity suite (`tests/native_backend.rs`) still pins the contract at
//! 1e-4 relative tolerance — the documented bound a future
//! FMA-accepting kernel would have to meet.

use super::gemm::{MR, NR};

/// Which micro-kernel implementation computes each `MR x NR` tile.
///
/// Requesting a variant the running CPU does not support is safe:
/// [`compute_tile`] re-checks the feature bit and falls back to
/// [`Micro::Scalar`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Micro {
    /// Portable scalar loops — the parity oracle, available everywhere.
    Scalar,
    /// 256-bit AVX2 lanes (x86_64, runtime-detected).
    Avx2,
    /// 128-bit NEON lanes (aarch64, runtime-detected).
    Neon,
}

impl Micro {
    /// Short lowercase name for bench labels and logs.
    pub fn name(self) -> &'static str {
        match self {
            Micro::Scalar => "scalar",
            Micro::Avx2 => "avx2",
            Micro::Neon => "neon",
        }
    }
}

/// The best micro-kernel the running CPU supports: AVX2 on x86_64,
/// NEON on aarch64, scalar everywhere else (or when the feature bit is
/// absent). Detection is cached by the standard library, so calling
/// this per `sgemm` is free.
pub fn detected() -> Micro {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Micro::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Micro::Neon;
        }
    }
    Micro::Scalar
}

/// Compute one `MR x NR` accumulator tile over a packed panel pair:
/// `acc[r][c] = sum_l a_panel[l*MR+r] * b_panel[l*NR+c]` in ascending-`l`
/// order with one accumulator per element (the summation-order
/// contract of `backend::gemm`). Falls back to the scalar oracle when
/// the requested ISA is unavailable on this CPU, so any `Micro` value
/// is safe to pass.
#[inline]
pub fn compute_tile(micro: Micro, a_panel: &[f32], b_panel: &[f32], kc: usize) -> [[f32; NR]; MR] {
    debug_assert!(a_panel.len() >= kc * MR, "A panel too short for kc");
    debug_assert!(b_panel.len() >= kc * NR, "B panel too short for kc");
    match micro {
        Micro::Scalar => tile_scalar(a_panel, b_panel, kc),
        Micro::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("avx2") {
                    // SAFETY: the avx2 feature bit was just checked.
                    return unsafe { tile_avx2(a_panel, b_panel, kc) };
                }
            }
            tile_scalar(a_panel, b_panel, kc)
        }
        Micro::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                if std::arch::is_aarch64_feature_detected!("neon") {
                    // SAFETY: the neon feature bit was just checked.
                    return unsafe { tile_neon(a_panel, b_panel, kc) };
                }
            }
            tile_scalar(a_panel, b_panel, kc)
        }
    }
}

/// The scalar oracle tile: exactly the pre-SIMD `macro_kernel`
/// accumulator loop, kept as the reference every vector kernel must
/// match.
fn tile_scalar(a_panel: &[f32], b_panel: &[f32], kc: usize) -> [[f32; NR]; MR] {
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..kc {
        let ar = &a_panel[l * MR..l * MR + MR];
        let br = &b_panel[l * NR..l * NR + NR];
        for r in 0..MR {
            let av = ar[r];
            for (dst, &bv) in acc[r].iter_mut().zip(br) {
                *dst += av * bv;
            }
        }
    }
    acc
}

/// AVX2 tile: one 8-lane register per output row (`NR == 8`), broadcast
/// A element, separate `mul` + `add` (no `fmadd` — see the module
/// docs' bitwise contract).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile_avx2(a_panel: &[f32], b_panel: &[f32], kc: usize) -> [[f32; NR]; MR] {
    use std::arch::x86_64::*;
    let mut acc = [_mm256_setzero_ps(); MR];
    for l in 0..kc {
        let bv = _mm256_loadu_ps(b_panel.as_ptr().add(l * NR));
        let ar = a_panel.as_ptr().add(l * MR);
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*ar.add(r));
            *accr = _mm256_add_ps(*accr, _mm256_mul_ps(av, bv));
        }
    }
    let mut out = [[0.0f32; NR]; MR];
    for (r, accr) in acc.iter().enumerate() {
        _mm256_storeu_ps(out[r].as_mut_ptr(), *accr);
    }
    out
}

/// NEON tile: two 4-lane registers per output row (`NR == 8`),
/// broadcast A element, separate `mul` + `add` (no fused multiply-add —
/// see the module docs' bitwise contract).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile_neon(a_panel: &[f32], b_panel: &[f32], kc: usize) -> [[f32; NR]; MR] {
    use std::arch::aarch64::*;
    let mut lo = [vdupq_n_f32(0.0); MR];
    let mut hi = [vdupq_n_f32(0.0); MR];
    for l in 0..kc {
        let b0 = vld1q_f32(b_panel.as_ptr().add(l * NR));
        let b1 = vld1q_f32(b_panel.as_ptr().add(l * NR + 4));
        let ar = a_panel.as_ptr().add(l * MR);
        for r in 0..MR {
            let av = vdupq_n_f32(*ar.add(r));
            lo[r] = vaddq_f32(lo[r], vmulq_f32(av, b0));
            hi[r] = vaddq_f32(hi[r], vmulq_f32(av, b1));
        }
    }
    let mut out = [[0.0f32; NR]; MR];
    for r in 0..MR {
        vst1q_f32(out[r].as_mut_ptr(), lo[r]);
        vst1q_f32(out[r].as_mut_ptr().add(4), hi[r]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn panels(seed: u64, kc: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::seeded(seed);
        let a = (0..kc * MR).map(|_| rng.normal()).collect();
        let b = (0..kc * NR).map(|_| rng.normal()).collect();
        (a, b)
    }

    #[test]
    fn scalar_tile_matches_naive_dot() {
        let kc = 37;
        let (a, b) = panels(0x51, kc);
        let acc = tile_scalar(&a, &b, kc);
        for r in 0..MR {
            for c in 0..NR {
                let mut want = 0.0f32;
                for l in 0..kc {
                    want += a[l * MR + r] * b[l * NR + c];
                }
                assert_eq!(acc[r][c].to_bits(), want.to_bits(), "({r},{c})");
            }
        }
    }

    #[test]
    fn detected_tile_matches_scalar_bitwise() {
        // The no-FMA contract: whatever kernel this CPU detects, its
        // tiles are bit-identical to the scalar oracle's.
        for kc in [1usize, 7, 64, 300] {
            let (a, b) = panels(0x52 ^ kc as u64, kc);
            let want = tile_scalar(&a, &b, kc);
            let got = compute_tile(detected(), &a, &b, kc);
            for r in 0..MR {
                for c in 0..NR {
                    assert_eq!(
                        got[r][c].to_bits(),
                        want[r][c].to_bits(),
                        "kc={kc} ({r},{c}): {} vs {}",
                        got[r][c],
                        want[r][c]
                    );
                }
            }
        }
    }

    #[test]
    fn every_kernel_variant_is_safe_to_request() {
        // Unsupported ISAs fall back to scalar instead of faulting, so
        // explicit `sgemm_with` callers can't crash on the wrong host.
        let kc = 19;
        let (a, b) = panels(0x53, kc);
        let want = tile_scalar(&a, &b, kc);
        for micro in [Micro::Scalar, Micro::Avx2, Micro::Neon] {
            let got = compute_tile(micro, &a, &b, kc);
            for r in 0..MR {
                for c in 0..NR {
                    let tol = 1e-4 * (1.0 + want[r][c].abs());
                    assert!(
                        (got[r][c] - want[r][c]).abs() <= tol,
                        "{:?} ({r},{c}): {} vs {}",
                        micro,
                        got[r][c],
                        want[r][c]
                    );
                }
            }
        }
    }
}
