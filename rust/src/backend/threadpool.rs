//! Lightweight persistent worker pool for intra-GEMM parallelism.
//!
//! `backend::gemm` partitions the `(jc, ic)` macro-tile grid statically
//! over `t` slots; slot 0 always runs on the calling thread and slots
//! `1..t` run on detached worker threads owned by this module. Workers
//! are spawned lazily, live for the process, and each installs a
//! thread-lifetime [`PoolScope`](crate::pool::PoolScope) so the packing
//! panels a worker leases recycle through its *own* pool — warm
//! steady-state GEMM allocates nothing on any thread, and the pools are
//! inspectable via [`worker_pool_stats`] for the cross-worker
//! zero-alloc probes.
//!
//! No work stealing, no futures, no dependencies: a job is a borrowed
//! `&dyn Fn(usize)` whose lifetime is erased before crossing the
//! channel. That erasure is sound because [`run`] blocks on a
//! completion latch before returning, so the borrow outlives every
//! worker-side call. A panicking job is caught on the worker (the
//! worker survives for future jobs), recorded in the latch, and
//! re-raised on the calling thread.
//!
//! # Thread-count policy
//!
//! [`configured_threads`] resolves, in order:
//! 1. `PIPESTALE_GEMM_THREADS` (explicit, absolute — `0`, unset or
//!    unparsable means "auto");
//! 2. auto: `min(available cores, per-thread cap)`. The threaded
//!    runtime sets the cap to `max(1, cores / P)` on each of its P
//!    stage workers ([`set_local_cap`]) so GEMM threads x stage
//!    workers never oversubscribes the machine.

use std::cell::Cell;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Condvar, Mutex, OnceLock};

use crate::pool::{PoolScope, PoolStats, TensorPool};

/// Completion latch for one [`run`] call: counts outstanding worker
/// jobs down to zero and carries the first worker panic, if any.
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<String>,
}

impl Latch {
    fn new(remaining: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining, panic: None }),
            done: Condvar::new(),
        }
    }

    fn arrive(&self, panic: Option<String>) {
        let mut st = self.state.lock().expect("gemm latch poisoned");
        if let Some(p) = panic {
            st.panic.get_or_insert(p);
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) -> Option<String> {
        let mut st = self.state.lock().expect("gemm latch poisoned");
        while st.remaining > 0 {
            st = self.done.wait(st).expect("gemm latch poisoned");
        }
        st.panic.take()
    }
}

/// One unit of work shipped to a worker. The `'static` lifetimes are a
/// lie told by [`run`]'s transmutes; see the module docs for why that
/// is sound (the caller blocks on `latch` before its borrows end).
struct Job {
    body: &'static (dyn Fn(usize) + Sync),
    latch: &'static Latch,
    slot: usize,
}

struct Worker {
    jobs: Sender<Job>,
    pool: TensorPool,
}

static WORKERS: OnceLock<Mutex<Vec<Worker>>> = OnceLock::new();

fn workers() -> &'static Mutex<Vec<Worker>> {
    WORKERS.get_or_init(|| Mutex::new(Vec::new()))
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn spawn_worker(idx: usize) -> Worker {
    let (jobs_tx, jobs_rx) = channel::<Job>();
    let (pool_tx, pool_rx) = channel::<TensorPool>();
    std::thread::Builder::new()
        .name(format!("gemm-{idx}"))
        .spawn(move || worker_main(jobs_rx, pool_tx))
        .expect("spawning gemm worker thread");
    let pool = pool_rx.recv().expect("gemm worker failed to start");
    Worker { jobs: jobs_tx, pool }
}

fn worker_main(jobs: Receiver<Job>, pool_tx: Sender<TensorPool>) {
    // Thread-lifetime scope: every panel this worker leases recycles
    // through its own pool, keeping warm GEMM allocation-free without
    // contending on the caller's pool.
    let scope = PoolScope::new();
    let _ = pool_tx.send(scope.pool().clone());
    // A pool worker never fans out further, whatever the process-wide
    // auto thread count says.
    set_local_cap(1);
    for job in jobs {
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (job.body)(job.slot)));
        job.latch.arrive(result.err().map(|p| panic_message(&*p)));
    }
}

/// Run `body(slot)` for every slot in `0..threads`, blocking until all
/// slots complete. Slot 0 executes on the calling thread; the rest are
/// dispatched to the persistent workers (spawned on first use). A
/// worker panic is re-raised here after every slot has finished, so C
/// is never left half-written while tiles are still in flight.
///
/// `threads <= 1` degenerates to a plain `body(0)` call with no
/// locking, channels or worker involvement at all — which is what
/// makes the 1-thread path trivially identical to the serial one.
///
/// The pool is not reentrant: a job must never call `run` with
/// `threads > 1` itself (it could enqueue behind — and then wait on —
/// its own worker). In-crate callers never do: worker threads cap
/// their auto thread count to 1 at startup, and tile bodies only pack
/// and multiply.
pub fn run(threads: usize, body: &(dyn Fn(usize) + Sync)) {
    let extra = threads.saturating_sub(1);
    if extra == 0 {
        body(0);
        return;
    }
    let latch = Latch::new(extra);
    // SAFETY: the erased lifetimes outlive every worker-side use
    // because this function blocks on `latch.wait()` — which returns
    // only after each dispatched job has called `arrive` — before
    // `body` and `latch` go out of scope.
    let body_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(body) };
    let latch_static: &'static Latch = unsafe { std::mem::transmute(&latch) };
    {
        let mut ws = workers().lock().expect("gemm worker registry poisoned");
        while ws.len() < extra {
            let idx = ws.len();
            ws.push(spawn_worker(idx));
        }
        for (i, w) in ws[..extra].iter().enumerate() {
            let job = Job { body: body_static, latch: latch_static, slot: i + 1 };
            w.jobs.send(job).expect("gemm worker hung up");
        }
    }
    // Catch a caller-slot panic too: unwinding past `latch.wait()`
    // would free the latch (and end `body`'s borrow) while workers
    // still hold pointers to both.
    let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(0)));
    let worker_panic = latch.wait();
    if let Err(p) = caller {
        std::panic::resume_unwind(p);
    }
    if let Some(panic) = worker_panic {
        panic!("gemm worker panicked: {panic}");
    }
}

thread_local! {
    static LOCAL_CAP: Cell<usize> = Cell::new(0);
}

/// Cap this thread's *auto* GEMM thread count (0 lifts the cap). Used
/// by `pipeline/threaded.rs` to divide the machine between its P stage
/// workers; an explicit `PIPESTALE_GEMM_THREADS` still overrides.
pub fn set_local_cap(cap: usize) {
    LOCAL_CAP.with(|c| c.set(cap));
}

/// Number of hardware threads, falling back to 1 when unknowable.
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Pure resolution rule behind [`configured_threads`], split out so the
/// env/cap/core interplay is unit-testable without touching process
/// state.
fn resolve(env: Option<&str>, cores: usize, cap: usize) -> usize {
    let explicit = env.and_then(|v| v.trim().parse::<usize>().ok()).filter(|&t| t > 0);
    let t = match explicit {
        Some(t) => t,
        None => {
            if cap == 0 {
                cores
            } else {
                cores.min(cap)
            }
        }
    };
    t.max(1)
}

/// The GEMM thread count a dispatched `sgemm` call uses on this thread
/// right now (see the module docs for the policy). Always >= 1.
pub fn configured_threads() -> usize {
    let env = std::env::var("PIPESTALE_GEMM_THREADS").ok();
    resolve(env.as_deref(), available_cores(), LOCAL_CAP.with(|c| c.get()))
}

/// Snapshot of every live GEMM worker's pool counters, in spawn order.
/// The cross-worker zero-alloc probes diff two of these to show warm
/// threaded GEMM allocates nothing off the calling thread either.
pub fn worker_pool_stats() -> Vec<PoolStats> {
    workers()
        .lock()
        .expect("gemm worker registry poisoned")
        .iter()
        .map(|w| w.pool.stats())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_executes_every_slot_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        run(5, &|slot| {
            hits[slot].fetch_add(1, Ordering::SeqCst);
        });
        for (slot, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "slot {slot}");
        }
    }

    #[test]
    fn single_thread_runs_on_the_caller() {
        let caller = std::thread::current().id();
        let same = AtomicUsize::new(0);
        run(1, &|slot| {
            assert_eq!(slot, 0);
            if std::thread::current().id() == caller {
                same.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(same.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let err = std::panic::catch_unwind(|| {
            run(3, &|slot| {
                if slot == 2 {
                    panic!("tile {slot} exploded");
                }
            });
        })
        .expect_err("worker panic must re-raise on the caller");
        let msg = panic_message(&*err);
        assert!(msg.contains("tile 2 exploded"), "got: {msg}");
        // The pool survives a panicking job and keeps serving.
        let total = AtomicUsize::new(0);
        run(3, &|_| {
            total.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn thread_resolution_rules() {
        // Explicit env var is absolute (ignores cores and cap).
        assert_eq!(resolve(Some("6"), 4, 2), 6);
        // "0", unset, junk -> auto = min(cores, cap), cap 0 = uncapped.
        assert_eq!(resolve(Some("0"), 8, 0), 8);
        assert_eq!(resolve(None, 8, 3), 3);
        assert_eq!(resolve(Some("lots"), 8, 0), 8);
        // Never returns 0.
        assert_eq!(resolve(None, 1, 1), 1);
        assert!(configured_threads() >= 1);
    }

    #[test]
    fn worker_pools_are_reachable_for_probes() {
        run(3, &|_| {});
        let stats = worker_pool_stats();
        assert!(stats.len() >= 2, "expected >=2 workers, saw {}", stats.len());
    }
}
