//! Golden-fixture generators: byte-exact MNIST IDX and CIFAR-10
//! binary files written from generated, *learnable* u8 datasets.
//!
//! Nothing binary is checked into git — tests and the `gen-data` CLI
//! subcommand call these writers to materialize a real-format dataset
//! into a scratch directory, and the returned [`FixtureSet`] is the
//! ground truth the parsers are checked against (round-trip: parsed
//! pixel k must equal `bytes[k]/255 - 0.5` bitwise). The images are
//! quantized class prototypes (same recipe as the synthetic
//! substitution, DESIGN.md §4), so a small CNN actually learns on
//! them — the e2e smoke in `tests/data_stream.rs` trains on a fixture
//! set and asserts the loss falls.
//!
//! The malformed variants (truncated header, wrong magic, bad dims,
//! short body, out-of-range label, bad record size) exist to pin the
//! loaders' validation errors to the offending field.

use std::path::Path;

use anyhow::{Context, Result};

use super::synthetic::{self, SyntheticSpec};

/// A generated u8 dataset: the byte-level ground truth for fixture
/// files (pixels HWC sample-major, exactly what a parser must yield).
pub struct FixtureSet {
    /// Image height.
    pub h: usize,
    /// Image width.
    pub w: usize,
    /// Channels (1 for MNIST-shaped, 3 for CIFAR-shaped).
    pub c: usize,
    /// Raw pixels, HWC within each sample, sample-major.
    pub images: Vec<u8>,
    /// One label byte per sample, each `< 10`.
    pub labels: Vec<u8>,
}

impl FixtureSet {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the set holds no samples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Scalars per sample.
    pub fn sample_elems(&self) -> usize {
        self.h * self.w * self.c
    }

    /// The normalized f32 value a parser must produce for pixel `k`.
    pub fn expected_f32(&self, k: usize) -> f32 {
        self.images[k] as f32 / 255.0 - 0.5
    }
}

/// Quantize a synthetic f32 image stream to u8 (clamped affine map;
/// the class structure survives, so the fixture datasets stay
/// learnable).
fn quantize(images: &[f32]) -> Vec<u8> {
    images.iter().map(|&v| (v * 32.0 + 128.0).round().clamp(0.0, 255.0) as u8).collect()
}

/// Generate a (train, test) pair of u8 fixture sets sharing class
/// prototypes — train accuracy transfers to test, like the real thing.
pub fn generate_pair(
    dataset: &str,
    train: usize,
    test: usize,
    seed: u64,
) -> (FixtureSet, FixtureSet) {
    let spec = SyntheticSpec { train, test, noise: 0.5, seed };
    let (tr, te) = synthetic::generate(dataset, &spec);
    let to_set = |ds: &super::Dataset| FixtureSet {
        h: ds.input_shape[0],
        w: ds.input_shape[1],
        c: ds.input_shape[2],
        images: quantize(&ds.images),
        labels: ds.labels.iter().map(|&l| l as u8).collect(),
    };
    (to_set(&tr), to_set(&te))
}

/// Serialize an IDX3 image file (big-endian header + raw pixels).
pub fn idx_images_bytes(set: &FixtureSet) -> Vec<u8> {
    assert_eq!(set.c, 1, "IDX3 fixtures are single-channel");
    let mut bytes = Vec::with_capacity(16 + set.images.len());
    bytes.extend_from_slice(&0x0000_0803u32.to_be_bytes());
    bytes.extend_from_slice(&(set.len() as u32).to_be_bytes());
    bytes.extend_from_slice(&(set.h as u32).to_be_bytes());
    bytes.extend_from_slice(&(set.w as u32).to_be_bytes());
    bytes.extend_from_slice(&set.images);
    bytes
}

/// Serialize an IDX1 label file.
pub fn idx_labels_bytes(labels: &[u8]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(8 + labels.len());
    bytes.extend_from_slice(&0x0000_0801u32.to_be_bytes());
    bytes.extend_from_slice(&(labels.len() as u32).to_be_bytes());
    bytes.extend_from_slice(labels);
    bytes
}

/// Serialize a CIFAR-10 binary file for samples `range` of the set:
/// per record one label byte + 3072 pixel bytes in CHW planes (the
/// ground-truth pixels are HWC, so this transposes on the way out —
/// the parser must transpose back).
pub fn cifar_bytes(set: &FixtureSet, range: std::ops::Range<usize>) -> Vec<u8> {
    assert_eq!((set.h, set.w, set.c), (32, 32, 3), "CIFAR fixtures are 32x32x3");
    let n = set.sample_elems();
    let mut bytes = Vec::with_capacity(range.len() * (1 + n));
    for i in range {
        bytes.push(set.labels[i]);
        let px = &set.images[i * n..(i + 1) * n];
        for c in 0..3 {
            for y in 0..32 {
                for x in 0..32 {
                    bytes.push(px[(y * 32 + x) * 3 + c]);
                }
            }
        }
    }
    bytes
}

/// Write a complete MNIST-format fixture dataset (the four standard
/// file names `load_or_synthesize` auto-detects) into `dir`; returns
/// the (train, test) ground truth.
pub fn write_mnist_fixture(
    dir: &Path,
    train: usize,
    test: usize,
    seed: u64,
) -> Result<(FixtureSet, FixtureSet)> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let (tr, te) = generate_pair("mnist", train, test, seed);
    std::fs::write(dir.join("train-images-idx3-ubyte"), idx_images_bytes(&tr))?;
    std::fs::write(dir.join("train-labels-idx1-ubyte"), idx_labels_bytes(&tr.labels))?;
    std::fs::write(dir.join("t10k-images-idx3-ubyte"), idx_images_bytes(&te))?;
    std::fs::write(dir.join("t10k-labels-idx1-ubyte"), idx_labels_bytes(&te.labels))?;
    Ok((tr, te))
}

/// Write a complete CIFAR-10-format fixture dataset into `dir`: the
/// train samples split across `data_batch_1.bin` / `data_batch_2.bin`
/// (two shards, exercising multi-file index accounting) plus
/// `test_batch.bin`; returns the (train, test) ground truth.
pub fn write_cifar_fixture(
    dir: &Path,
    train: usize,
    test: usize,
    seed: u64,
) -> Result<(FixtureSet, FixtureSet)> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let (tr, te) = generate_pair("cifar10", train, test, seed);
    let half = tr.len() / 2;
    std::fs::write(dir.join("data_batch_1.bin"), cifar_bytes(&tr, 0..half))?;
    std::fs::write(dir.join("data_batch_2.bin"), cifar_bytes(&tr, half..tr.len()))?;
    std::fs::write(dir.join("test_batch.bin"), cifar_bytes(&te, 0..te.len()))?;
    Ok((tr, te))
}

/// Write any real-format fixture dataset by name ("mnist"/"cifar10").
pub fn write_fixture(
    dataset: &str,
    dir: &Path,
    train: usize,
    test: usize,
    seed: u64,
) -> Result<(FixtureSet, FixtureSet)> {
    match dataset {
        "mnist" => write_mnist_fixture(dir, train, test, seed),
        "cifar10" => write_cifar_fixture(dir, train, test, seed),
        other => anyhow::bail!("unknown fixture dataset {other:?} (mnist|cifar10)"),
    }
}

// ---------------------------------------------------------------------------
// Malformed variants: each writes one specific corruption.
// ---------------------------------------------------------------------------

/// IDX file cut off inside the header (shorter than 16 bytes).
pub fn write_idx_truncated_header(path: &Path) -> Result<()> {
    std::fs::write(path, 0x0000_0803u32.to_be_bytes())?;
    Ok(())
}

/// IDX3 file with a wrong magic number (0x805).
pub fn write_idx_wrong_magic(path: &Path) -> Result<()> {
    let set = generate_pair("mnist", 2, 0, 3).0;
    let mut bytes = idx_images_bytes(&set);
    bytes[3] = 0x05;
    std::fs::write(path, bytes)?;
    Ok(())
}

/// IDX3 file whose header dims are zero (rows = 0).
pub fn write_idx_bad_dims(path: &Path) -> Result<()> {
    let set = generate_pair("mnist", 2, 0, 3).0;
    let mut bytes = idx_images_bytes(&set);
    bytes[8..12].copy_from_slice(&0u32.to_be_bytes());
    std::fs::write(path, bytes)?;
    Ok(())
}

/// IDX3 file whose pixel body is shorter than the header claims.
pub fn write_idx_short_body(path: &Path) -> Result<()> {
    let set = generate_pair("mnist", 4, 0, 3).0;
    let bytes = idx_images_bytes(&set);
    std::fs::write(path, &bytes[..bytes.len() - 100])?;
    Ok(())
}

/// IDX1 label file with label 37 at record 2.
pub fn write_idx_bad_label(path: &Path) -> Result<()> {
    let labels = [1u8, 9, 37, 0];
    std::fs::write(path, idx_labels_bytes(&labels))?;
    Ok(())
}

/// CIFAR file whose size is not a whole number of records.
pub fn write_cifar_bad_size(path: &Path) -> Result<()> {
    let set = generate_pair("cifar10", 2, 0, 3).0;
    let bytes = cifar_bytes(&set, 0..2);
    std::fs::write(path, &bytes[..bytes.len() - 7])?;
    Ok(())
}

/// CIFAR file with label 11 in record 1.
pub fn write_cifar_bad_label(path: &Path) -> Result<()> {
    let set = generate_pair("cifar10", 2, 0, 3).0;
    let mut bytes = cifar_bytes(&set, 0..2);
    bytes[1 + 3 * 32 * 32 + 1 - 1] = 11; // record 1's label byte
    std::fs::write(path, bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_sets_are_balanced_and_in_range() {
        let (tr, te) = generate_pair("mnist", 40, 20, 9);
        assert_eq!(tr.len(), 40);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.images.len(), 40 * 28 * 28);
        assert!(tr.labels.iter().all(|&l| l < 10));
        let counts = tr.labels.iter().fold([0usize; 10], |mut acc, &l| {
            acc[l as usize] += 1;
            acc
        });
        assert!(counts.iter().all(|&c| c == 4), "{counts:?}");
    }

    #[test]
    fn serializers_are_byte_exact() {
        let set = generate_pair("mnist", 3, 0, 5).0;
        let img = idx_images_bytes(&set);
        assert_eq!(img.len(), 16 + 3 * 28 * 28);
        assert_eq!(&img[0..4], &0x0000_0803u32.to_be_bytes());
        assert_eq!(&img[16..], &set.images[..]);
        let lab = idx_labels_bytes(&set.labels);
        assert_eq!(&lab[8..], &set.labels[..]);

        let cs = generate_pair("cifar10", 2, 0, 5).0;
        let rec = cifar_bytes(&cs, 0..2);
        assert_eq!(rec.len(), 2 * (1 + 3072));
        assert_eq!(rec[0], cs.labels[0]);
        // CHW plane 0 (R), pixel (0,0) is HWC element 0
        assert_eq!(rec[1], cs.images[0]);
        // CHW plane 1 (G), pixel (0,0) is HWC element 1
        assert_eq!(rec[1 + 1024], cs.images[1]);
    }

    #[test]
    fn deterministic_per_seed() {
        let (a, _) = generate_pair("cifar10", 10, 0, 7);
        let (b, _) = generate_pair("cifar10", 10, 0, 7);
        assert_eq!(a.images, b.images);
        let (c, _) = generate_pair("cifar10", 10, 0, 8);
        assert_ne!(a.images, c.images);
    }
}
