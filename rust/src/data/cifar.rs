//! CIFAR-10 binary format parser (data_batch_*.bin / test_batch.bin).
//!
//! Record layout: 1 label byte + 3072 pixel bytes in CHW planes (R,G,B);
//! converted here to NHWC normalized f32.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;

const REC: usize = 1 + 3 * 32 * 32;

pub fn load_cifar10_bin(path: &Path) -> Result<(Vec<f32>, Vec<i32>)> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.is_empty() || bytes.len() % REC != 0 {
        bail!("{}: size {} is not a multiple of {REC}", path.display(), bytes.len());
    }
    let n = bytes.len() / REC;
    let mut images = Vec::with_capacity(n * 3072);
    let mut labels = Vec::with_capacity(n);
    for r in 0..n {
        let rec = &bytes[r * REC..(r + 1) * REC];
        let label = rec[0] as i32;
        if label > 9 {
            bail!("{}: record {} has label {}", path.display(), r, label);
        }
        labels.push(label);
        let px = &rec[1..];
        // CHW planes -> HWC
        for y in 0..32 {
            for x in 0..32 {
                for c in 0..3 {
                    let v = px[c * 1024 + y * 32 + x] as f32 / 255.0 - 0.5;
                    images.push(v);
                }
            }
        }
    }
    Ok((images, labels))
}

/// Load the standard 5 train batches + test batch from a directory.
pub fn load_cifar10_dir(dir: &Path) -> Result<(Dataset, Dataset)> {
    let mut images = Vec::new();
    let mut labels = Vec::new();
    for i in 1..=5 {
        let p = dir.join(format!("data_batch_{i}.bin"));
        if !p.exists() {
            break;
        }
        let (im, la) = load_cifar10_bin(&p)?;
        images.extend(im);
        labels.extend(la);
    }
    if labels.is_empty() {
        bail!("no CIFAR-10 train batches under {}", dir.display());
    }
    let train = Dataset {
        name: "cifar10-train".into(),
        input_shape: vec![32, 32, 3],
        images,
        labels,
        num_classes: 10,
    };
    let (ti, tl) = load_cifar10_bin(&dir.join("test_batch.bin"))?;
    let test = Dataset {
        name: "cifar10-test".into(),
        input_shape: vec![32, 32, 3],
        images: ti,
        labels: tl,
        num_classes: 10,
    };
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(dir: &Path, name: &str, n: usize) {
        let mut bytes = Vec::with_capacity(n * REC);
        for r in 0..n {
            bytes.push((r % 10) as u8);
            for i in 0..3072 {
                bytes.push(((r + i) % 256) as u8);
            }
        }
        std::fs::write(dir.join(name), bytes).unwrap();
    }

    #[test]
    fn parses_and_transposes() {
        let dir = std::env::temp_dir().join(format!("cifar_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fixture(&dir, "data_batch_1.bin", 20);
        fixture(&dir, "test_batch.bin", 10);
        let (train, test) = load_cifar10_dir(&dir).unwrap();
        assert_eq!(train.len(), 20);
        assert_eq!(test.len(), 10);
        assert_eq!(train.images.len(), 20 * 3072);
        // record 0, pixel (0,0): R plane byte 0 = 0 -> -0.5; G plane byte
        // 1024 -> (1024%256=0)/255-0.5 = -0.5
        assert!((train.images[0] + 0.5).abs() < 1e-6);
        assert_eq!(train.labels[3], 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_sizes_and_labels() {
        let dir = std::env::temp_dir().join(format!("cifar_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        std::fs::write(&p, vec![0u8; REC - 1]).unwrap();
        assert!(load_cifar10_bin(&p).is_err());
        let mut rec = vec![0u8; REC];
        rec[0] = 11; // label out of range
        std::fs::write(&p, rec).unwrap();
        assert!(load_cifar10_bin(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
