//! CIFAR-10 binary format parser (data_batch_*.bin / test_batch.bin).
//!
//! Record layout: 1 label byte + 3072 pixel bytes in CHW planes
//! (R,G,B). The streaming loaders ([`load_cifar10_records`],
//! [`load_cifar10_dir_stream`]) validate every record — including the
//! label range, with the record index in the error — and keep the raw
//! records in one shared buffer; the CHW -> NHWC transpose happens at
//! batch-decode time inside
//! [`StreamDataset`](super::StreamDataset). The eager wrappers
//! ([`load_cifar10_bin`], [`load_cifar10_dir`]) keep the original
//! decoded-f32 API.

use std::path::Path;

use anyhow::{bail, Result};

use super::stream::{read_file_chunked, Shard, StreamDataset, CIFAR_REC};
use super::Dataset;

const REC: usize = CIFAR_REC;

/// Parse one CIFAR-10 binary file into `(labels, raw records)`. Every
/// record's label byte is validated against `num_classes` — a corrupt
/// byte would otherwise index past the logits — with the record index
/// named in the error.
pub fn load_cifar10_records(path: &Path, num_classes: usize) -> Result<(Vec<i32>, Vec<u8>)> {
    let bytes = read_file_chunked(path)?;
    if bytes.is_empty() || bytes.len() % REC != 0 {
        bail!(
            "{}: size {} is not a multiple of the {REC}-byte record",
            path.display(),
            bytes.len()
        );
    }
    let n = bytes.len() / REC;
    let mut labels = Vec::with_capacity(n);
    for r in 0..n {
        let label = bytes[r * REC];
        if label as usize >= num_classes {
            bail!(
                "{}: record {r}: label {label} out of range (0..{num_classes})",
                path.display()
            );
        }
        labels.push(label as i32);
    }
    Ok((labels, bytes))
}

/// Load the train batches + test batch from a directory as streaming
/// datasets, one shard per source file.
pub fn load_cifar10_dir_stream(dir: &Path) -> Result<(StreamDataset, StreamDataset)> {
    let mut records = Vec::new();
    let mut labels = Vec::new();
    let mut shards = Vec::new();
    for i in 1..=5 {
        let name = format!("data_batch_{i}.bin");
        let p = dir.join(&name);
        if !p.exists() {
            break;
        }
        let (la, rec) = load_cifar10_records(&p, 10)?;
        shards.push(Shard { name, start: labels.len(), len: la.len() });
        labels.extend(la);
        records.extend(rec);
    }
    if labels.is_empty() {
        bail!("no CIFAR-10 train batches under {}", dir.display());
    }
    let train = StreamDataset::from_cifar_records("cifar10-train".into(), labels, records, shards);
    let tp = dir.join("test_batch.bin");
    let (tl, trec) = load_cifar10_records(&tp, 10)?;
    let tn = tl.len();
    let test = StreamDataset::from_cifar_records(
        "cifar10-test".into(),
        tl,
        trec,
        vec![Shard { name: "test_batch.bin".into(), start: 0, len: tn }],
    );
    Ok((train, test))
}

/// Parse one CIFAR-10 binary file eagerly (normalized NHWC f32).
pub fn load_cifar10_bin(path: &Path) -> Result<(Vec<f32>, Vec<i32>)> {
    let (labels, records) = load_cifar10_records(path, 10)?;
    let n = labels.len();
    let ds = StreamDataset::from_cifar_records(
        "cifar10".into(),
        labels.clone(),
        records,
        vec![Shard { name: path.display().to_string(), start: 0, len: n }],
    );
    Ok((ds.to_eager().images, labels))
}

/// Load the standard 5 train batches + test batch eagerly.
pub fn load_cifar10_dir(dir: &Path) -> Result<(Dataset, Dataset)> {
    let (train, test) = load_cifar10_dir_stream(dir)?;
    Ok((train.to_eager(), test.to_eager()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(dir: &Path, name: &str, n: usize) {
        let mut bytes = Vec::with_capacity(n * REC);
        for r in 0..n {
            bytes.push((r % 10) as u8);
            for i in 0..3072 {
                bytes.push(((r + i) % 256) as u8);
            }
        }
        std::fs::write(dir.join(name), bytes).unwrap();
    }

    #[test]
    fn parses_and_transposes() {
        let dir = std::env::temp_dir().join(format!("cifar_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fixture(&dir, "data_batch_1.bin", 12);
        fixture(&dir, "data_batch_2.bin", 8);
        fixture(&dir, "test_batch.bin", 10);
        let (train, test) = load_cifar10_dir(&dir).unwrap();
        assert_eq!(train.len(), 20);
        assert_eq!(test.len(), 10);
        assert_eq!(train.images.len(), 20 * 3072);
        // record 0, pixel (0,0): R plane byte 0 = 0 -> -0.5
        assert!((train.images[0] + 0.5).abs() < 1e-6);
        assert_eq!(train.labels[3], 3);
        // shard accounting: two train files, index ranges abut
        let (ts, _) = load_cifar10_dir_stream(&dir).unwrap();
        assert_eq!(ts.shards().len(), 2);
        assert_eq!(ts.shard_of(11).name, "data_batch_1.bin");
        assert_eq!(ts.shard_of(12).name, "data_batch_2.bin");
        assert_eq!(ts.to_eager().images, train.images);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_sizes_and_labels() {
        let dir = std::env::temp_dir().join(format!("cifar_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        std::fs::write(&p, vec![0u8; REC - 1]).unwrap();
        let e = load_cifar10_bin(&p).unwrap_err().to_string();
        assert!(e.contains("record"), "{e}");
        // label out of range in the second record: error names it
        let mut recs = vec![0u8; 2 * REC];
        recs[REC] = 11;
        std::fs::write(&p, recs).unwrap();
        let e = load_cifar10_bin(&p).unwrap_err().to_string();
        assert!(e.contains("label 11"), "{e}");
        assert!(e.contains("record 1"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
