//! Synthetic class-prototype datasets (DESIGN.md §4 substitution).
//!
//! Each class has a smooth random prototype image; samples are the
//! prototype plus per-sample Gaussian noise and a small random global
//! shift. The task is linearly non-trivial but learnable by a small CNN
//! in a few hundred iterations — the paper's comparisons are *paired*
//! (pipelined vs non-pipelined on identical data/seeds), so the staleness
//! effects of interest survive the substitution.

use super::Dataset;
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub train: usize,
    pub test: usize,
    /// Per-pixel noise std relative to prototype contrast.
    pub noise: f32,
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec { train: 2048, test: 512, noise: 0.6, seed: 1234 }
    }
}

fn shape_for(dataset: &str) -> (Vec<usize>, usize) {
    match dataset {
        "mnist" => (vec![28, 28, 1], 10),
        _ => (vec![32, 32, 3], 10),
    }
}

/// Low-frequency prototype: sum of a few random 2-D cosine waves per
/// channel, so classes differ in smooth global structure (like digits /
/// object silhouettes) rather than i.i.d. pixels.
fn prototype(rng: &mut Pcg32, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut img = vec![0.0f32; h * w * c];
    for ch in 0..c {
        for _wave in 0..3 {
            let fx = rng.uniform(0.5, 3.0) * std::f32::consts::PI / w as f32;
            let fy = rng.uniform(0.5, 3.0) * std::f32::consts::PI / h as f32;
            let px = rng.uniform(0.0, std::f32::consts::TAU);
            let py = rng.uniform(0.0, std::f32::consts::TAU);
            let amp = rng.uniform(0.4, 1.0);
            for y in 0..h {
                for x in 0..w {
                    img[(y * w + x) * c + ch] +=
                        amp * (fx * x as f32 + px).cos() * (fy * y as f32 + py).cos();
                }
            }
        }
    }
    img
}

pub fn generate(dataset: &str, spec: &SyntheticSpec) -> (Dataset, Dataset) {
    let (shape, num_classes) = shape_for(dataset);
    let (h, w, c) = (shape[0], shape[1], shape[2]);
    let mut rng = Pcg32::seeded(spec.seed);
    let protos: Vec<Vec<f32>> =
        (0..num_classes).map(|_| prototype(&mut rng, h, w, c)).collect();

    let make = |n: usize, name: &str, rng: &mut Pcg32| -> Dataset {
        let elems = h * w * c;
        let mut images = Vec::with_capacity(n * elems);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let cls = (i % num_classes) as i32; // balanced classes
            let proto = &protos[cls as usize];
            // small global shift emulates augmentation jitter
            let dx = rng.below(5) as isize - 2;
            let dy = rng.below(5) as isize - 2;
            for y in 0..h as isize {
                for x in 0..w as isize {
                    let sy = (y + dy).rem_euclid(h as isize) as usize;
                    let sx = (x + dx).rem_euclid(w as isize) as usize;
                    for ch in 0..c {
                        let v = proto[(sy * w + sx) * c + ch]
                            + spec.noise * rng.normal();
                        images.push(v);
                    }
                }
            }
            labels.push(cls);
        }
        Dataset {
            name: format!("synthetic-{dataset}-{name}"),
            input_shape: shape.clone(),
            images,
            labels,
            num_classes,
        }
    };

    let train = make(spec.train, "train", &mut rng);
    let test = make(spec.test, "test", &mut rng);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        let spec = SyntheticSpec { train: 100, test: 50, noise: 0.5, seed: 9 };
        let (tr, te) = generate("cifar10", &spec);
        assert_eq!(tr.len(), 100);
        assert_eq!(te.len(), 50);
        assert_eq!(tr.images.len(), 100 * 32 * 32 * 3);
        let counts = tr.labels.iter().fold([0; 10], |mut acc, &l| {
            acc[l as usize] += 1;
            acc
        });
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = SyntheticSpec { train: 10, test: 5, noise: 0.5, seed: 3 };
        let (a, _) = generate("mnist", &spec);
        let (b, _) = generate("mnist", &spec);
        assert_eq!(a.images, b.images);
        let spec2 = SyntheticSpec { seed: 4, ..spec };
        let (c, _) = generate("mnist", &spec2);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // nearest-prototype classification should beat chance easily:
        // a sanity check that the task is learnable at all.
        let spec = SyntheticSpec { train: 200, test: 0, noise: 0.4, seed: 5 };
        let (tr, _) = generate("mnist", &spec);
        let mut rng = Pcg32::seeded(5);
        let protos: Vec<Vec<f32>> = (0..10).map(|_| prototype(&mut rng, 28, 28, 1)).collect();
        let elems = 28 * 28;
        let mut correct = 0;
        for i in 0..tr.len() {
            let img = &tr.images[i * elems..(i + 1) * elems];
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = img.iter().zip(&protos[a]).map(|(x, p)| (x - p).powi(2)).sum();
                    let db: f32 = img.iter().zip(&protos[b]).map(|(x, p)| (x - p).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == tr.labels[i] {
                correct += 1;
            }
        }
        assert!(correct > tr.len() / 2, "only {correct}/{} nearest-proto", tr.len());
    }
}
