//! MNIST IDX format parser (big-endian, magic 0x801/0x803).
//!
//! Used automatically when real MNIST files are present; unit tests
//! exercise the parser on generated fixture files.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;

fn read_u32(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Parse an IDX3 image file into normalized f32 pixels (x/255 - 0.5).
pub fn load_idx_images(path: &Path) -> Result<(usize, usize, usize, Vec<f32>)> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() < 16 {
        bail!("{}: truncated IDX header", path.display());
    }
    let magic = read_u32(&bytes, 0);
    if magic != 0x0000_0803 {
        bail!("{}: bad IDX3 magic {magic:#x}", path.display());
    }
    let n = read_u32(&bytes, 4) as usize;
    let h = read_u32(&bytes, 8) as usize;
    let w = read_u32(&bytes, 12) as usize;
    let want = 16 + n * h * w;
    if bytes.len() < want {
        bail!("{}: expected {} bytes, got {}", path.display(), want, bytes.len());
    }
    let data = bytes[16..want].iter().map(|&b| b as f32 / 255.0 - 0.5).collect();
    Ok((n, h, w, data))
}

/// Parse an IDX1 label file.
pub fn load_idx_labels(path: &Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() < 8 {
        bail!("{}: truncated IDX header", path.display());
    }
    let magic = read_u32(&bytes, 0);
    if magic != 0x0000_0801 {
        bail!("{}: bad IDX1 magic {magic:#x}", path.display());
    }
    let n = read_u32(&bytes, 4) as usize;
    if bytes.len() < 8 + n {
        bail!("{}: truncated IDX1 body", path.display());
    }
    Ok(bytes[8..8 + n].iter().map(|&b| b as i32).collect())
}

pub fn load_mnist(images: &Path, labels: &Path, name: &str) -> Result<Dataset> {
    let (n, h, w, data) = load_idx_images(images)?;
    let lab = load_idx_labels(labels)?;
    if lab.len() != n {
        bail!("mnist: {} images but {} labels", n, lab.len());
    }
    Ok(Dataset {
        name: name.to_string(),
        input_shape: vec![h, w, 1],
        images: data,
        labels: lab,
        num_classes: 10,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture_images(dir: &Path, n: usize, h: usize, w: usize) -> std::path::PathBuf {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        bytes.extend_from_slice(&(n as u32).to_be_bytes());
        bytes.extend_from_slice(&(h as u32).to_be_bytes());
        bytes.extend_from_slice(&(w as u32).to_be_bytes());
        for i in 0..n * h * w {
            bytes.push((i % 256) as u8);
        }
        let p = dir.join("imgs");
        std::fs::write(&p, bytes).unwrap();
        p
    }

    fn fixture_labels(dir: &Path, n: usize) -> std::path::PathBuf {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        bytes.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            bytes.push((i % 10) as u8);
        }
        let p = dir.join("labels");
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn parses_fixture() {
        let dir = std::env::temp_dir().join(format!("idx_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ip = fixture_images(&dir, 4, 3, 3);
        let lp = fixture_labels(&dir, 4);
        let ds = load_mnist(&ip, &lp, "fixture").unwrap();
        assert_eq!(ds.len(), 4);
        assert_eq!(ds.input_shape, vec![3, 3, 1]);
        assert_eq!(ds.labels, vec![0, 1, 2, 3]);
        // pixel 0 is 0 -> normalized -0.5
        assert!((ds.images[0] + 0.5).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let dir = std::env::temp_dir().join(format!("idx_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad");
        std::fs::write(&p, [0u8; 4]).unwrap();
        assert!(load_idx_images(&p).is_err());
        std::fs::write(&p, 0x0000_0802u32.to_be_bytes()).unwrap();
        assert!(load_idx_labels(&p).is_err());
        // valid header, short body
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        bytes.extend_from_slice(&10u32.to_be_bytes());
        bytes.extend_from_slice(&28u32.to_be_bytes());
        bytes.extend_from_slice(&28u32.to_be_bytes());
        std::fs::write(&p, bytes).unwrap();
        assert!(load_idx_images(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
