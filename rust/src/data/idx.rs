//! MNIST IDX format parser (big-endian, magic 0x801/0x803).
//!
//! Two entry points: the streaming loaders (`*_raw`,
//! [`load_mnist_stream`]) validate the headers, range-check every
//! label, and hand the raw pixel bytes to a
//! [`StreamDataset`](super::StreamDataset) — one chunked read, no f32
//! expansion; and the eager wrappers ([`load_mnist`],
//! [`load_idx_images`], [`load_idx_labels`]) keep the original
//! decoded-to-f32 API for tests and small sets. Every malformed-file
//! error names the offending field (magic, count, dims, body, label)
//! and the file; label errors carry the record index.

use std::path::Path;

use anyhow::{bail, Result};

use super::stream::{read_file_chunked, Shard, StreamDataset};
use super::Dataset;

fn read_u32(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Parse an IDX3 image file, keeping the pixels as raw u8 bytes
/// (`(count, rows, cols, body)`); the body is row-major sample-major,
/// exactly as stored on disk.
pub fn load_idx_images_raw(path: &Path) -> Result<(usize, usize, usize, Vec<u8>)> {
    let bytes = read_file_chunked(path)?;
    if bytes.len() < 16 {
        bail!(
            "{}: truncated IDX3 header: 16 bytes needed, file has {}",
            path.display(),
            bytes.len()
        );
    }
    let magic = read_u32(&bytes, 0);
    if magic != 0x0000_0803 {
        bail!("{}: bad IDX3 magic {magic:#010x} (want 0x00000803)", path.display());
    }
    let n = read_u32(&bytes, 4) as usize;
    let h = read_u32(&bytes, 8) as usize;
    let w = read_u32(&bytes, 12) as usize;
    if h == 0 || w == 0 || h > 4096 || w > 4096 {
        bail!("{}: bad image dims {h}x{w} (rows/cols must be 1..=4096)", path.display());
    }
    let want = 16 + n * h * w;
    if bytes.len() != want {
        bail!(
            "{}: pixel body mismatch: header claims {n} images of {h}x{w} \
             ({want} bytes total), file has {}",
            path.display(),
            bytes.len()
        );
    }
    let mut body = bytes;
    body.drain(..16);
    Ok((n, h, w, body))
}

/// Parse an IDX1 label file into raw label bytes, rejecting any label
/// `>= num_classes` with the offending record index — a corrupt label
/// would otherwise train silently against a garbage class.
pub fn load_idx_labels_raw(path: &Path, num_classes: usize) -> Result<Vec<u8>> {
    let bytes = read_file_chunked(path)?;
    if bytes.len() < 8 {
        bail!(
            "{}: truncated IDX1 header: 8 bytes needed, file has {}",
            path.display(),
            bytes.len()
        );
    }
    let magic = read_u32(&bytes, 0);
    if magic != 0x0000_0801 {
        bail!("{}: bad IDX1 magic {magic:#010x} (want 0x00000801)", path.display());
    }
    let n = read_u32(&bytes, 4) as usize;
    if bytes.len() != 8 + n {
        bail!(
            "{}: label body mismatch: header claims {n} labels, file has {} body bytes",
            path.display(),
            bytes.len().saturating_sub(8)
        );
    }
    let mut body = bytes;
    body.drain(..8);
    for (i, &l) in body.iter().enumerate() {
        if l as usize >= num_classes {
            bail!(
                "{}: record {i}: label {l} out of range (0..{num_classes})",
                path.display()
            );
        }
    }
    Ok(body)
}

/// Load an MNIST image/label file pair as a streaming dataset: one
/// chunked read per file, raw bytes retained, per-batch decode.
pub fn load_mnist_stream(images: &Path, labels: &Path, name: &str) -> Result<StreamDataset> {
    let (n, h, w, body) = load_idx_images_raw(images)?;
    let lab = load_idx_labels_raw(labels, 10)?;
    if lab.len() != n {
        bail!(
            "mnist: {} claims {n} images but {} claims {} labels",
            images.display(),
            labels.display(),
            lab.len()
        );
    }
    let shard_name = images
        .file_name()
        .map(|f| f.to_string_lossy().into_owned())
        .unwrap_or_else(|| images.display().to_string());
    Ok(StreamDataset::from_u8_hwc(
        name.to_string(),
        vec![h, w, 1],
        10,
        lab.into_iter().map(|l| l as i32).collect(),
        body,
        vec![Shard { name: shard_name, start: 0, len: n }],
    ))
}

/// Parse an IDX3 image file into normalized f32 pixels (x/255 - 0.5).
pub fn load_idx_images(path: &Path) -> Result<(usize, usize, usize, Vec<f32>)> {
    let (n, h, w, body) = load_idx_images_raw(path)?;
    let data = body.iter().map(|&b| b as f32 / 255.0 - 0.5).collect();
    Ok((n, h, w, data))
}

/// Parse an IDX1 label file (labels validated against 10 classes).
pub fn load_idx_labels(path: &Path) -> Result<Vec<i32>> {
    Ok(load_idx_labels_raw(path, 10)?.into_iter().map(|l| l as i32).collect())
}

/// Load an MNIST image/label pair eagerly (decoded f32 in memory).
pub fn load_mnist(images: &Path, labels: &Path, name: &str) -> Result<Dataset> {
    Ok(load_mnist_stream(images, labels, name)?.to_eager())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture_images(dir: &Path, n: usize, h: usize, w: usize) -> std::path::PathBuf {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        bytes.extend_from_slice(&(n as u32).to_be_bytes());
        bytes.extend_from_slice(&(h as u32).to_be_bytes());
        bytes.extend_from_slice(&(w as u32).to_be_bytes());
        for i in 0..n * h * w {
            bytes.push((i % 256) as u8);
        }
        let p = dir.join("imgs");
        std::fs::write(&p, bytes).unwrap();
        p
    }

    fn fixture_labels(dir: &Path, n: usize) -> std::path::PathBuf {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        bytes.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            bytes.push((i % 10) as u8);
        }
        let p = dir.join("labels");
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn parses_fixture() {
        let dir = std::env::temp_dir().join(format!("idx_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ip = fixture_images(&dir, 4, 3, 3);
        let lp = fixture_labels(&dir, 4);
        let ds = load_mnist(&ip, &lp, "fixture").unwrap();
        assert_eq!(ds.len(), 4);
        assert_eq!(ds.input_shape, vec![3, 3, 1]);
        assert_eq!(ds.labels, vec![0, 1, 2, 3]);
        // pixel 0 is 0 -> normalized -0.5
        assert!((ds.images[0] + 0.5).abs() < 1e-6);
        // streaming and eager agree bitwise
        let stream = load_mnist_stream(&ip, &lp, "fixture").unwrap();
        assert_eq!(stream.shards().len(), 1);
        assert_eq!(stream.shards()[0].len, 4);
        assert_eq!(stream.to_eager().images, ds.images);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let dir = std::env::temp_dir().join(format!("idx_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad");
        std::fs::write(&p, [0u8; 4]).unwrap();
        let e = load_idx_images(&p).unwrap_err().to_string();
        assert!(e.contains("header"), "{e}");
        std::fs::write(&p, 0x0000_0802u32.to_be_bytes()).unwrap();
        assert!(load_idx_labels(&p).is_err());
        // valid header, short body
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        bytes.extend_from_slice(&10u32.to_be_bytes());
        bytes.extend_from_slice(&28u32.to_be_bytes());
        bytes.extend_from_slice(&28u32.to_be_bytes());
        std::fs::write(&p, bytes).unwrap();
        let e = load_idx_images(&p).unwrap_err().to_string();
        assert!(e.contains("body"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_out_of_range_label_with_record_index() {
        // Regression: load_idx_labels used to accept any byte, so a
        // corrupt label (e.g. 37) trained silently against a garbage
        // class. It must now fail naming the field and the record.
        let dir = std::env::temp_dir().join(format!("idx_lab_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("labels");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        bytes.extend_from_slice(&4u32.to_be_bytes());
        bytes.extend_from_slice(&[1, 9, 37, 0]);
        std::fs::write(&p, bytes).unwrap();
        let e = load_idx_labels(&p).unwrap_err().to_string();
        assert!(e.contains("label 37"), "{e}");
        assert!(e.contains("record 2"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
