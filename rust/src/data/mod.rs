//! Data pipeline: synthetic prototype datasets + real-format loaders.
//!
//! The testbed has no MNIST/CIFAR files (DESIGN.md §4), so experiments
//! default to synthetic class-prototype datasets with the same shapes
//! (28x28x1 / 32x32x3) and train/test splits. Real-format parsers (MNIST
//! IDX, CIFAR-10 binary) are provided and auto-selected when files exist;
//! they are unit-tested on generated fixture files.

mod cifar;
pub mod fixtures;
mod idx;
mod stream;
mod synthetic;

pub use cifar::{load_cifar10_bin, load_cifar10_dir, load_cifar10_dir_stream};
pub use idx::{load_idx_images, load_idx_labels, load_mnist, load_mnist_stream};
pub use stream::{
    materialize_into, sample_seed, Augment, BatchStream, Prefetcher, Shard, StreamDataset,
    StreamOptions, SyncStream,
};
pub use synthetic::SyntheticSpec;

use anyhow::Result;

use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Pcg32;

/// An in-memory labelled image dataset (NHWC f32 + i32 labels).
#[derive(Clone)]
pub struct Dataset {
    pub name: String,
    /// (H, W, C)
    pub input_shape: Vec<usize>,
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn sample_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Copy samples `idxs` into a batch tensor pair. The image tensor is
    /// assembled in pooled storage, so per-iteration batch construction
    /// stops allocating once the pool is warm (§Perf).
    pub fn gather(&self, idxs: &[usize]) -> (Tensor, IntTensor) {
        let n = self.sample_elems();
        let mut images = crate::pool::acquire(idxs.len() * n);
        let buf = images.as_mut_slice();
        let mut labels = Vec::with_capacity(idxs.len());
        for (k, &i) in idxs.iter().enumerate() {
            buf[k * n..(k + 1) * n].copy_from_slice(&self.images[i * n..(i + 1) * n]);
            labels.push(self.labels[i]);
        }
        let mut shape = vec![idxs.len()];
        shape.extend_from_slice(&self.input_shape);
        (
            Tensor::from_pooled(&shape, images).expect("batch tensor"),
            IntTensor::from_vec(&[idxs.len()], labels).expect("batch labels"),
        )
    }
}

/// Epoch-shuffling fixed-size batcher. The last partial batch of an epoch
/// is dropped (static XLA shapes require a fixed batch size).
pub struct Batcher {
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Pcg32,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(len: usize, batch: usize, seed: u64) -> Self {
        assert!(batch > 0 && batch <= len, "batch {batch} vs dataset {len}");
        let mut b = Batcher {
            order: (0..len).collect(),
            cursor: 0,
            batch,
            rng: Pcg32::seeded(seed),
            epoch: 0,
        };
        b.rng.shuffle(&mut b.order);
        b
    }

    /// Indices of the next mini-batch (reshuffles at epoch boundaries).
    pub fn next_indices(&mut self) -> &[usize] {
        if self.cursor + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let s = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        s
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.order.len() / self.batch
    }

    /// Burn `n` batches without materializing them. Restart-after-
    /// checkpoint replay: a fresh `Batcher` with the original seed plus
    /// `skip(at)` lands on exactly the batch the interrupted run would
    /// have fed next, including epoch-boundary reshuffles.
    pub fn skip(&mut self, n: usize) {
        for _ in 0..n {
            self.next_indices();
        }
    }
}

/// Build train/test datasets for a config: real files when present under
/// `data_dir`, synthetic otherwise.
pub fn load_or_synthesize(
    dataset: &str,
    data_dir: Option<&std::path::Path>,
    spec: &SyntheticSpec,
) -> Result<(Dataset, Dataset)> {
    if let Some(dir) = data_dir {
        match dataset {
            "mnist" => {
                let ti = dir.join("train-images-idx3-ubyte");
                let tl = dir.join("train-labels-idx1-ubyte");
                let vi = dir.join("t10k-images-idx3-ubyte");
                let vl = dir.join("t10k-labels-idx1-ubyte");
                if ti.exists() && tl.exists() && vi.exists() && vl.exists() {
                    let train = idx::load_mnist(&ti, &tl, "mnist-train")?;
                    let test = idx::load_mnist(&vi, &vl, "mnist-test")?;
                    return Ok((train, test));
                }
            }
            "cifar10" => {
                if dir.join("data_batch_1.bin").exists() {
                    return cifar::load_cifar10_dir(dir);
                }
            }
            _ => {}
        }
        log::warn!("no {dataset} files under {}; using synthetic data", dir.display());
    }
    Ok(synthetic::generate(dataset, spec))
}

/// Build a streaming train dataset + eager test dataset for a config:
/// real files when present under `data_dir` (raw bytes retained,
/// per-batch decode), synthetic otherwise (wrapped without copies).
///
/// The test split stays eager: evaluation touches it rarely and whole,
/// so the decoded-f32 `Dataset` API (`evaluate`, accuracy sweeps) keeps
/// working unchanged.
pub fn load_streaming(
    dataset: &str,
    data_dir: Option<&std::path::Path>,
    spec: &SyntheticSpec,
) -> Result<(StreamDataset, Dataset)> {
    if let Some(dir) = data_dir {
        match dataset {
            "mnist" => {
                let ti = dir.join("train-images-idx3-ubyte");
                let tl = dir.join("train-labels-idx1-ubyte");
                let vi = dir.join("t10k-images-idx3-ubyte");
                let vl = dir.join("t10k-labels-idx1-ubyte");
                if ti.exists() && tl.exists() && vi.exists() && vl.exists() {
                    let train = idx::load_mnist_stream(&ti, &tl, "mnist-train")?;
                    let test = idx::load_mnist(&vi, &vl, "mnist-test")?;
                    return Ok((train, test));
                }
            }
            "cifar10" => {
                if dir.join("data_batch_1.bin").exists() {
                    let (train, test) = cifar::load_cifar10_dir_stream(dir)?;
                    return Ok((train, test.to_eager()));
                }
            }
            _ => {}
        }
        log::warn!("no {dataset} files under {}; using synthetic data", dir.display());
    }
    let (train, test) = synthetic::generate(dataset, spec);
    Ok((StreamDataset::from_dataset(train), test))
}

/// Deterministic per-batch dropout seed (must match between the fwd and
/// bwd executions of the same mini-batch — the coordinator passes the
/// value it stored with the activations).
pub fn batch_seed(global_seed: u64, batch_id: u64) -> i32 {
    let mut x = global_seed ^ batch_id.wrapping_mul(0x9e3779b97f4a7c15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58476d1ce4e5b9);
    x ^= x >> 27;
    (x as u32 & 0x7fff_ffff) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let spec = SyntheticSpec { train: 64, test: 32, noise: 0.5, seed: 1 };
        synthetic::generate("mnist", &spec).0
    }

    #[test]
    fn gather_shapes() {
        let d = tiny();
        let (x, y) = d.gather(&[0, 5, 9]);
        assert_eq!(x.shape, vec![3, 28, 28, 1]);
        assert_eq!(y.data.len(), 3);
    }

    #[test]
    fn batcher_covers_epoch_without_repeats() {
        let mut b = Batcher::new(100, 10, 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10 {
            for &i in b.next_indices() {
                assert!(seen.insert(i), "repeat within epoch");
            }
        }
        assert_eq!(seen.len(), 100);
        assert_eq!(b.batches_per_epoch(), 10);
        // next call rolls the epoch
        b.next_indices();
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn batcher_drops_partial_batch() {
        let mut b = Batcher::new(25, 10, 0);
        b.next_indices();
        b.next_indices();
        // only 5 left -> reshuffle, epoch++
        b.next_indices();
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn batcher_skip_replays_interrupted_stream() {
        // Crossing an epoch boundary (len 30, batch 10 -> 3 per epoch)
        // exercises the reshuffle inside the burned region.
        let mut full = Batcher::new(30, 10, 7);
        for _ in 0..5 {
            full.next_indices();
        }
        let want: Vec<usize> = full.next_indices().to_vec();
        let mut resumed = Batcher::new(30, 10, 7);
        resumed.skip(5);
        assert_eq!(resumed.epoch, full.epoch);
        assert_eq!(resumed.next_indices(), &want[..]);
    }

    #[test]
    fn batch_seed_is_deterministic_and_spread() {
        assert_eq!(batch_seed(1, 2), batch_seed(1, 2));
        assert_ne!(batch_seed(1, 2), batch_seed(1, 3));
        assert_ne!(batch_seed(1, 2), batch_seed(2, 2));
        assert!(batch_seed(0, 0) >= 0);
    }

    #[test]
    fn load_or_synthesize_falls_back() {
        let spec = SyntheticSpec { train: 32, test: 16, noise: 0.5, seed: 0 };
        let (tr, te) = load_or_synthesize("cifar10", None, &spec).unwrap();
        assert_eq!(tr.input_shape, vec![32, 32, 3]);
        assert_eq!(tr.len(), 32);
        assert_eq!(te.len(), 16);
    }

    #[test]
    fn load_streaming_matches_eager_on_synthetic_fallback() {
        let spec = SyntheticSpec { train: 32, test: 16, noise: 0.5, seed: 0 };
        let (st, ste) = load_streaming("mnist", None, &spec).unwrap();
        let (et, ete) = load_or_synthesize("mnist", None, &spec).unwrap();
        assert_eq!(st.to_eager().images, et.images);
        assert_eq!(ste.images, ete.images);
        assert_eq!(st.input_shape, vec![28, 28, 1]);
    }

    #[test]
    fn load_streaming_reads_fixture_files() {
        let dir = std::env::temp_dir().join(format!("stream_fix_{}", std::process::id()));
        let (gt, _) = fixtures::write_mnist_fixture(&dir, 20, 10, 5).unwrap();
        let spec = SyntheticSpec { train: 4, test: 2, noise: 0.5, seed: 0 };
        let (tr, te) = load_streaming("mnist", Some(&dir), &spec).unwrap();
        // real files win over the synthetic spec sizes
        assert_eq!(tr.len(), 20);
        assert_eq!(te.len(), 10);
        let eager = tr.to_eager();
        for k in 0..gt.sample_elems() {
            assert_eq!(eager.images[k], gt.expected_f32(k), "pixel {k}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
