//! Streaming ingestion (DESIGN.md §11): raw-byte datasets decoded
//! on demand into pooled batch tensors, optional augmentation, and a
//! shard-aware prefetcher that overlaps decode with the training
//! pipeline.
//!
//! The eager [`Dataset`](super::Dataset) path expands every sample to
//! f32 at load time (4x the on-disk footprint for u8 sources) and
//! copies per batch. A [`StreamDataset`] instead retains the file
//! bytes exactly once (`Arc<Vec<u8>>`, read in bounded chunks) and
//! decodes each sample directly into a pooled batch buffer at feed
//! time, so the steady-state ingest path allocates nothing once the
//! pool is warm — the same zero-alloc discipline as the compute cycle
//! (§Perf), probed in `tests/data_stream.rs`.
//!
//! Determinism contract: batch content is a pure function of
//! (shuffle seed, augment seed, batch index). The shuffle order comes
//! from the existing [`Batcher`] (so `Batcher::skip` replay and
//! checkpoint-restart stay bitwise-invisible), and every augmentation
//! draw is derived from `(aug_seed, epoch, sample index)` — never from
//! worker identity, arrival order, or thread count. Prefetching with
//! any number of worker threads is therefore bitwise identical to
//! synchronous iteration; `tests/data_stream.rs` holds the line.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::{Batcher, Dataset};
use crate::pool::{self, PoolStats, PoolVec, TensorPool};
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Pcg32;

/// CIFAR-10 binary record length: 1 label byte + 3x32x32 pixel bytes.
pub(super) const CIFAR_REC: usize = 1 + 3 * 32 * 32;

/// Pcg32 stream id for per-sample augmentation draws (distinct from
/// weight init and shuffle streams so the draw sequences never alias).
const AUG_STREAM: u64 = 0xda7a_a46e;

/// Chunk size for [`read_file_chunked`] (1 MiB: large enough that the
/// syscall count is negligible, small enough to keep the resident
/// working set of a partial read bounded).
const READ_CHUNK: usize = 1 << 20;

/// Read a whole file into an exact-length buffer in bounded chunks —
/// the loaders' one copy of the raw bytes, shared via `Arc` by every
/// decode afterwards. A file shorter than its reported metadata length
/// (torn mid-download) is an error, not a silent truncation.
pub(super) fn read_file_chunked(path: &std::path::Path) -> Result<Vec<u8>> {
    use std::io::Read;
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let len = f
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len() as usize;
    let mut buf = vec![0u8; len];
    let mut off = 0;
    while off < len {
        let end = (off + READ_CHUNK).min(len);
        let n = f
            .read(&mut buf[off..end])
            .with_context(|| format!("reading {}", path.display()))?;
        if n == 0 {
            bail!("{}: file truncated at byte {off} (expected {len})", path.display());
        }
        off += n;
    }
    Ok(buf)
}

/// One source file's contiguous index range inside a [`StreamDataset`]
/// (e.g. `data_batch_3.bin` covers samples 20000..30000).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    /// Source file name (or "synthetic" / "memory" for generated data).
    pub name: String,
    /// First sample index this shard holds.
    pub start: usize,
    /// Number of samples in the shard.
    pub len: usize,
}

/// How the raw pixels are stored; decoding normalizes to f32
/// `byte/255 - 0.5` exactly like the eager loaders, so a noop-augment
/// stream is bitwise the eager path.
enum PixelStore {
    /// Already-decoded f32 samples in HWC order (synthetic data, or a
    /// wrapped eager [`Dataset`]).
    F32(Arc<Vec<f32>>),
    /// Raw u8 pixels in HWC sample-major order (MNIST IDX body bytes;
    /// C is 1 so HW == HWC).
    U8Hwc(Arc<Vec<u8>>),
    /// Raw CIFAR-10 records (label byte + CHW planes, `CIFAR_REC`
    /// bytes each); decode transposes CHW -> HWC.
    CifarRecords(Arc<Vec<u8>>),
}

/// A labelled image dataset whose pixels live as raw shared bytes and
/// are decoded per batch into pooled tensors.
pub struct StreamDataset {
    /// Human-readable dataset name (shows up in logs).
    pub name: String,
    /// Per-sample (H, W, C).
    pub input_shape: Vec<usize>,
    /// Number of label classes.
    pub num_classes: usize,
    labels: Vec<i32>,
    pixels: PixelStore,
    shards: Vec<Shard>,
}

impl StreamDataset {
    /// Wrap an eager dataset (synthetic or already decoded) as a
    /// single-shard stream; decoding is then a plain copy.
    pub fn from_dataset(ds: Dataset) -> StreamDataset {
        let n = ds.len();
        StreamDataset {
            name: ds.name,
            input_shape: ds.input_shape,
            num_classes: ds.num_classes,
            labels: ds.labels,
            pixels: PixelStore::F32(Arc::new(ds.images)),
            shards: vec![Shard { name: "memory".into(), start: 0, len: n }],
        }
    }

    /// Build from raw u8 HWC pixel bytes (the IDX loader's path).
    pub(super) fn from_u8_hwc(
        name: String,
        input_shape: Vec<usize>,
        num_classes: usize,
        labels: Vec<i32>,
        bytes: Vec<u8>,
        shards: Vec<Shard>,
    ) -> StreamDataset {
        debug_assert_eq!(bytes.len(), labels.len() * input_shape.iter().product::<usize>());
        StreamDataset {
            name,
            input_shape,
            num_classes,
            labels,
            pixels: PixelStore::U8Hwc(Arc::new(bytes)),
            shards,
        }
    }

    /// Build from raw CIFAR-10 records (the CIFAR loader's path).
    pub(super) fn from_cifar_records(
        name: String,
        labels: Vec<i32>,
        records: Vec<u8>,
        shards: Vec<Shard>,
    ) -> StreamDataset {
        debug_assert_eq!(records.len(), labels.len() * CIFAR_REC);
        StreamDataset {
            name,
            input_shape: vec![32, 32, 3],
            num_classes: 10,
            labels,
            pixels: PixelStore::CifarRecords(Arc::new(records)),
            shards,
        }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Scalars per sample (H*W*C).
    pub fn sample_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Sample `i`'s label.
    pub fn label(&self, i: usize) -> i32 {
        self.labels[i]
    }

    /// The source shards, in index order.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// The shard holding sample `i`.
    pub fn shard_of(&self, i: usize) -> &Shard {
        assert!(i < self.len(), "sample {i} out of range ({} samples)", self.len());
        self.shards
            .iter()
            .find(|s| i >= s.start && i < s.start + s.len)
            .expect("shards cover the index space")
    }

    /// Decode sample `i` (normalized f32, HWC) into `out`. This is the
    /// zero-copy seam: bytes go straight from the shared file buffer
    /// into the pooled batch tensor, with no intermediate sample vec.
    pub fn decode_into(&self, i: usize, out: &mut [f32]) {
        let n = self.sample_elems();
        debug_assert_eq!(out.len(), n);
        match &self.pixels {
            PixelStore::F32(data) => out.copy_from_slice(&data[i * n..(i + 1) * n]),
            PixelStore::U8Hwc(bytes) => {
                for (o, &b) in out.iter_mut().zip(&bytes[i * n..(i + 1) * n]) {
                    *o = b as f32 / 255.0 - 0.5;
                }
            }
            PixelStore::CifarRecords(recs) => {
                let px = &recs[i * CIFAR_REC + 1..(i + 1) * CIFAR_REC];
                for y in 0..32 {
                    for x in 0..32 {
                        for c in 0..3 {
                            out[(y * 32 + x) * 3 + c] =
                                px[c * 1024 + y * 32 + x] as f32 / 255.0 - 0.5;
                        }
                    }
                }
            }
        }
    }

    /// Expand to an eager [`Dataset`] (test sets, comparisons).
    pub fn to_eager(&self) -> Dataset {
        let n = self.sample_elems();
        let mut images = vec![0.0f32; self.len() * n];
        for i in 0..self.len() {
            self.decode_into(i, &mut images[i * n..(i + 1) * n]);
        }
        Dataset {
            name: self.name.clone(),
            input_shape: self.input_shape.clone(),
            images,
            labels: self.labels.clone(),
            num_classes: self.num_classes,
        }
    }
}

/// Training-time augmentation knobs (`--augment`): random crop with
/// zero padding, horizontal flip, per-channel normalization. All draws
/// are pure functions of `(aug_seed, epoch, sample index)` — see
/// [`sample_seed`] — so the same sample augments identically whether
/// it is decoded synchronously, by any prefetch worker, or replayed
/// after a checkpoint restart.
#[derive(Debug, Clone, PartialEq)]
pub struct Augment {
    /// Zero-padding border before the random crop (0 disables crop).
    pub pad: usize,
    /// Randomly mirror left-right with probability 1/2.
    pub hflip: bool,
    /// Per-channel mean in [0,1] pixel units (empty disables).
    pub mean: Vec<f32>,
    /// Per-channel std in [0,1] pixel units (paired with `mean`).
    pub std: Vec<f32>,
}

impl Augment {
    /// No augmentation: decode output is bitwise the eager path.
    pub fn none() -> Augment {
        Augment { pad: 0, hflip: false, mean: Vec::new(), std: Vec::new() }
    }

    /// The standard recipe for a dataset: MNIST pads 2 with no flip
    /// (digits are chiral); CIFAR-10 pads 4, flips, and normalizes
    /// per channel with the conventional statistics.
    pub fn standard(dataset: &str) -> Augment {
        match dataset {
            "mnist" => Augment {
                pad: 2,
                hflip: false,
                mean: vec![0.1307],
                std: vec![0.3081],
            },
            _ => Augment {
                pad: 4,
                hflip: true,
                mean: vec![0.4914, 0.4822, 0.4465],
                std: vec![0.2470, 0.2435, 0.2616],
            },
        }
    }

    /// True when applying this augmentation is the identity.
    pub fn is_noop(&self) -> bool {
        self.pad == 0 && !self.hflip && self.mean.is_empty()
    }
}

/// Per-sample augmentation seed: a splitmix-style hash of
/// `(aug_seed, epoch, sample index)`. Epoch is folded in so the same
/// sample draws a *different* crop each epoch, yet any replay of the
/// same epoch reproduces it exactly.
pub fn sample_seed(aug_seed: u64, epoch: usize, index: usize) -> u64 {
    let mut x = aug_seed
        ^ (epoch as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ (index as u64).wrapping_mul(0xd1b5_4a32_d192_ed03);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Decode + augment sample `i` into `out`. `scratch` must hold
/// `sample_elems` scalars; it is only touched when augmentation is
/// active (the noop path decodes straight into `out`).
///
/// Augmentation math (DESIGN.md §11): with decoded value
/// `d = byte/255 - 0.5`, the output at (y, x, c) is
/// `norm(padded(y + dy, flip(x) + dx, c))` where `dy, dx` are drawn
/// uniformly from `0..=2*pad`, `padded` reads `d` in bounds and the
/// zero-pixel value `-0.5` outside, `flip` mirrors x with probability
/// 1/2 when enabled, and `norm(v) = (v + 0.5 - mean[c]) / std[c]`
/// (identity when no statistics are set). Draw order is fixed:
/// dy, dx, then flip.
pub fn materialize_into(
    ds: &StreamDataset,
    i: usize,
    aug: &Augment,
    aug_seed: u64,
    epoch: usize,
    out: &mut [f32],
    scratch: &mut [f32],
) {
    if aug.is_noop() {
        ds.decode_into(i, out);
        return;
    }
    ds.decode_into(i, scratch);
    let (h, w, c) = (ds.input_shape[0], ds.input_shape[1], ds.input_shape[2]);
    let mut rng = Pcg32::new(sample_seed(aug_seed, epoch, i), AUG_STREAM);
    let (dy, dx) = if aug.pad > 0 {
        (rng.below(2 * aug.pad as u32 + 1) as isize, rng.below(2 * aug.pad as u32 + 1) as isize)
    } else {
        (aug.pad as isize, aug.pad as isize)
    };
    let flip = aug.hflip && rng.below(2) == 1;
    let normalize = aug.mean.len() == c;
    let pad = aug.pad as isize;
    for y in 0..h {
        let sy = y as isize + dy - pad;
        let row_in = 0 <= sy && sy < h as isize;
        for x in 0..w {
            let xx = if flip { w - 1 - x } else { x };
            let sx = xx as isize + dx - pad;
            for ch in 0..c {
                let mut v = if row_in && 0 <= sx && sx < w as isize {
                    scratch[(sy as usize * w + sx as usize) * c + ch]
                } else {
                    -0.5 // zero pixel in byte units
                };
                if normalize {
                    v = (v + 0.5 - aug.mean[ch]) / aug.std[ch];
                }
                out[(y * w + x) * c + ch] = v;
            }
        }
    }
}

/// Decode + augment a whole mini-batch into a pooled tensor pair.
/// Epoch is the batch's epoch (for the per-sample augmentation seeds).
fn materialize_batch(
    ds: &StreamDataset,
    idxs: &[usize],
    epoch: usize,
    aug: &Augment,
    aug_seed: u64,
) -> (Tensor, IntTensor) {
    let n = ds.sample_elems();
    let mut images: PoolVec = pool::acquire(idxs.len() * n);
    let mut scratch: PoolVec = pool::acquire(if aug.is_noop() { 0 } else { n });
    let buf = images.as_mut_slice();
    let mut labels = Vec::with_capacity(idxs.len());
    for (k, &i) in idxs.iter().enumerate() {
        materialize_into(
            ds,
            i,
            aug,
            aug_seed,
            epoch,
            &mut buf[k * n..(k + 1) * n],
            scratch.as_mut_slice(),
        );
        labels.push(ds.label(i));
    }
    let mut shape = vec![idxs.len()];
    shape.extend_from_slice(&ds.input_shape);
    (
        Tensor::from_pooled(&shape, images).expect("batch tensor"),
        IntTensor::from_vec(&[idxs.len()], labels).expect("batch labels"),
    )
}

/// Launch-time knobs for a [`BatchStream`].
#[derive(Debug, Clone)]
pub struct StreamOptions {
    /// Mini-batch size.
    pub batch: usize,
    /// Seed for the epoch shuffle (the training driver passes
    /// `rc.seed ^ 0xba7c4`, the same salt the eager path always used).
    pub shuffle_seed: u64,
    /// Seed for augmentation draws (the run's global seed).
    pub aug_seed: u64,
    /// Batches already consumed by an earlier generation: the stream
    /// burns them with `Batcher::skip` so checkpoint-restart replay is
    /// bitwise-invisible.
    pub start: u64,
    /// Augmentation recipe ([`Augment::none`] to disable).
    pub augment: Augment,
    /// Prefetch worker threads (0 = synchronous decode on the caller).
    pub threads: usize,
    /// In-flight batch cap for prefetch (0 = `2 * threads`).
    pub depth: usize,
}

impl StreamOptions {
    /// Synchronous, unaugmented defaults for a given batch/seed — the
    /// configuration that reproduces the legacy eager feed bitwise.
    pub fn plain(batch: usize, shuffle_seed: u64, aug_seed: u64) -> StreamOptions {
        StreamOptions {
            batch,
            shuffle_seed,
            aug_seed,
            start: 0,
            augment: Augment::none(),
            threads: 0,
            depth: 0,
        }
    }
}

/// A deterministic mini-batch source over a [`StreamDataset`]:
/// synchronous or prefetched, identical output either way.
pub enum BatchStream {
    /// Caller-thread decode.
    Sync(SyncStream),
    /// Worker-thread decode, emitted strictly in batch order.
    Prefetch(Prefetcher),
}

impl BatchStream {
    /// Build a stream per the options (validates sizes up front).
    pub fn new(ds: Arc<StreamDataset>, opts: StreamOptions) -> Result<BatchStream> {
        ensure!(!ds.is_empty(), "streaming {}: empty dataset", ds.name);
        ensure!(
            opts.batch > 0 && opts.batch <= ds.len(),
            "streaming {}: batch {} vs {} samples",
            ds.name,
            opts.batch,
            ds.len()
        );
        if opts.threads == 0 {
            Ok(BatchStream::Sync(SyncStream::new(ds, opts)))
        } else {
            Ok(BatchStream::Prefetch(Prefetcher::launch(ds, opts)?))
        }
    }

    /// The next mini-batch (pooled image tensor + labels).
    pub fn next_batch(&mut self) -> Result<(Tensor, IntTensor)> {
        match self {
            BatchStream::Sync(s) => Ok(s.next_batch()),
            BatchStream::Prefetch(p) => p.next_batch(),
        }
    }

    /// Full batches per epoch (the tail partial batch is dropped,
    /// exactly like [`Batcher`]).
    pub fn batches_per_epoch(&self) -> usize {
        match self {
            BatchStream::Sync(s) => s.batcher.batches_per_epoch(),
            BatchStream::Prefetch(p) => p.batches_per_epoch,
        }
    }

    /// Per-worker pool counters (empty for a synchronous stream) —
    /// inputs to the merged zero-alloc probe in `tests/data_stream.rs`.
    pub fn worker_pool_stats(&self) -> Vec<PoolStats> {
        match self {
            BatchStream::Sync(_) => Vec::new(),
            BatchStream::Prefetch(p) => p.pools.iter().map(|p| p.stats()).collect(),
        }
    }
}

/// Synchronous stream: shuffle, decode, augment on the caller thread.
pub struct SyncStream {
    ds: Arc<StreamDataset>,
    batcher: Batcher,
    augment: Augment,
    aug_seed: u64,
}

impl SyncStream {
    fn new(ds: Arc<StreamDataset>, opts: StreamOptions) -> SyncStream {
        let mut batcher = Batcher::new(ds.len(), opts.batch, opts.shuffle_seed);
        batcher.skip(opts.start as usize);
        SyncStream { ds, batcher, augment: opts.augment, aug_seed: opts.aug_seed }
    }

    fn next_batch(&mut self) -> (Tensor, IntTensor) {
        let idxs = self.batcher.next_indices().to_vec();
        let epoch = self.batcher.epoch;
        materialize_batch(&self.ds, &idxs, epoch, &self.augment, self.aug_seed)
    }
}

/// A unit of prefetch work: decode batch `seq` (drawn in epoch
/// `epoch`) from the given sample indices.
struct Task {
    seq: u64,
    epoch: usize,
    idxs: Vec<usize>,
}

/// A decoded batch travelling back to the coordinator.
struct Done {
    seq: u64,
    x: Tensor,
    labels: IntTensor,
}

/// Prefetching stream: N workers decode batches concurrently; the
/// coordinator dispatches tasks round-robin (`seq % threads`) from its
/// own [`Batcher`] and reorders completions so emission is strictly
/// sequential. Each worker installs a private
/// [`PoolScope`](crate::pool::PoolScope), so batch buffers recycle
/// through the pool that leased them no matter which thread drops
/// them — the same idiom as `pipeline/threaded.rs` workers.
pub struct Prefetcher {
    batcher: Batcher,
    batches_per_epoch: usize,
    task_txs: Vec<Sender<Task>>,
    done_rx: Receiver<Done>,
    ready: HashMap<u64, (Tensor, IntTensor)>,
    next_dispatch: u64,
    next_emit: u64,
    depth: u64,
    workers: Vec<JoinHandle<()>>,
    pools: Vec<TensorPool>,
}

impl Prefetcher {
    fn launch(ds: Arc<StreamDataset>, opts: StreamOptions) -> Result<Prefetcher> {
        let threads = opts.threads;
        let depth = if opts.depth == 0 { 2 * threads as u64 } else { opts.depth as u64 };
        let (done_tx, done_rx) = channel::<Done>();
        let (pool_tx, pool_rx) = channel::<TensorPool>();
        let mut task_txs = Vec::with_capacity(threads);
        let mut workers = Vec::with_capacity(threads);
        for widx in 0..threads {
            let (tx, rx) = channel::<Task>();
            task_txs.push(tx);
            let ds = Arc::clone(&ds);
            let aug = opts.augment.clone();
            let aug_seed = opts.aug_seed;
            let done = done_tx.clone();
            let pools = pool_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("prefetch-{widx}"))
                .spawn(move || prefetch_worker(ds, aug, aug_seed, rx, done, pools))
                .map_err(|e| anyhow!("spawning prefetch worker {widx}: {e}"))?;
            workers.push(handle);
        }
        drop(pool_tx);
        let pools: Vec<TensorPool> = pool_rx.iter().take(threads).collect();
        ensure!(pools.len() == threads, "a prefetch worker died before publishing its pool");
        let mut batcher = Batcher::new(ds.len(), opts.batch, opts.shuffle_seed);
        batcher.skip(opts.start as usize);
        let batches_per_epoch = batcher.batches_per_epoch();
        let mut p = Prefetcher {
            batcher,
            batches_per_epoch,
            task_txs,
            done_rx,
            ready: HashMap::new(),
            next_dispatch: 0,
            next_emit: 0,
            depth: depth.max(1),
            workers,
            pools,
        };
        p.fill();
        Ok(p)
    }

    /// Dispatch tasks until `depth` batches are in flight. Runs on the
    /// caller thread, so the (seq, epoch, idxs) assignment — and hence
    /// every augmentation draw — is identical at any thread count.
    fn fill(&mut self) {
        while self.next_dispatch < self.next_emit + self.depth {
            let idxs = self.batcher.next_indices().to_vec();
            let epoch = self.batcher.epoch;
            let seq = self.next_dispatch;
            let w = (seq % self.task_txs.len() as u64) as usize;
            // A send failure means the worker died; surfaced as a
            // disconnect in next_batch, where it can carry an error.
            let _ = self.task_txs[w].send(Task { seq, epoch, idxs });
            self.next_dispatch += 1;
        }
    }

    fn next_batch(&mut self) -> Result<(Tensor, IntTensor)> {
        loop {
            if let Some(batch) = self.ready.remove(&self.next_emit) {
                self.next_emit += 1;
                self.fill();
                return Ok(batch);
            }
            match self.done_rx.recv() {
                Ok(d) => {
                    self.ready.insert(d.seq, (d.x, d.labels));
                }
                Err(_) => bail!("prefetch worker exited mid-stream (decode thread died)"),
            }
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Disconnect the task channels; workers exit their recv loop.
        self.task_txs.clear();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Prefetch worker body: publish the private pool (for the merged
/// zero-alloc probe), then decode tasks until the channel disconnects.
fn prefetch_worker(
    ds: Arc<StreamDataset>,
    aug: Augment,
    aug_seed: u64,
    tasks: Receiver<Task>,
    done: Sender<Done>,
    pools: Sender<TensorPool>,
) {
    let scope = pool::PoolScope::new();
    let _ = pools.send(scope.pool().clone());
    for t in tasks {
        let (x, labels) = materialize_batch(&ds, &t.idxs, t.epoch, &aug, aug_seed);
        if done.send(Done { seq: t.seq, x, labels }).is_err() {
            break; // coordinator dropped; shut down quietly
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::SyntheticSpec;
    use super::*;

    fn tiny(n: usize) -> Arc<StreamDataset> {
        let spec = SyntheticSpec { train: n, test: 8, noise: 0.5, seed: 11 };
        Arc::new(StreamDataset::from_dataset(super::super::synthetic::generate("mnist", &spec).0))
    }

    #[test]
    fn noop_stream_matches_eager_gather() {
        let ds = tiny(32);
        let eager = ds.to_eager();
        let mut s = BatchStream::new(Arc::clone(&ds), StreamOptions::plain(8, 7, 42)).unwrap();
        let mut b = Batcher::new(32, 8, 7);
        for _ in 0..6 {
            let idxs = b.next_indices().to_vec();
            let (want_x, want_y) = eager.gather(&idxs);
            let (x, y) = s.next_batch().unwrap();
            assert_eq!(x.data(), want_x.data());
            assert_eq!(y.data, want_y.data);
        }
    }

    #[test]
    fn sample_seed_is_pure_and_spread() {
        assert_eq!(sample_seed(1, 2, 3), sample_seed(1, 2, 3));
        assert_ne!(sample_seed(1, 2, 3), sample_seed(1, 3, 3));
        assert_ne!(sample_seed(1, 2, 3), sample_seed(1, 2, 4));
        assert_ne!(sample_seed(1, 2, 3), sample_seed(2, 2, 3));
    }

    #[test]
    fn augment_is_pure_per_epoch_and_varies_across_epochs() {
        let ds = tiny(16);
        let aug = Augment::standard("mnist");
        let n = ds.sample_elems();
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        let mut scratch = vec![0.0; n];
        materialize_into(&ds, 3, &aug, 99, 0, &mut a, &mut scratch);
        materialize_into(&ds, 3, &aug, 99, 0, &mut b, &mut scratch);
        assert_eq!(a, b, "same (seed, epoch, index) must reproduce exactly");
        // Across epochs at least one of several samples must draw a
        // different crop (25 crop offsets; 8 identical draws in a row
        // would be astronomically unlikely under a working hash).
        let mut any_differ = false;
        for i in 0..8 {
            materialize_into(&ds, i, &aug, 99, 0, &mut a, &mut scratch);
            materialize_into(&ds, i, &aug, 99, 1, &mut b, &mut scratch);
            any_differ |= a != b;
        }
        assert!(any_differ, "epoch must perturb the augmentation draws");
    }

    #[test]
    fn crop_pads_with_zero_pixels() {
        // Fully out-of-range crop cannot happen (pad bounds the
        // shift), but border rows do read the pad: with dy=0 the top
        // `pad` rows come from the zero-padding. Force it by scanning
        // seeds for a (dy=0, dx=pad) draw, then check the top row.
        let ds = tiny(4);
        let aug = Augment { pad: 2, hflip: false, mean: Vec::new(), std: Vec::new() };
        let n = ds.sample_elems();
        let (mut out, mut scratch) = (vec![0.0; n], vec![0.0; n]);
        for seed in 0..400u64 {
            let mut rng = Pcg32::new(sample_seed(seed, 0, 0), AUG_STREAM);
            let dy = rng.below(5);
            let dx = rng.below(5);
            if dy == 0 && dx == 2 {
                materialize_into(&ds, 0, &aug, seed, 0, &mut out, &mut scratch);
                // output row 0 reads padded row -2: all pad values
                assert!(out[..28].iter().all(|&v| v == -0.5), "top rows must be pad");
                return;
            }
        }
        panic!("no (dy=0, dx=2) draw in 400 seeds — hash is broken");
    }

    #[test]
    fn prefetch_matches_sync_bitwise() {
        let ds = tiny(40);
        let mut opts = StreamOptions::plain(8, 13, 77);
        opts.augment = Augment::standard("mnist");
        for threads in [1usize, 3] {
            let mut o = opts.clone();
            o.threads = threads;
            let mut pre = BatchStream::new(Arc::clone(&ds), o).unwrap();
            let mut sync = BatchStream::new(Arc::clone(&ds), opts.clone()).unwrap();
            for _ in 0..12 {
                let (ax, ay) = sync.next_batch().unwrap();
                let (bx, by) = pre.next_batch().unwrap();
                assert_eq!(ax.data(), bx.data(), "prefetch({threads}) diverged from sync");
                assert_eq!(ay.data, by.data);
            }
        }
    }

    #[test]
    fn start_replays_the_interrupted_stream() {
        let ds = tiny(40);
        let mut opts = StreamOptions::plain(8, 5, 21);
        opts.augment = Augment::standard("mnist");
        let mut full = BatchStream::new(Arc::clone(&ds), opts.clone()).unwrap();
        // 40/8 = 5 batches/epoch: skipping 7 crosses an epoch boundary.
        for _ in 0..7 {
            full.next_batch().unwrap();
        }
        let mut resumed = opts.clone();
        resumed.start = 7;
        resumed.threads = 2;
        let mut resumed = BatchStream::new(Arc::clone(&ds), resumed).unwrap();
        for _ in 0..4 {
            let (ax, ay) = full.next_batch().unwrap();
            let (bx, by) = resumed.next_batch().unwrap();
            assert_eq!(ax.data(), bx.data(), "replay diverged");
            assert_eq!(ay.data, by.data);
        }
    }

    #[test]
    fn shards_cover_and_resolve() {
        let labels = vec![0i32; 6];
        let bytes = vec![0u8; 6 * 4];
        let ds = StreamDataset::from_u8_hwc(
            "t".into(),
            vec![2, 2, 1],
            10,
            labels,
            bytes,
            vec![
                Shard { name: "a".into(), start: 0, len: 4 },
                Shard { name: "b".into(), start: 4, len: 2 },
            ],
        );
        assert_eq!(ds.shard_of(0).name, "a");
        assert_eq!(ds.shard_of(3).name, "a");
        assert_eq!(ds.shard_of(4).name, "b");
        assert_eq!(ds.shard_of(5).name, "b");
        assert_eq!(ds.shards().len(), 2);
    }

    #[test]
    fn u8_decode_normalizes_like_the_eager_path() {
        let bytes: Vec<u8> = (0..8u8).map(|b| b * 30).collect();
        let ds = StreamDataset::from_u8_hwc(
            "t".into(),
            vec![2, 2, 1],
            10,
            vec![1, 2],
            bytes.clone(),
            vec![Shard { name: "m".into(), start: 0, len: 2 }],
        );
        let mut out = vec![0.0; 4];
        ds.decode_into(1, &mut out);
        for (k, &b) in bytes[4..].iter().enumerate() {
            assert_eq!(out[k], b as f32 / 255.0 - 0.5);
        }
        assert_eq!(ds.label(1), 2);
    }
}
