//! Pooled tensor backing stores (§Perf tentpole).
//!
//! Every `Tensor` in the training cycle is backed by a `PoolVec`: an
//! f32 buffer leased from a `TensorPool` that recycles buffers by size
//! class when the last owner drops. Training workloads touch a small,
//! fixed set of tensor sizes (per-partition weights, carries, batch
//! inputs), so after a few warmup cycles every acquire is served from
//! the shelf and the steady-state cycle performs **zero heap
//! allocations of tensor backing stores** — verified by the pool-stats
//! counters and `tests/pool_and_kernel.rs`.
//!
//! Sharing: `Storage` wraps `Arc<PoolVec>`, so cloning a tensor (e.g.
//! a carry crossing an mpsc channel in `pipeline/threaded.rs`, or a
//! `params_snapshot`) is a refcount bump, never a deep copy. Mutation
//! goes through `Storage::make_mut`, which is in-place when unique and
//! copy-on-write (into a fresh pooled buffer) when shared — the SGD hot
//! loop mutates uniquely-owned weights in place.
//!
//! Scoping: `TensorPool::global()` serves all allocations by default.
//! Tests that assert on counters install a private pool for the current
//! thread with `PoolScope::new()`, so parallel test threads cannot
//! perturb each other's stats. A buffer always returns to the pool that
//! issued it ("home"), regardless of which thread drops it.
//!
//! Safety contract: a recycled buffer is returned with **arbitrary
//! contents**. The only constructors of `Tensor`/`IntTensor` either
//! fully overwrite the buffer or zero it (`acquire_zeroed`), so stale
//! data can never leak through the public tensor API — property-tested
//! in `tests/pool_and_kernel.rs`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Per-size-class shelf capacity; bounds pool memory at
/// `MAX_BUFS_PER_CLASS * live size classes` buffers.
const MAX_BUFS_PER_CLASS: usize = 32;

/// Global cap on shelved scalars (1 GiB of f32); beyond it, returned
/// buffers are freed instead of shelved.
const MAX_RETAINED_SCALARS: u64 = 1 << 28;

#[derive(Default)]
struct Shelves {
    by_len: HashMap<usize, Vec<Vec<f32>>>,
    retained_scalars: u64,
}

impl Shelves {
    fn take(&mut self, len: usize) -> Option<Vec<f32>> {
        let buf = self.by_len.get_mut(&len)?.pop()?;
        self.retained_scalars -= len as u64;
        Some(buf)
    }

    /// Shelve `data` if caps allow; returns false (freeing it) otherwise.
    fn try_shelve(&mut self, data: Vec<f32>) -> bool {
        let len = data.len() as u64;
        if self.retained_scalars + len > MAX_RETAINED_SCALARS {
            return false;
        }
        let bucket = self.by_len.entry(data.len()).or_default();
        if bucket.len() >= MAX_BUFS_PER_CLASS {
            return false;
        }
        bucket.push(data);
        self.retained_scalars += len;
        true
    }
}

/// Counter snapshot for perf assertions and diagnostics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers created with a fresh heap allocation.
    pub fresh_allocs: u64,
    /// Acquires served from the shelf (no heap allocation).
    pub reuses: u64,
    /// Buffers returned to the shelf on drop.
    pub recycled: u64,
    /// Buffers freed on drop (pool disabled, odd capacity, or caps hit).
    pub discarded: u64,
    /// Scalars currently sitting on shelves.
    pub retained_scalars: u64,
}

impl PoolStats {
    /// Fraction of acquires that avoided a heap allocation.
    pub fn hit_rate(&self) -> f64 {
        let total = self.fresh_allocs + self.reuses;
        if total == 0 {
            0.0
        } else {
            self.reuses as f64 / total as f64
        }
    }

    /// Counter deltas since an earlier snapshot (`retained_scalars` is
    /// a level, not a counter, and is carried over as-is). The standard
    /// probe shape for zero-allocation assertions: snapshot, run the
    /// steady-state loop, assert `delta(..).fresh_allocs == 0`.
    pub fn delta(&self, since: &PoolStats) -> PoolStats {
        PoolStats {
            fresh_allocs: self.fresh_allocs - since.fresh_allocs,
            reuses: self.reuses - since.reuses,
            recycled: self.recycled - since.recycled,
            discarded: self.discarded - since.discarded,
            retained_scalars: self.retained_scalars,
        }
    }

    /// Field-wise sum of two snapshots, for aggregating the per-worker
    /// pools the GEMM thread pool installs into one probe-able view
    /// (fold over `backend::threadpool::worker_pool_stats()` starting
    /// from `PoolStats::default()`); the cross-worker zero-alloc probe
    /// in `tests/pool_and_kernel.rs` asserts on the merged delta.
    pub fn merge(&self, other: &PoolStats) -> PoolStats {
        PoolStats {
            fresh_allocs: self.fresh_allocs + other.fresh_allocs,
            reuses: self.reuses + other.reuses,
            recycled: self.recycled + other.recycled,
            discarded: self.discarded + other.discarded,
            retained_scalars: self.retained_scalars + other.retained_scalars,
        }
    }
}

struct PoolInner {
    shelves: Mutex<Shelves>,
    enabled: AtomicBool,
    fresh_allocs: AtomicU64,
    reuses: AtomicU64,
    recycled: AtomicU64,
    discarded: AtomicU64,
}

impl PoolInner {
    fn new() -> Self {
        PoolInner {
            shelves: Mutex::new(Shelves::default()),
            enabled: AtomicBool::new(true),
            fresh_allocs: AtomicU64::new(0),
            reuses: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
            discarded: AtomicU64::new(0),
        }
    }

    fn acquire(this: &Arc<PoolInner>, len: usize) -> PoolVec {
        if len > 0 && this.enabled.load(Ordering::Relaxed) {
            let reused = this.shelves.lock().expect("pool lock").take(len);
            if let Some(buf) = reused {
                this.reuses.fetch_add(1, Ordering::Relaxed);
                return PoolVec { data: buf, home: Arc::clone(this) };
            }
        }
        if len > 0 {
            this.fresh_allocs.fetch_add(1, Ordering::Relaxed);
        }
        PoolVec { data: vec![0.0; len], home: Arc::clone(this) }
    }

    fn release(&self, data: Vec<f32>) {
        let len = data.len();
        // Only shelve exact-capacity buffers: `acquire(len)` hands out
        // whatever sits in bucket `len`, so capacity must equal length.
        if len == 0 || !self.enabled.load(Ordering::Relaxed) || data.capacity() != len {
            self.discarded.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let shelved = self.shelves.lock().expect("pool lock").try_shelve(data);
        if shelved {
            self.recycled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.discarded.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn stats(&self) -> PoolStats {
        let retained = self.shelves.lock().expect("pool lock").retained_scalars;
        PoolStats {
            fresh_allocs: self.fresh_allocs.load(Ordering::Relaxed),
            reuses: self.reuses.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
            discarded: self.discarded.load(Ordering::Relaxed),
            retained_scalars: retained,
        }
    }
}

static GLOBAL: OnceLock<Arc<PoolInner>> = OnceLock::new();

thread_local! {
    /// Stack of scoped pools; the innermost serves this thread's
    /// acquires (see `PoolScope`).
    static SCOPED: RefCell<Vec<Arc<PoolInner>>> = const { RefCell::new(Vec::new()) };
}

fn global_inner() -> Arc<PoolInner> {
    Arc::clone(GLOBAL.get_or_init(|| Arc::new(PoolInner::new())))
}

fn current_inner() -> Arc<PoolInner> {
    SCOPED
        .with(|s| s.borrow().last().cloned())
        .unwrap_or_else(global_inner)
}

/// Handle to a buffer pool (the process-global one, or a scoped one).
#[derive(Clone)]
pub struct TensorPool {
    inner: Arc<PoolInner>,
}

impl TensorPool {
    /// The pool serving the current thread (scoped pool if one is
    /// installed, else the process-global pool).
    pub fn current() -> TensorPool {
        TensorPool { inner: current_inner() }
    }

    /// The process-global pool.
    pub fn global() -> TensorPool {
        TensorPool { inner: global_inner() }
    }

    /// Lease a buffer of exactly `len` scalars. Contents are
    /// ARBITRARY (recycled buffers keep old data) — the caller must
    /// fully overwrite, or use `acquire_zeroed`.
    pub fn acquire(&self, len: usize) -> PoolVec {
        PoolInner::acquire(&self.inner, len)
    }

    /// Lease a buffer of `len` zeros.
    pub fn acquire_zeroed(&self, len: usize) -> PoolVec {
        let mut b = PoolInner::acquire(&self.inner, len);
        b.data.fill(0.0);
        b
    }

    /// Wrap an externally-allocated vec so it recycles into this pool
    /// on drop (exact-capacity vecs only; others are freed normally).
    pub fn adopt(&self, data: Vec<f32>) -> PoolVec {
        PoolVec { data, home: Arc::clone(&self.inner) }
    }

    /// Snapshot the pool's counters.
    pub fn stats(&self) -> PoolStats {
        self.inner.stats()
    }

    /// Turn recycling on/off (off: every acquire allocates fresh and
    /// every drop frees — the "before" configuration for benches).
    pub fn set_enabled(&self, enabled: bool) {
        self.inner.enabled.store(enabled, Ordering::Relaxed);
        if !enabled {
            // Flush shelves so disabled means "no pool memory held".
            let mut sh = self.inner.shelves.lock().expect("pool lock");
            sh.by_len.clear();
            sh.retained_scalars = 0;
        }
    }
}

/// Convenience: lease from the current pool.
pub fn acquire(len: usize) -> PoolVec {
    TensorPool::current().acquire(len)
}

/// Convenience: lease zeros from the current pool.
pub fn acquire_zeroed(len: usize) -> PoolVec {
    TensorPool::current().acquire_zeroed(len)
}

/// Convenience: adopt a vec into the current pool.
pub fn adopt(data: Vec<f32>) -> PoolVec {
    TensorPool::current().adopt(data)
}

/// Installs a fresh private pool for the current thread; restores the
/// previous pool on drop. Lets tests assert on counters without
/// interference from parallel test threads.
///
/// ```
/// use pipestale::pool::PoolScope;
/// let scope = PoolScope::new();
/// let pool = scope.pool().clone();
/// drop(pool.acquire(64));
/// let _again = pool.acquire(64); // served from the shelf
/// assert_eq!(pool.stats().reuses, 1);
/// ```
pub struct PoolScope {
    pool: TensorPool,
}

impl PoolScope {
    /// Install a fresh private pool for the current thread.
    #[allow(clippy::new_without_default)]
    pub fn new() -> PoolScope {
        let inner = Arc::new(PoolInner::new());
        SCOPED.with(|s| s.borrow_mut().push(Arc::clone(&inner)));
        PoolScope { pool: TensorPool { inner } }
    }

    /// The scope's pool handle (clone it to outlive the scope).
    pub fn pool(&self) -> &TensorPool {
        &self.pool
    }
}

impl Drop for PoolScope {
    fn drop(&mut self) {
        SCOPED.with(|s| {
            s.borrow_mut().pop();
        });
    }
}

/// An f32 buffer leased from a pool; returns home when dropped.
pub struct PoolVec {
    data: Vec<f32>,
    home: Arc<PoolInner>,
}

impl PoolVec {
    /// Read-only view of the buffer.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable view of the buffer.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Number of scalars in the buffer.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for a zero-length lease.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

impl Deref for PoolVec {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl DerefMut for PoolVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

impl Clone for PoolVec {
    fn clone(&self) -> PoolVec {
        let mut fresh = PoolInner::acquire(&self.home, self.data.len());
        fresh.data.copy_from_slice(&self.data);
        fresh
    }
}

impl Drop for PoolVec {
    fn drop(&mut self) {
        let data = std::mem::take(&mut self.data);
        self.home.release(data);
    }
}

impl std::fmt::Debug for PoolVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.data.iter()).finish()
    }
}

/// Shared, cheaply-clonable tensor storage with copy-on-write mutation.
#[derive(Clone, Debug)]
pub struct Storage {
    buf: Arc<PoolVec>,
}

impl Storage {
    /// Wrap a pool lease as shared storage.
    pub fn from_pool_vec(buf: PoolVec) -> Storage {
        Storage { buf: Arc::new(buf) }
    }

    /// Read-only view of the elements.
    pub fn as_slice(&self) -> &[f32] {
        &self.buf
    }

    /// Number of scalars stored.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True for zero-length storage.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// True if both handles view the same buffer (fast equality path).
    pub fn ptr_eq(&self, other: &Storage) -> bool {
        Arc::ptr_eq(&self.buf, &other.buf)
    }

    /// Mutable view: in place when uniquely owned, copy-on-write into a
    /// fresh pooled buffer when shared.
    pub fn make_mut(&mut self) -> &mut [f32] {
        if Arc::get_mut(&mut self.buf).is_none() {
            self.buf = Arc::new((*self.buf).clone());
        }
        Arc::get_mut(&mut self.buf)
            .expect("storage unique after copy-on-write")
            .as_mut_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_by_size_class() {
        let scope = PoolScope::new();
        let pool = scope.pool().clone();
        let a = pool.acquire(128);
        drop(a);
        let b = pool.acquire(128);
        let st = pool.stats();
        assert_eq!(st.fresh_allocs, 1, "{st:?}");
        assert_eq!(st.reuses, 1, "{st:?}");
        assert_eq!(st.recycled, 1, "{st:?}");
        drop(b);
        // different size class -> fresh allocation
        let _c = pool.acquire(64);
        assert_eq!(pool.stats().fresh_allocs, 2);
    }

    #[test]
    fn acquire_zeroed_always_zeroes() {
        let scope = PoolScope::new();
        let pool = scope.pool().clone();
        let mut a = pool.acquire(16);
        a.as_mut_slice().fill(7.5);
        drop(a);
        let b = pool.acquire_zeroed(16);
        assert!(b.iter().all(|&v| v == 0.0));
        assert_eq!(pool.stats().reuses, 1, "must reuse the dirtied buffer");
    }

    #[test]
    fn adopt_recycles_exact_capacity_only() {
        let scope = PoolScope::new();
        let pool = scope.pool().clone();
        drop(pool.adopt(vec![1.0; 8]));
        assert_eq!(pool.stats().recycled, 1);
        // over-capacity vec is freed, not shelved
        let mut v = Vec::with_capacity(100);
        v.extend_from_slice(&[0.0; 8]);
        drop(pool.adopt(v));
        assert_eq!(pool.stats().recycled, 1);
        assert_eq!(pool.stats().discarded, 1);
    }

    #[test]
    fn disabled_pool_never_shelves() {
        let scope = PoolScope::new();
        let pool = scope.pool().clone();
        pool.set_enabled(false);
        drop(pool.acquire(32));
        drop(pool.acquire(32));
        let st = pool.stats();
        assert_eq!(st.fresh_allocs, 2);
        assert_eq!(st.reuses, 0);
        assert_eq!(st.retained_scalars, 0);
    }

    #[test]
    fn per_class_cap_bounds_memory() {
        let scope = PoolScope::new();
        let pool = scope.pool().clone();
        let bufs: Vec<PoolVec> = (0..MAX_BUFS_PER_CLASS + 5).map(|_| pool.acquire(4)).collect();
        drop(bufs);
        let st = pool.stats();
        assert_eq!(st.recycled, MAX_BUFS_PER_CLASS as u64);
        assert_eq!(st.discarded, 5);
        assert_eq!(st.retained_scalars, 4 * MAX_BUFS_PER_CLASS as u64);
    }

    #[test]
    fn scope_isolates_and_restores() {
        // Outer scope shields this test from the global pool (which
        // other test threads share); the inner scope nests on top.
        let _outer_scope = PoolScope::new();
        let outer = TensorPool::current();
        let outer_allocs = outer.stats().fresh_allocs;
        {
            let scope = PoolScope::new();
            let _x = acquire(8); // routed to the innermost scoped pool
            assert_eq!(scope.pool().stats().fresh_allocs, 1);
        }
        assert_eq!(outer.stats().fresh_allocs, outer_allocs);
        let _y = acquire(8); // back to the outer scope's pool
        assert_eq!(outer.stats().fresh_allocs, outer_allocs + 1);
    }

    #[test]
    fn buffers_return_to_their_home_pool() {
        let scope = PoolScope::new();
        let pool = scope.pool().clone();
        let buf = pool.acquire(12);
        drop(scope); // scope ends while the lease is live
        drop(buf); // must return to its issuing pool, not the global one
        assert_eq!(pool.stats().recycled, 1);
    }

    #[test]
    fn storage_cow_copies_only_when_shared() {
        let scope = PoolScope::new();
        let pool = scope.pool().clone();
        let mut a = Storage::from_pool_vec(pool.acquire_zeroed(4));
        let before = pool.stats().fresh_allocs;
        a.make_mut()[0] = 1.0; // unique: in place
        assert_eq!(pool.stats().fresh_allocs, before);
        let b = a.clone();
        assert!(a.ptr_eq(&b));
        a.make_mut()[1] = 2.0; // shared: copy-on-write
        assert!(!a.ptr_eq(&b));
        assert_eq!(b.as_slice(), &[1.0, 0.0, 0.0, 0.0]);
        assert_eq!(a.as_slice(), &[1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn hit_rate_reflects_reuse() {
        let scope = PoolScope::new();
        let pool = scope.pool().clone();
        for _ in 0..10 {
            drop(pool.acquire(256));
        }
        let st = pool.stats();
        assert_eq!(st.fresh_allocs, 1);
        assert_eq!(st.reuses, 9);
        assert!(st.hit_rate() > 0.89);
    }
}
