//! Profile-guided per-block cost model (the PipeDream recipe, §PAPERS).
//!
//! The paper picks pipeline partition vectors by hand and observes that
//! throughput is governed by the slowest stage. PipeDream (arXiv
//! 1806.03377) made the obvious next step the headline: *profile* each
//! layer's compute, then *solve* for the cuts that minimize the
//! bottleneck stage. This module is the profiling half of that recipe
//! for the native backend:
//!
//! * [`CostProfile::analytic`] prices each paper-numbered block from
//!   the recorded per-layer FLOPs accounting (`meta.json` /
//!   `native_config`), with the canonical
//!   [`BWD_FLOPS_FACTOR`](crate::backend::BWD_FLOPS_FACTOR) backward
//!   ratio. It is pure arithmetic — bitwise deterministic — and is the
//!   *only* cost model `--partition auto` uses at train time, so an
//!   auto-partitioned run stays reproducible run-to-run.
//! * [`CostProfile::measure`] times each block's forward+backward on
//!   the real native kernels (warmup + median-of-K, deterministic
//!   iteration order and inputs), by synthesizing a full-register
//!   variant of the config — one partition per block — through
//!   [`native_config_with_ppv`]. Wall-clock numbers feed the perfsim
//!   CLI and the partition bench, never the training path.
//!
//! Either profile serializes to `results/profile_<config>.json`
//! ([`CostProfile::save`]) and converts into solver inputs
//! ([`CostProfile::block_totals`]) or per-stage cost vectors for a
//! given PPV ([`CostProfile::stage_costs`]). [`auto_native_meta`] is
//! the one-call entry point `--partition auto` uses: analytic profile →
//! [`solve_partition`] at the manifest's stage count → full
//! [`ConfigMeta`] synthesis through the same bounds machinery as the
//! hand-tabulated PPVs.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::backend::{native_config, native_config_with_ppv, NativePartition, BWD_FLOPS_FACTOR};
use crate::meta::ConfigMeta;
use crate::model::ModelParams;
use crate::pipeline::perfsim::{solve_partition, PartitionSolution, StageCosts};
use crate::tensor::{IntTensor, Tensor};
use crate::util::json::{self, Json};

/// Reference accelerator throughput for the analytic profile, FLOP/s.
///
/// The bottleneck-minimizing cut is *scale-invariant*: multiplying
/// every block cost by a constant does not move the argmin, so the
/// specific value only affects the human-readable seconds in reports,
/// never the chosen PPV. 50 GFLOP/s matches the perfsim CLI default.
pub const REFERENCE_FLOPS_PER_S: f64 = 50e9;

/// Measured or modeled cost of one paper-numbered model block (layer).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockCost {
    /// 1-based paper layer index — the PPV cut numbering.
    pub layer: usize,
    /// Layer name from the model IR (`l1`, `l2`, ...).
    pub name: String,
    /// Forward seconds per mini-batch.
    pub fwd_seconds: f64,
    /// Backward seconds per mini-batch (carry-in recompute + gradient
    /// walk + update, the native backend's delayed-backward shape).
    pub bwd_seconds: f64,
    /// Analytic forward FLOPs per sample, from the op accounting.
    pub flops_per_sample: u64,
    /// Bytes of the block's output carry for one mini-batch — the
    /// register traffic a cut after this block would cost.
    pub carry_bytes: f64,
}

impl BlockCost {
    /// fwd+bwd seconds: the block's contribution to a paired-mapping
    /// stage, and the solver's per-block cost.
    pub fn total_seconds(&self) -> f64 {
        self.fwd_seconds + self.bwd_seconds
    }
}

/// A per-block cost profile of one config: the partition solver's input
/// and the payload of `results/profile_<config>.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct CostProfile {
    /// Config name the profile describes.
    pub config: String,
    /// Model name (for report readers; not used by the solver).
    pub model: String,
    /// Mini-batch size the costs are priced at.
    pub batch: usize,
    /// `"analytic"` (FLOPs model) or `"measured"` (wall-clock on the
    /// native kernels).
    pub source: String,
    /// One entry per paper layer, in layer order.
    pub blocks: Vec<BlockCost>,
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    xs[xs.len() / 2]
}

impl CostProfile {
    /// Price every block from the recorded FLOPs accounting: fwd =
    /// `flops × batch / flops_per_s`, bwd = [`BWD_FLOPS_FACTOR`] × fwd.
    /// Works for any `ConfigMeta` with per-layer metadata (native or
    /// artifact-loaded) — no kernels run, so the result is bitwise
    /// deterministic and safe for the training path.
    pub fn analytic(meta: &ConfigMeta, flops_per_s: f64) -> Result<CostProfile> {
        ensure!(flops_per_s > 0.0, "flops_per_s must be positive, got {flops_per_s}");
        ensure!(
            meta.layers.len() == meta.num_layers,
            "{}: per-layer metadata incomplete ({} of {} layers)",
            meta.config,
            meta.layers.len(),
            meta.num_layers
        );
        let batch = meta.batch as f64;
        let blocks = meta
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let fwd = l.flops_per_sample as f64 * batch / flops_per_s;
                BlockCost {
                    layer: i + 1,
                    name: l.name.clone(),
                    fwd_seconds: fwd,
                    bwd_seconds: BWD_FLOPS_FACTOR * fwd,
                    flops_per_sample: l.flops_per_sample,
                    carry_bytes: l.carry_elems_per_sample as f64 * batch * 4.0,
                }
            })
            .collect();
        Ok(CostProfile {
            config: meta.config.clone(),
            model: meta.model.clone(),
            batch: meta.batch,
            source: "analytic".into(),
            blocks,
        })
    }

    /// Time every block's fwd+bwd on the real native kernels: `warmup`
    /// untimed iterations then the median of `reps` timed ones, per
    /// block, in deterministic layer order with deterministic inputs
    /// (all-ones carries, all-zero labels, seeded weights).
    ///
    /// Implemented by synthesizing the config's *full-register* variant
    /// — PPV `(1, 2, …, L-1)`, one partition per block — through
    /// [`native_config_with_ppv`], so each block is a complete
    /// [`NativePartition`] timed in isolation, cuts land on block edges
    /// by construction, and the fused last block is split by the
    /// bench's 1/3 fwd + 2/3 bwd convention. Native built-ins only;
    /// wall-clock numbers are for perfsim/bench reporting, not the
    /// (determinism-bound) training path.
    pub fn measure(config: &str, warmup: usize, reps: usize) -> Result<CostProfile> {
        ensure!(reps >= 1, "need at least one timing rep");
        let manifest_meta = native_config(config)?;
        let num_layers = manifest_meta.num_layers;
        let full_ppv: Vec<usize> = (1..num_layers).collect();
        let meta = native_config_with_ppv(config, Some(&full_ppv))?;
        let params = ModelParams::init(&meta.partitions, 0xb10c)?;
        let optims = crate::train::build_optims(&meta, 1, 1.0);
        let labels = IntTensor::from_vec(&[meta.batch], vec![0i32; meta.batch])?;

        let mut blocks = Vec::with_capacity(num_layers);
        for ((idx, part), optim) in params.partitions.into_iter().enumerate().zip(optims) {
            let pm = &meta.partitions[idx];
            let mut stage = NativePartition::for_partition(&meta, idx, part, optim)?;
            let carry: Vec<Tensor> =
                pm.carry_in.iter().map(|s| Tensor::ones(s)).collect();
            let is_last = idx == num_layers - 1;
            let (fwd_seconds, bwd_seconds) = if is_last {
                let mut time_last = || -> Result<f64> {
                    let t0 = Instant::now();
                    stage.stage_last(&carry, &labels)?;
                    Ok(t0.elapsed().as_secs_f64())
                };
                for _ in 0..warmup {
                    time_last()?;
                }
                let dt = median((0..reps).map(|_| time_last()).collect::<Result<_>>()?);
                (dt / 3.0, 2.0 * dt / 3.0)
            } else {
                let gcarry: Vec<Tensor> =
                    pm.carry_out.iter().map(|s| Tensor::ones(s)).collect();
                let mut time_fwd = || -> Result<f64> {
                    let t0 = Instant::now();
                    stage.stage_forward(&carry)?;
                    Ok(t0.elapsed().as_secs_f64())
                };
                for _ in 0..warmup {
                    time_fwd()?;
                }
                let tf = median((0..reps).map(|_| time_fwd()).collect::<Result<_>>()?);
                let mut time_bwd = || -> Result<f64> {
                    let t0 = Instant::now();
                    stage.stage_backward(&carry, &gcarry)?;
                    Ok(t0.elapsed().as_secs_f64())
                };
                for _ in 0..warmup {
                    time_bwd()?;
                }
                let tb = median((0..reps).map(|_| time_bwd()).collect::<Result<_>>()?);
                (tf, tb)
            };
            let l = &meta.layers[idx];
            blocks.push(BlockCost {
                layer: idx + 1,
                name: l.name.clone(),
                fwd_seconds,
                bwd_seconds,
                flops_per_sample: l.flops_per_sample,
                carry_bytes: l.carry_elems_per_sample as f64 * meta.batch as f64 * 4.0,
            });
        }
        Ok(CostProfile {
            config: config.to_string(),
            model: meta.model,
            batch: meta.batch,
            source: "measured".into(),
            blocks,
        })
    }

    /// Per-block fwd+bwd seconds in layer order — the
    /// [`solve_partition`] input array.
    pub fn block_totals(&self) -> Vec<f64> {
        self.blocks.iter().map(BlockCost::total_seconds).collect()
    }

    /// Solve the bottleneck-minimizing `p`-stage cut over this profile.
    pub fn solve(&self, p: usize) -> Result<PartitionSolution> {
        solve_partition(&self.block_totals(), p)
    }

    /// Aggregate the per-block costs into perfsim [`StageCosts`] under
    /// a PPV (manual or solved): per-stage fwd/bwd sums plus the
    /// register edge bytes of each cut.
    pub fn stage_costs(&self, ppv: &[usize]) -> Result<StageCosts> {
        let n = self.blocks.len();
        ensure!(n >= 1, "profile for {} has no blocks", self.config);
        ensure!(
            ppv.windows(2).all(|w| w[0] < w[1]) && ppv.iter().all(|&c| c >= 1 && c < n),
            "PPV {ppv:?} invalid for {n} blocks"
        );
        let mut bounds = vec![0usize];
        bounds.extend_from_slice(ppv);
        bounds.push(n);
        let mut fwd = Vec::with_capacity(ppv.len() + 1);
        let mut bwd = Vec::with_capacity(ppv.len() + 1);
        for w in bounds.windows(2) {
            fwd.push(self.blocks[w[0]..w[1]].iter().map(|b| b.fwd_seconds).sum());
            bwd.push(self.blocks[w[0]..w[1]].iter().map(|b| b.bwd_seconds).sum());
        }
        let edge_bytes = ppv.iter().map(|&c| self.blocks[c - 1].carry_bytes).collect();
        Ok(StageCosts { fwd, bwd, edge_bytes })
    }

    /// Serialize to the `pipestale/profile/v1` JSON document.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", json::s("pipestale/profile/v1")),
            ("config", json::s(&self.config)),
            ("model", json::s(&self.model)),
            ("batch", json::num(self.batch as f64)),
            ("source", json::s(&self.source)),
            (
                "blocks",
                json::arr(self.blocks.iter().map(|b| {
                    json::obj(vec![
                        ("layer", json::num(b.layer as f64)),
                        ("name", json::s(&b.name)),
                        ("fwd_seconds", json::num(b.fwd_seconds)),
                        ("bwd_seconds", json::num(b.bwd_seconds)),
                        ("flops_per_sample", json::num(b.flops_per_sample as f64)),
                        ("carry_bytes", json::num(b.carry_bytes)),
                    ])
                })),
            ),
        ])
    }

    /// Parse a `pipestale/profile/v1` document written by [`Self::to_json`].
    pub fn from_json(j: &Json) -> Result<CostProfile> {
        let field = |k: &str| j.get(k).ok_or_else(|| anyhow!("profile JSON missing {k:?}"));
        let schema = field("schema")?.as_str().unwrap_or_default();
        ensure!(schema == "pipestale/profile/v1", "unsupported profile schema {schema:?}");
        let mut blocks = Vec::new();
        for (i, bj) in field("blocks")?.as_arr().unwrap_or_default().iter().enumerate() {
            let bfield = |k: &str| {
                bj.get(k).ok_or_else(|| anyhow!("profile block {i} missing {k:?}"))
            };
            blocks.push(BlockCost {
                layer: bfield("layer")?.as_usize().unwrap_or_default(),
                name: bfield("name")?.as_str().unwrap_or_default().to_string(),
                fwd_seconds: bfield("fwd_seconds")?.as_f64().unwrap_or_default(),
                bwd_seconds: bfield("bwd_seconds")?.as_f64().unwrap_or_default(),
                flops_per_sample: bfield("flops_per_sample")?.as_f64().unwrap_or_default()
                    as u64,
                carry_bytes: bfield("carry_bytes")?.as_f64().unwrap_or_default(),
            });
        }
        Ok(CostProfile {
            config: field("config")?.as_str().unwrap_or_default().to_string(),
            model: field("model")?.as_str().unwrap_or_default().to_string(),
            batch: field("batch")?.as_usize().unwrap_or_default(),
            source: field("source")?.as_str().unwrap_or_default().to_string(),
            blocks,
        })
    }

    /// Write the profile to `results/profile_<config>.json` (under
    /// [`crate::results_root`]); returns the path written.
    pub fn save(&self) -> Result<PathBuf> {
        let path = crate::results_root().join(format!("profile_{}.json", self.config));
        std::fs::write(&path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }
}

/// `--partition auto` for a native built-in config: analytic per-block
/// profile → bottleneck-minimizing solve at the *manifest's* stage
/// count (same P, rebalanced cuts — which keeps every auto-vs-manual
/// comparison apples-to-apples and the worker topology unchanged) →
/// full [`ConfigMeta`] synthesis through [`native_config_with_ppv`], so
/// `partition_nodes` cross-validation, memory accounting and
/// checkpointing consume the result exactly like a manual config.
///
/// Deliberately analytic-only: wall-clock profiling at train time would
/// make the chosen PPV — and with it the entire run — machine- and
/// noise-dependent, breaking the bitwise run-to-run determinism the
/// pipeline guarantees. Errors cleanly (via [`native_config`]) when the
/// config is not a native built-in.
pub fn auto_native_meta(config: &str) -> Result<(ConfigMeta, PartitionSolution)> {
    let manual = native_config(config)?;
    let profile = CostProfile::analytic(&manual, REFERENCE_FLOPS_PER_S)?;
    let p = manual.partitions.len();
    if p == 0 {
        bail!("{config}: cannot auto-partition a config with no partitions");
    }
    let sol = profile.solve(p)?;
    let meta = if sol.ppv == manual.ppv {
        manual
    } else {
        native_config_with_ppv(config, Some(&sol.ppv))?
    };
    Ok((meta, sol))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::partition_nodes;
    use crate::pipeline::perfsim::stage_costs_of;

    #[test]
    fn analytic_profile_matches_flops_accounting() {
        let meta = native_config("native_lenet_small_4s").unwrap();
        let prof = CostProfile::analytic(&meta, REFERENCE_FLOPS_PER_S).unwrap();
        assert_eq!(prof.blocks.len(), meta.num_layers);
        assert_eq!(prof.source, "analytic");
        for (i, b) in prof.blocks.iter().enumerate() {
            assert_eq!(b.layer, i + 1);
            assert_eq!(b.flops_per_sample, meta.layers[i].flops_per_sample);
            let expect = b.flops_per_sample as f64 * meta.batch as f64 / REFERENCE_FLOPS_PER_S;
            assert!((b.fwd_seconds - expect).abs() < 1e-15, "block {i}");
            assert!((b.bwd_seconds - BWD_FLOPS_FACTOR * b.fwd_seconds).abs() < 1e-15);
        }
        // Stage costs under the manifest PPV agree with analytic_costs.
        let sc = prof.stage_costs(&meta.ppv).unwrap();
        let reference = crate::pipeline::perfsim::analytic_costs(&meta, REFERENCE_FLOPS_PER_S);
        for (a, b) in sc.fwd.iter().zip(&reference.fwd) {
            assert!((a - b).abs() < 1e-15);
        }
        for (a, b) in sc.edge_bytes.iter().zip(&reference.edge_bytes) {
            assert!((a - b).abs() < 1e-9);
        }
        // Bad PPVs are rejected.
        assert!(prof.stage_costs(&[0]).is_err());
        assert!(prof.stage_costs(&[meta.num_layers]).is_err());
        assert!(prof.stage_costs(&[2, 2]).is_err());
    }

    #[test]
    fn profile_json_roundtrip() {
        let meta = native_config("quickstart_lenet").unwrap();
        let prof = CostProfile::analytic(&meta, 1e9).unwrap();
        let back = CostProfile::from_json(&Json::parse(&prof.to_json().to_string_pretty())
            .unwrap())
        .unwrap();
        assert_eq!(prof, back);
        // Wrong schema tag fails.
        assert!(CostProfile::from_json(&Json::parse("{\"schema\": \"nope\"}").unwrap()).is_err());
    }

    #[test]
    fn measured_profile_runs_real_kernels_per_block() {
        let prof = CostProfile::measure("native_lenet_small", 1, 3).unwrap();
        let meta = native_config("native_lenet_small").unwrap();
        assert_eq!(prof.source, "measured");
        assert_eq!(prof.blocks.len(), meta.num_layers);
        for b in &prof.blocks {
            assert!(b.fwd_seconds > 0.0 && b.fwd_seconds.is_finite(), "{b:?}");
            assert!(b.bwd_seconds > 0.0 && b.bwd_seconds.is_finite(), "{b:?}");
        }
        // Unknown configs error cleanly.
        assert!(CostProfile::measure("no_such_config", 0, 1).is_err());
    }

    #[test]
    fn auto_native_meta_is_deterministic_and_no_worse_than_manual() {
        for config in ["native_resnet20_4s", "native_lenet_small_4s", "lenet5_8s"] {
            let manual = native_config(config).unwrap();
            let (meta, sol) = auto_native_meta(config).unwrap();
            // Deterministic: solving again picks the identical PPV.
            let (meta2, sol2) = auto_native_meta(config).unwrap();
            assert_eq!(sol.ppv, sol2.ppv, "{config}");
            assert_eq!(meta.ppv, meta2.ppv, "{config}");
            // Same stage count as the manifest, full contract intact.
            assert_eq!(meta.partitions.len(), manual.partitions.len(), "{config}");
            for part in &meta.partitions {
                partition_nodes(&meta, part).unwrap();
            }
            // The solved bottleneck never exceeds the hand-tabulated
            // PPV's under the same cost model (the acceptance bar).
            let prof = CostProfile::analytic(&manual, REFERENCE_FLOPS_PER_S).unwrap();
            let totals = prof.block_totals();
            let manual_bn = stage_costs_of(&totals, &manual.ppv)
                .into_iter()
                .fold(0.0f64, f64::max);
            assert!(
                sol.bottleneck <= manual_bn + 1e-15,
                "{config}: auto {} > manual {manual_bn}",
                sol.bottleneck
            );
        }
    }
}
