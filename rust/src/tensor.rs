//! Host-side tensors and conversions to/from `xla::Literal`.
//!
//! The coordinator's authoritative copies of weights, optimizer state,
//! activations and gradients are host tensors; stage programs consume and
//! produce PJRT literals. Conversions are the FFI boundary and are
//! profiled in the §Perf pass.

use anyhow::{bail, Context, Result};

/// Dense f32 tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![1.0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        if numel(shape) != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel(shape), data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn scalar(&self) -> f32 {
        debug_assert_eq!(self.numel(), 1);
        self.data[0]
    }

    /// L2 norm (metrics / debugging).
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data)
            .reshape(&dims)
            .context("reshape literal")
    }

    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Self> {
        let data = lit.to_vec::<f32>().context("literal -> f32 vec")?;
        Tensor::from_vec(shape, data)
    }
}

/// Dense i32 tensor (labels, seeds).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        if numel(shape) != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel(shape), data.len());
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data)
            .reshape(&dims)
            .context("reshape literal")
    }
}

/// Scalar i32 literal (the per-batch dropout seed).
pub fn seed_literal(seed: i32) -> xla::Literal {
    xla::Literal::scalar(seed)
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_numel() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
        assert!(IntTensor::from_vec(&[2], vec![1, 2]).is_ok());
    }

    #[test]
    fn norm_and_finite() {
        let t = Tensor::from_vec(&[4], vec![3.0, 4.0, 0.0, 0.0]).unwrap();
        assert!((t.norm() - 5.0).abs() < 1e-6);
        assert!(t.is_finite());
        let bad = Tensor::from_vec(&[1], vec![f32::NAN]).unwrap();
        assert!(!bad.is_finite());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit, &[2, 3]).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = IntTensor::from_vec(&[4], vec![7, -1, 0, 3]).unwrap();
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, -1, 0, 3]);
    }

    #[test]
    fn scalar_seed() {
        let lit = seed_literal(42);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![42]);
    }
}
