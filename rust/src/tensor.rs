//! Host-side tensors and conversions to/from `xla::Literal`.
//!
//! The coordinator's authoritative copies of weights, optimizer state,
//! activations and gradients are host tensors; stage programs consume and
//! produce PJRT literals. Conversions are the FFI boundary and are
//! profiled in the §Perf pass.
//!
//! Since the zero-copy data plane (DESIGN.md §Perf):
//! * backing stores are pooled (`crate::pool`): construction after
//!   warmup reuses recycled buffers instead of allocating;
//! * storage is shared (`Arc`-based): `Tensor::clone` is a refcount
//!   bump, mutation via `data_mut` is copy-on-write;
//! * shapes are inline (`Shape`, max rank 8): cloning a tensor touches
//!   no heap at all;
//! * `to_literal`/`from_literal` are single-copy (no intermediate
//!   rank-1 literal, no fresh `Vec` per conversion).

use anyhow::{bail, Context, Result};

use crate::pool::{self, PoolVec, Storage};

/// Maximum tensor rank (matches the checkpoint format's sanity bound).
pub const MAX_RANK: usize = 8;

/// Inline tensor shape: no heap allocation, `Copy`, derefs to `[usize]`.
#[derive(Clone, Copy)]
pub struct Shape {
    dims: [usize; MAX_RANK],
    rank: u8,
}

impl Shape {
    /// Build from a slice. Panics on rank > `MAX_RANK` (no real network
    /// comes close; fallible construction goes through
    /// `Tensor::from_vec`, which checks first).
    pub fn from_slice(dims: &[usize]) -> Shape {
        assert!(
            dims.len() <= MAX_RANK,
            "tensor rank {} exceeds MAX_RANK {}",
            dims.len(),
            MAX_RANK
        );
        let mut s = Shape { dims: [0; MAX_RANK], rank: dims.len() as u8 };
        s.dims[..dims.len()].copy_from_slice(dims);
        s
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.dims[..self.rank as usize]
    }

    pub fn rank(&self) -> usize {
        self.rank as usize
    }

    pub fn numel(&self) -> usize {
        self.as_slice().iter().product()
    }

    /// Dims as i64 for literal APIs, in a stack buffer.
    fn dims_i64(&self) -> ([i64; MAX_RANK], usize) {
        let mut out = [0i64; MAX_RANK];
        for (o, &d) in out.iter_mut().zip(self.as_slice()) {
            *o = d as i64;
        }
        (out, self.rank())
    }
}

impl std::ops::Deref for Shape {
    type Target = [usize];

    fn deref(&self) -> &[usize] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl PartialEq for Shape {
    fn eq(&self, other: &Shape) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Shape {}

impl PartialEq<Vec<usize>> for Shape {
    fn eq(&self, other: &Vec<usize>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Shape> for Vec<usize> {
    fn eq(&self, other: &Shape) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<&[usize]> for Shape {
    fn eq(&self, other: &&[usize]) -> bool {
        self.as_slice() == *other
    }
}

impl From<&[usize]> for Shape {
    fn from(dims: &[usize]) -> Shape {
        Shape::from_slice(dims)
    }
}

/// Dense f32 tensor (row-major) over pooled, shared storage.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Shape,
    data: Storage,
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape
            && (self.data.ptr_eq(&other.data) || self.data.as_slice() == other.data.as_slice())
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let s = Shape::from_slice(shape);
        Tensor { shape: s, data: Storage::from_pool_vec(pool::acquire_zeroed(s.numel())) }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor::filled(shape, 1.0)
    }

    /// Pooled construction with every element set to `v`.
    pub fn filled(shape: &[usize], v: f32) -> Self {
        let s = Shape::from_slice(shape);
        let mut buf = pool::acquire(s.numel());
        buf.as_mut_slice().fill(v);
        Tensor { shape: s, data: Storage::from_pool_vec(buf) }
    }

    /// Adopt an existing vec (it recycles into the pool when the tensor
    /// fully drops, if exactly sized).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        if shape.len() > MAX_RANK {
            bail!("shape {:?} exceeds max rank {}", shape, MAX_RANK);
        }
        if numel(shape) != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel(shape), data.len());
        }
        Ok(Tensor {
            shape: Shape::from_slice(shape),
            data: Storage::from_pool_vec(pool::adopt(data)),
        })
    }

    /// Wrap a pool lease directly (the zero-copy construction path).
    pub fn from_pooled(shape: &[usize], buf: PoolVec) -> Result<Self> {
        if shape.len() > MAX_RANK {
            bail!("shape {:?} exceeds max rank {}", shape, MAX_RANK);
        }
        if numel(shape) != buf.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel(shape), buf.len());
        }
        Ok(Tensor { shape: Shape::from_slice(shape), data: Storage::from_pool_vec(buf) })
    }

    /// Read-only view of the elements.
    pub fn data(&self) -> &[f32] {
        self.data.as_slice()
    }

    /// Mutable view: in place when this tensor is the sole owner,
    /// copy-on-write otherwise.
    pub fn data_mut(&mut self) -> &mut [f32] {
        self.data.make_mut()
    }

    /// True if `other` shares this tensor's backing buffer.
    pub fn shares_storage(&self, other: &Tensor) -> bool {
        self.data.ptr_eq(&other.data)
    }

    /// Same storage, different shape (zero-copy view; numel must match).
    /// The native backend's flatten/unflatten path.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        if shape.len() > MAX_RANK {
            bail!("shape {:?} exceeds max rank {}", shape, MAX_RANK);
        }
        if numel(shape) != self.numel() {
            bail!("cannot reshape {:?} ({} elems) to {:?}", self.shape, self.numel(), shape);
        }
        Ok(Tensor { shape: Shape::from_slice(shape), data: self.data.clone() })
    }

    pub fn numel(&self) -> usize {
        self.shape.numel()
    }

    pub fn scalar(&self) -> f32 {
        debug_assert_eq!(self.numel(), 1);
        self.data()[0]
    }

    /// L2 norm (metrics / debugging).
    pub fn norm(&self) -> f32 {
        self.data().iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn is_finite(&self) -> bool {
        self.data().iter().all(|v| v.is_finite())
    }

    /// Single-copy conversion to a shaped literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (dims, rank) = self.shape.dims_i64();
        xla::Literal::from_f32_and_dims(self.data(), &dims[..rank])
            .context("tensor -> literal")
    }

    /// Single-copy conversion from a literal into pooled storage.
    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Self> {
        let n = numel(shape);
        let src = lit.f32_slice().context("literal -> f32 view")?;
        if src.len() != n {
            bail!("literal has {} elements, shape {:?} wants {}", src.len(), shape, n);
        }
        let mut buf = pool::acquire(n);
        buf.as_mut_slice().copy_from_slice(src);
        Tensor::from_pooled(shape, buf)
    }
}

/// Dense i32 tensor (labels, seeds).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        if numel(shape) != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel(shape), data.len());
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let mut dims = [0i64; MAX_RANK];
        if self.shape.len() > MAX_RANK {
            bail!("shape {:?} exceeds max rank {}", self.shape, MAX_RANK);
        }
        for (o, &d) in dims.iter_mut().zip(&self.shape) {
            *o = d as i64;
        }
        xla::Literal::from_i32_and_dims(&self.data, &dims[..self.shape.len()])
            .context("int tensor -> literal")
    }
}

/// Scalar i32 literal (the per-batch dropout seed).
pub fn seed_literal(seed: i32) -> xla::Literal {
    xla::Literal::scalar(seed)
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_numel() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
        assert!(IntTensor::from_vec(&[2], vec![1, 2]).is_ok());
    }

    #[test]
    fn norm_and_finite() {
        let t = Tensor::from_vec(&[4], vec![3.0, 4.0, 0.0, 0.0]).unwrap();
        assert!((t.norm() - 5.0).abs() < 1e-6);
        assert!(t.is_finite());
        let bad = Tensor::from_vec(&[1], vec![f32::NAN]).unwrap();
        assert!(!bad.is_finite());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.dims(), &[2, 3]);
        let back = Tensor::from_literal(&lit, &[2, 3]).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = IntTensor::from_vec(&[4], vec![7, -1, 0, 3]).unwrap();
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, -1, 0, 3]);
    }

    #[test]
    fn scalar_seed() {
        let lit = seed_literal(42);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![42]);
    }

    #[test]
    fn clone_shares_storage_and_mutation_unshares() {
        let a = Tensor::filled(&[8], 3.0);
        let mut b = a.clone();
        assert!(a.shares_storage(&b));
        assert_eq!(a, b);
        b.data_mut()[0] = -1.0;
        assert!(!a.shares_storage(&b));
        assert_eq!(a.data()[0], 3.0);
        assert_eq!(b.data()[0], -1.0);
    }

    #[test]
    fn shape_compares_with_vecs_and_slices() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape, vec![2, 3]);
        assert!(t.shape == [2usize, 3].as_slice());
        assert_eq!(t.shape.rank(), 2);
        assert_eq!(t.shape.numel(), 6);
        assert_eq!(&t.shape[..], &[2, 3]);
    }

    #[test]
    fn reshape_is_zero_copy_and_checked() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let r = t.reshape(&[6]).unwrap();
        assert_eq!(r.shape, vec![6]);
        assert!(t.shares_storage(&r));
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(&[4]).is_err());
    }

    #[test]
    fn from_literal_rejects_wrong_numel() {
        let t = Tensor::filled(&[4], 1.0);
        let lit = t.to_literal().unwrap();
        assert!(Tensor::from_literal(&lit, &[5]).is_err());
    }
}
