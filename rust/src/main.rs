//! pipestale CLI — leader entrypoint.
//!
//! Subcommands:
//!   train        train one config: --mode pipelined|sequential|hybrid,
//!                orthogonally --backend auto|native|xla (compute),
//!                --runtime scheduler|threaded (how the schedule executes),
//!                --staleness-fix none|stash|predict|correct (mitigation),
//!                and --partition manual|auto (profile-guided PPV);
//!                --data-dir/--augment/--prefetch drive the streaming
//!                ingest path (DESIGN.md §11)
//!   gen-data     write a real-format (IDX / CIFAR-10 binary) fixture
//!                dataset for --data-dir runs without network access
//!   inspect      staleness report for a config (paper §3 accounting)
//!   memory       Table-6-style memory model for a config
//!   perfsim      discrete-event speedup estimate (Table 5 machinery):
//!                --iters, --gflops, --mapping paired|full,
//!                --partition manual|auto, --profile analytic|measured
//!   list-configs enumerate artifact configs + native built-ins

use anyhow::{anyhow, Result};

use pipestale::config::{Backend, Mode, OnFailure, PartitionMode, RunConfig, RuntimeKind};
use pipestale::memory::{
    partition_memory_rows, pipedream_stash_bytes, stash_extra_bytes_total, MemoryReport,
};
use pipestale::meta::ConfigMeta;
use pipestale::pipeline::perfsim::{
    imbalance_ratio, simulate_nonpipelined, simulate_pipelined, stage_totals, CommModel, Mapping,
};
use pipestale::pipeline::{FixKind, StalenessReport};
use pipestale::profile::CostProfile;
use pipestale::util::bench::Table;
use pipestale::util::cli::Command;
use pipestale::util::logging;

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> Result<()> {
    let sub = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    match sub {
        "train" => cmd_train(rest),
        "gen-data" => cmd_gen_data(rest),
        "inspect" => cmd_inspect(rest),
        "memory" => cmd_memory(rest),
        "perfsim" => cmd_perfsim(rest),
        "list-configs" => cmd_list(),
        "help" | "--help" | "-h" => {
            println!(
                "pipestale — pipelined training with stale weights\n\n\
                 SUBCOMMANDS:\n  \
                 train --config <name> [--mode pipelined|sequential|hybrid]\n        \
                 [--backend auto|native|xla] [--runtime scheduler|threaded]\n        \
                 [--staleness-fix none|stash|predict|correct] [--partition manual|auto]\n        \
                 [--data-dir <dir>] [--augment] [--prefetch N] ...\n  \
                 gen-data --dir <dir> [--dataset mnist|cifar10] [--train N] [--test M] [--seed S]\n  \
                 inspect --config <name>\n  \
                 memory --config <name> [--batch N] [--partition manual|auto]\n  \
                 perfsim --config <name> [--iters N] [--gflops G] [--mapping paired|full]\n        \
                 [--partition manual|auto] [--profile analytic|measured] [--save-profile]\n  \
                 list-configs\n\n\
                 Run a subcommand with --help for its options."
            );
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand {other:?}; try `pipestale help`")),
    }
}

fn parse(cmd: Command, args: &[String]) -> Result<pipestale::util::cli::Matches> {
    cmd.parse(args).map_err(|usage| anyhow!("{usage}"))
}

fn cmd_train(args: &[String]) -> Result<()> {
    let m = parse(
        Command::new("pipestale train", "train one artifact config")
            .req("config", "artifact config name (see list-configs)")
            .opt("mode", "pipelined", "pipelined | sequential | hybrid")
            .opt("backend", "auto", "auto | native | xla (native needs no artifacts)")
            .opt("runtime", "scheduler", "scheduler | threaded (thread-per-partition)")
            .opt("iters", "300", "training iterations (mini-batches)")
            .opt("pipelined-iters", "0", "hybrid: pipelined prefix length")
            .opt("seed", "42", "global seed")
            .opt("eval-every", "0", "evaluate every N iters (0 = end only)")
            .opt("train-size", "2048", "synthetic train set size")
            .opt("test-size", "512", "synthetic test set size")
            .opt("noise", "0.6", "synthetic noise level")
            .opt("stale-lr-scale", "1.0", "LR multiplier for stale partitions (Table 7)")
            .opt("data-dir", "", "directory with real MNIST/CIFAR files")
            .flag("augment", "train-time augmentation (pad+crop, flip, normalize)")
            .opt("prefetch", "0", "decode/augment prefetch threads (0 = synchronous)")
            .opt("out", "", "write loss/eval CSVs with this prefix")
            .opt("resume", "", "initialize weights from this checkpoint file or dir")
            .opt("save-checkpoint", "", "write final weights to this path")
            .opt("on-failure", "fail", "fail | restart | degrade (threaded runtime)")
            .opt("max-restarts", "3", "restart budget per segment before giving up")
            .opt("restart-backoff-ms", "250", "base of the capped exponential relaunch backoff")
            .opt("ckpt-every", "0", "rotating checkpoint every N iters (0 = off; needs --ckpt-dir)")
            .opt("ckpt-dir", "", "directory for rotating checkpoints")
            .opt("ckpt-keep", "3", "rotating checkpoints to keep")
            .opt("stall-timeout-ms", "60000", "watchdog: declare a stage hung after this long")
            .opt("fault-plan", "", "inject faults, e.g. 'panic@1:12;stall@2:30:4000;corrupt@0'")
            .opt(
                "staleness-fix",
                "none",
                "none | stash | predict | correct (stale-weight mitigation, DESIGN.md §9)",
            )
            .opt(
                "partition",
                "manual",
                "manual | auto (profile-guided bottleneck-minimizing PPV, DESIGN.md §10)",
            ),
        args,
    )?;
    let mut rc = RunConfig::new(m.get("config"));
    rc.mode = Mode::parse(m.get("mode"))?;
    rc.backend = Backend::parse(m.get("backend"))?;
    rc.runtime = RuntimeKind::parse(m.get("runtime"))?;
    rc.iters = m.get_u64("iters").map_err(|e| anyhow!(e))?;
    rc.pipelined_iters = m.get_u64("pipelined-iters").map_err(|e| anyhow!(e))?;
    rc.seed = m.get_u64("seed").map_err(|e| anyhow!(e))?;
    rc.eval_every = m.get_u64("eval-every").map_err(|e| anyhow!(e))?;
    rc.train_size = m.get_usize("train-size").map_err(|e| anyhow!(e))?;
    rc.test_size = m.get_usize("test-size").map_err(|e| anyhow!(e))?;
    rc.noise = m.get_f64("noise").map_err(|e| anyhow!(e))?;
    rc.stale_lr_scale = m.get_f64("stale-lr-scale").map_err(|e| anyhow!(e))?;
    if !m.get("data-dir").is_empty() {
        rc.data_dir = Some(m.get("data-dir").into());
    }
    rc.augment = m.has("augment");
    rc.prefetch = m.get_usize("prefetch").map_err(|e| anyhow!(e))?;
    if !m.get("resume").is_empty() {
        rc.resume_from = Some(m.get("resume").into());
    }
    if !m.get("save-checkpoint").is_empty() {
        rc.save_to = Some(m.get("save-checkpoint").into());
    }
    rc.on_failure = OnFailure::parse(m.get("on-failure"))?;
    rc.max_restarts = m.get_u64("max-restarts").map_err(|e| anyhow!(e))? as u32;
    rc.restart_backoff_ms = m.get_u64("restart-backoff-ms").map_err(|e| anyhow!(e))?;
    rc.ckpt_every = m.get_u64("ckpt-every").map_err(|e| anyhow!(e))?;
    if !m.get("ckpt-dir").is_empty() {
        rc.ckpt_dir = Some(m.get("ckpt-dir").into());
    }
    rc.ckpt_keep = m.get_usize("ckpt-keep").map_err(|e| anyhow!(e))?;
    rc.stall_timeout_ms = m.get_u64("stall-timeout-ms").map_err(|e| anyhow!(e))?;
    if !m.get("fault-plan").is_empty() {
        rc.fault_plan = Some(m.get("fault-plan").to_string());
    }
    rc.staleness_fix = FixKind::parse(m.get("staleness-fix"))?;
    rc.partition = PartitionMode::parse(m.get("partition"))?;

    let res = pipestale::train::run(&rc)?;
    let recovery = if res.degraded {
        format!(" ({} restarts, degraded to single occupancy)", res.restarts)
    } else if res.restarts > 0 {
        format!(" ({} restarts)", res.restarts)
    } else {
        String::new()
    };
    println!(
        "{} [{}/{}] {} iters: final test acc {:.2}%, train loss {:.4}, wall {:.1}s{}",
        res.config,
        res.mode,
        res.runtime,
        res.iters,
        100.0 * res.final_accuracy,
        res.final_train_loss,
        res.wall_seconds,
        recovery
    );
    if !m.get("out").is_empty() {
        let prefix = m.get("out");
        std::fs::write(format!("{prefix}_train.csv"), res.recorder.train_csv())?;
        std::fs::write(format!("{prefix}_eval.csv"), res.recorder.eval_csv())?;
        println!("wrote {prefix}_train.csv / {prefix}_eval.csv");
    }
    Ok(())
}

/// Materialize a real-format (IDX / CIFAR-10 binary) fixture dataset
/// on disk — the files `train --data-dir` then parses like downloaded
/// originals. Used by CI's data-plane smoke and handy for local runs
/// without network access.
fn cmd_gen_data(args: &[String]) -> Result<()> {
    let m = parse(
        Command::new("pipestale gen-data", "write a real-format fixture dataset")
            .req("dir", "output directory (created if missing)")
            .opt("dataset", "mnist", "mnist | cifar10 (file format to write)")
            .opt("train", "512", "train samples")
            .opt("test", "128", "test samples")
            .opt("seed", "42", "generator seed"),
        args,
    )?;
    let dir = std::path::PathBuf::from(m.get("dir"));
    let dataset = m.get("dataset");
    let (tr, te) = pipestale::data::fixtures::write_fixture(
        dataset,
        &dir,
        m.get_usize("train").map_err(|e| anyhow!(e))?,
        m.get_usize("test").map_err(|e| anyhow!(e))?,
        m.get_u64("seed").map_err(|e| anyhow!(e))?,
    )?;
    println!(
        "wrote {dataset} fixture to {}: {} train + {} test samples ({}x{}x{})",
        dir.display(),
        tr.len(),
        te.len(),
        tr.h,
        tr.w,
        tr.c
    );
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let m = parse(
        Command::new("pipestale inspect", "staleness report (paper §3)")
            .req("config", "artifact config name"),
        args,
    )?;
    let meta = pipestale::train::load_native_meta(m.get("config"))?;
    let r = StalenessReport::from_meta(&meta);
    println!(
        "{}: model={} PPV={:?} -> {} paper stages, {:.1}% stale weights",
        r.config,
        meta.model,
        meta.ppv,
        r.paper_stages,
        100.0 * r.stale_weight_fraction
    );
    let mut t = Table::new(&["partition", "layers", "params", "degree of staleness", "extra act copies"]);
    for p in &r.partitions {
        t.row(&[
            p.partition.to_string(),
            format!("{}..{}", p.layer_range.0, p.layer_range.1),
            p.param_count.to_string(),
            p.degree.to_string(),
            p.extra_activation_copies.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_memory(args: &[String]) -> Result<()> {
    let m = parse(
        Command::new("pipestale memory", "Table-6-style memory model")
            .req("config", "artifact config name")
            .opt("batch", "128", "batch size for absolute numbers")
            .opt("partition", "manual", "manual | auto (profile-guided PPV)"),
        args,
    )?;
    let pmode = PartitionMode::parse(m.get("partition"))?;
    let meta = pipestale::train::resolve_meta(m.get("config"), pmode, false)?;
    let batch = m.get_usize("batch").map_err(|e| anyhow!(e))?;
    let r = MemoryReport::from_meta(&meta);
    let mb = 1024.0 * 1024.0;
    println!("{} (PPV {:?} [{}], batch {batch}):", r.config, r.ppv, pmode.name());
    // Per-stage footprint + analytic compute share: the load-imbalance
    // view that motivates --partition auto.
    let prof = CostProfile::analytic(&meta, pipestale::profile::REFERENCE_FLOPS_PER_S)?;
    let totals = stage_totals(&prof.stage_costs(&meta.ppv)?);
    let sum: f64 = totals.iter().sum();
    let mut t = Table::new(&["stage", "layers", "weights MB", "carry-in MB", "compute share"]);
    for (row, cost) in partition_memory_rows(&meta).iter().zip(&totals) {
        t.row(&[
            row.partition.to_string(),
            format!("{}..{}", row.layer_range.0, row.layer_range.1),
            format!("{:.2}", row.weight_bytes / mb),
            format!("{:.2}", row.carry_in_bytes / mb),
            format!("{:.1}%", 100.0 * cost / sum.max(f64::MIN_POSITIVE)),
        ]);
    }
    println!("{}", t.render());
    println!("  stage imbalance (bottleneck/mean, analytic): {:.3}", imbalance_ratio(&totals));
    println!("  activations: {:7.2} MB x batch", r.activations_per_sample / mb);
    println!("  weights:     {:7.2} MB", r.weight_bytes / mb);
    println!(
        "  increase:    {:7.2} MB x batch ({:.0}% paper-style; ours {:.2} MB x batch = {:.0}%)",
        r.increase_paper_style_per_sample / mb,
        r.increase_pct_paper_style(),
        r.increase_per_sample / mb,
        r.increase_pct()
    );
    println!(
        "  PipeDream weight stash would add {:.2} MB (we stash none by default)",
        pipedream_stash_bytes(&meta) / mb
    );
    println!(
        "  --staleness-fix stash ring would add {:.2} MB (deeper in-flight window)",
        stash_extra_bytes_total(&meta) / mb
    );
    println!("  total (ours, batch {batch}): {:.1} MB", r.total_bytes(batch) / mb);
    Ok(())
}

fn cmd_perfsim(args: &[String]) -> Result<()> {
    let m = parse(
        Command::new("pipestale perfsim", "DES speedup estimate from a per-block cost model")
            .req("config", "artifact config name")
            .opt("iters", "200", "simulated training iterations")
            .opt("gflops", "50.0", "assumed accelerator GFLOP/s (analytic profile)")
            .opt("mapping", "paired", "paired | full")
            .opt("partition", "manual", "manual | auto (profile-guided PPV)")
            .opt("profile", "analytic", "analytic | measured (wall-clock on native kernels)")
            .opt("warmup", "1", "measured profile: untimed warmup reps per block")
            .opt("reps", "5", "measured profile: timed reps per block (median taken)")
            .flag("save-profile", "write the profile to results/profile_<config>.json"),
        args,
    )?;
    let pmode = PartitionMode::parse(m.get("partition"))?;
    let meta = pipestale::train::resolve_meta(m.get("config"), pmode, false)?;
    let iters = m.get_u64("iters").map_err(|e| anyhow!(e))?;
    let gflops = m.get_f64("gflops").map_err(|e| anyhow!(e))?;
    let mapping = match m.get("mapping") {
        "full" => Mapping::Full,
        _ => Mapping::Paired,
    };
    let prof = match m.get("profile") {
        "analytic" => CostProfile::analytic(&meta, gflops * 1e9)?,
        "measured" => CostProfile::measure(
            m.get("config"),
            m.get_usize("warmup").map_err(|e| anyhow!(e))?,
            m.get_usize("reps").map_err(|e| anyhow!(e))?,
        )?,
        other => return Err(anyhow!("unknown profile {other:?} (analytic|measured)")),
    };
    let costs = prof.stage_costs(&meta.ppv)?;
    let totals = stage_totals(&costs);
    println!("{} (PPV {:?} [{}], {} profile):", meta.config, meta.ppv, pmode.name(), prof.source);
    for (i, ((f, b), part)) in
        costs.fwd.iter().zip(&costs.bwd).zip(&meta.partitions).enumerate()
    {
        println!(
            "  stage {} (layers {}..{}): fwd {:.3} ms + bwd {:.3} ms = {:.3} ms",
            i + 1,
            part.layer_lo,
            part.layer_hi,
            1e3 * f,
            1e3 * b,
            1e3 * totals[i]
        );
    }
    println!("  stage imbalance (bottleneck/mean): {:.3}", imbalance_ratio(&totals));
    let comm = CommModel::default();
    let tp = simulate_pipelined(&costs, &comm, mapping, iters);
    let tn = simulate_nonpipelined(&costs, iters);
    println!(
        "{}: {} iters, mapping={:?}: non-pipelined {:.2}s, pipelined {:.2}s, speedup {:.2}X",
        meta.config,
        iters,
        mapping,
        tn,
        tp,
        tn / tp
    );
    if m.has("save-profile") {
        let path = prof.save()?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    let root = pipestale::artifacts_root();
    let mut names: Vec<String> = std::fs::read_dir(&root)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.path().join("meta.json").exists())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .collect()
        })
        .unwrap_or_default();
    names.sort();
    let mut t = Table::new(&["config", "model", "stages", "PPV", "batch", "%stale", "backend"]);
    let mut row = |meta: &ConfigMeta, backend: &str| {
        t.row(&[
            meta.config.clone(),
            meta.model.clone(),
            meta.paper_stages().to_string(),
            format!("{:?}", meta.ppv),
            meta.batch.to_string(),
            format!("{:.1}%", 100.0 * meta.stale_weight_fraction()),
            backend.to_string(),
        ]);
    };
    for n in &names {
        if let Ok(meta) = ConfigMeta::load_named(&root, n) {
            row(&meta, if meta.meta_only { "meta-only" } else { "xla" });
        }
    }
    // Built-in native configs need no artifacts at all.
    for n in pipestale::backend::native_config_names() {
        if names.iter().any(|a| a.as_str() == n) {
            continue; // artifact version already listed
        }
        if let Ok(meta) = pipestale::backend::native_config(n) {
            row(&meta, "native");
        }
    }
    println!("{}", t.render());
    Ok(())
}
