//! Declarative command-line parsing (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
    pub required: bool,
}

#[derive(Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    args: Vec<ArgSpec>,
    positionals: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, args: Vec::new(), positionals: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
            required: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec { name, help, default: None, is_flag: false, required: true });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec { name, help, default: None, is_flag: true, required: false });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
            required: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for p in &self.positionals {
            out.push_str(&format!(" <{}>", p.name));
        }
        out.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for a in &self.args {
            let head = if a.is_flag {
                format!("--{}", a.name)
            } else {
                format!("--{} <v>", a.name)
            };
            let def = match &a.default {
                Some(d) if !a.is_flag => format!(" [default: {}]", d),
                _ => String::new(),
            };
            out.push_str(&format!("  {:<24} {}{}\n", head, a.help, def));
        }
        out
    }

    /// Parse argv (without program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Matches, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut pos_iter = self.positionals.iter();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    values.insert(key, val);
                }
            } else {
                let spec = pos_iter
                    .next()
                    .ok_or_else(|| format!("unexpected argument {tok:?}\n\n{}", self.usage()))?;
                values.insert(spec.name.to_string(), tok.clone());
            }
            i += 1;
        }
        for a in &self.args {
            if a.required && !values.contains_key(a.name) {
                return Err(format!("missing required --{}\n\n{}", a.name, self.usage()));
            }
            if let Some(d) = &a.default {
                values.entry(a.name.to_string()).or_insert_with(|| d.clone());
            }
        }
        if let Some(p) = pos_iter.next() {
            return Err(format!("missing <{}>\n\n{}", p.name, self.usage()));
        }
        Ok(Matches { values, flags })
    }
}

#[derive(Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("arg {name} not declared"))
    }

    pub fn get_opt(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name} expects an integer, got {:?}", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name} expects an integer, got {:?}", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name} expects a number, got {:?}", self.get(name)))
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .req("config", "config name")
            .opt("iters", "100", "iterations")
            .flag("verbose", "log more")
            .positional("outdir", "output directory")
    }

    #[test]
    fn parses_mixed_forms() {
        let m = cmd()
            .parse(&argv(&["--config=resnet20_4s", "out", "--iters", "500", "--verbose"]))
            .unwrap();
        assert_eq!(m.get("config"), "resnet20_4s");
        assert_eq!(m.get_usize("iters").unwrap(), 500);
        assert_eq!(m.get("outdir"), "out");
        assert!(m.has("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let m = cmd().parse(&argv(&["--config", "c", "out"])).unwrap();
        assert_eq!(m.get_usize("iters").unwrap(), 100);
        assert!(!m.has("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cmd().parse(&argv(&["out"])).is_err());
        assert!(cmd().parse(&argv(&["--config", "c"])).is_err()); // no positional
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&argv(&["--config", "c", "--nope", "1", "out"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("USAGE"));
        assert!(err.contains("--iters"));
    }

    #[test]
    fn numeric_errors_are_friendly() {
        let m = cmd().parse(&argv(&["--config", "c", "--iters", "abc", "out"])).unwrap();
        assert!(m.get_usize("iters").is_err());
    }
}
