//! Tiny leveled logger implementing the `log` facade.
//!
//! `PIPESTALE_LOG=debug|info|warn|error` controls the level (default info).

use std::io::Write;
use std::sync::Once;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INIT: Once = Once::new();
static mut START: Option<Instant> = None;

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let elapsed = unsafe {
            #[allow(static_mut_refs)]
            START.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
        };
        let level = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{elapsed:9.3}s {level}] {}", record.args());
    }

    fn flush(&self) {}
}

static LOGGER: Logger = Logger;

/// Install the logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        unsafe {
            START = Some(Instant::now());
        }
        let level = match std::env::var("PIPESTALE_LOG").as_deref() {
            Ok("trace") => LevelFilter::Trace,
            Ok("debug") => LevelFilter::Debug,
            Ok("warn") => LevelFilter::Warn,
            Ok("error") => LevelFilter::Error,
            _ => LevelFilter::Info,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
