//! From-scratch substrates: JSON, CLI, RNG, logging, bench harness,
//! property testing (the offline vendor set lacks serde/clap/criterion/
//! proptest — see DESIGN.md §5).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;

/// Emit the per-test `skipping: <reason>` marker that `scripts/ci.sh`
/// subtracts when recomputing the executed-test coverage floor.
///
/// The marker must land in the `--nocapture` log as one intact line:
/// the floor is a `grep -c 'skipping:'` over a log that parallel test
/// threads write concurrently, and the old per-site `eprintln!` calls
/// could interleave mid-line (stderr is unbuffered, so one logical
/// line may be several `write(2)` calls), silently miscounting
/// `executed`. This helper formats the full line first and pushes it
/// through a single `write_all` on locked stdout — one syscall, which
/// POSIX keeps atomic at pipe granularity — so markers can neither
/// split nor merge no matter how many tests print at once.
pub fn skip_marker(reason: &str) {
    use std::io::Write;
    let line = format!("skipping: {reason}\n");
    let mut out = std::io::stdout().lock();
    let _ = out.write_all(line.as_bytes());
    let _ = out.flush();
}
