//! From-scratch substrates: JSON, CLI, RNG, logging, bench harness,
//! property testing (the offline vendor set lacks serde/clap/criterion/
//! proptest — see DESIGN.md §5).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
