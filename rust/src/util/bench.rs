//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Runs a closure with warmup, collects per-iteration wall times, and
//! reports mean / p50 / p95 / min. `cargo bench` targets use this plus
//! table printers for the paper-reproduction harnesses.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    /// Machine-readable form (BENCH_micro.json schema): name ->
    /// {mean_ms, p50_ms, p95_ms, min_ms, iters}.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("mean_ms", num(self.mean_s * 1e3)),
            ("p50_ms", num(self.p50_s * 1e3)),
            ("p95_ms", num(self.p95_s * 1e3)),
            ("min_ms", num(self.min_s * 1e3)),
            ("iters", num(self.iters as f64)),
        ])
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} iters={:<5} mean={:>10.3}ms p50={:>10.3}ms p95={:>10.3}ms min={:>10.3}ms",
            self.name,
            self.iters,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.p95_s * 1e3,
            self.min_s * 1e3
        )
    }
}

/// Benchmark `f`, auto-scaling iteration count to roughly `budget_s`
/// seconds of measurement after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget_s: f64, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    // estimate a single-iter cost
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / est) as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    stats_from(name, &mut samples)
}

/// Fixed-iteration variant.
pub fn bench_n<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    stats_from(name, &mut samples)
}

fn stats_from(name: &str, samples: &mut [f64]) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_s: samples.iter().sum::<f64>() / n as f64,
        p50_s: samples[n / 2],
        p95_s: samples[(n as f64 * 0.95) as usize % n],
        min_s: samples[0],
    }
}

/// Markdown-ish table printer used by the paper-table benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        for row in &self.rows {
            out.push('\n');
            out.push_str(&line(row));
        }
        out
    }

    /// CSV for results/ dumps.
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        for row in &self.rows {
            out.push('\n');
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let st = bench_n("noop-ish", 1, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(st.iters, 50);
        assert!(st.min_s <= st.p50_s && st.p50_s <= st.p95_s);
        assert!(st.mean_s > 0.0);
    }

    #[test]
    fn table_renders_and_csv() {
        let mut t = Table::new(&["ResNet", "Speedup"]);
        t.row(&["-20".into(), "1.23X".into()]);
        t.row(&["-362".into(), "1.82X".into()]);
        let r = t.render();
        assert!(r.contains("| ResNet"));
        assert!(r.lines().count() == 4);
        let csv = t.to_csv();
        assert!(csv.starts_with("ResNet,Speedup\n"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
