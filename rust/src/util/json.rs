//! Minimal JSON parser/serializer.
//!
//! The offline vendor set has no `serde`, so configs, `meta.json` and
//! metric dumps go through this hand-rolled implementation. It supports
//! the full JSON grammar we emit (objects, arrays, strings with escapes,
//! numbers, bool, null) and nothing exotic (no comments, no NaN).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `[1,2,3]` -> `vec![1,2,3]` (for shape lists).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- writer --------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    nl(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    nl(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn nl(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let rest = &self.b[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap().as_str(), Some("hi\n"));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":{"e":null}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shape":[64,28,28,1],"name":"conv1/w","f":1.25,"neg":-3}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\u{1}".to_string());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
        assert_eq!(Json::parse("\"\\u2603\"").unwrap().as_str(), Some("☃"));
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[3,4,5]").unwrap();
        assert_eq!(j.as_usize_vec(), Some(vec![3, 4, 5]));
        assert_eq!(Json::parse("[1,\"x\"]").unwrap().as_usize_vec(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }
}
