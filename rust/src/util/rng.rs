//! PCG32 random number generator + sampling helpers.
//!
//! Deterministic, seedable, and fast — used for weight initialization,
//! synthetic dataset generation, batch shuffling, and the property-test
//! driver. (The vendor set has only `rand_core`, no `rand`.)

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n || l >= (n.wrapping_neg() % n) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-12 {
                let u2 = self.next_f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_scaled(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            slice.swap(i, j);
        }
    }

    /// Fill with He-normal initialization (std = sqrt(2 / fan_in)).
    pub fn fill_he(&mut self, out: &mut [f32], fan_in: usize) {
        let std = (2.0 / fan_in.max(1) as f32).sqrt();
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fill with Glorot-uniform initialization.
    pub fn fill_glorot(&mut self, out: &mut [f32], fan_in: usize, fan_out: usize) {
        let limit = (6.0 / (fan_in + fan_out).max(1) as f32).sqrt();
        for v in out.iter_mut() {
            *v = self.uniform(-limit, limit);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u32> = (0..8).map({
            let mut r = Pcg32::seeded(7);
            move |_| r.next_u32()
        }).collect();
        let b: Vec<u32> = (0..8).map({
            let mut r = Pcg32::seeded(7);
            move |_| r.next_u32()
        }).collect();
        assert_eq!(a, b);
        let c: Vec<u32> = (0..8).map({
            let mut r = Pcg32::seeded(8);
            move |_| r.next_u32()
        }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg32::seeded(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Pcg32::seeded(2);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(3);
        let n = 50_000;
        let (mut m, mut v) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(4);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn he_init_std() {
        let mut r = Pcg32::seeded(5);
        let mut buf = vec![0.0f32; 40_000];
        r.fill_he(&mut buf, 50);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 = buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / buf.len() as f64;
        assert!((var.sqrt() - (2.0f64 / 50.0).sqrt()).abs() < 0.01);
    }
}
