//! Mini property-testing driver (proptest is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it performs greedy shrinking via the
//! input's `Shrink` implementation and panics with the minimal
//! counterexample. Coordinator invariants (routing/staleness/batching)
//! are property-tested with this.

use crate::util::rng::Pcg32;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        match self {
            0 => vec![],
            1 => vec![0],
            n => vec![0, n / 2, n - 1],
        }
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        match self {
            0 => vec![],
            1 => vec![0],
            n => vec![0, n / 2, n - 1],
        }
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

impl Shrink for Vec<usize> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() - 1].to_vec());
            out.push(self[1..].to_vec());
            out.push(self[..self.len() / 2].to_vec());
        }
        out
    }
}

/// Run a property over random cases with shrinking on failure.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Pcg32) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Pcg32::seeded(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // greedy shrink
            let mut best = (input.clone(), msg.clone());
            let mut improved = true;
            let mut budget = 200;
            while improved && budget > 0 {
                improved = false;
                for cand in best.0.shrink() {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = (cand, m);
                        improved = true;
                        break;
                    }
                    if budget == 0 {
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  input: {:?}\n  error: {}\n  (shrunk from: {:?} — {})",
                best.0, best.1, input, msg
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 100, |r| r.below(100) as usize, |&n| {
            if n < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let res = std::panic::catch_unwind(|| {
            check(2, 100, |r| r.below(1000) as usize + 10, |&n| {
                if n < 50 {
                    Ok(())
                } else {
                    Err(format!("{n} too big"))
                }
            });
        });
        let msg = format!("{:?}", res.unwrap_err().downcast_ref::<String>().unwrap());
        // greedy shrink should land well below the original draw
        assert!(msg.contains("property failed"));
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (4usize, 6usize);
        let sh = t.shrink();
        assert!(sh.contains(&(0, 6)));
        assert!(sh.contains(&(4, 0)));
    }
}
