//! Deterministic fault injection for the threaded runtime.
//!
//! A [`FaultPlan`] scripts failures at exact points — worker panics,
//! hard stalls, sub-watchdog delays, and checkpoint corruption — so
//! recovery paths can be soak-tested reproducibly (CLI `--fault-plan`,
//! CI, and the resilience test suite all share this machinery; it is
//! first-class, not test-only).
//!
//! Trigger model: worker faults fire on a stage's *N-th stage call*
//! (forward / fused-last / backward). The per-stage op counters live in
//! the shared [`FaultInjector`], so they accumulate across supervisor
//! relaunches — a trigger addresses a point of *absolute* progress, and
//! can therefore land in a segment that only runs after earlier
//! segments were checkpointed (the restore-from-checkpoint path is
//! reachable). Because every worker follows the deterministic 1F1B
//! schedule (`pipeline::threaded` module docs), triggers are
//! deterministic up to the small counter skew surviving workers accrue
//! while an abort propagates; recovery itself restores bitwise state
//! regardless of where in a segment a fault lands. Checkpoint faults
//! fire on the K-th checkpoint *save*, counted across the whole run.
//!
//! Every fault is one-shot: the [`FaultInjector`] is shared (via `Arc`)
//! across supervisor relaunches, so a fired fault stays fired — the
//! transient-fault model under which checkpoint-restart makes progress.
//!
//! Plan grammar (`;` or `,` separated, whitespace ignored):
//!
//! ```text
//! panic@S:N        unwinding panic on stage S's op N
//! fail@S:N         error return (Fatal path) on stage S's op N
//! stall@S:N:MS     sleep MS ms on stage S's op N (≥ watchdog: hung)
//! delay@S:N:MS     sleep MS ms on stage S's op N (< watchdog: slow)
//! corrupt@K        bit-flip the K-th checkpoint save
//! truncate@K       truncate the K-th checkpoint save
//! seeded@SEED:P:N  deterministic soak mix for P stages, ops < N
//! ```

use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::meta::ConfigMeta;
use crate::model::PartitionParams;
use crate::optim::Sgd;
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Pcg32;

use super::executor::{LastResult, WorkerStage};
use super::threaded::WorkerBackend;

/// What an injected fault does when its trigger point is reached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Unwinding panic on the worker thread (caught by the runtime and
    /// converted into a Fatal event).
    Panic,
    /// Error return from the stage call (the ordinary Fatal path).
    Fail,
    /// Hard sleep, meant to exceed the watchdog timeout (a hung stage).
    Stall,
    /// Soft sleep, meant to stay below the watchdog timeout (a slow
    /// stage the watchdog must *not* flag).
    Delay,
    /// Flip one byte of the just-written checkpoint file (checksum
    /// mismatch on restore).
    CorruptCkpt,
    /// Truncate the just-written checkpoint file (short read on
    /// restore).
    TruncateCkpt,
}

/// One scripted fault: a [`FaultKind`] plus its trigger coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    /// What happens at the trigger point.
    pub kind: FaultKind,
    /// Worker/stage index for worker faults; unused (zero) for
    /// checkpoint faults.
    pub stage: usize,
    /// Trigger: 0-based stage-op count for worker faults, 0-based
    /// checkpoint-save count for checkpoint faults.
    pub at: u64,
    /// Sleep duration for `Stall`/`Delay`; zero for the other kinds.
    pub ms: u64,
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            FaultKind::Panic => write!(f, "panic@{}:{}", self.stage, self.at),
            FaultKind::Fail => write!(f, "fail@{}:{}", self.stage, self.at),
            FaultKind::Stall => write!(f, "stall@{}:{}:{}", self.stage, self.at, self.ms),
            FaultKind::Delay => write!(f, "delay@{}:{}:{}", self.stage, self.at, self.ms),
            FaultKind::CorruptCkpt => write!(f, "corrupt@{}", self.at),
            FaultKind::TruncateCkpt => write!(f, "truncate@{}", self.at),
        }
    }
}

/// A parsed fault-injection script (see the module docs for the
/// grammar). The default plan is empty: nothing fires.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// The scripted faults, in plan order.
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// Parse the `;`/`,`-separated plan grammar. An empty string is the
    /// empty plan.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for part in text.split([';', ',']) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (kind, rest) = part
                .split_once('@')
                .ok_or_else(|| anyhow!("fault {part:?}: expected kind@args"))?;
            let nums: Vec<u64> = rest
                .split(':')
                .map(|t| {
                    t.trim()
                        .parse::<u64>()
                        .map_err(|_| anyhow!("fault {part:?}: bad number {t:?}"))
                })
                .collect::<Result<_>>()?;
            let args = |n: usize| -> Result<&[u64]> {
                if nums.len() != n {
                    bail!("fault {part:?}: expected {n} ':'-separated numbers, got {}", nums.len());
                }
                Ok(&nums)
            };
            let worker = |kind: FaultKind, n: usize| -> Result<Fault> {
                let a = args(n)?;
                Ok(Fault {
                    kind,
                    stage: a[0] as usize,
                    at: a[1],
                    ms: a.get(2).copied().unwrap_or(0),
                })
            };
            match kind {
                "panic" => faults.push(worker(FaultKind::Panic, 2)?),
                "fail" => faults.push(worker(FaultKind::Fail, 2)?),
                "stall" => faults.push(worker(FaultKind::Stall, 3)?),
                "delay" => faults.push(worker(FaultKind::Delay, 3)?),
                "corrupt" => {
                    let a = args(1)?;
                    faults.push(Fault { kind: FaultKind::CorruptCkpt, stage: 0, at: a[0], ms: 0 });
                }
                "truncate" => {
                    let a = args(1)?;
                    faults.push(Fault { kind: FaultKind::TruncateCkpt, stage: 0, at: a[0], ms: 0 });
                }
                "seeded" => {
                    let a = args(3)?;
                    faults.extend(FaultPlan::seeded(a[0], a[1] as usize, a[2]).faults);
                }
                other => bail!(
                    "unknown fault kind {other:?} (panic|fail|stall|delay|corrupt|truncate|seeded)"
                ),
            }
        }
        Ok(FaultPlan { faults })
    }

    /// Deterministic soak mix for a `stages`-worker pipeline whose
    /// stages each run fewer than `max_op` ops: one panic, one
    /// sub-watchdog delay, and one corrupted checkpoint, at
    /// seed-derived points. Same seed, same plan — always.
    pub fn seeded(seed: u64, stages: usize, max_op: u64) -> FaultPlan {
        let stages = stages.max(1) as u32;
        let max_op = max_op.max(1).min(u32::MAX as u64) as u32;
        let mut rng = Pcg32::seeded(seed ^ 0xfa17_7a61);
        let faults = vec![
            Fault {
                kind: FaultKind::Panic,
                stage: rng.below(stages) as usize,
                at: rng.below(max_op) as u64,
                ms: 0,
            },
            Fault {
                kind: FaultKind::Delay,
                stage: rng.below(stages) as usize,
                at: rng.below(max_op) as u64,
                ms: 1 + rng.below(20) as u64,
            },
            Fault { kind: FaultKind::CorruptCkpt, stage: 0, at: rng.below(3) as u64, ms: 0 },
        ];
        FaultPlan { faults }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let parts: Vec<String> = self.faults.iter().map(Fault::to_string).collect();
        write!(f, "{}", parts.join(";"))
    }
}

/// An armed [`FaultPlan`]: checks triggers at runtime and fires each
/// fault at most once. Shared by `Arc` between the supervisor and every
/// relaunched worker generation, so a fired fault stays fired across
/// restarts (transient faults — the model under which restart makes
/// forward progress).
#[derive(Debug, Default)]
pub struct FaultInjector {
    faults: Vec<Fault>,
    fired: Vec<AtomicBool>,
    ckpts_saved: AtomicU64,
    /// Per-stage op counters, shared across worker generations so that
    /// trigger points address absolute progress (see the module docs).
    stage_ops: Vec<AtomicU64>,
}

/// Upper bound on addressable stages for per-stage op counters; far
/// above any real pipeline depth here (paper configs use P ≤ 8).
const MAX_STAGES: usize = 64;

impl FaultInjector {
    /// Arm a plan.
    pub fn new(plan: FaultPlan) -> Self {
        let fired = plan.faults.iter().map(|_| AtomicBool::new(false)).collect();
        let stage_ops = (0..MAX_STAGES).map(|_| AtomicU64::new(0)).collect();
        FaultInjector {
            faults: plan.faults,
            fired,
            ckpts_saved: AtomicU64::new(0),
            stage_ops,
        }
    }

    /// Consume and return stage `stage`'s next 0-based op index.
    /// Out-of-range stages (≥ `MAX_STAGES`) get `u64::MAX`, which no
    /// plan entry can target.
    pub fn next_op(&self, stage: usize) -> u64 {
        self.stage_ops.get(stage).map_or(u64::MAX, |c| c.fetch_add(1, Ordering::SeqCst))
    }

    /// True when the plan is empty (nothing will ever fire).
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// How many faults have fired so far.
    pub fn fired_count(&self) -> usize {
        self.fired.iter().filter(|f| f.load(Ordering::SeqCst)).count()
    }

    /// Worker-side trigger check, called before stage `stage`'s `op`-th
    /// stage call: may sleep (stall/delay), return an error (fail), or
    /// panic (panic). A no-op at non-trigger points.
    pub fn before_op(&self, stage: usize, op: u64) -> Result<()> {
        for (f, fired) in self.faults.iter().zip(&self.fired) {
            if f.stage != stage || f.at != op {
                continue;
            }
            match f.kind {
                FaultKind::Panic => {
                    if !fired.swap(true, Ordering::SeqCst) {
                        log::warn!("fault plan: injecting panic at stage {stage} op {op}");
                        panic!("fault plan: injected panic at stage {stage} op {op}");
                    }
                }
                FaultKind::Fail => {
                    if !fired.swap(true, Ordering::SeqCst) {
                        bail!("fault plan: injected failure at stage {stage} op {op}");
                    }
                }
                FaultKind::Stall | FaultKind::Delay => {
                    if !fired.swap(true, Ordering::SeqCst) {
                        log::warn!(
                            "fault plan: stage {stage} sleeping {}ms at op {op}",
                            f.ms
                        );
                        std::thread::sleep(Duration::from_millis(f.ms));
                    }
                }
                FaultKind::CorruptCkpt | FaultKind::TruncateCkpt => {}
            }
        }
        Ok(())
    }

    /// Checkpoint-side trigger check, called after every checkpoint
    /// save with the written path: damages the file in place when this
    /// save's 0-based index matches a `corrupt@K`/`truncate@K` entry.
    pub fn after_checkpoint(&self, path: &Path) -> Result<()> {
        let k = self.ckpts_saved.fetch_add(1, Ordering::SeqCst);
        for (f, fired) in self.faults.iter().zip(&self.fired) {
            let hit = matches!(f.kind, FaultKind::CorruptCkpt | FaultKind::TruncateCkpt)
                && f.at == k
                && !fired.swap(true, Ordering::SeqCst);
            if !hit {
                continue;
            }
            let bytes = std::fs::read(path)
                .with_context(|| format!("fault plan: reading {}", path.display()))?;
            match f.kind {
                FaultKind::CorruptCkpt => {
                    let mut b = bytes;
                    let mid = b.len() / 2;
                    b[mid] ^= 0xFF;
                    std::fs::write(path, &b)?;
                }
                _ => std::fs::write(path, &bytes[..bytes.len() / 3])?,
            }
            log::warn!("fault plan: damaged checkpoint save #{k} at {}", path.display());
        }
        Ok(())
    }
}

/// [`WorkerBackend`] decorator that wraps every stage it builds in a
/// [`FaultyStage`], so an armed [`FaultInjector`] sees every stage call
/// of every worker generation. With an empty plan the overhead is one
/// counter bump and a scan of an empty list per op.
#[derive(Clone, Debug)]
pub struct FaultyWorkerBackend<B: WorkerBackend> {
    inner: B,
    injector: Arc<FaultInjector>,
}

impl<B: WorkerBackend> FaultyWorkerBackend<B> {
    /// Wrap `inner`, injecting the faults armed in `injector`.
    pub fn new(inner: B, injector: Arc<FaultInjector>) -> Self {
        FaultyWorkerBackend { inner, injector }
    }
}

impl<B: WorkerBackend> WorkerBackend for FaultyWorkerBackend<B> {
    type Stage = FaultyStage<B::Stage>;

    fn make_stage(
        &self,
        meta: &ConfigMeta,
        idx: usize,
        params: PartitionParams,
        optim: Sgd,
    ) -> Result<FaultyStage<B::Stage>> {
        Ok(FaultyStage {
            inner: self.inner.make_stage(meta, idx, params, optim)?,
            stage: idx,
            injector: Arc::clone(&self.injector),
        })
    }
}

/// A [`WorkerStage`] that consults the shared [`FaultInjector`] before
/// delegating each stage call; op indices come from the injector's
/// shared per-stage counters, so they keep counting across relaunches.
pub struct FaultyStage<S: WorkerStage> {
    inner: S,
    stage: usize,
    injector: Arc<FaultInjector>,
}

impl<S: WorkerStage> FaultyStage<S> {
    fn hook(&mut self) -> Result<()> {
        let op = self.injector.next_op(self.stage);
        self.injector.before_op(self.stage, op)
    }
}

impl<S: WorkerStage> WorkerStage for FaultyStage<S> {
    fn forward(&mut self, seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        self.hook()?;
        self.inner.forward(seed, carry)
    }

    fn last(&mut self, seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult> {
        self.hook()?;
        self.inner.last(seed, carry, labels)
    }

    fn backward(
        &mut self,
        seed: i32,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.hook()?;
        self.inner.backward(seed, carry_in, gcarry_out)
    }

    fn into_params(self) -> PartitionParams {
        self.inner.into_params()
    }

    fn set_staleness_fix(&mut self, kind: super::mitigation::FixKind) -> Result<()> {
        // Forward to the wrapped stage: fault injection must be
        // transparent to the mitigation axis (a decorator that ate the
        // fix would silently train a different algorithm).
        self.inner.set_staleness_fix(kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grammar_roundtrip() {
        let text = "panic@1:30; stall@2:10:4000, delay@0:3:25;corrupt@1;truncate@0;fail@3:7";
        let p = FaultPlan::parse(text).unwrap();
        assert_eq!(p.faults.len(), 6);
        assert_eq!(p.faults[0], Fault { kind: FaultKind::Panic, stage: 1, at: 30, ms: 0 });
        assert_eq!(p.faults[1], Fault { kind: FaultKind::Stall, stage: 2, at: 10, ms: 4000 });
        let back = FaultPlan::parse(&p.to_string()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn plan_rejects_malformed_entries() {
        for bad in
            ["panic", "panic@", "panic@x:1", "panic@1", "stall@1:2", "corrupt@1:2", "frob@1:2"]
        {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should be rejected");
        }
        assert!(FaultPlan::parse("").unwrap().faults.is_empty());
        assert!(FaultPlan::parse(" ; ").unwrap().faults.is_empty());
    }

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::parse("seeded@7:4:100").unwrap();
        let b = FaultPlan::parse("seeded@7:4:100").unwrap();
        assert_eq!(a, b);
        assert!(!a.faults.is_empty());
        assert!(a.faults.iter().any(|f| f.kind == FaultKind::Panic));
        assert!(a
            .faults
            .iter()
            .all(|f| f.stage < 4 && (f.at < 100 || matches!(f.kind, FaultKind::CorruptCkpt))));
    }

    #[test]
    fn empty_plan_injector_is_inert() {
        // The default CLI path: no --fault-plan means an armed-but-empty
        // injector on every stage call.
        let inj = FaultInjector::new(FaultPlan::parse("").unwrap());
        assert!(inj.is_empty());
        for stage in 0..4 {
            for _ in 0..8 {
                let op = inj.next_op(stage);
                assert!(inj.before_op(stage, op).is_ok());
            }
        }
        let p = std::env::temp_dir().join(format!("faults_empty_{}.pst", std::process::id()));
        std::fs::write(&p, [1u8, 2, 3, 4]).unwrap();
        inj.after_checkpoint(&p).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), [1, 2, 3, 4], "empty plan must not touch saves");
        std::fs::remove_file(&p).ok();
        assert_eq!(inj.fired_count(), 0);
    }

    #[test]
    fn duplicate_one_shot_triggers_each_fire_once() {
        // Two entries on the same trigger point: each is independently
        // one-shot, so the point fires twice in total — the scan stops
        // at the first unfired entry per call, the next call reaches
        // the second.
        let plan = FaultPlan::parse("fail@1:3;fail@1:3").unwrap();
        assert_eq!(plan.faults.len(), 2, "duplicates are kept, not deduped");
        let inj = FaultInjector::new(plan);
        assert!(inj.before_op(1, 3).is_err(), "first duplicate fires");
        assert!(inj.before_op(1, 3).is_err(), "second duplicate fires on the next hit");
        assert!(inj.before_op(1, 3).is_ok(), "both spent");
        assert_eq!(inj.fired_count(), 2);
    }

    #[test]
    fn out_of_range_stage_index_parses_but_never_fires() {
        // Stage ids beyond MAX_STAGES are legal in the grammar but can
        // never trigger through the runtime path: next_op hands such a
        // stage u64::MAX, which no finite plan coordinate matches.
        let plan = FaultPlan::parse(&format!("panic@{}:0", MAX_STAGES + 3)).unwrap();
        let inj = FaultInjector::new(plan);
        let op = inj.next_op(MAX_STAGES + 3);
        assert_eq!(op, u64::MAX);
        assert!(inj.before_op(MAX_STAGES + 3, op).is_ok(), "must not fire at the sentinel op");
        assert_eq!(inj.fired_count(), 0);
    }

    #[test]
    fn seeded_prefix_expands_and_roundtrips_through_display() {
        // `seeded@SEED:P:N` expands at parse time into concrete faults;
        // Display therefore prints plain grammar that reparses to the
        // identical plan (the prefix itself never survives a roundtrip).
        let p = FaultPlan::parse("seeded@9:4:50").unwrap();
        assert!(!p.faults.is_empty());
        let shown = p.to_string();
        assert!(!shown.contains("seeded"), "display must be concrete: {shown}");
        assert_eq!(FaultPlan::parse(&shown).unwrap(), p);
        // Degenerate parameters clamp instead of panicking.
        let tiny = FaultPlan::parse("seeded@0:0:0").unwrap();
        assert!(tiny.faults.iter().all(|f| f.stage == 0));
    }

    #[test]
    fn injector_fires_each_fault_once() {
        let inj = FaultInjector::new(FaultPlan::parse("fail@1:3;delay@1:4:1").unwrap());
        assert!(inj.before_op(1, 2).is_ok());
        assert!(inj.before_op(0, 3).is_ok());
        assert!(inj.before_op(1, 3).is_err(), "fail fault must fire");
        assert!(inj.before_op(1, 3).is_ok(), "one-shot: same trigger is spent");
        assert!(inj.before_op(1, 4).is_ok(), "delay sleeps, no error");
        assert_eq!(inj.fired_count(), 2);
    }

    #[test]
    fn injected_panic_unwinds_and_is_one_shot() {
        let inj = FaultInjector::new(FaultPlan::parse("panic@0:0").unwrap());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| inj.before_op(0, 0)));
        assert!(r.is_err(), "panic fault must unwind");
        assert!(inj.before_op(0, 0).is_ok(), "spent after firing");
        assert_eq!(inj.fired_count(), 1);
    }

    #[test]
    fn op_counters_accumulate_across_generations() {
        let inj = FaultInjector::new(FaultPlan::default());
        assert_eq!(inj.next_op(2), 0);
        assert_eq!(inj.next_op(2), 1, "per-stage counter keeps counting");
        assert_eq!(inj.next_op(3), 0, "counters are per stage");
        assert_eq!(inj.next_op(MAX_STAGES + 1), u64::MAX, "out-of-range stage never triggers");
    }

    #[test]
    fn injector_damages_scheduled_checkpoint_saves() {
        let dir = std::env::temp_dir().join(format!("faults_ck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.pst");
        let inj = FaultInjector::new(FaultPlan::parse("corrupt@1;truncate@2").unwrap());
        let body = vec![7u8; 64];
        std::fs::write(&path, &body).unwrap();
        inj.after_checkpoint(&path).unwrap(); // save #0: untouched
        assert_eq!(std::fs::read(&path).unwrap(), body);
        inj.after_checkpoint(&path).unwrap(); // save #1: bit-flipped
        let flipped = std::fs::read(&path).unwrap();
        assert_eq!(flipped.len(), 64);
        assert_ne!(flipped, body);
        std::fs::write(&path, &body).unwrap();
        inj.after_checkpoint(&path).unwrap(); // save #2: truncated
        assert!(std::fs::read(&path).unwrap().len() < 64);
        inj.after_checkpoint(&path).unwrap(); // save #3: plan exhausted
        std::fs::remove_dir_all(&dir).ok();
    }
}
