//! Cycle-accurate pipelined-backpropagation scheduler (paper §3, Fig. 4).
//!
//! The pipeline has P = K+1 partitions connected by K register pairs.
//! Per cycle every stage consumes the register value written in the
//! *previous* cycle (double-buffered registers), computes, and writes its
//! output register; weight updates (applied inside `last`/`backward`)
//! become visible to forwards of the next cycle. The fused last stage
//! (FS_{K+1}+BKS_1 on one accelerator) updates in-cycle, giving the last
//! partition staleness 0 — exactly the paper's co-location trick.
//!
//! The same scheduler also provides `sequential_step` (non-pipelined
//! K=0 semantics over the same partitions/executables), which hybrid
//! training switches to after draining the pipe (paper §4).

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::tensor::{IntTensor, Tensor};

use super::executor::StageExecutor;

/// One mini-batch travelling forward through the pipe.
#[derive(Debug, Clone)]
struct InFlight {
    batch_id: u64,
    seed: i32,
    carry: Vec<Tensor>,
}

/// A gradient message travelling backward.
#[derive(Debug, Clone)]
struct GradMsg {
    batch_id: u64,
    gcarry: Vec<Tensor>,
}

/// Saved intermediate activations of one partition (paper §3): the
/// carry_in (plus seed) of every in-flight mini-batch, FIFO-ordered.
#[derive(Debug, Default)]
struct ActivationFifo {
    entries: VecDeque<InFlight>,
    pub max_depth: usize,
}

impl ActivationFifo {
    fn push(&mut self, e: InFlight) {
        self.entries.push_back(e);
        self.max_depth = self.max_depth.max(self.entries.len());
    }

    fn pop_for(&mut self, batch_id: u64) -> Result<InFlight> {
        match self.entries.pop_front() {
            Some(e) if e.batch_id == batch_id => Ok(e),
            Some(e) => bail!(
                "activation FIFO order violated: popped batch {} for gradient of batch {}",
                e.batch_id,
                batch_id
            ),
            None => bail!("activation FIFO empty for gradient of batch {batch_id}"),
        }
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

/// Per-completed-batch training record.
#[derive(Debug, Clone)]
pub struct TrainEvent {
    /// The mini-batch this event belongs to (feed order).
    pub batch_id: u64,
    /// Mean training loss of the batch.
    pub loss: f32,
    /// Correct predictions in the batch (a count, as f32).
    pub correct: f32,
    /// Samples in the batch.
    pub batch_size: usize,
    /// Cycle at which the fused last stage processed this batch (the
    /// threaded runtime, which has no global cycles, records batch_id).
    pub cycle: u64,
}

/// Fill/drain accounting shared by both runtimes: how many batches
/// entered the pipe, how many fully retired (backward complete on
/// every partition), and an optional in-flight occupancy cap. The
/// cycle-accurate scheduler uses it uncapped (occupancy is bounded
/// structurally by its registers); the threaded runtime caps feeding
/// to bound activation memory across its channel registers.
#[derive(Debug, Clone)]
pub struct FlowControl {
    cap: Option<u64>,
    fed: u64,
    retired: u64,
}

impl FlowControl {
    /// New accounting with an optional in-flight occupancy cap.
    pub fn new(cap: Option<u64>) -> Self {
        FlowControl { cap, fed: 0, retired: 0 }
    }

    /// Batches fed into the pipe so far.
    pub fn fed(&self) -> u64 {
        self.fed
    }

    /// Batches fully retired (backward complete on every partition).
    pub fn retired(&self) -> u64 {
        self.retired
    }

    /// Batches currently somewhere in the pipe.
    pub fn in_flight(&self) -> u64 {
        self.fed - self.retired
    }

    /// True when the occupancy cap (if any) admits another feed.
    pub fn can_feed(&self) -> bool {
        self.cap.map_or(true, |c| self.in_flight() < c)
    }

    /// Count one batch entering the pipe.
    pub fn record_fed(&mut self) {
        self.fed += 1;
    }

    /// Count one batch fully retiring from the pipe.
    pub fn record_retired(&mut self) {
        debug_assert!(self.retired < self.fed, "retire without a matching feed");
        self.retired += 1;
    }
}

/// Event accounting shared by both runtimes: every fed batch must
/// produce exactly one `TrainEvent`, in batch order, and retires must
/// be monotone and never precede the batch's train event. Catches
/// lost/duplicated/reordered events in the concurrent runtime and
/// schedule bugs in the cycle-accurate one.
#[derive(Debug, Default)]
pub struct EventLedger {
    events: Vec<TrainEvent>,
    keep: bool,
    recorded: u64,
    retired: u64,
}

impl EventLedger {
    /// Validate-only ledger (events are counted, not stored).
    pub fn new() -> Self {
        EventLedger::default()
    }

    /// Ledger that also keeps the events for the caller.
    pub fn keeping() -> Self {
        EventLedger { keep: true, ..EventLedger::default() }
    }

    /// Validate-only ledger resuming after `base` batches are already
    /// accounted for — checkpoint-restart: batches `0..base` were
    /// recorded (and retired) by an earlier pipeline generation, so the
    /// next expected batch id is `base` and `expect_complete` takes the
    /// *absolute* feed count.
    pub fn resume_from(base: u64) -> Self {
        EventLedger { recorded: base, retired: base, ..EventLedger::default() }
    }

    /// Keeping ledger resuming at `base` (see [`EventLedger::resume_from`]);
    /// `into_events` returns only the events recorded since `base`.
    pub fn keeping_from(base: u64) -> Self {
        EventLedger { keep: true, recorded: base, retired: base, ..EventLedger::default() }
    }

    /// Record the next train event; events must arrive in batch order.
    pub fn record(&mut self, e: TrainEvent) -> Result<()> {
        if e.batch_id != self.recorded {
            bail!(
                "train event out of order or duplicated: got batch {}, expected {}",
                e.batch_id,
                self.recorded
            );
        }
        self.recorded += 1;
        if self.keep {
            self.events.push(e);
        }
        Ok(())
    }

    /// Record a batch's full retirement; retires must be monotone and
    /// never precede the batch's train event.
    pub fn retire(&mut self, batch_id: u64) -> Result<()> {
        if batch_id != self.retired {
            bail!("retire order violated: got batch {batch_id}, expected {}", self.retired);
        }
        if batch_id >= self.recorded {
            bail!("batch {batch_id} retired before its train event");
        }
        self.retired += 1;
        Ok(())
    }

    /// Train events recorded so far.
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Retirements recorded so far.
    pub fn retired(&self) -> u64 {
        self.retired
    }

    /// All `feeds` events were recorded (none lost).
    pub fn expect_complete(&self, feeds: u64) -> Result<()> {
        if self.recorded != feeds {
            bail!("lost train events: {} of {feeds} recorded", self.recorded);
        }
        Ok(())
    }

    /// Hand back the kept events (empty for a validate-only ledger).
    pub fn into_events(self) -> Vec<TrainEvent> {
        self.events
    }
}

/// Input for one fed mini-batch.
#[derive(Debug, Clone)]
pub struct Feed {
    /// Monotone batch identifier (feed order).
    pub batch_id: u64,
    /// Per-batch dropout/shuffle seed threaded to every stage.
    pub seed: i32,
    /// The input mini-batch.
    pub x: Tensor,
    /// Integer class labels, one per sample.
    pub labels: IntTensor,
}

/// The cycle-accurate register pipeline of Figure 4 (plus the
/// non-pipelined `sequential_step` over the same executables).
pub struct Pipeline<E: StageExecutor> {
    /// The stage compute this pipeline drives.
    pub exec: E,
    p: usize,
    fwd_reg: Vec<Option<InFlight>>,
    bwd_reg: Vec<Option<GradMsg>>,
    /// Persistent scratch for the register-read phase: values taken from
    /// the registers at cycle start live here, so a steady-state cycle
    /// allocates no vectors and clones no tensors (§Perf).
    fwd_cur: Vec<Option<InFlight>>,
    bwd_cur: Vec<Option<GradMsg>>,
    fifos: Vec<ActivationFifo>,
    labels_q: VecDeque<(u64, IntTensor)>,
    cycle: u64,
    batch_size: usize,
    /// Feed/retire accounting (uncapped: the registers bound occupancy
    /// structurally). Shared with the threaded runtime's coordinator.
    flow: FlowControl,
}

impl<E: StageExecutor> Pipeline<E> {
    /// Build an empty (drained) pipeline over an executor.
    pub fn new(exec: E, batch_size: usize) -> Self {
        let p = exec.num_partitions();
        assert!(p >= 1);
        Pipeline {
            exec,
            p,
            fwd_reg: (0..p.saturating_sub(1)).map(|_| None).collect(),
            bwd_reg: (0..p.saturating_sub(1)).map(|_| None).collect(),
            fwd_cur: (0..p.saturating_sub(1)).map(|_| None).collect(),
            bwd_cur: (0..p.saturating_sub(1)).map(|_| None).collect(),
            fifos: (0..p.saturating_sub(1)).map(|_| ActivationFifo::default()).collect(),
            labels_q: VecDeque::new(),
            cycle: 0,
            batch_size,
            flow: FlowControl::new(None),
        }
    }

    /// Number of partitions P = K+1.
    pub fn num_partitions(&self) -> usize {
        self.p
    }

    /// Cycles executed so far (sequential steps count one cycle).
    pub fn cycles_run(&self) -> u64 {
        self.cycle
    }

    /// Feed/retire accounting for this pipeline.
    pub fn flow(&self) -> &FlowControl {
        &self.flow
    }

    /// Number of register pairs K.
    pub fn k(&self) -> usize {
        self.p - 1
    }

    /// True when no mini-batch is in flight.
    pub fn is_drained(&self) -> bool {
        self.fwd_reg.iter().all(Option::is_none)
            && self.bwd_reg.iter().all(Option::is_none)
            && self.fifos.iter().all(|f| f.len() == 0)
            && self.labels_q.is_empty()
    }

    /// Observed maximum FIFO depth per partition (staleness invariant:
    /// must equal 2(P-1-p)+1 at steady state).
    pub fn fifo_max_depths(&self) -> Vec<usize> {
        self.fifos.iter().map(|f| f.max_depth).collect()
    }

    /// Execute one pipeline cycle, optionally feeding a new mini-batch
    /// into FS_1. Returns a TrainEvent if the fused last stage ran.
    ///
    /// §Perf: the register-read snapshot goes into persistent scratch
    /// (`fwd_cur`/`bwd_cur`) and every in-flight payload is *moved* —
    /// into the executor, the activation FIFO, or the next register —
    /// so a steady-state cycle performs no tensor clones and no vector
    /// allocations beyond what the executor itself produces.
    pub fn cycle(&mut self, feed: Option<Feed>) -> Result<Option<TrainEvent>> {
        // ---- register reads: values written in previous cycles --------
        // (double buffering: `*_cur` is this cycle's read snapshot,
        // `*_reg` collects writes that become visible next cycle)
        for e in 0..self.p - 1 {
            self.fwd_cur[e] = self.fwd_reg[e].take();
            self.bwd_cur[e] = self.bwd_reg[e].take();
        }

        let mut feed_inflight = feed.map(|f| {
            self.labels_q.push_back((f.batch_id, f.labels));
            self.flow.record_fed();
            InFlight { batch_id: f.batch_id, seed: f.seed, carry: vec![f.x] }
        });

        // ---- forward stages 0..P-2 (cycle-start weights) --------------
        let mut event = None;
        for p in 0..self.p - 1 {
            let input = if p == 0 { feed_inflight.take() } else { self.fwd_cur[p - 1].take() };
            if let Some(inf) = input {
                let carry_out = self.exec.forward(p, inf.seed, &inf.carry)?;
                self.fwd_reg[p] =
                    Some(InFlight { batch_id: inf.batch_id, seed: inf.seed, carry: carry_out });
                self.fifos[p].push(inf);
            }
        }

        // ---- fused last stage ------------------------------------------
        let last_input =
            if self.p == 1 { feed_inflight.take() } else { self.fwd_cur[self.p - 2].take() };
        if let Some(inf) = last_input {
            let labels = match self.labels_q.pop_front() {
                Some((id, l)) if id == inf.batch_id => l,
                Some((id, _)) => bail!(
                    "label queue out of order: batch {} arrived, labels for {}",
                    inf.batch_id,
                    id
                ),
                None => bail!("label queue empty for batch {}", inf.batch_id),
            };
            let res = self.exec.last(inf.seed, &inf.carry, &labels)?;
            if self.p > 1 {
                self.bwd_reg[self.p - 2] =
                    Some(GradMsg { batch_id: inf.batch_id, gcarry: res.gcarry_in });
            } else {
                self.flow.record_retired();
            }
            event = Some(TrainEvent {
                batch_id: inf.batch_id,
                loss: res.loss,
                correct: res.correct,
                batch_size: self.batch_size,
                cycle: self.cycle,
            });
        }

        // ---- backward stages P-2..0 ------------------------------------
        for p in (0..self.p - 1).rev() {
            if let Some(g) = self.bwd_cur[p].take() {
                let saved = self.fifos[p].pop_for(g.batch_id)?;
                let gcarry_in = self.exec.backward(p, saved.seed, &saved.carry, &g.gcarry)?;
                if p > 0 {
                    self.bwd_reg[p - 1] = Some(GradMsg { batch_id: g.batch_id, gcarry: gcarry_in });
                } else {
                    self.flow.record_retired();
                }
            }
        }

        self.cycle += 1;
        Ok(event)
    }

    /// Run cycles without feeding until every in-flight batch has fully
    /// retired (hybrid-switch and end-of-training drain). Returns events
    /// from last-stage completions during the drain.
    pub fn drain(&mut self) -> Result<Vec<TrainEvent>> {
        let mut events = Vec::new();
        let mut guard = 0;
        while !self.is_drained() {
            if let Some(e) = self.cycle(None)? {
                events.push(e);
            }
            guard += 1;
            if guard > 4 * self.p as u64 + 8 {
                bail!("pipeline failed to drain after {guard} cycles");
            }
        }
        Ok(events)
    }

    /// Non-pipelined training step (paper's baseline): forward through
    /// all partitions, fused last, backward chain — all on one batch with
    /// immediate updates. Uses the same executables; only the schedule
    /// differs.
    pub fn sequential_step(&mut self, feed: Feed) -> Result<TrainEvent> {
        if !self.is_drained() {
            bail!("sequential_step on a non-drained pipeline");
        }
        let mut carry = vec![feed.x];
        let mut saved: Vec<Vec<Tensor>> = Vec::with_capacity(self.p - 1);
        for p in 0..self.p - 1 {
            saved.push(carry.clone());
            carry = self.exec.forward(p, feed.seed, &carry)?;
        }
        let res = self.exec.last(feed.seed, &carry, &feed.labels)?;
        let mut gcarry = res.gcarry_in;
        for p in (0..self.p - 1).rev() {
            gcarry = self.exec.backward(p, feed.seed, &saved[p], &gcarry)?;
        }
        self.cycle += 1;
        self.flow.record_fed();
        self.flow.record_retired();
        Ok(TrainEvent {
            batch_id: feed.batch_id,
            loss: res.loss,
            correct: res.correct,
            batch_size: self.batch_size,
            cycle: self.cycle - 1,
        })
    }

    /// Eval-mode forward through the whole chain; returns logits.
    pub fn eval_forward(&mut self, x: Tensor) -> Result<Tensor> {
        let mut carry = vec![x];
        for p in 0..self.p {
            carry = self.exec.eval_forward(p, &carry)?;
        }
        Ok(carry.remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::super::mock::MockExecutor;
    use super::*;
    use crate::util::prop;

    fn feed(b: u64) -> Feed {
        Feed {
            batch_id: b,
            seed: b as i32,
            x: Tensor::from_vec(&[1], vec![b as f32]).unwrap(),
            labels: IntTensor::from_vec(&[1], vec![0]).unwrap(),
        }
    }

    #[test]
    fn single_partition_is_sequential() {
        let mut pipe = Pipeline::new(MockExecutor::new(1), 1);
        for b in 0..5 {
            let e = pipe.cycle(Some(feed(b))).unwrap().unwrap();
            assert_eq!(e.batch_id, b);
        }
        assert!(pipe.is_drained());
        // every forward used fully-fresh weights
        for (b, v) in pipe.exec.last_versions.iter().enumerate() {
            assert_eq!(*v, b as u64, "batch {b}");
        }
    }

    #[test]
    fn staleness_matches_paper_formula() {
        // P=3 (K=2): partition p sees weights missing the last 2(P-1-p)
        // updates; version used by batch b must be max(0, b - 2(P-1-p)).
        let p = 3;
        let mut pipe = Pipeline::new(MockExecutor::new(p), 1);
        let batches = 12u64;
        let mut fed = 0;
        let mut done = 0;
        while done < batches {
            let f = if fed < batches {
                fed += 1;
                Some(feed(fed - 1))
            } else {
                None
            };
            if pipe.cycle(f).unwrap().is_some() {
                done += 1;
            }
        }
        pipe.drain().unwrap();
        for part in 0..p - 1 {
            let degree = 2 * (p - 1 - part) as u64;
            for (b, &v) in pipe.exec.fwd_versions[part].iter().enumerate() {
                let want = (b as u64).saturating_sub(degree);
                assert_eq!(v, want, "partition {part} batch {b}");
            }
        }
        // last partition always fresh
        for (b, &v) in pipe.exec.last_versions.iter().enumerate() {
            assert_eq!(v, b as u64);
        }
    }

    #[test]
    fn fifo_depth_is_2k_minus_2p_plus_1() {
        let p = 4;
        let mut pipe = Pipeline::new(MockExecutor::new(p), 1);
        for b in 0..20u64 {
            pipe.cycle(Some(feed(b))).unwrap();
        }
        pipe.drain().unwrap();
        let depths = pipe.fifo_max_depths();
        for (part, &d) in depths.iter().enumerate() {
            assert_eq!(d, 2 * (p - 1 - part) + 1, "partition {part}");
        }
    }

    #[test]
    fn bwd_uses_same_activations_as_fwd() {
        let p = 3;
        let mut pipe = Pipeline::new(MockExecutor::new(p), 1);
        for b in 0..10u64 {
            pipe.cycle(Some(feed(b))).unwrap();
        }
        pipe.drain().unwrap();
        // MockExecutor asserts batch-tagged activations internally; also
        // check every batch retired exactly once per partition.
        for part in 0..p - 1 {
            assert_eq!(pipe.exec.bwd_batches[part], (0..10u64).collect::<Vec<_>>());
        }
    }

    #[test]
    fn drain_completes_all_in_flight() {
        let p = 4;
        let mut pipe = Pipeline::new(MockExecutor::new(p), 1);
        let mut events = 0;
        for b in 0..6u64 {
            if pipe.cycle(Some(feed(b))).unwrap().is_some() {
                events += 1;
            }
        }
        let drained = pipe.drain().unwrap();
        assert_eq!(events + drained.len(), 6);
        assert!(pipe.is_drained());
        // updates: every batch updated every partition exactly once
        for v in &pipe.exec.versions {
            assert_eq!(*v, 6);
        }
    }

    #[test]
    fn sequential_step_equals_single_batch_pipeline() {
        // One batch fed into an otherwise empty pipe experiences zero
        // staleness, so it must match sequential_step exactly.
        let p = 3;
        let mut a = Pipeline::new(MockExecutor::new(p), 1);
        let mut b = Pipeline::new(MockExecutor::new(p), 1);
        a.sequential_step(feed(0)).unwrap();
        b.cycle(Some(feed(0))).unwrap();
        b.drain().unwrap();
        assert_eq!(a.exec.trace, b.exec.trace);
    }

    #[test]
    fn sequential_on_dirty_pipe_errors() {
        let mut pipe = Pipeline::new(MockExecutor::new(3), 1);
        pipe.cycle(Some(feed(0))).unwrap();
        assert!(pipe.sequential_step(feed(1)).is_err());
    }

    #[test]
    fn flow_control_caps_and_counts() {
        let mut f = FlowControl::new(Some(2));
        assert!(f.can_feed());
        f.record_fed();
        f.record_fed();
        assert!(!f.can_feed(), "cap of 2 must block the third feed");
        assert_eq!(f.in_flight(), 2);
        f.record_retired();
        assert!(f.can_feed());
        assert_eq!((f.fed(), f.retired(), f.in_flight()), (2, 1, 1));
        // uncapped never blocks
        let mut u = FlowControl::new(None);
        for _ in 0..100 {
            assert!(u.can_feed());
            u.record_fed();
        }
    }

    #[test]
    fn pipeline_flow_accounting_matches_schedule() {
        let mut pipe = Pipeline::new(MockExecutor::new(3), 1);
        for b in 0..6u64 {
            pipe.cycle(Some(feed(b))).unwrap();
        }
        assert_eq!(pipe.flow().fed(), 6);
        assert!(pipe.flow().in_flight() > 0, "batches must be mid-pipe before drain");
        pipe.drain().unwrap();
        assert_eq!(pipe.flow().retired(), 6);
        assert_eq!(pipe.flow().in_flight(), 0);
        // sequential steps feed and retire atomically
        pipe.sequential_step(feed(6)).unwrap();
        assert_eq!((pipe.flow().fed(), pipe.flow().retired()), (7, 7));
    }

    #[test]
    fn event_ledger_catches_loss_duplication_and_reorder() {
        let ev = |b: u64| TrainEvent {
            batch_id: b,
            loss: 0.0,
            correct: 0.0,
            batch_size: 1,
            cycle: b,
        };
        let mut l = EventLedger::keeping();
        l.record(ev(0)).unwrap();
        l.record(ev(1)).unwrap();
        assert!(l.record(ev(1)).is_err(), "duplicate event must be rejected");
        let mut l = EventLedger::new();
        l.record(ev(0)).unwrap();
        assert!(l.record(ev(2)).is_err(), "skipped event must be rejected");
        assert!(l.expect_complete(2).is_err(), "missing events must fail completion");
        let mut l = EventLedger::keeping();
        l.record(ev(0)).unwrap();
        l.retire(0).unwrap();
        assert!(l.retire(0).is_err(), "duplicate retire must be rejected");
        assert!(l.retire(2).is_err(), "out-of-order retire must be rejected");
        l.record(ev(1)).unwrap();
        l.retire(1).unwrap();
        l.expect_complete(2).unwrap();
        assert_eq!(l.retired(), 2);
        assert_eq!(l.into_events().len(), 2);
    }

    #[test]
    fn event_ledger_rejects_retire_before_event() {
        let mut l = EventLedger::new();
        assert!(l.retire(0).is_err(), "retire without a train event must fail");
    }

    #[test]
    fn event_ledger_resumes_at_absolute_batch_ids() {
        let ev = |b: u64| TrainEvent {
            batch_id: b,
            loss: 0.0,
            correct: 0.0,
            batch_size: 1,
            cycle: b,
        };
        // A resumed ledger expects the restart batch first, not batch 0.
        let mut l = EventLedger::keeping_from(5);
        assert!(l.record(ev(0)).is_err(), "pre-restart ids must be rejected");
        l.record(ev(5)).unwrap();
        l.retire(5).unwrap();
        l.record(ev(6)).unwrap();
        assert!(l.expect_complete(6).is_err(), "absolute count includes batch 6");
        l.retire(6).unwrap();
        l.expect_complete(7).unwrap();
        assert_eq!((l.recorded(), l.retired()), (7, 7));
        // Only the post-restart segment is kept.
        let events: Vec<u64> = l.into_events().iter().map(|e| e.batch_id).collect();
        assert_eq!(events, vec![5, 6]);
        // Validate-only variant behaves identically minus storage.
        let mut l = EventLedger::resume_from(2);
        l.record(ev(2)).unwrap();
        assert!(l.retire(1).is_err());
        l.retire(2).unwrap();
        assert!(l.into_events().is_empty());
    }

    #[test]
    fn prop_staleness_invariant_random_shapes() {
        // Property over (P, n_batches, stall pattern): staleness formula
        // holds for every partition and batch, with arbitrary feed gaps.
        prop::check(
            0xBEEF,
            40,
            |rng| {
                let p = 2 + rng.below(4) as usize; // 2..=5 partitions
                let n = 4 + rng.below(16) as u64;
                let gaps = rng.below(3) as usize; // every gaps-th cycle skips a feed
                (p, n as usize, gaps)
            },
            |&(p, n, gaps)| {
                let mut pipe = Pipeline::new(MockExecutor::new(p), 1);
                let mut b = 0u64;
                let mut cycle_idx = 0usize;
                while b < n as u64 {
                    let f = if gaps > 0 && cycle_idx % (gaps + 1) == gaps {
                        None // bubble: no feed this cycle
                    } else {
                        b += 1;
                        Some(feed(b - 1))
                    };
                    pipe.cycle(f).map_err(|e| e.to_string())?;
                    cycle_idx += 1;
                }
                pipe.drain().map_err(|e| e.to_string())?;
                // With bubbles the staleness bound becomes an inequality:
                // version used is at most b (fresh) and at least
                // b - 2(P-1-p) (paper's full-pipe staleness).
                for part in 0..p - 1 {
                    let degree = 2 * (p - 1 - part) as u64;
                    for (bi, &v) in pipe.exec.fwd_versions[part].iter().enumerate() {
                        let lo = (bi as u64).saturating_sub(degree);
                        if v < lo || v > bi as u64 {
                            return Err(format!(
                                "partition {part} batch {bi}: version {v} outside [{lo}, {bi}]"
                            ));
                        }
                        // with NO bubbles the bound is exact
                        if gaps == 0 && v != lo {
                            return Err(format!(
                                "partition {part} batch {bi}: version {v} != {lo}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
