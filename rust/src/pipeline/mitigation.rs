//! Staleness mitigation: the `--staleness-fix` axis (DESIGN.md §9).
//!
//! The paper answers deep-split accuracy collapse with the hybrid
//! schedule only; the related work names stronger *per-update* fixes.
//! This module implements three of them behind one seam so the
//! cycle-accurate scheduler and the threaded runtime get every fix for
//! free (the hooks live inside the per-partition stage compute, which
//! both runtimes share):
//!
//! * `stash` — PipeDream-style weight stashing (arXiv 1806.03377): a
//!   pool-backed FIFO ring of per-stage weight versions, pushed at
//!   forward time and popped at backward time, so each backward's
//!   recompute uses exactly the weights its forward saw. Pushing is a
//!   refcount bump per tensor (copy-on-write storage); a stashed
//!   version only materializes when the live weights are updated while
//!   it is still in flight, so the ring's *extra* footprint is at most
//!   `degree × param_bytes` per stage (accounted in [`crate::memory`]).
//! * `predict` — momentum-based weight prediction (arXiv 2003.11666):
//!   the forward runs on `w_hat = w - s·lr·velocity`, where `s` is the
//!   stage's in-flight staleness at feed time, approximating the
//!   weights the matching backward will see. Nothing persistent is
//!   mutated: the predicted tensors are scratch, velocity is read-only.
//! * `correct` — gradient damping toward the "Diversely Stale
//!   Parameters" correction (arXiv 1909.02625): the backward's
//!   gradient is rescaled by `1/(1+s)` with `s` the number of updates
//!   applied between this batch's forward and backward, shrinking
//!   exactly the updates whose linearization point is farthest away.
//!
//! Every fix measures staleness *at run time* (ring occupancy or
//! update-count delta, not the structural schedule degree), so all
//! three degenerate to **bitwise no-ops** at staleness 0 — sequential
//! mode, single-in-flight occupancy, the hybrid tail, and degraded
//! (post-failure) runs need no special-casing, which is what keeps the
//! repo's equivalence ladder (`tests/mitigation.rs`) sharp.

use std::collections::VecDeque;

use anyhow::{bail, ensure, Result};

use crate::optim::Sgd;
use crate::pool;
use crate::tensor::Tensor;

/// Which staleness fix a run applies (`--staleness-fix`), orthogonal
/// to `--backend` and `--runtime`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FixKind {
    /// Plain stale-weight training (the paper's baseline).
    #[default]
    None,
    /// PipeDream weight stashing: backward uses forward's weights.
    Stash,
    /// Momentum-based weight prediction at forward time.
    Predict,
    /// Staleness-damped gradient rescaling at backward time.
    Correct,
}

impl FixKind {
    /// Parse a CLI/JSON value.
    pub fn parse(s: &str) -> Result<FixKind> {
        match s {
            "none" => Ok(FixKind::None),
            "stash" => Ok(FixKind::Stash),
            "predict" => Ok(FixKind::Predict),
            "correct" => Ok(FixKind::Correct),
            other => bail!("unknown staleness fix '{other}' (use none | stash | predict | correct)"),
        }
    }

    /// Canonical CLI/JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            FixKind::None => "none",
            FixKind::Stash => "stash",
            FixKind::Predict => "predict",
            FixKind::Correct => "correct",
        }
    }

    /// Every fix, in CLI order (matrix drivers).
    pub fn all() -> [FixKind; 4] {
        [FixKind::None, FixKind::Stash, FixKind::Predict, FixKind::Correct]
    }
}

/// What a backward call must do differently under the active fix.
#[derive(Debug, Default)]
pub struct BackwardPlan {
    /// Weights the backward's forward-recompute must use (`None` =
    /// the live, stale-by-schedule weights — paper semantics).
    pub params: Option<Vec<Tensor>>,
    /// Scale applied to the weight gradients before the optimizer step
    /// (`1.0` = untouched, and callers must skip the multiply so the
    /// no-op stays bitwise).
    pub grad_scale: f32,
}

impl BackwardPlan {
    fn unchanged() -> Self {
        BackwardPlan { params: None, grad_scale: 1.0 }
    }
}

/// Observable counters of one stage's fix (memory-accounting tests and
/// diagnostics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixStats {
    /// The active fix.
    pub kind: FixKind,
    /// Entries currently in the ring (must be 0 on a drained pipe).
    pub ring_len: usize,
    /// High-water mark of ring entries (stash: stashed weight
    /// versions; predict/correct: in-flight batches tracked).
    pub ring_high_water: usize,
    /// High-water mark of stashed weight bytes (f32), counting every
    /// ring slot; `stash` only, 0 for the other fixes.
    pub stashed_bytes_high_water: usize,
}

impl FixStats {
    fn empty(kind: FixKind) -> Self {
        FixStats { kind, ring_len: 0, ring_high_water: 0, stashed_bytes_high_water: 0 }
    }
}

/// One stage's staleness-mitigation hooks. The stage compute calls
/// `on_forward` once per training forward (never for the fused last
/// stage or eval) and `on_backward` once per matching backward, in
/// FIFO order — exactly the activation-store discipline, so ring
/// occupancy at forward time *is* the batch's staleness degree.
pub trait StalenessFix: Send {
    /// Which fix this is.
    fn kind(&self) -> FixKind;

    /// Called at training-forward time with the live weights, the
    /// stage's optimizer (read-only) and its applied-update count.
    /// Returns replacement weights for this forward (`None` = live).
    fn on_forward(
        &mut self,
        live: &[Tensor],
        optim: &Sgd,
        update_count: usize,
    ) -> Result<Option<Vec<Tensor>>>;

    /// Called at backward time with the stage's current applied-update
    /// count; pops the oldest in-flight record.
    fn on_backward(&mut self, update_count: usize) -> Result<BackwardPlan>;

    /// Current counters (drain checks, memory-accounting tests).
    fn stats(&self) -> FixStats;
}

/// Build the hook implementation for a fix kind.
pub fn fix_for(kind: FixKind) -> Box<dyn StalenessFix> {
    match kind {
        FixKind::None => Box::new(NoFix),
        FixKind::Stash => Box::new(WeightStash::default()),
        FixKind::Predict => Box::new(WeightPredict::default()),
        FixKind::Correct => Box::new(GradCorrect::default()),
    }
}

/// The paper's baseline: no hooks, no state.
struct NoFix;

impl StalenessFix for NoFix {
    fn kind(&self) -> FixKind {
        FixKind::None
    }

    fn on_forward(&mut self, _: &[Tensor], _: &Sgd, _: usize) -> Result<Option<Vec<Tensor>>> {
        Ok(None)
    }

    fn on_backward(&mut self, _: usize) -> Result<BackwardPlan> {
        Ok(BackwardPlan::unchanged())
    }

    fn stats(&self) -> FixStats {
        FixStats::empty(FixKind::None)
    }
}

/// PipeDream weight stashing: FIFO ring of weight versions.
#[derive(Default)]
struct WeightStash {
    ring: VecDeque<Vec<Tensor>>,
    high_water: usize,
    bytes_high_water: usize,
}

impl StalenessFix for WeightStash {
    fn kind(&self) -> FixKind {
        FixKind::Stash
    }

    fn on_forward(&mut self, live: &[Tensor], _: &Sgd, _: usize) -> Result<Option<Vec<Tensor>>> {
        // Clones are refcount bumps on copy-on-write storage: a slot
        // costs real memory only once the live weights are updated
        // while it is in flight.
        self.ring.push_back(live.to_vec());
        self.high_water = self.high_water.max(self.ring.len());
        let param_scalars: usize = live.iter().map(Tensor::numel).sum();
        self.bytes_high_water = self.bytes_high_water.max(self.ring.len() * param_scalars * 4);
        // Forward itself runs on the freshest weights (PipeDream keeps
        // its newest stashed version == live between updates).
        Ok(None)
    }

    fn on_backward(&mut self, _: usize) -> Result<BackwardPlan> {
        match self.ring.pop_front() {
            Some(w) => Ok(BackwardPlan { params: Some(w), grad_scale: 1.0 }),
            None => bail!("weight stash underflow: backward without a matching forward"),
        }
    }

    fn stats(&self) -> FixStats {
        FixStats {
            kind: FixKind::Stash,
            ring_len: self.ring.len(),
            ring_high_water: self.high_water,
            stashed_bytes_high_water: self.bytes_high_water,
        }
    }
}

/// Momentum-based weight prediction: forward on `w - s·lr·velocity`.
#[derive(Default)]
struct WeightPredict {
    in_flight: usize,
    high_water: usize,
}

impl StalenessFix for WeightPredict {
    fn kind(&self) -> FixKind {
        FixKind::Predict
    }

    fn on_forward(
        &mut self,
        live: &[Tensor],
        optim: &Sgd,
        update_count: usize,
    ) -> Result<Option<Vec<Tensor>>> {
        let s = self.in_flight;
        self.in_flight += 1;
        self.high_water = self.high_water.max(self.in_flight);
        // Staleness 0 (sequential / single-in-flight / drained tail) or
        // nothing to extrapolate with yet: bitwise no-op.
        if s == 0 || !optim.has_velocity() {
            return Ok(None);
        }
        let shift = s as f32 * optim.effective_lr(update_count);
        if shift == 0.0 {
            return Ok(None);
        }
        let mut out = Vec::with_capacity(live.len());
        for (i, w) in live.iter().enumerate() {
            match optim.velocity(i) {
                Some(v) => {
                    ensure!(
                        v.len() == w.numel(),
                        "predict: velocity {i} has {} elements, param has {}",
                        v.len(),
                        w.numel()
                    );
                    let mut buf = pool::acquire(w.numel());
                    for ((o, &wv), &vv) in
                        buf.as_mut_slice().iter_mut().zip(w.data()).zip(v.iter())
                    {
                        *o = wv - shift * vv;
                    }
                    out.push(Tensor::from_pooled(w.shape.as_slice(), buf)?);
                }
                None => out.push(w.clone()),
            }
        }
        Ok(Some(out))
    }

    fn on_backward(&mut self, _: usize) -> Result<BackwardPlan> {
        ensure!(self.in_flight > 0, "predict underflow: backward without a matching forward");
        self.in_flight -= 1;
        // The backward recomputes at the live weights (paper
        // semantics); prediction only moved the forward.
        Ok(BackwardPlan::unchanged())
    }

    fn stats(&self) -> FixStats {
        FixStats {
            kind: FixKind::Predict,
            ring_len: self.in_flight,
            ring_high_water: self.high_water,
            stashed_bytes_high_water: 0,
        }
    }
}

/// Staleness-damped gradient rescaling: `g ← g / (1 + s)`.
#[derive(Default)]
struct GradCorrect {
    fed_at: VecDeque<usize>,
    high_water: usize,
}

impl StalenessFix for GradCorrect {
    fn kind(&self) -> FixKind {
        FixKind::Correct
    }

    fn on_forward(&mut self, _: &[Tensor], _: &Sgd, update_count: usize) -> Result<Option<Vec<Tensor>>> {
        self.fed_at.push_back(update_count);
        self.high_water = self.high_water.max(self.fed_at.len());
        Ok(None)
    }

    fn on_backward(&mut self, update_count: usize) -> Result<BackwardPlan> {
        let at = match self.fed_at.pop_front() {
            Some(a) => a,
            None => bail!("correct underflow: backward without a matching forward"),
        };
        // s = updates applied between this batch's forward and its
        // backward; 0 in sequential/single-in-flight mode, where the
        // scale of 1.0 is skipped entirely by the caller (bitwise
        // no-op).
        let s = update_count.saturating_sub(at);
        Ok(BackwardPlan { params: None, grad_scale: 1.0 / (1.0 + s as f32) })
    }

    fn stats(&self) -> FixStats {
        FixStats {
            kind: FixKind::Correct,
            ring_len: self.fed_at.len(),
            ring_high_water: self.high_water,
            stashed_bytes_high_water: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Schedule;

    fn t(v: &[f32]) -> Tensor {
        Tensor::from_vec(&[v.len()], v.to_vec()).unwrap()
    }

    #[test]
    fn fix_kind_parse_name_roundtrip() {
        for k in FixKind::all() {
            assert_eq!(FixKind::parse(k.name()).unwrap(), k);
        }
        assert!(FixKind::parse("pipedream").is_err());
        assert_eq!(FixKind::default(), FixKind::None);
    }

    #[test]
    fn none_is_inert() {
        let mut f = fix_for(FixKind::None);
        let o = Sgd::new(Schedule::Const { base: 0.1 }, 0.9, false, 0.0);
        assert!(f.on_forward(&[t(&[1.0])], &o, 0).unwrap().is_none());
        let plan = f.on_backward(0).unwrap();
        assert!(plan.params.is_none());
        assert_eq!(plan.grad_scale, 1.0);
        assert_eq!(f.stats(), FixStats::empty(FixKind::None));
    }

    #[test]
    fn stash_pops_the_pushed_version_despite_later_updates() {
        // The defining PipeDream invariant at the unit level: the
        // popped entry is bitwise the weights pushed at forward time,
        // even after the live tensors were mutated in between.
        let mut f = fix_for(FixKind::Stash);
        let o = Sgd::new(Schedule::Const { base: 0.1 }, 0.0, false, 0.0);
        let mut live = vec![t(&[1.0, 2.0])];
        f.on_forward(&live, &o, 0).unwrap();
        live[0].data_mut().copy_from_slice(&[9.0, 9.0]); // simulated update
        f.on_forward(&live, &o, 1).unwrap();
        assert_eq!(f.stats().ring_high_water, 2);
        assert_eq!(f.stats().stashed_bytes_high_water, 2 * 2 * 4);
        let first = f.on_backward(1).unwrap().params.unwrap();
        assert_eq!(first[0].data(), &[1.0, 2.0], "stash must preserve forward-time weights");
        let second = f.on_backward(1).unwrap().params.unwrap();
        assert_eq!(second[0].data(), &[9.0, 9.0]);
        assert_eq!(f.stats().ring_len, 0);
        assert!(f.on_backward(1).is_err(), "underflow must be loud");
    }

    #[test]
    fn predict_is_noop_at_staleness_zero_and_shifts_otherwise() {
        let mut o = Sgd::new(Schedule::Const { base: 0.5 }, 0.9, false, 0.0);
        let mut p = vec![t(&[0.0, 0.0])];
        o.step(0, &mut p, &[t(&[1.0, -2.0])]).unwrap(); // velocity = [1, -2]
        let mut f = fix_for(FixKind::Predict);
        // s = 0: bitwise no-op
        assert!(f.on_forward(&p, &o, 1).unwrap().is_none());
        // s = 1: w_hat = w - 1*lr*v
        let out = f.on_forward(&p, &o, 1).unwrap().unwrap();
        let w = p[0].data();
        let want = [w[0] - 0.5 * 1.0, w[1] - 0.5 * (-2.0)];
        assert_eq!(out[0].data(), &want);
        assert_eq!(f.stats().ring_high_water, 2);
        f.on_backward(1).unwrap();
        f.on_backward(1).unwrap();
        assert_eq!(f.stats().ring_len, 0);
        assert!(f.on_backward(1).is_err());
    }

    #[test]
    fn predict_without_velocity_is_noop() {
        // Vanilla SGD (momentum 0) never allocates velocity: nothing to
        // extrapolate with, so prediction must stand down.
        let o = Sgd::new(Schedule::Const { base: 0.5 }, 0.0, false, 0.0);
        let mut f = fix_for(FixKind::Predict);
        let live = vec![t(&[1.0])];
        assert!(f.on_forward(&live, &o, 0).unwrap().is_none());
        assert!(f.on_forward(&live, &o, 0).unwrap().is_none(), "s=1 but no velocity");
    }

    #[test]
    fn correct_scales_by_update_count_delta() {
        let mut f = fix_for(FixKind::Correct);
        let o = Sgd::new(Schedule::Const { base: 0.1 }, 0.9, false, 0.0);
        let live = vec![t(&[1.0])];
        f.on_forward(&live, &o, 5).unwrap(); // fed at update 5
        f.on_forward(&live, &o, 5).unwrap();
        // backward after 3 intervening updates: s = 3
        let plan = f.on_backward(8).unwrap();
        assert!((plan.grad_scale - 0.25).abs() < 1e-7);
        // staleness 0: exact 1.0 (callers skip the multiply)
        let plan = f.on_backward(5).unwrap();
        assert_eq!(plan.grad_scale, 1.0);
        assert!(f.on_backward(5).is_err());
    }
}
