//! The paper's coordination contribution: pipelined backpropagation with
//! unconstrained stale weights.
//!
//! * `scheduler` — cycle-accurate register pipeline (Figure 4) +
//!   non-pipelined sequential mode over the same executables;
//! * `executor`/`engine` — XLA-backed stage compute with coordinator-
//!   owned weights (and the mock used by property tests);
//! * `staleness` — paper §3 accounting (degree, % stale weights);
//! * `mitigation` — the `--staleness-fix` axis: PipeDream weight
//!   stashing, momentum weight prediction, gradient damping (§9);
//! * `hybrid` — paper §4 schedule switching;
//! * `threaded` — executor-generic thread-per-accelerator runtime with
//!   channel registers (native or XLA workers, real concurrency);
//! * `faults` — deterministic fault injection (scripted panics, stalls,
//!   checkpoint corruption) for soak-testing the recovery paths;
//! * `perfsim` — discrete-event timing model for Table 5 speedups.

pub mod engine;
pub mod executor;
pub mod faults;
pub mod hybrid;
pub mod mitigation;
pub mod mock;
pub mod perfsim;
pub mod scheduler;
pub mod staleness;
pub mod threaded;

pub use crate::backend::NativeExecutor;
pub use executor::{LastResult, StageExecutor, WorkerStage, XlaExecutor};
pub use faults::{Fault, FaultInjector, FaultKind, FaultPlan, FaultyWorkerBackend};
pub use hybrid::{HybridSchedule, Phase};
pub use mitigation::{fix_for, BackwardPlan, FixKind, FixStats, StalenessFix};
pub use scheduler::{EventLedger, Feed, FlowControl, Pipeline, TrainEvent};
pub use staleness::StalenessReport;
pub use threaded::{
    Heartbeat, NativeWorkerBackend, Occupancy, ThreadedOptions, ThreadedPipeline, WorkerBackend,
    XlaWorkerBackend,
};
