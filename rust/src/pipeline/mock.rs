//! Deterministic mock executor for scheduler/staleness property tests.
//!
//! Batch identity is threaded through the data plane itself: every carry
//! and gradient tensor holds the batch id as its single element, so the
//! mock can verify that (a) forwards see the batch the registers say they
//! should, (b) backward receives the *same* saved activations as its
//! forward, and (c) weight versions evolve exactly per the paper's
//! staleness formula (asserted by the tests in scheduler.rs).

use anyhow::{ensure, Result};

use crate::tensor::{IntTensor, Tensor};

use super::executor::{LastResult, StageExecutor};

/// The deterministic mock: batch-tagged tensors, versioned "weights",
/// a flat call trace (see the module docs).
pub struct MockExecutor {
    p: usize,
    /// Per-partition applied-update count (the "weight version").
    pub versions: Vec<u64>,
    /// versions observed by forward, per partition, in batch order.
    pub fwd_versions: Vec<Vec<u64>>,
    /// versions observed by the fused last stage, in batch order.
    pub last_versions: Vec<u64>,
    /// retirement order of backward per partition.
    pub bwd_batches: Vec<Vec<u64>>,
    /// Flat call trace for equality tests.
    pub trace: Vec<String>,
}

fn tag(t: &[Tensor]) -> u64 {
    t[0].data()[0] as u64
}

fn tagged(b: u64) -> Vec<Tensor> {
    // Pooled construction: the mock's data plane recycles backing
    // stores exactly like the XLA executor's, so scheduler benches and
    // the zero-alloc steady-state test measure the real cycle behavior.
    vec![Tensor::filled(&[1], b as f32)]
}

impl MockExecutor {
    /// Mock over `p` partitions, all counters zeroed.
    pub fn new(p: usize) -> Self {
        MockExecutor {
            p,
            versions: vec![0; p],
            fwd_versions: vec![Vec::new(); p.saturating_sub(1)],
            last_versions: Vec::new(),
            bwd_batches: vec![Vec::new(); p.saturating_sub(1)],
            trace: Vec::new(),
        }
    }
}

impl StageExecutor for MockExecutor {
    fn num_partitions(&self) -> usize {
        self.p
    }

    fn forward(&mut self, p: usize, _seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        let b = tag(carry);
        ensure!(
            self.fwd_versions[p].len() as u64 == b,
            "forward at partition {p} out of batch order: got {b}, expected {}",
            self.fwd_versions[p].len()
        );
        self.fwd_versions[p].push(self.versions[p]);
        self.trace.push(format!("fwd p{p} b{b} v{}", self.versions[p]));
        Ok(tagged(b))
    }

    fn last(&mut self, _seed: i32, carry: &[Tensor], _labels: &IntTensor) -> Result<LastResult> {
        let b = tag(carry);
        ensure!(
            self.last_versions.len() as u64 == b,
            "last stage out of batch order: got {b}, expected {}",
            self.last_versions.len()
        );
        self.last_versions.push(self.versions[self.p - 1]);
        self.trace.push(format!("last b{b} v{}", self.versions[self.p - 1]));
        self.versions[self.p - 1] += 1;
        Ok(LastResult { loss: b as f32, correct: 1.0, gcarry_in: tagged(b) })
    }

    fn backward(
        &mut self,
        p: usize,
        _seed: i32,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let b_act = tag(carry_in);
        let b_grad = tag(gcarry_out);
        ensure!(
            b_act == b_grad,
            "backward at partition {p}: activations of batch {b_act} paired with gradient of batch {b_grad}"
        );
        self.bwd_batches[p].push(b_grad);
        self.trace.push(format!("bwd p{p} b{b_grad}"));
        self.versions[p] += 1;
        Ok(tagged(b_grad))
    }

    fn eval_forward(&mut self, _p: usize, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        Ok(carry.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_tags_roundtrip() {
        let mut m = MockExecutor::new(3);
        let out = m.forward(0, 0, &tagged(0)).unwrap();
        assert_eq!(tag(&out), 0);
        let r = m
            .last(0, &tagged(0), &IntTensor::from_vec(&[1], vec![0]).unwrap())
            .unwrap();
        assert_eq!(r.loss, 0.0);
        assert_eq!(m.versions, vec![0, 0, 1]);
    }

    #[test]
    fn mock_detects_mismatched_grad_pairing() {
        let mut m = MockExecutor::new(2);
        assert!(m.backward(0, 0, &tagged(1), &tagged(2)).is_err());
    }
}
