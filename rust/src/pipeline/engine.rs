//! PartitionEngine: one partition's programs + weights + optimizer.
//!
//! The single-process `XlaExecutor` holds a vector of these; each worker
//! thread of the threaded runtime owns exactly one (its "accelerator"
//! state), mirroring the paper's one-partition-per-GPU deployment.
//!
//! §Perf: each engine owns an `InputScratch` so the positional literal
//! list is assembled into a persistent buffer, and stage outputs are
//! split by moving tensors out of the result vec (no per-call clones of
//! gradient or carry tensors).

use anyhow::{anyhow, ensure, Result};

use crate::meta::PartitionMeta;
use crate::model::PartitionParams;
use crate::optim::Sgd;
use crate::runtime::{InputScratch, StagePrograms};
use crate::tensor::{IntTensor, Tensor};

use super::executor::LastResult;
use super::mitigation::{fix_for, FixKind, FixStats, StalenessFix};

/// One partition's XLA-backed compute: compiled stage programs, the
/// partition's weights/state, and its SGD optimizer.
pub struct PartitionEngine {
    /// The partition's recorded contract (layouts, carry shapes).
    pub meta: PartitionMeta,
    /// Compiled stage programs (`fwd`/`bwd`/`last`/`*_eval`).
    pub programs: StagePrograms,
    /// The partition's weights and functional state.
    pub params: PartitionParams,
    /// Per-partition SGD optimizer.
    pub optim: Sgd,
    /// Weight updates applied so far — the LR-schedule position, seeded
    /// from `params.version` so checkpoint restores continue the
    /// schedule where they left off.
    pub update_count: usize,
    scratch: InputScratch,
    /// Active staleness mitigation (DESIGN.md §9); `none` by default.
    fix: Box<dyn StalenessFix>,
}

impl PartitionEngine {
    /// Wire programs + weights + optimizer into an engine.
    pub fn new(
        meta: PartitionMeta,
        programs: StagePrograms,
        params: PartitionParams,
        optim: Sgd,
    ) -> Self {
        let update_count = params.version as usize;
        PartitionEngine {
            meta,
            programs,
            params,
            optim,
            update_count,
            scratch: InputScratch::new(),
            fix: fix_for(FixKind::None),
        }
    }

    /// Install a staleness fix (DESIGN.md §9). Must be called on a
    /// drained engine (no batch in flight).
    pub fn set_staleness_fix(&mut self, kind: FixKind) {
        self.fix = fix_for(kind);
    }

    /// The active fix's observable counters.
    pub fn fix_stats(&self) -> FixStats {
        self.fix.stats()
    }

    fn take_state(&mut self, outputs: &mut Vec<Tensor>, n_keep: usize) {
        let ns = self.params.state.len();
        debug_assert_eq!(outputs.len(), n_keep + ns);
        for (i, t) in outputs.drain(n_keep..).enumerate() {
            self.params.state[i] = t;
        }
    }

    fn apply_update(&mut self, grads: &[Tensor]) -> Result<()> {
        self.optim.step(self.update_count, &mut self.params.params, grads)?;
        self.update_count += 1;
        self.params.version += 1;
        Ok(())
    }

    /// Training forward: commits BN-state updates, never touches
    /// weights; returns the carry_out. Engages the active staleness
    /// fix (stash push / weight prediction).
    pub fn forward(&mut self, seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        let over = self.fix.on_forward(&self.params.params, &self.optim, self.update_count)?;
        let prog = self
            .programs
            .fwd
            .as_ref()
            .ok_or_else(|| anyhow!("partition {} has no fwd program", self.meta.index))?;
        self.scratch.clear();
        self.scratch.push_tensors(over.as_deref().unwrap_or(&self.params.params))?;
        self.scratch.push_tensors(&self.params.state)?;
        self.scratch.push_seed(seed);
        self.scratch.push_tensors(carry)?;
        let mut out = prog.run(self.scratch.literals())?;
        let n_carry = self.meta.carry_out.len();
        self.take_state(&mut out, n_carry);
        Ok(out)
    }

    /// Fused last stage: forward + loss + backward + weight update.
    pub fn last(&mut self, seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult> {
        let prog = self
            .programs
            .last
            .as_ref()
            .ok_or_else(|| anyhow!("partition {} has no last program", self.meta.index))?;
        self.scratch.clear();
        self.scratch.push_tensors(&self.params.params)?;
        self.scratch.push_tensors(&self.params.state)?;
        self.scratch.push_seed(seed);
        self.scratch.push_tensors(carry)?;
        self.scratch.push_ints(labels)?;
        let mut out = prog.run(self.scratch.literals())?;
        let n_carry = self.meta.carry_in.len();
        let n_params = self.params.params.len();
        let keep = 2 + n_carry + n_params;
        ensure!(
            out.len() == keep + self.params.state.len(),
            "last stage of partition {} returned {} outputs, want {}",
            self.meta.index,
            out.len(),
            keep + self.params.state.len()
        );
        let loss = out[0].scalar();
        let correct = out[1].scalar();
        self.take_state(&mut out, keep);
        // out is now [loss, correct, gcarry.., dparams..]; move the
        // tails out instead of cloning them.
        let grads: Vec<Tensor> = out.drain(2 + n_carry..).collect();
        let gcarry: Vec<Tensor> = out.drain(2..).collect();
        self.apply_update(&grads)?;
        Ok(LastResult { loss, correct, gcarry_in: gcarry })
    }

    /// Backward on the saved carry_in of the same mini-batch; applies
    /// the weight update; returns gcarry_in.
    pub fn backward(
        &mut self,
        seed: i32,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let plan = self.fix.on_backward(self.update_count)?;
        let prog = self
            .programs
            .bwd
            .as_ref()
            .ok_or_else(|| anyhow!("partition {} has no bwd program", self.meta.index))?;
        self.scratch.clear();
        // Stash: the recompute runs on the weights the forward saw.
        self.scratch
            .push_tensors(plan.params.as_deref().unwrap_or(&self.params.params))?;
        self.scratch.push_tensors(&self.params.state)?;
        self.scratch.push_seed(seed);
        self.scratch.push_tensors(carry_in)?;
        self.scratch.push_tensors(gcarry_out)?;
        let mut out = prog.run(self.scratch.literals())?;
        let n_carry_in = self.meta.carry_in.len();
        let mut grads: Vec<Tensor> = out.drain(n_carry_in..).collect();
        if plan.grad_scale != 1.0 {
            for gt in &mut grads {
                for v in gt.data_mut() {
                    *v *= plan.grad_scale;
                }
            }
        }
        self.apply_update(&grads)?;
        Ok(out)
    }

    /// True for the fused-last partition.
    pub fn is_last(&self) -> bool {
        self.meta.is_last()
    }

    /// Hand the weights back (threaded worker shutdown).
    pub fn into_params(self) -> PartitionParams {
        self.params
    }

    /// Eval-mode forward (running BN statistics; logits on the last
    /// partition).
    pub fn eval_forward(&mut self, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        let prog = if self.meta.is_last() {
            self.programs.last_eval.as_ref()
        } else {
            self.programs.fwd_eval.as_ref()
        }
        .ok_or_else(|| anyhow!("partition {} has no eval program", self.meta.index))?;
        self.scratch.clear();
        self.scratch.push_tensors(&self.params.params)?;
        self.scratch.push_tensors(&self.params.state)?;
        self.scratch.push_tensors(carry)?;
        prog.run(self.scratch.literals())
    }
}
