//! Discrete-event performance simulator (Table 5 substitution).
//!
//! The paper measures wall-clock speedup of 4-stage pipelined training on
//! 2 GPUs. This testbed has one CPU core, so parallel wall-clock speedup
//! is physically unobservable; instead we simulate the accelerator
//! timeline: workers process stage tasks with *measured* (or analytic)
//! per-stage costs, pipeline registers impose host-staged communication
//! delays (the paper's GPU->CPU->GPU copies), and the simulator reports
//! the makespan of N training iterations. Speedup = simulated
//! non-pipelined time / simulated pipelined time — the same arithmetic
//! the paper's measurement resolves, with fill/drain effects included.
//!
//! Worker mappings:
//! * `Paired` — K+1 workers, worker p runs FS_p and BKS_p (one weight
//!   copy per device; the paper's 2-GPU setup for 4-stage pipelines).
//! * `Full`   — 2K+1 workers, separate forward/backward accelerators
//!   (the paper's general scheme, FS_{K+1}+BKS_1 fused).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{ensure, Result};

use crate::backend::BWD_FLOPS_FACTOR;

/// Per-partition compute costs in seconds.
#[derive(Debug, Clone)]
pub struct StageCosts {
    /// Forward time per partition, seconds.
    pub fwd: Vec<f64>,
    /// Backward time per partition, seconds.
    pub bwd: Vec<f64>,
    /// Bytes of activations crossing register e (one direction);
    /// gradients are assumed symmetric.
    pub edge_bytes: Vec<f64>,
}

impl StageCosts {
    /// Number of partitions the cost vectors describe.
    pub fn num_partitions(&self) -> usize {
        self.fwd.len()
    }

    /// Scale compute and traffic to a different batch size (both are
    /// linear in batch; meta-only configs carry batch=1).
    pub fn scale_batch(&self, factor: f64) -> StageCosts {
        StageCosts {
            fwd: self.fwd.iter().map(|t| t * factor).collect(),
            bwd: self.bwd.iter().map(|t| t * factor).collect(),
            edge_bytes: self.edge_bytes.iter().map(|b| b * factor).collect(),
        }
    }
}

/// Communication model: host-staged copy (device->host->device).
#[derive(Debug, Clone)]
pub struct CommModel {
    /// Effective one-hop bandwidth in bytes/s (applied twice: via host).
    pub bandwidth: f64,
    /// Fixed per-message latency in seconds (applied twice).
    pub latency: f64,
    /// 1.0 = direct peer copy, 2.0 = staged through the host (paper §5).
    pub hops: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        // PCIe 3.0 x16-ish effective bandwidth, small launch latency.
        CommModel { bandwidth: 6e9, latency: 30e-6, hops: 2.0 }
    }
}

impl CommModel {
    /// Register-crossing delay for a message of `bytes`.
    pub fn delay(&self, bytes: f64) -> f64 {
        self.hops * (self.latency + bytes / self.bandwidth)
    }

    /// Communication-free (the paper's 1-GPU baseline).
    pub fn free() -> Self {
        CommModel { bandwidth: f64::INFINITY, latency: 0.0, hops: 0.0 }
    }
}

/// Stage-to-accelerator mapping (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mapping {
    /// K+1 workers: worker p runs both FS_p and BKS_p.
    Paired,
    /// 2K+1 workers: separate forward/backward accelerators.
    Full,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Task {
    Fwd(usize),
    /// Fused FS_{P-1}+BKS_{P-1} (the paper's co-located last stages).
    Last,
    Bwd(usize),
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    worker: usize,
    task: Task,
    batch: u64,
}

// BinaryHeap ordering by time (min-heap via Reverse on bits).
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.partial_cmp(&other.time).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Simulate `n_batches` of pipelined training; returns makespan seconds.
///
/// With the analytic FLOPs cost model this runs entirely offline from
/// a built-in native config:
///
/// ```
/// use pipestale::pipeline::perfsim::{
///     analytic_costs, simulate_nonpipelined, simulate_pipelined, CommModel, Mapping,
/// };
/// let meta = pipestale::backend::native_config("lenet5_4s").unwrap();
/// let costs = analytic_costs(&meta, 50e9); // 50 GFLOP/s accelerators
/// let tp = simulate_pipelined(&costs, &CommModel::free(), Mapping::Paired, 100);
/// let tn = simulate_nonpipelined(&costs, 100);
/// assert!(tn / tp > 1.0, "pipelining must beat the 1-accelerator baseline");
/// ```
pub fn simulate_pipelined(
    costs: &StageCosts,
    comm: &CommModel,
    mapping: Mapping,
    n_batches: u64,
) -> f64 {
    let p = costs.num_partitions();
    assert!(p >= 1);
    if p == 1 {
        return n_batches as f64 * (costs.fwd[0] + costs.bwd[0]);
    }
    let worker_of = |t: Task| -> usize {
        match (mapping, t) {
            (Mapping::Paired, Task::Fwd(q)) => q,
            (Mapping::Paired, Task::Bwd(q)) => q,
            (Mapping::Paired, Task::Last) => p - 1,
            (Mapping::Full, Task::Fwd(q)) => q,
            // last fused pair lives on worker p-1; BKS_q for q<p-1 on
            // workers p..2p-2 (2K+1 accelerators total)
            (Mapping::Full, Task::Last) => p - 1,
            (Mapping::Full, Task::Bwd(q)) => p + (p - 2 - q),
        }
    };
    let n_workers = match mapping {
        Mapping::Paired => p,
        Mapping::Full => 2 * p - 1,
    };
    let cost_of = |t: Task| -> f64 {
        match t {
            Task::Fwd(q) => costs.fwd[q],
            Task::Last => costs.fwd[p - 1] + costs.bwd[p - 1],
            Task::Bwd(q) => costs.bwd[q],
        }
    };

    // Arrival events (message ready at worker) -> queue; workers pull
    // FIFO when free.
    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    let mut queues: Vec<std::collections::VecDeque<(Task, u64)>> =
        (0..n_workers).map(|_| Default::default()).collect();
    let mut free_at: Vec<f64> = vec![0.0; n_workers];
    let mut makespan = 0.0f64;
    let mut retired = 0u64;

    // Feed: batch b is available to FS_0 at time 0 (the input pipeline is
    // not the bottleneck in the paper's setup).
    for b in 0..n_batches {
        heap.push(Reverse(Event { time: 0.0, worker: worker_of(Task::Fwd(0)), task: Task::Fwd(0), batch: b }));
    }

    // Completion bookkeeping: we process arrival events; a worker starts
    // its queue head when free. We model this by draining arrivals in
    // time order and greedily scheduling.
    while let Some(Reverse(ev)) = heap.pop() {
        let w = ev.worker;
        queues[w].push_back((ev.task, ev.batch));
        // try to run everything queued on this worker starting at
        // max(free_at, arrival time)
        while let Some(&(task, batch)) = queues[w].front() {
            let start = free_at[w].max(ev.time);
            let finish = start + cost_of(task);
            // Only run if this queue head's message has actually arrived
            // (it has: it is in the queue). Run it.
            queues[w].pop_front();
            free_at[w] = finish;
            makespan = makespan.max(finish);
            // Emit the successor message. The send is *blocking* on the
            // sending accelerator (the paper's host-staged PyTorch
            // copies, §5), so its delay is charged to the sender's
            // occupancy as well as to the message arrival time — this is
            // what makes communication overhead eat into throughput and
            // produces Table 5's depth trend.
            let mut send = |bytes: f64, nt: Task, nw: usize| {
                let delay = comm.delay(bytes);
                free_at[w] += delay;
                makespan = makespan.max(free_at[w]);
                heap.push(Reverse(Event { time: finish + delay, worker: nw, task: nt, batch }));
            };
            match task {
                Task::Fwd(q) => {
                    let (nt, nw) = if q + 1 == p - 1 {
                        (Task::Last, worker_of(Task::Last))
                    } else {
                        (Task::Fwd(q + 1), worker_of(Task::Fwd(q + 1)))
                    };
                    send(costs.edge_bytes[q], nt, nw);
                }
                Task::Last => {
                    if p >= 2 {
                        send(costs.edge_bytes[p - 2], Task::Bwd(p - 2), worker_of(Task::Bwd(p - 2)));
                    } else {
                        retired += 1;
                    }
                }
                Task::Bwd(q) => {
                    if q == 0 {
                        retired += 1;
                    } else {
                        send(costs.edge_bytes[q - 1], Task::Bwd(q - 1), worker_of(Task::Bwd(q - 1)));
                    }
                }
            }
        }
    }
    assert_eq!(retired, n_batches, "DES lost batches");
    makespan
}

/// Non-pipelined baseline: one communication-free accelerator running
/// the whole model per batch (the paper's baseline definition, §6.1).
pub fn simulate_nonpipelined(costs: &StageCosts, n_batches: u64) -> f64 {
    let per_iter: f64 =
        costs.fwd.iter().sum::<f64>() + costs.bwd.iter().sum::<f64>();
    n_batches as f64 * per_iter
}

/// Hybrid: n_p pipelined iterations + (n - n_p) non-pipelined (paper §4).
pub fn simulate_hybrid(
    costs: &StageCosts,
    comm: &CommModel,
    mapping: Mapping,
    n_batches: u64,
    n_pipelined: u64,
) -> f64 {
    let np = n_pipelined.min(n_batches);
    simulate_pipelined(costs, comm, mapping, np)
        + simulate_nonpipelined(costs, n_batches - np)
}

/// Paper §4 closed-form hybrid speedup upper bound with 2K+1 accelerators.
///
/// Degenerate inputs are guarded the same way PR 2 fixed
/// `HybridSchedule::ideal_speedup(0)`: a schedule with no iterations at
/// all (`n_np <= 0`) has nothing to speed up and returns 1.0 — finite,
/// not the raw formula's 0/0 NaN — and the pipelined count is clamped
/// into `[0, n_np]`, where the unclamped formula would return a
/// negative or above-`2K+1` "speedup". Within that domain the result
/// always lies in `[1, 2K+1]`.
pub fn hybrid_speedup_bound(n_np: f64, n_p: f64, k: usize) -> f64 {
    if !(n_np > 0.0) {
        return 1.0;
    }
    let n_p = n_p.clamp(0.0, n_np);
    n_np / (n_p / (2.0 * k as f64 + 1.0) + (n_np - n_p))
}

/// Bytes crossing each internal pipeline register per iteration (one
/// entry per partition *boundary*, so `partitions.len() - 1` entries):
/// 4 bytes per scalar over a partition's carry_out tensors. Shared by
/// [`analytic_costs`] and [`roofline_costs`] — this was copy-pasted in
/// both, and both underflowed `len() - 1` on a zero-partition meta
/// (legal for meta-only tooling); `saturating_sub` makes the
/// degenerate case simply have no edges.
fn edge_bytes_of(meta: &crate::meta::ConfigMeta) -> Vec<f64> {
    meta.partitions
        .iter()
        .take(meta.partitions.len().saturating_sub(1))
        .map(|p| {
            p.carry_out
                .iter()
                .map(|s| s.iter().product::<usize>() as f64 * 4.0)
                .sum()
        })
        .collect()
}

/// Analytic per-partition costs from the meta.json FLOPs model (bwd is
/// the canonical ~2x fwd); edge bytes are the register carry tensors.
/// Used for meta-only configs (ResNet-224/362) and as the perfsim CLI
/// default; benches calibrate with measured stage times instead.
pub fn analytic_costs(meta: &crate::meta::ConfigMeta, flops_per_s: f64) -> StageCosts {
    let batch = meta.batch as f64;
    let mut fwd = Vec::new();
    let mut bwd = Vec::new();
    for p in &meta.partitions {
        let fl: f64 = meta.layers[p.layer_lo - 1..p.layer_hi]
            .iter()
            .map(|l| l.flops_per_sample as f64)
            .sum();
        fwd.push(fl * batch / flops_per_s);
        bwd.push(BWD_FLOPS_FACTOR * fl * batch / flops_per_s);
    }
    StageCosts { fwd, bwd, edge_bytes: edge_bytes_of(meta) }
}

/// Roofline cost model calibrated to the paper's observed profile.
///
/// The paper (§6.3) measures that ResNet-20's first three residual
/// functions take >50% of runtime although all three groups have equal
/// FLOPs — early layers have 4x the activation bytes and are memory-
/// bound on the GTX1060. Layer time = max(flops / peak_flops,
/// passes * activation_bytes / mem_bw); `passes` folds the conv/BN/ReLU
/// read-write passes over the activation map (NCHW PyTorch ~6-10).
/// Defaults approximate a GTX1060 (4.4 TFLOP/s, 192 GB/s).
pub fn roofline_costs(
    meta: &crate::meta::ConfigMeta,
    peak_flops: f64,
    mem_bw: f64,
    passes: f64,
) -> StageCosts {
    let batch = meta.batch as f64;
    let mut fwd = Vec::new();
    let mut bwd = Vec::new();
    for p in &meta.partitions {
        let mut t = 0.0;
        for l in &meta.layers[p.layer_lo - 1..p.layer_hi] {
            let tc = l.flops_per_sample as f64 / peak_flops;
            let tm = passes * (l.carry_elems_per_sample as f64 * 4.0) / mem_bw;
            t += tc.max(tm);
        }
        fwd.push(t * batch);
        bwd.push(BWD_FLOPS_FACTOR * t * batch);
    }
    StageCosts { fwd, bwd, edge_bytes: edge_bytes_of(meta) }
}

/// GTX1060-flavoured default roofline (the paper's testbed).
pub fn gtx1060_costs(meta: &crate::meta::ConfigMeta) -> StageCosts {
    roofline_costs(meta, 4.4e12, 192e9, 8.0)
}

/// GPipe-style micro-batch pipeline estimate for the §6.7 comparison:
/// bubble fraction (P-1)/(M+P-1) with M micro-batches, no staleness.
pub fn gpipe_speedup_estimate(p: usize, microbatches: usize) -> f64 {
    let m = microbatches as f64;
    let bubble = (p as f64 - 1.0) / (m + p as f64 - 1.0);
    p as f64 * (1.0 - bubble)
}

/// A bottleneck-minimizing partition chosen by [`solve_partition`].
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionSolution {
    /// Chosen PPV: 1-based block indices after which a register sits
    /// (same numbering as `ConfigMeta::ppv`; empty for P=1).
    pub ppv: Vec<usize>,
    /// Per-stage total (fwd+bwd) cost under the chosen cuts, in the
    /// units of the input block costs.
    pub stage_costs: Vec<f64>,
    /// The slowest stage's cost — the pipeline cycle time at full
    /// occupancy in the paired mapping, and the quantity the solver
    /// minimizes.
    pub bottleneck: f64,
    /// Load-imbalance ratio bottleneck / mean stage cost; 1.0 means
    /// perfectly balanced stages.
    pub imbalance: f64,
    /// Predicted steady-state speedup over one accelerator running the
    /// whole model: total cost / bottleneck (communication-free).
    pub predicted_speedup: f64,
}

/// Sum per-block costs into per-stage totals under a PPV: cut values
/// are 1-based block indices, stage `i` covers blocks
/// `bounds[i]+1..=bounds[i+1]` with `bounds = [0] ++ ppv ++ [n]` — the
/// exact bounds convention `native_config` uses for layer ranges.
///
/// Callers must pass a PPV that is strictly increasing with every cut
/// in `1..n`; [`solve_partition`] and the profile helpers only produce
/// such PPVs.
pub fn stage_costs_of(block_costs: &[f64], ppv: &[usize]) -> Vec<f64> {
    let mut bounds = Vec::with_capacity(ppv.len() + 2);
    bounds.push(0usize);
    bounds.extend_from_slice(ppv);
    bounds.push(block_costs.len());
    bounds.windows(2).map(|w| block_costs[w[0]..w[1]].iter().sum()).collect()
}

/// Per-stage fwd+bwd seconds of a cost model — the per-stage totals the
/// CLI and benches report next to [`imbalance_ratio`].
pub fn stage_totals(costs: &StageCosts) -> Vec<f64> {
    costs.fwd.iter().zip(&costs.bwd).map(|(f, b)| f + b).collect()
}

/// Load-imbalance ratio of per-stage totals: max / mean. 1.0 is
/// perfectly balanced; an empty or all-zero input reports 1.0 (nothing
/// is imbalanced about no work).
pub fn imbalance_ratio(stage_totals: &[f64]) -> f64 {
    if stage_totals.is_empty() {
        return 1.0;
    }
    let max = stage_totals.iter().cloned().fold(0.0f64, f64::max);
    let mean = stage_totals.iter().sum::<f64>() / stage_totals.len() as f64;
    if mean > 0.0 {
        max / mean
    } else {
        1.0
    }
}

fn solution_for(block_costs: &[f64], ppv: Vec<usize>) -> PartitionSolution {
    let stage_costs = stage_costs_of(block_costs, &ppv);
    let bottleneck = stage_costs.iter().cloned().fold(0.0f64, f64::max);
    let total: f64 = stage_costs.iter().sum();
    PartitionSolution {
        imbalance: imbalance_ratio(&stage_costs),
        predicted_speedup: if bottleneck > 0.0 { total / bottleneck } else { 1.0 },
        ppv,
        stage_costs,
        bottleneck,
    }
}

/// PipeDream-style bottleneck-minimizing partition search: choose the
/// `p-1` cut points that split `block_costs` into `p` contiguous stages
/// minimizing the maximum stage cost. Exact dynamic program over all
/// contiguous partitions (O(n²·p)); ties break deterministically toward
/// the lowest cut indices (cut candidates are scanned ascending and
/// only a strictly better bottleneck replaces the incumbent), so the
/// result is identical across runs, platforms, and thread counts.
///
/// Costs must be finite and non-negative; errors cleanly on `p == 0`,
/// an empty cost array, or `p > block_costs.len()` (a stage cannot be
/// empty — every stage owns at least one block).
pub fn solve_partition(block_costs: &[f64], p: usize) -> Result<PartitionSolution> {
    let n = block_costs.len();
    ensure!(p >= 1, "cannot partition into zero stages");
    ensure!(n >= 1, "cannot partition an empty block-cost array");
    ensure!(p <= n, "cannot cut {n} blocks into {p} non-empty stages (need p <= num_blocks)");
    ensure!(
        block_costs.iter().all(|c| c.is_finite() && *c >= 0.0),
        "block costs must be finite and non-negative: {block_costs:?}"
    );

    let mut prefix = vec![0.0f64; n + 1];
    for (i, c) in block_costs.iter().enumerate() {
        prefix[i + 1] = prefix[i] + c;
    }
    let seg = |lo: usize, hi: usize| prefix[hi] - prefix[lo];

    // dp[k][j]: minimal bottleneck splitting the first j blocks into k
    // stages; cut[k][j]: the boundary i achieving it (stage k covers
    // blocks i..j, the first k-1 stages cover ..i).
    let mut dp = vec![vec![f64::INFINITY; n + 1]; p + 1];
    let mut cut = vec![vec![0usize; n + 1]; p + 1];
    for j in 1..=n {
        dp[1][j] = seg(0, j);
    }
    for k in 2..=p {
        for j in k..=n {
            for i in (k - 1)..j {
                let cand = dp[k - 1][i].max(seg(i, j));
                if cand < dp[k][j] {
                    dp[k][j] = cand;
                    cut[k][j] = i;
                }
            }
        }
    }

    let mut ppv = Vec::with_capacity(p - 1);
    let mut j = n;
    for k in (2..=p).rev() {
        let i = cut[k][j];
        ppv.push(i);
        j = i;
    }
    ppv.reverse();
    Ok(solution_for(block_costs, ppv))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn balanced(p: usize, t: f64) -> StageCosts {
        StageCosts {
            fwd: vec![t; p],
            bwd: vec![2.0 * t; p],
            edge_bytes: vec![0.0; p.saturating_sub(1)],
        }
    }

    #[test]
    fn nonpipelined_is_linear() {
        let c = balanced(3, 0.01);
        let t1 = simulate_nonpipelined(&c, 10);
        let t2 = simulate_nonpipelined(&c, 20);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn paired_speedup_approaches_p_for_balanced_stages_no_comm() {
        // With perfectly balanced fwd+bwd per worker and free comm, the
        // steady-state speedup of the paired mapping tends to P.
        let p = 2;
        let c = balanced(p, 0.01);
        let comm = CommModel::free();
        let n = 500;
        let tp = simulate_pipelined(&c, &comm, Mapping::Paired, n);
        let tn = simulate_nonpipelined(&c, n);
        let s = tn / tp;
        assert!(s > 1.9 && s <= 2.0 + 1e-9, "speedup {s}");
    }

    #[test]
    fn full_mapping_uses_more_workers_and_is_faster() {
        // Costs where the fused last stage is NOT the bottleneck: the
        // full (2K+1-accelerator) mapping then beats the paired one
        // because fwd and bwd of the early partitions run on separate
        // workers. (With balanced stages both mappings are bound by the
        // fused FS_{K+1}+BKS_1 accelerator — the paper's co-location
        // trade-off.)
        let t = 0.001;
        let c = StageCosts {
            fwd: vec![4.0 * t, 4.0 * t, t],
            bwd: vec![8.0 * t, 8.0 * t, 2.0 * t],
            edge_bytes: vec![0.0, 0.0],
        };
        let comm = CommModel::free();
        let tp_paired = simulate_pipelined(&c, &comm, Mapping::Paired, 300);
        let tp_full = simulate_pipelined(&c, &comm, Mapping::Full, 300);
        assert!(tp_full < tp_paired, "full {tp_full} vs paired {tp_paired}");
        // bottleneck worker = bwd(0 or 1) at 8t; total work 27t -> ~3.4x
        let s = simulate_nonpipelined(&c, 300) / tp_full;
        assert!(s > 3.0, "speedup {s}");

        // balanced case: both mappings bound by the fused last worker
        let cb = balanced(3, 0.01);
        let a = simulate_pipelined(&cb, &comm, Mapping::Paired, 300);
        let b = simulate_pipelined(&cb, &comm, Mapping::Full, 300);
        assert!((a - b).abs() / a < 0.05, "paired {a} vs full {b}");
    }

    #[test]
    fn communication_reduces_speedup() {
        let p = 2;
        let mut c = balanced(p, 0.001);
        c.edge_bytes = vec![50e6]; // 50 MB activations
        let n = 200;
        let free = simulate_pipelined(&c, &CommModel::free(), Mapping::Paired, n);
        let staged = simulate_pipelined(&c, &CommModel::default(), Mapping::Paired, n);
        assert!(staged > free);
    }

    #[test]
    fn bigger_compute_to_comm_ratio_improves_speedup() {
        // Paper Table 5 trend: deeper ResNets (more compute per byte
        // communicated) get closer to the 2.0 bound.
        let comm = CommModel::default();
        let n = 300;
        let mut prev = 0.0;
        for scale in [1.0, 4.0, 16.0] {
            let c = StageCosts {
                fwd: vec![0.002 * scale; 2],
                bwd: vec![0.004 * scale; 2],
                edge_bytes: vec![4e6],
            };
            let s = simulate_nonpipelined(&c, n)
                / simulate_pipelined(&c, &comm, Mapping::Paired, n);
            assert!(s > prev, "scale {scale}: {s} <= {prev}");
            prev = s;
        }
        assert!(prev > 1.5);
    }

    #[test]
    fn unbalanced_stage_bounds_cycle_time() {
        let c = StageCosts {
            fwd: vec![0.01, 0.001],
            bwd: vec![0.02, 0.002],
            edge_bytes: vec![0.0],
        };
        let n = 400;
        let tp = simulate_pipelined(&c, &CommModel::free(), Mapping::Paired, n);
        // worker 0 is the bottleneck: cycle ~= 0.03
        let expect = 0.03 * n as f64;
        assert!((tp - expect).abs() / expect < 0.1, "tp={tp} expect~{expect}");
    }

    #[test]
    fn hybrid_between_pipelined_and_baseline() {
        let c = balanced(2, 0.01);
        let comm = CommModel::free();
        let n = 100;
        let tp = simulate_pipelined(&c, &comm, Mapping::Paired, n);
        let tn = simulate_nonpipelined(&c, n);
        let th = simulate_hybrid(&c, &comm, Mapping::Paired, n, n / 2);
        assert!(tp < th && th < tn);
    }

    #[test]
    fn hybrid_bound_matches_paper_example() {
        // Paper §6.5: K=1 (2K+1=3)... but their 2-GPU case: max speedup 2,
        // half epochs pipelined -> bound 1.33
        let s: f64 = 1.0 / (0.5 / 2.0 + 0.5);
        assert!((s - 4.0 / 3.0).abs() < 1e-9);
        // closed form from §4 with K=... full mapping example:
        let b = hybrid_speedup_bound(100.0, 100.0, 2);
        assert!((b - 5.0).abs() < 1e-9); // all iterations pipelined, 2K+1=5
    }

    #[test]
    fn hybrid_bound_degenerate_inputs_are_guarded() {
        // Regression: n_np == n_p == 0 was 0/0 = NaN. Empty schedules
        // speed nothing up — 1.0, mirroring ideal_speedup(0).
        let b = hybrid_speedup_bound(0.0, 0.0, 2);
        assert!(b.is_finite() && b == 1.0, "{b}");
        // Regression: n_p > n_np produced a nonsense bound (the raw
        // formula exceeds 2K+1 and can even go negative). Clamped to
        // all-pipelined instead.
        let b = hybrid_speedup_bound(100.0, 250.0, 2);
        assert!((b - 5.0).abs() < 1e-9, "{b}");
        // Negative pipelined count clamps to the plain baseline.
        let b = hybrid_speedup_bound(100.0, -5.0, 1);
        assert!((b - 1.0).abs() < 1e-9, "{b}");
        // The guarded domain keeps the paper's invariant: 1 <= bound
        // <= 2K+1 for every input.
        for &(n_np, n_p) in &[(10.0, 0.0), (10.0, 5.0), (10.0, 10.0), (10.0, 99.0), (0.0, 7.0)] {
            for k in [0usize, 1, 2, 4] {
                let b = hybrid_speedup_bound(n_np, n_p, k);
                assert!(b >= 1.0 - 1e-12, "({n_np},{n_p},{k}) -> {b}");
                assert!(b <= 2.0 * k as f64 + 1.0 + 1e-12, "({n_np},{n_p},{k}) -> {b}");
            }
        }
    }

    #[test]
    fn cost_models_accept_a_zero_partition_meta() {
        // Regression: both cost models crashed on `.take(len - 1)`
        // with an empty partition list (a legal degenerate meta for
        // meta-only tooling) before edge_bytes_of's saturating_sub.
        let meta = crate::meta::ConfigMeta {
            dir: std::path::PathBuf::new(),
            config: "degenerate_empty".into(),
            model: "lenet5".into(),
            width_mult: 1.0,
            batch: 1,
            dataset: "mnist".into(),
            input_shape: vec![28, 28, 1],
            num_classes: 10,
            num_layers: 0,
            ppv: vec![],
            meta_only: true,
            layers: vec![],
            partitions: vec![],
        };
        let a = analytic_costs(&meta, 1e12);
        assert!(a.fwd.is_empty() && a.bwd.is_empty() && a.edge_bytes.is_empty());
        let r = roofline_costs(&meta, 4.4e12, 192e9, 8.0);
        assert!(r.fwd.is_empty() && r.bwd.is_empty() && r.edge_bytes.is_empty());
        // And a normal meta still has one fewer edge than partitions.
        let c = balanced(3, 0.01);
        assert_eq!(c.edge_bytes.len(), c.fwd.len() - 1);
    }

    #[test]
    fn gpipe_bubble_shrinks_with_microbatches() {
        let s4 = gpipe_speedup_estimate(4, 4);
        let s32 = gpipe_speedup_estimate(4, 32);
        assert!(s4 < s32 && s32 < 4.0);
    }

    #[test]
    fn solver_balances_known_arrays() {
        // Uniform costs split evenly.
        let sol = solve_partition(&[1.0, 1.0, 1.0, 1.0], 2).unwrap();
        assert_eq!(sol.ppv, vec![2]);
        assert_eq!(sol.stage_costs, vec![2.0, 2.0]);
        assert!((sol.bottleneck - 2.0).abs() < 1e-12);
        assert!((sol.imbalance - 1.0).abs() < 1e-12);
        assert!((sol.predicted_speedup - 2.0).abs() < 1e-12);
        // A heavy head block gets its own stage.
        let sol = solve_partition(&[3.0, 1.0, 1.0, 1.0], 2).unwrap();
        assert_eq!(sol.ppv, vec![1]);
        assert!((sol.bottleneck - 3.0).abs() < 1e-12);
        // A heavy tail block likewise.
        let sol = solve_partition(&[1.0, 1.0, 1.0, 5.0], 2).unwrap();
        assert_eq!(sol.ppv, vec![3]);
        assert!((sol.bottleneck - 5.0).abs() < 1e-12);
    }

    #[test]
    fn solver_degenerate_cases() {
        // P=1: no cuts, bottleneck is the whole model.
        let sol = solve_partition(&[2.0, 3.0, 4.0], 1).unwrap();
        assert!(sol.ppv.is_empty());
        assert!((sol.bottleneck - 9.0).abs() < 1e-12);
        assert!((sol.predicted_speedup - 1.0).abs() < 1e-12);
        // P=n: every block its own stage, bottleneck = max block.
        let sol = solve_partition(&[2.0, 3.0, 4.0], 3).unwrap();
        assert_eq!(sol.ppv, vec![1, 2]);
        assert!((sol.bottleneck - 4.0).abs() < 1e-12);
        // P=0, P>n, empty costs, and non-finite costs error cleanly.
        assert!(solve_partition(&[1.0, 2.0], 0).is_err());
        assert!(solve_partition(&[1.0, 2.0], 3).is_err());
        assert!(solve_partition(&[], 1).is_err());
        assert!(solve_partition(&[1.0, f64::NAN], 1).is_err());
        assert!(solve_partition(&[1.0, -2.0], 1).is_err());
    }

    #[test]
    fn stage_cost_and_imbalance_helpers_are_consistent() {
        let costs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(stage_costs_of(&costs, &[2, 4]), vec![3.0, 7.0, 5.0]);
        assert_eq!(stage_costs_of(&costs, &[]), vec![15.0]);
        assert!((imbalance_ratio(&[3.0, 7.0, 5.0]) - 7.0 / 5.0).abs() < 1e-12);
        assert_eq!(imbalance_ratio(&[]), 1.0);
        assert_eq!(imbalance_ratio(&[0.0, 0.0]), 1.0);
        // stage_totals pairs fwd+bwd elementwise.
        let sc = StageCosts { fwd: vec![1.0, 2.0], bwd: vec![2.0, 4.0], edge_bytes: vec![0.0] };
        assert_eq!(stage_totals(&sc), vec![3.0, 6.0]);
        // The solver's reported fields agree with the helpers.
        let sol = solve_partition(&costs, 3).unwrap();
        assert_eq!(sol.stage_costs, stage_costs_of(&costs, &sol.ppv));
        assert!((sol.imbalance - imbalance_ratio(&sol.stage_costs)).abs() < 1e-12);
    }
}
