//! Threaded pipeline runtime: one OS thread per accelerator, mpsc
//! channels as pipeline registers (the paper's §5 "actual" PyTorch
//! implementation, adapted: each worker owns its partition's weights —
//! one copy, no stashing — and runs both its forward and backward stage,
//! the paper's 2-GPU pairing).
//!
//! PJRT handles are not Send, so every worker creates its own CPU client
//! and compiles its own partition programs — faithfully "one device per
//! worker". Tensors cross threads as host buffers. On this 1-core
//! container the threads time-slice (no wall-clock speedup is possible —
//! DESIGN.md §4); the runtime demonstrates the architecture and feeds the
//! Table-5 cross-check, while speedups come from the calibrated DES
//! (perfsim).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::data::batch_seed;
use crate::meta::ConfigMeta;
use crate::model::{ModelParams, PartitionParams};
use crate::optim::Sgd;
use crate::runtime::Runtime;
use crate::tensor::{IntTensor, Tensor};

use super::engine::PartitionEngine;
use super::scheduler::TrainEvent;

enum ToWorker {
    /// Forward payload: carries labels through to the last worker.
    Fwd { batch_id: u64, seed: i32, carry: Vec<Tensor>, labels: IntTensor },
    /// Backward payload.
    Bwd { batch_id: u64, gcarry: Vec<Tensor> },
    /// Return the partition params and stop.
    Stop,
}

enum FromWorker {
    Trained(TrainEvent),
    Retired(u64),
    Params(usize, Box<PartitionParams>),
    Fatal(String),
}

struct Worker {
    handle: JoinHandle<()>,
    inbox: Sender<ToWorker>,
}

/// Orchestrates P worker threads and feeds mini-batches.
pub struct ThreadedPipeline {
    workers: Vec<Worker>,
    events: Receiver<FromWorker>,
    p: usize,
    batch_size: usize,
}

impl ThreadedPipeline {
    pub fn launch(meta: &ConfigMeta, params: ModelParams, optims: Vec<Sgd>) -> Result<Self> {
        let p = meta.partitions.len();
        anyhow::ensure!(optims.len() == p && params.partitions.len() == p);
        let (ev_tx, ev_rx) = channel::<FromWorker>();

        // Build inboxes first so each worker can hold its neighbours'.
        let channels: Vec<(Sender<ToWorker>, Receiver<ToWorker>)> =
            (0..p).map(|_| channel()).collect();
        let senders: Vec<Sender<ToWorker>> = channels.iter().map(|(s, _)| s.clone()).collect();
        let mut receivers: Vec<Option<Receiver<ToWorker>>> =
            channels.into_iter().map(|(_, r)| Some(r)).collect();

        let mut workers = Vec::with_capacity(p);
        for (idx, pp) in params.partitions.into_iter().enumerate() {
            let rx = receivers[idx].take().unwrap();
            let next = if idx + 1 < p { Some(senders[idx + 1].clone()) } else { None };
            let prev = if idx > 0 { Some(senders[idx - 1].clone()) } else { None };
            let meta = meta.clone();
            let optim = optims[idx].clone();
            let events = ev_tx.clone();
            let batch = meta.batch;
            let handle = std::thread::Builder::new()
                .name(format!("accel-{idx}"))
                .spawn(move || {
                    if let Err(e) =
                        worker_main(idx, meta, pp, optim, rx, next, prev, events.clone(), batch)
                    {
                        let _ = events.send(FromWorker::Fatal(format!("worker {idx}: {e:#}")));
                    }
                })
                .context("spawning worker")?;
            workers.push(Worker { handle, inbox: senders[idx].clone() });
        }
        Ok(ThreadedPipeline { workers, events: ev_rx, p, batch_size: meta.batch })
    }

    /// Train for `feeds` mini-batches; returns (events, wall_seconds).
    /// In-flight batches are capped at 2P+2 (the pipeline's natural
    /// occupancy) to bound activation memory, as the register-file does
    /// in the synchronous scheduler.
    pub fn train<F>(&mut self, feeds: u64, global_seed: u64, mut next_batch: F) -> Result<(Vec<TrainEvent>, f64)>
    where
        F: FnMut(u64) -> (Tensor, IntTensor),
    {
        let start = std::time::Instant::now();
        let cap = (2 * self.p + 2) as u64;
        let mut fed = 0u64;
        let mut retired = 0u64;
        let mut events = Vec::new();
        while retired < feeds {
            while fed < feeds && fed - retired < cap {
                let (x, labels) = next_batch(fed);
                self.workers[0]
                    .inbox
                    .send(ToWorker::Fwd {
                        batch_id: fed,
                        seed: batch_seed(global_seed, fed),
                        carry: vec![x],
                        labels,
                    })
                    .map_err(|_| anyhow!("worker 0 hung up"))?;
                fed += 1;
            }
            match self.events.recv().map_err(|_| anyhow!("all workers hung up"))? {
                FromWorker::Trained(e) => events.push(e),
                FromWorker::Retired(_) => retired += 1,
                FromWorker::Fatal(msg) => return Err(anyhow!(msg)),
                FromWorker::Params(..) => unreachable!("params before stop"),
            }
        }
        Ok((events, start.elapsed().as_secs_f64()))
    }

    /// Stop workers and collect the trained weights.
    pub fn shutdown(self) -> Result<ModelParams> {
        for w in &self.workers {
            let _ = w.inbox.send(ToWorker::Stop);
        }
        let mut parts: Vec<Option<PartitionParams>> = (0..self.p).map(|_| None).collect();
        let mut got = 0;
        while got < self.p {
            match self.events.recv().map_err(|_| anyhow!("workers died before params"))? {
                FromWorker::Params(idx, pp) => {
                    parts[idx] = Some(*pp);
                    got += 1;
                }
                FromWorker::Fatal(msg) => return Err(anyhow!(msg)),
                _ => {}
            }
        }
        for w in self.workers {
            let _ = w.handle.join();
        }
        Ok(ModelParams { partitions: parts.into_iter().map(Option::unwrap).collect() })
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    idx: usize,
    meta: ConfigMeta,
    params: PartitionParams,
    optim: Sgd,
    rx: Receiver<ToWorker>,
    next: Option<Sender<ToWorker>>,
    prev: Option<Sender<ToWorker>>,
    events: Sender<FromWorker>,
    batch_size: usize,
) -> Result<()> {
    // Each worker leases tensor buffers from a private pool, so the
    // steady-state acquire path never contends on the global pool's
    // lock (buffers acquired here but dropped by a neighbour return to
    // this pool — contention is at worst pairwise along pipe edges).
    let _pool = crate::pool::PoolScope::new();
    // Each worker is its own accelerator: own PJRT client + programs.
    let runtime = Runtime::cpu()?;
    let pm = meta.partitions[idx].clone();
    let programs = runtime.load_partition(&meta, &pm)?;
    let mut engine = PartitionEngine::new(pm, programs, params, optim);
    let is_last = engine.meta.is_last();

    // Saved activations + label store (FIFO, like the register scheduler).
    let mut fifo: std::collections::VecDeque<(u64, i32, Vec<Tensor>)> = Default::default();

    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::Fwd { batch_id, seed, carry, labels } => {
                if is_last {
                    let res = engine.last(seed, &carry, &labels)?;
                    let _ = events.send(FromWorker::Trained(TrainEvent {
                        batch_id,
                        loss: res.loss,
                        correct: res.correct,
                        batch_size,
                        cycle: batch_id,
                    }));
                    match &prev {
                        Some(tx) => {
                            let _ = tx.send(ToWorker::Bwd { batch_id, gcarry: res.gcarry_in });
                        }
                        None => {
                            let _ = events.send(FromWorker::Retired(batch_id));
                        }
                    }
                } else {
                    let out = engine.forward(seed, &carry)?;
                    fifo.push_back((batch_id, seed, carry));
                    let _ = next
                        .as_ref()
                        .expect("non-last worker has next")
                        .send(ToWorker::Fwd { batch_id, seed, carry: out, labels });
                }
            }
            ToWorker::Bwd { batch_id, gcarry } => {
                let (saved_id, seed, saved) = fifo
                    .pop_front()
                    .ok_or_else(|| anyhow!("worker {idx}: FIFO empty for batch {batch_id}"))?;
                anyhow::ensure!(
                    saved_id == batch_id,
                    "worker {idx}: FIFO order violated ({saved_id} vs {batch_id})"
                );
                let gin = engine.backward(seed, &saved, &gcarry)?;
                match &prev {
                    Some(tx) => {
                        let _ = tx.send(ToWorker::Bwd { batch_id, gcarry: gin });
                    }
                    None => {
                        let _ = events.send(FromWorker::Retired(batch_id));
                    }
                }
            }
            ToWorker::Stop => break,
        }
    }
    let _ = events.send(FromWorker::Params(idx, Box::new(engine.params.clone())));
    Ok(())
}
