//! Threaded pipeline runtime: one OS thread per accelerator, mpsc
//! channels as pipeline registers (the paper's §5 "actual"
//! implementation, adapted: each worker owns its partition's weights —
//! one copy, no stashing — and runs both its forward and backward
//! stage, the paper's 2-GPU pairing).
//!
//! The runtime is **executor-generic**: a `WorkerBackend` factory
//! builds each worker's `WorkerStage` *on the worker thread* (PJRT
//! handles are not `Send`; the native backend's `NativePartition` is
//! plain `Send` data and could be built anywhere). Only host tensors
//! cross threads, and each worker leases buffers from a private
//! `PoolScope` — a tensor dropped by a neighbour returns to the pool
//! that issued it, so the steady-state cycle stays allocation-free.
//!
//! Determinism: staleness here is *emergent* from real concurrency,
//! yet reproducible. Each worker follows the static 1F1B alternation
//! the cycle-accurate scheduler induces — a warmup of `d_eff + 1`
//! forwards, then strictly alternating forward/backward (forward
//! first, like the register scheduler's in-cycle order), with
//! `d_eff = 2(P-1-p)` at full occupancy and `0` single-in-flight.
//! A worker's weights are touched only by its own backward, so the
//! entire computation is bitwise identical to the scheduler runtime
//! on the same seed — property-tested in `tests/threaded_native.rs`.
//! Liveness: the full-occupancy schedule needs at most `2P-1` batches
//! in flight, below the coordinator's `2P+2` feed cap.
//!
//! Failure handling (DESIGN.md §8): a worker that errors — or panics;
//! the worker body runs under `catch_unwind` — sets the shared shutdown
//! flag *before* its channels drop and reports the original error;
//! peers parked on their inboxes poll the flag, hand their weights
//! back, and exit — no thread is left parked (regression-tested by
//! fault injection). Each worker additionally publishes [`Heartbeat`]
//! counters; the coordinator's watchdog reads them to distinguish a
//! *hung* stage (liveness counter frozen: stuck inside an op) from a
//! merely *slow* one (still ticking), and a globally *stalled* pipe
//! (every worker parked, no progress anywhere) — instead of the old
//! blanket event timeout. Supervised restart on top of this lives in
//! `train::run_threaded`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::backend::NativePartition;
use crate::data::batch_seed;
use crate::meta::ConfigMeta;
use crate::model::{ModelParams, PartitionParams};
use crate::optim::Sgd;
use crate::runtime::Runtime;
use crate::tensor::{IntTensor, Tensor};

use super::engine::PartitionEngine;
use super::executor::WorkerStage;
use super::mitigation::FixKind;
use super::scheduler::{EventLedger, FlowControl, TrainEvent};

/// How often a parked worker re-checks the shutdown flag.
const WORKER_POLL: Duration = Duration::from_millis(10);

/// Upper bound on the coordinator's event-wait slice between watchdog
/// checks (the lower bound is `stall_timeout / 4`, so short test
/// timeouts are still detected promptly).
const WATCHDOG_SLICE: Duration = Duration::from_millis(250);

/// Builds one worker thread's stage compute. Called on the worker
/// thread itself, so backends whose handles are not `Send` (PJRT)
/// work unchanged; the factory is what crosses the spawn boundary.
pub trait WorkerBackend: Clone + Send + 'static {
    /// The per-worker stage compute this backend constructs.
    type Stage: WorkerStage;

    /// Build partition `idx`'s stage compute (called on the worker
    /// thread itself).
    fn make_stage(
        &self,
        meta: &ConfigMeta,
        idx: usize,
        params: PartitionParams,
        optim: Sgd,
    ) -> Result<Self::Stage>;
}

/// Native pure-Rust worker compute: each worker owns a
/// `NativePartition` (in-crate kernels, no artifacts, no Python).
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeWorkerBackend;

impl WorkerBackend for NativeWorkerBackend {
    type Stage = NativePartition;

    fn make_stage(
        &self,
        meta: &ConfigMeta,
        idx: usize,
        params: PartitionParams,
        optim: Sgd,
    ) -> Result<NativePartition> {
        NativePartition::for_partition(meta, idx, params, optim)
    }
}

/// XLA worker compute: each worker is its own accelerator — own PJRT
/// client, own compiled partition programs.
#[derive(Clone, Copy, Debug, Default)]
pub struct XlaWorkerBackend;

/// One XLA worker's stage compute: a private PJRT client plus the
/// partition's compiled programs and weights.
pub struct XlaWorkerStage {
    /// Keeps the PJRT client alive for the engine's executables.
    _runtime: Runtime,
    engine: PartitionEngine,
}

impl WorkerBackend for XlaWorkerBackend {
    type Stage = XlaWorkerStage;

    fn make_stage(
        &self,
        meta: &ConfigMeta,
        idx: usize,
        params: PartitionParams,
        optim: Sgd,
    ) -> Result<XlaWorkerStage> {
        let runtime = Runtime::cpu()?;
        let pm = meta.partitions[idx].clone();
        let programs = runtime.load_partition(meta, &pm)?;
        let engine = PartitionEngine::new(pm, programs, params, optim);
        Ok(XlaWorkerStage { _runtime: runtime, engine })
    }
}

impl WorkerStage for XlaWorkerStage {
    fn forward(&mut self, seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        self.engine.forward(seed, carry)
    }

    fn last(
        &mut self,
        seed: i32,
        carry: &[Tensor],
        labels: &IntTensor,
    ) -> Result<super::executor::LastResult> {
        self.engine.last(seed, carry, labels)
    }

    fn backward(
        &mut self,
        seed: i32,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.engine.backward(seed, carry_in, gcarry_out)
    }

    fn into_params(self) -> PartitionParams {
        self.engine.into_params()
    }

    fn set_staleness_fix(&mut self, kind: FixKind) -> Result<()> {
        self.engine.set_staleness_fix(kind);
        Ok(())
    }
}

/// In-flight occupancy of the threaded pipe, fixed at launch (each
/// worker derives its deterministic schedule from it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Occupancy {
    /// One batch in flight: every worker strictly alternates
    /// forward/backward — bitwise-equal to sequential training.
    Single,
    /// The paper's full pipe: feed cap 2P+2, per-worker warmup depth
    /// 2(P-1-p) — bitwise-equal to the scheduler's pipelined schedule.
    Full,
}

impl Occupancy {
    fn cap(&self, p: usize) -> u64 {
        match self {
            Occupancy::Single => 1,
            Occupancy::Full => (2 * p + 2) as u64,
        }
    }

    /// Forwards worker `idx` runs ahead of its backwards (d_eff).
    fn warmup(&self, p: usize, idx: usize) -> u64 {
        match self {
            Occupancy::Single => 0,
            Occupancy::Full => 2 * (p - 1 - idx) as u64,
        }
    }
}

/// Launch-time knobs for the threaded runtime.
#[derive(Debug, Clone, Copy)]
pub struct ThreadedOptions {
    /// In-flight occupancy (fixes every worker's 1F1B schedule).
    pub occupancy: Occupancy,
    /// Coordinator-side liveness guard: if no worker event arrives
    /// within this window, the run is declared stalled and shut down
    /// (turns a would-be deadlock into an error).
    pub stall_timeout: Duration,
    /// Staleness mitigation installed on every worker's stage at spawn
    /// (DESIGN.md §9); `none` by default.
    pub staleness_fix: FixKind,
}

impl Default for ThreadedOptions {
    fn default() -> Self {
        ThreadedOptions {
            occupancy: Occupancy::Full,
            stall_timeout: Duration::from_secs(60),
            staleness_fix: FixKind::None,
        }
    }
}

/// Liveness counters one worker publishes for the coordinator's
/// watchdog. Two monotone counters separate the failure modes:
/// `alive` ticks whenever the worker thread is scheduled at all
/// (inbox polls included), so a frozen `alive` means the thread is
/// stuck *inside* a stage op (or dead); `progress` ticks only on real
/// work (message consumed, stage op completed), so `alive` ticking
/// while every worker's `progress` is frozen means all workers are
/// parked polling — a logic deadlock. A slow-but-working stage ticks
/// both and is never flagged.
#[derive(Debug, Default)]
pub struct Heartbeat {
    alive: AtomicU64,
    progress: AtomicU64,
}

impl Heartbeat {
    fn tick_alive(&self) {
        self.alive.fetch_add(1, Ordering::Relaxed);
    }

    fn tick_progress(&self) {
        self.alive.fetch_add(1, Ordering::Relaxed);
        self.progress.fetch_add(1, Ordering::Relaxed);
    }

    /// Monotone liveness counter (any scheduling of the worker thread).
    pub fn alive(&self) -> u64 {
        self.alive.load(Ordering::Relaxed)
    }

    /// Monotone progress counter (messages consumed + ops completed).
    pub fn progress(&self) -> u64 {
        self.progress.load(Ordering::Relaxed)
    }
}

/// Coordinator-side stall detector over the workers' [`Heartbeat`]s:
/// remembers when each counter last changed and raises a per-stage
/// "hung" error (frozen `alive`) or a pipeline-wide "stalled" error
/// (total `progress` frozen) once a counter sits still for the full
/// timeout window.
struct Watchdog {
    timeout: Duration,
    alive_seen: Vec<(u64, Instant)>,
    progress_seen: (u64, Instant),
}

impl Watchdog {
    fn new(hbs: &[Arc<Heartbeat>], timeout: Duration) -> Self {
        let now = Instant::now();
        Watchdog {
            timeout,
            alive_seen: hbs.iter().map(|hb| (hb.alive(), now)).collect(),
            progress_seen: (hbs.iter().map(|hb| hb.progress()).sum(), now),
        }
    }

    fn check(&mut self, hbs: &[Arc<Heartbeat>]) -> Result<()> {
        let now = Instant::now();
        let mut total = 0u64;
        for (idx, hb) in hbs.iter().enumerate() {
            total = total.wrapping_add(hb.progress());
            let a = hb.alive();
            let seen = &mut self.alive_seen[idx];
            if a != seen.0 {
                *seen = (a, now);
            } else if now.duration_since(seen.1) > self.timeout {
                bail!(
                    "stage {idx} hung: no heartbeat within {:?} (worker stuck inside an op or dead)",
                    self.timeout
                );
            }
        }
        if total != self.progress_seen.0 {
            self.progress_seen = (total, now);
        } else if now.duration_since(self.progress_seen.1) > self.timeout {
            bail!(
                "pipeline stalled: workers responsive but no batch progress within {:?}",
                self.timeout
            );
        }
        Ok(())
    }
}

/// Forward-path messages (coordinator -> worker 0 -> ... -> last).
enum FwdMsg {
    /// A mini-batch travelling forward; labels ride through to the
    /// last worker.
    Batch { batch_id: u64, seed: i32, carry: Vec<Tensor>, labels: IntTensor },
    /// No further batches will arrive (drain marker, forwarded down
    /// the pipe once a worker has run all its forwards).
    Flush,
    /// Return the partition params and exit.
    Stop,
}

/// Backward-path message (worker p+1 -> worker p).
struct BwdMsg {
    batch_id: u64,
    gcarry: Vec<Tensor>,
}

enum FromWorker {
    Trained(TrainEvent),
    Retired(u64),
    Params(usize, Box<PartitionParams>),
    Fatal(String),
}

struct Worker {
    handle: JoinHandle<()>,
    inbox: Sender<FwdMsg>,
}

/// Orchestrates P worker threads and feeds mini-batches.
pub struct ThreadedPipeline {
    workers: Vec<Worker>,
    heartbeats: Vec<Arc<Heartbeat>>,
    busy_ns: Vec<Arc<AtomicU64>>,
    events: Receiver<FromWorker>,
    shutdown: Arc<AtomicBool>,
    p: usize,
    batch_size: usize,
    cap: u64,
    stall_timeout: Duration,
    trained: bool,
}

impl ThreadedPipeline {
    /// XLA workers at full occupancy (the original API).
    pub fn launch(meta: &ConfigMeta, params: ModelParams, optims: Vec<Sgd>) -> Result<Self> {
        Self::launch_with(XlaWorkerBackend, meta, params, optims, ThreadedOptions::default())
    }

    /// Native pure-Rust workers at full occupancy: true concurrent
    /// stale-weight training with no artifacts and no Python.
    pub fn launch_native(meta: &ConfigMeta, params: ModelParams, optims: Vec<Sgd>) -> Result<Self> {
        Self::launch_with(NativeWorkerBackend, meta, params, optims, ThreadedOptions::default())
    }

    /// Generic launch: any `WorkerBackend`, any options.
    pub fn launch_with<B: WorkerBackend>(
        backend: B,
        meta: &ConfigMeta,
        params: ModelParams,
        optims: Vec<Sgd>,
        opts: ThreadedOptions,
    ) -> Result<Self> {
        let p = meta.partitions.len();
        ensure!(p >= 1, "config {} has no partitions", meta.config);
        ensure!(
            optims.len() == p && params.partitions.len() == p,
            "params/optims/partitions arity mismatch"
        );
        let (ev_tx, ev_rx) = channel::<FromWorker>();
        let shutdown = Arc::new(AtomicBool::new(false));

        // Channel registers: a forward channel into every worker and a
        // backward channel into every non-last worker.
        let mut fwd_txs: Vec<Sender<FwdMsg>> = Vec::with_capacity(p);
        let mut fwd_rxs: Vec<Option<Receiver<FwdMsg>>> = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = channel::<FwdMsg>();
            fwd_txs.push(tx);
            fwd_rxs.push(Some(rx));
        }
        let mut bwd_txs: Vec<Sender<BwdMsg>> = Vec::with_capacity(p.saturating_sub(1));
        let mut bwd_rxs: Vec<Option<Receiver<BwdMsg>>> = Vec::with_capacity(p.saturating_sub(1));
        for _ in 0..p.saturating_sub(1) {
            let (tx, rx) = channel::<BwdMsg>();
            bwd_txs.push(tx);
            bwd_rxs.push(Some(rx));
        }

        let mut workers = Vec::with_capacity(p);
        let heartbeats: Vec<Arc<Heartbeat>> =
            (0..p).map(|_| Arc::new(Heartbeat::default())).collect();
        let busy_ns: Vec<Arc<AtomicU64>> = (0..p).map(|_| Arc::new(AtomicU64::new(0))).collect();
        for (idx, (pp, optim)) in params.partitions.into_iter().zip(optims).enumerate() {
            let fwd_rx = fwd_rxs[idx].take().expect("fwd receiver taken once");
            let bwd_rx = if idx + 1 < p { bwd_rxs[idx].take() } else { None };
            let next_fwd = fwd_txs.get(idx + 1).cloned();
            let prev_bwd = if idx > 0 { Some(bwd_txs[idx - 1].clone()) } else { None };
            let meta = meta.clone();
            let events = ev_tx.clone();
            let flag = Arc::clone(&shutdown);
            let backend = backend.clone();
            let hb = Arc::clone(&heartbeats[idx]);
            let busy = Arc::clone(&busy_ns[idx]);
            let d_eff = opts.occupancy.warmup(p, idx);
            let fix = opts.staleness_fix;
            let batch = meta.batch;
            let handle = std::thread::Builder::new()
                .name(format!("accel-{idx}"))
                .spawn(move || {
                    // Private per-worker pool: steady-state acquires
                    // never contend on the global pool's lock, and a
                    // buffer dropped by a neighbour returns here.
                    let _pool = crate::pool::PoolScope::new();
                    // Nested-parallelism cap (DESIGN.md §7): P stage
                    // workers share the machine, so each stage's
                    // intra-GEMM fan-out defaults to cores/P instead
                    // of cores. An explicit PIPESTALE_GEMM_THREADS
                    // still overrides; results are bitwise identical
                    // at every thread count either way.
                    crate::backend::threadpool::set_local_cap(
                        (crate::backend::threadpool::available_cores() / p).max(1),
                    );
                    // catch_unwind so a *panicking* stage takes the
                    // same orderly exit as an erroring one: flag set
                    // before the channels drop, panic payload surfaced
                    // as the Fatal message.
                    let result = catch_unwind(AssertUnwindSafe(|| {
                        backend.make_stage(&meta, idx, pp, optim).and_then(|mut stage| {
                            stage.set_staleness_fix(fix)?;
                            run_worker(
                                idx,
                                p,
                                stage,
                                &fwd_rx,
                                bwd_rx.as_ref(),
                                next_fwd.as_ref(),
                                prev_bwd.as_ref(),
                                &events,
                                &flag,
                                &hb,
                                &busy,
                                d_eff,
                                batch,
                            )
                        })
                    }))
                    .unwrap_or_else(|payload| {
                        Err(anyhow!("panicked: {}", panic_message(payload.as_ref())))
                    });
                    if let Err(e) = result {
                        // Flag first, then report: peers parked on a
                        // channel of ours must observe the shutdown
                        // before (or instead of) the disconnect, so
                        // the *original* error is what surfaces.
                        flag.store(true, Ordering::SeqCst);
                        let _ = events.send(FromWorker::Fatal(format!("worker {idx}: {e:#}")));
                    }
                    // (fwd_rx/bwd_rx/next_fwd/prev_bwd drop here, after
                    // the flag is set on the error path)
                })
                .context("spawning worker")?;
            workers.push(Worker { handle, inbox: fwd_txs[idx].clone() });
        }
        Ok(ThreadedPipeline {
            workers,
            heartbeats,
            busy_ns,
            events: ev_rx,
            shutdown,
            p,
            batch_size: meta.batch,
            cap: opts.occupancy.cap(p),
            stall_timeout: opts.stall_timeout,
            trained: false,
        })
    }

    /// Train for `feeds` mini-batches; returns (events, wall_seconds).
    /// Feeding is capped at the launch occupancy to bound activation
    /// memory, mirroring the synchronous scheduler's register file.
    /// One-shot: the drain marker ends the forward stream, so a second
    /// call is an error — relaunch for a new run.
    pub fn train<F>(
        &mut self,
        feeds: u64,
        global_seed: u64,
        next_batch: F,
    ) -> Result<(Vec<TrainEvent>, f64)>
    where
        F: FnMut(u64) -> Result<(Tensor, IntTensor)>,
    {
        self.train_range(0, feeds, global_seed, next_batch)
    }

    /// Train batches `start..end` of a longer run (checkpoint-restart:
    /// a fresh pipeline generation picks up where the checkpointed one
    /// left off). Batch ids, per-batch seeds, and event accounting all
    /// use *absolute* ids, so a segment retrained after a restore is
    /// bitwise the run the failed generation would have produced.
    pub fn train_range<F>(
        &mut self,
        start: u64,
        end: u64,
        global_seed: u64,
        mut next_batch: F,
    ) -> Result<(Vec<TrainEvent>, f64)>
    where
        F: FnMut(u64) -> Result<(Tensor, IntTensor)>,
    {
        ensure!(!self.trained, "ThreadedPipeline::train may only run once per launch");
        ensure!(start <= end, "train_range: start {start} past end {end}");
        self.trained = true;
        let feeds = end - start;
        let start_t = Instant::now();
        let mut flow = FlowControl::new(Some(self.cap));
        let mut ledger = EventLedger::keeping_from(start);
        let mut dog = Watchdog::new(&self.heartbeats, self.stall_timeout);
        // A failed send means worker 0 exited — on its own error (its
        // Fatal is already queued) or another worker's (whose Fatal
        // is). Stop feeding and drain the event queue so the original
        // error is what surfaces, not a generic "hung up".
        let mut feeding = true;
        let mut flushed = false;
        loop {
            while feeding && flow.fed() < feeds && flow.can_feed() {
                let b = start + flow.fed();
                let (x, labels) = next_batch(b)?;
                let msg = FwdMsg::Batch {
                    batch_id: b,
                    seed: batch_seed(global_seed, b),
                    carry: vec![x],
                    labels,
                };
                if self.workers[0].inbox.send(msg).is_err() {
                    feeding = false;
                } else {
                    flow.record_fed();
                }
            }
            if feeding && flow.fed() == feeds && !flushed {
                let _ = self.send_worker0(FwdMsg::Flush);
                flushed = true;
            }
            if flow.retired() >= feeds {
                break;
            }
            match self.recv_event(&mut dog)? {
                FromWorker::Trained(e) => ledger.record(e)?,
                FromWorker::Retired(b) => {
                    ledger.retire(b)?;
                    flow.record_retired();
                }
                FromWorker::Fatal(msg) => {
                    self.shutdown.store(true, Ordering::SeqCst);
                    return Err(anyhow!(msg));
                }
                // Param returns only happen on shutdown paths; seeing
                // one here means a peer is already unwinding — keep
                // draining until its Fatal (or a stall) surfaces.
                FromWorker::Params(..) => {}
            }
        }
        ledger.expect_complete(end)?;
        Ok((ledger.into_events(), start_t.elapsed().as_secs_f64()))
    }

    fn send_worker0(&self, msg: FwdMsg) -> Result<()> {
        self.workers[0].inbox.send(msg).map_err(|_| anyhow!("worker 0 hung up"))
    }

    /// Wait for the next worker event in short slices, consulting the
    /// heartbeat watchdog between slices: a hung stage or deadlocked
    /// pipe is detected within roughly one `stall_timeout` window even
    /// while other workers keep producing events.
    fn recv_event(&self, dog: &mut Watchdog) -> Result<FromWorker> {
        let slice = (self.stall_timeout / 4).clamp(Duration::from_millis(5), WATCHDOG_SLICE);
        loop {
            match self.events.recv_timeout(slice) {
                Ok(m) => return Ok(m),
                Err(RecvTimeoutError::Timeout) => {
                    if let Err(e) = dog.check(&self.heartbeats) {
                        self.shutdown.store(true, Ordering::SeqCst);
                        return Err(e);
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return Err(anyhow!("all workers hung up")),
            }
        }
    }

    /// The workers' heartbeat counters, indexed by stage (watchdog
    /// inputs; exposed for supervision and tests).
    pub fn heartbeats(&self) -> &[Arc<Heartbeat>] {
        &self.heartbeats
    }

    /// Cumulative wall-clock seconds each stage spent *inside* its
    /// compute kernels (forward + backward + fused last), indexed by
    /// stage. This is the emergent side of the auto-partitioner's
    /// predicted-vs-emergent contract (DESIGN.md §10): the profiler
    /// predicts per-stage cost, these counters report what the real
    /// concurrent run actually spent. Read *before* [`Self::shutdown`]
    /// — shutdown consumes the pipeline.
    pub fn stage_busy_seconds(&self) -> Vec<f64> {
        self.busy_ns.iter().map(|b| b.load(Ordering::Relaxed) as f64 * 1e-9).collect()
    }

    /// Stop workers and collect the trained weights.
    pub fn shutdown(mut self) -> Result<ModelParams> {
        // The flag makes shutdown unconditional (a worker mid-wait on
        // its backward inbox still exits); after a clean train() all
        // work is already done, so nothing is lost.
        self.shutdown.store(true, Ordering::SeqCst);
        for w in &self.workers {
            let _ = w.inbox.send(FwdMsg::Stop);
        }
        let mut parts: Vec<Option<PartitionParams>> = (0..self.p).map(|_| None).collect();
        let mut got = 0;
        while got < self.p {
            match self.events.recv_timeout(self.stall_timeout) {
                Ok(FromWorker::Params(idx, pp)) => {
                    if parts[idx].is_none() {
                        parts[idx] = Some(*pp);
                        got += 1;
                    }
                }
                Ok(FromWorker::Fatal(msg)) => {
                    self.join_all();
                    return Err(anyhow!(msg));
                }
                Ok(_) => {}
                Err(_) => {
                    self.join_all();
                    return Err(anyhow!("workers did not return params (stalled or died)"));
                }
            }
        }
        self.join_all();
        Ok(ModelParams { partitions: parts.into_iter().map(Option::unwrap).collect() })
    }

    fn join_all(&mut self) {
        for w in self.workers.drain(..) {
            let _ = w.handle.join();
        }
    }

    /// The config's mini-batch size.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Number of partitions (== worker threads).
    pub fn num_partitions(&self) -> usize {
        self.p
    }
}

impl Drop for ThreadedPipeline {
    fn drop(&mut self) {
        if self.workers.is_empty() {
            return; // shutdown() already joined
        }
        self.shutdown.store(true, Ordering::SeqCst);
        for w in &self.workers {
            let _ = w.inbox.send(FwdMsg::Stop);
        }
        self.join_all();
    }
}

/// Outcome of a flag-aware channel operation.
enum Step<T> {
    Got(T),
    Shutdown,
}

/// Extract a printable message from a panic payload (the `&str` /
/// `String` cases cover `panic!` with a literal or a format string).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Blocking receive that polls the shutdown flag. A disconnect with
/// the flag raised is an orderly shutdown, not an error — the flag is
/// always set before a failing worker's channels drop. Ticks the
/// worker's `alive` heartbeat every poll (a parked worker is alive,
/// not hung) and `progress` on every message consumed.
fn recv_msg<T>(rx: &Receiver<T>, shutdown: &AtomicBool, hb: &Heartbeat, what: &str) -> Result<Step<T>> {
    loop {
        hb.tick_alive();
        if shutdown.load(Ordering::SeqCst) {
            return Ok(Step::Shutdown);
        }
        match rx.recv_timeout(WORKER_POLL) {
            Ok(m) => {
                hb.tick_progress();
                return Ok(Step::Got(m));
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                if shutdown.load(Ordering::SeqCst) {
                    return Ok(Step::Shutdown);
                }
                bail!("{what} channel disconnected");
            }
        }
    }
}

/// Flag-aware send (a receiver that hung up under a raised flag is an
/// orderly shutdown).
fn send_to<T>(tx: &Sender<T>, msg: T, shutdown: &AtomicBool, what: &str) -> Result<Step<()>> {
    match tx.send(msg) {
        Ok(()) => Ok(Step::Got(())),
        Err(_) if shutdown.load(Ordering::SeqCst) => Ok(Step::Shutdown),
        Err(_) => bail!("{what} receiver hung up"),
    }
}

/// One worker thread: follows the deterministic 1F1B schedule (see the
/// module docs) until the drain marker and Stop arrive, then hands its
/// weights back.
#[allow(clippy::too_many_arguments)]
fn run_worker<S: WorkerStage>(
    idx: usize,
    p_total: usize,
    mut stage: S,
    fwd_rx: &Receiver<FwdMsg>,
    bwd_rx: Option<&Receiver<BwdMsg>>,
    next_fwd: Option<&Sender<FwdMsg>>,
    prev_bwd: Option<&Sender<BwdMsg>>,
    events: &Sender<FromWorker>,
    shutdown: &AtomicBool,
    hb: &Heartbeat,
    busy: &AtomicU64,
    d_eff: u64,
    batch_size: usize,
) -> Result<()> {
    let is_last = idx + 1 == p_total;
    // Saved carry_in (+ seed) of in-flight batches, FIFO like the
    // register scheduler's activation store.
    let mut fifo: VecDeque<(u64, i32, Vec<Tensor>)> = VecDeque::new();
    let mut fwd_done = 0u64;
    let mut bwd_done = 0u64;
    let mut fwd_open = true;

    'run: loop {
        // Deterministic next-op choice (never arrival order): forwards
        // until the warmup depth, then alternate forward-then-backward;
        // after the drain marker, finish the remaining backwards; when
        // idle, park on the forward channel awaiting Stop.
        let take_fwd = is_last
            || (fwd_open && fwd_done < bwd_done + d_eff + 1)
            || (!fwd_open && bwd_done == fwd_done);
        if take_fwd {
            match recv_msg(fwd_rx, shutdown, hb, "forward")? {
                Step::Shutdown => break 'run,
                Step::Got(FwdMsg::Stop) => break 'run,
                Step::Got(FwdMsg::Flush) => {
                    fwd_open = false;
                    if let Some(tx) = next_fwd {
                        if let Step::Shutdown = send_to(tx, FwdMsg::Flush, shutdown, "forward")? {
                            break 'run;
                        }
                    }
                }
                Step::Got(FwdMsg::Batch { batch_id, seed, carry, labels }) => {
                    ensure!(fwd_open, "worker {idx}: batch {batch_id} after drain marker");
                    if is_last {
                        let t0 = Instant::now();
                        let res = stage.last(seed, &carry, &labels)?;
                        busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        hb.tick_progress();
                        let ev = TrainEvent {
                            batch_id,
                            loss: res.loss,
                            correct: res.correct,
                            batch_size,
                            cycle: batch_id,
                        };
                        if let Step::Shutdown =
                            send_to(events, FromWorker::Trained(ev), shutdown, "event")?
                        {
                            break 'run;
                        }
                        let done = match prev_bwd {
                            Some(tx) => send_to(
                                tx,
                                BwdMsg { batch_id, gcarry: res.gcarry_in },
                                shutdown,
                                "backward",
                            )?,
                            None => {
                                send_to(events, FromWorker::Retired(batch_id), shutdown, "event")?
                            }
                        };
                        if let Step::Shutdown = done {
                            break 'run;
                        }
                    } else {
                        let t0 = Instant::now();
                        let out = stage.forward(seed, &carry)?;
                        busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        hb.tick_progress();
                        fifo.push_back((batch_id, seed, carry));
                        let tx = next_fwd.expect("non-last worker has a next stage");
                        let msg = FwdMsg::Batch { batch_id, seed, carry: out, labels };
                        if let Step::Shutdown = send_to(tx, msg, shutdown, "forward")? {
                            break 'run;
                        }
                        fwd_done += 1;
                    }
                }
            }
        } else {
            let rx = bwd_rx.expect("non-last worker has a backward inbox");
            match recv_msg(rx, shutdown, hb, "backward")? {
                Step::Shutdown => break 'run,
                Step::Got(BwdMsg { batch_id, gcarry }) => {
                    let (saved_id, seed, saved) = fifo.pop_front().ok_or_else(|| {
                        anyhow!("worker {idx}: activation FIFO empty for batch {batch_id}")
                    })?;
                    ensure!(
                        saved_id == batch_id,
                        "worker {idx}: FIFO order violated ({saved_id} vs {batch_id})"
                    );
                    let t0 = Instant::now();
                    let gin = stage.backward(seed, &saved, &gcarry)?;
                    busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    hb.tick_progress();
                    let done = match prev_bwd {
                        Some(tx) => {
                            send_to(tx, BwdMsg { batch_id, gcarry: gin }, shutdown, "backward")?
                        }
                        None => send_to(events, FromWorker::Retired(batch_id), shutdown, "event")?,
                    };
                    if let Step::Shutdown = done {
                        break 'run;
                    }
                    bwd_done += 1;
                }
            }
        }
    }
    // One-copy discipline: hand the only copy of this partition's
    // weights back on every orderly exit (Stop or shutdown flag).
    let _ = events.send(FromWorker::Params(idx, Box::new(stage.into_params())));
    Ok(())
}
