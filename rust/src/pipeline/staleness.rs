//! Staleness accounting (paper §3 definitions) over a config.

use crate::meta::ConfigMeta;

/// Per-partition staleness report.
#[derive(Debug, Clone)]
pub struct PartitionStaleness {
    /// Partition index (1-based, matching `meta.json`).
    pub partition: usize,
    /// Inclusive paper-layer range `[lo, hi]` the partition spans.
    pub layer_range: (usize, usize),
    /// Trainable scalars in the partition.
    pub param_count: usize,
    /// Paper's "degree of staleness": 2(K - i + 1) for stage i (1-based).
    pub degree: usize,
    /// Extra activation copies this partition must hold: degree (the
    /// FIFO holds degree+1 entries; one is the live batch).
    pub extra_activation_copies: usize,
}

/// Whole-config staleness accounting (the `inspect` subcommand).
#[derive(Debug, Clone)]
pub struct StalenessReport {
    /// Config name.
    pub config: String,
    /// Paper-style stage count 2K+1 (K register pairs).
    pub paper_stages: usize,
    /// Fraction of trainable weights trained with stale gradients.
    pub stale_weight_fraction: f64,
    /// Per-partition breakdown, pipeline order.
    pub partitions: Vec<PartitionStaleness>,
}

impl StalenessReport {
    /// Compute the §3 accounting from a config's metadata.
    pub fn from_meta(meta: &ConfigMeta) -> Self {
        let partitions = meta
            .partitions
            .iter()
            .map(|p| {
                let degree = meta.degree_of_staleness(p.index);
                PartitionStaleness {
                    partition: p.index,
                    layer_range: (p.layer_lo, p.layer_hi),
                    param_count: p.param_count,
                    degree,
                    extra_activation_copies: degree,
                }
            })
            .collect();
        StalenessReport {
            config: meta.config.clone(),
            paper_stages: meta.paper_stages(),
            stale_weight_fraction: meta.stale_weight_fraction(),
            partitions,
        }
    }

    /// Weighted mean degree of staleness (weights = param counts) — used
    /// by the Fig-6 analysis to contrast "increasing stages" (varying
    /// degree) against "sliding stage" (constant degree).
    pub fn mean_degree(&self) -> f64 {
        let total: usize = self.partitions.iter().map(|p| p.param_count).sum();
        if total == 0 {
            return 0.0;
        }
        self.partitions
            .iter()
            .map(|p| p.degree as f64 * p.param_count as f64)
            .sum::<f64>()
            / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::meta::ConfigMeta;
    use std::path::PathBuf;

    fn root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn degrees_descend_to_zero() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let m = ConfigMeta::load_named(&root(), "resnet20_fine8").unwrap();
        let r = StalenessReport::from_meta(&m);
        assert_eq!(r.paper_stages, 8);
        let degrees: Vec<usize> = r.partitions.iter().map(|p| p.degree).collect();
        assert_eq!(degrees, vec![6, 4, 2, 0]);
    }

    #[test]
    fn sliding_stage_has_constant_degree() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        // Fig 6 "sliding stage": one register pair => every stale
        // partition has degree 2 regardless of position.
        for p in [3usize, 11, 19] {
            let m = ConfigMeta::load_named(&root(), &format!("resnet20_slide{p}")).unwrap();
            let r = StalenessReport::from_meta(&m);
            assert_eq!(r.partitions[0].degree, 2);
            assert_eq!(r.partitions[1].degree, 0);
        }
    }

    #[test]
    fn increasing_stages_raises_mean_degree_and_fraction() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let mut prev_frac = 0.0;
        let mut prev_deg = 0.0;
        for ns in [8usize, 12, 16, 20] {
            let m = ConfigMeta::load_named(&root(), &format!("resnet20_fine{ns}")).unwrap();
            let r = StalenessReport::from_meta(&m);
            assert!(r.stale_weight_fraction >= prev_frac);
            assert!(r.mean_degree() >= prev_deg);
            prev_frac = r.stale_weight_fraction;
            prev_deg = r.mean_degree();
        }
    }
}
