//! Hybrid pipelined/non-pipelined schedule controller (paper §4).
//!
//! Start pipelined (full accelerator utilization, stale weights); after
//! `pipelined_iters` mini-batches drain the pipe and continue with
//! non-pipelined training on the *same* weights/executables to recover
//! the accuracy lost to staleness.

/// Which schedule a given iteration should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Stale-weight pipelined training (full utilization).
    Pipelined,
    /// Drain must happen exactly once, between the phases.
    DrainThenSequential,
    /// Non-pipelined training (fresh weights every step).
    Sequential,
}

/// The §4 schedule: `pipelined_iters` stale-weight iterations, a
/// drain, then non-pipelined training to the end.
///
/// ```
/// use pipestale::pipeline::{HybridSchedule, Phase};
/// let h = HybridSchedule::new(3, 6);
/// assert_eq!(h.phase(0), Phase::Pipelined);
/// assert_eq!(h.phase(3), Phase::DrainThenSequential);
/// assert_eq!(h.phase(5), Phase::Sequential);
/// ```
#[derive(Debug, Clone)]
pub struct HybridSchedule {
    /// Iterations trained pipelined before the switch.
    pub pipelined_iters: u64,
    /// Total training iterations.
    pub total_iters: u64,
}

impl HybridSchedule {
    /// New schedule (`pipelined_iters` is clamped to `total_iters`).
    pub fn new(pipelined_iters: u64, total_iters: u64) -> Self {
        HybridSchedule { pipelined_iters: pipelined_iters.min(total_iters), total_iters }
    }

    /// Fully pipelined / fully sequential degenerate schedules.
    pub fn all_pipelined(total: u64) -> Self {
        Self::new(total, total)
    }

    /// The all-sequential degenerate schedule.
    pub fn all_sequential(total: u64) -> Self {
        Self::new(0, total)
    }

    /// The phase iteration `iter` (0-based) should run under.
    pub fn phase(&self, iter: u64) -> Phase {
        if iter < self.pipelined_iters {
            Phase::Pipelined
        } else if iter == self.pipelined_iters && self.pipelined_iters > 0 {
            Phase::DrainThenSequential
        } else {
            Phase::Sequential
        }
    }

    /// Paper §4 ideal speedup vs non-pipelined with `accels` accelerators
    /// (the pipelined fraction runs `accels`x faster at best). An empty
    /// schedule has nothing to speed up: 1.0, not 0/0 = NaN.
    pub fn ideal_speedup(&self, accels: usize) -> f64 {
        if self.total_iters == 0 {
            return 1.0;
        }
        let n = self.total_iters as f64;
        let np = self.pipelined_iters as f64;
        n / (np / accels as f64 + (n - np))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_in_order() {
        let h = HybridSchedule::new(3, 6);
        assert_eq!(h.phase(0), Phase::Pipelined);
        assert_eq!(h.phase(2), Phase::Pipelined);
        assert_eq!(h.phase(3), Phase::DrainThenSequential);
        assert_eq!(h.phase(4), Phase::Sequential);
        assert_eq!(h.phase(5), Phase::Sequential);
    }

    #[test]
    fn degenerate_schedules() {
        let p = HybridSchedule::all_pipelined(5);
        assert!((0..5).all(|i| p.phase(i) == Phase::Pipelined));
        let s = HybridSchedule::all_sequential(5);
        assert!((0..5).all(|i| s.phase(i) == Phase::Sequential));
    }

    #[test]
    fn clamp_pipelined_to_total() {
        let h = HybridSchedule::new(100, 10);
        assert_eq!(h.pipelined_iters, 10);
    }

    #[test]
    fn ideal_speedup_of_empty_schedule_is_one() {
        // Regression: 0/0 used to yield NaN and poison downstream math.
        for accels in [1usize, 2, 8] {
            let s = HybridSchedule::new(0, 0).ideal_speedup(accels);
            assert!(s.is_finite(), "accels={accels}: {s}");
            assert_eq!(s, 1.0, "accels={accels}");
        }
    }

    #[test]
    fn ideal_speedup_matches_paper_bound() {
        // Paper §6.5: 2 accelerators, half the epochs pipelined -> 1.33x.
        let h = HybridSchedule::new(100, 200);
        let s = h.ideal_speedup(2);
        assert!((s - 4.0 / 3.0).abs() < 1e-9, "{s}");
        // all-pipelined -> accels x
        let a = HybridSchedule::all_pipelined(100);
        assert!((a.ideal_speedup(3) - 3.0).abs() < 1e-9);
    }
}
