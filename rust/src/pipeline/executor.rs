//! Stage executors: the compute behind each pipeline stage.
//!
//! The scheduler is generic over `StageExecutor` so that staleness
//! invariants are property-tested against a deterministic mock, while
//! production uses `XlaExecutor` (AOT-compiled PJRT programs + the
//! coordinator-owned weights and SGD state, one `PartitionEngine` per
//! partition).
//!
//! Update-visibility contract (matches the paper's schedule, Figure 4):
//! within one cycle the scheduler calls every `forward` *before* any
//! `last`/`backward` of the same cycle, and each partition's weights are
//! mutated only by its own `last`/`backward`; updates therefore become
//! visible to forwards of the *next* cycle, exactly like the per-
//! accelerator weight copies of the paper.

use anyhow::Result;

use crate::meta::ConfigMeta;
use crate::model::{ModelParams, PartitionParams};
use crate::optim::Sgd;
use crate::runtime::Runtime;
use crate::tensor::{IntTensor, Tensor};

use super::engine::PartitionEngine;
use super::mitigation::FixKind;

/// Result of the fused last stage (FS_{K+1} + BKS_1).
#[derive(Debug, Clone)]
pub struct LastResult {
    /// Mean softmax-cross-entropy loss over the mini-batch.
    pub loss: f32,
    /// Correct predictions in the mini-batch (a count, as f32).
    pub correct: f32,
    /// Gradient w.r.t. the last partition's carry_in, to feed BKS_2.
    pub gcarry_in: Vec<Tensor>,
}

/// The compute behind every pipeline stage: the scheduler drives any
/// implementor (XLA programs, native kernels, or the deterministic
/// mock) through the same forward / fused-last / backward /
/// eval-forward contract, with coordinator-owned weights mutated only
/// by a partition's own `last`/`backward`.
pub trait StageExecutor {
    /// Number of partitions P = K+1.
    fn num_partitions(&self) -> usize;

    /// Forward of partition `p` (0-based, p < P-1). Applies BN-state
    /// updates internally; must not touch weights.
    fn forward(&mut self, p: usize, seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Fused last stage: forward + loss + backward + weight update for
    /// partition P-1.
    fn last(&mut self, seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult>;

    /// Backward of partition `p` (< P-1) on the *saved* carry_in of the
    /// same mini-batch; applies the weight update; returns gcarry_in.
    fn backward(
        &mut self,
        p: usize,
        seed: i32,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>>;

    /// Eval-mode forward of partition `p`; for p = P-1 returns (logits,).
    fn eval_forward(&mut self, p: usize, carry: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Snapshot of the executor's weights (checkpointing at the end of
    /// a training run). Executors without real weights (the mock) keep
    /// the empty default.
    fn params_snapshot(&self) -> ModelParams {
        ModelParams { partitions: Vec::new() }
    }

    /// Install a staleness fix on every partition (DESIGN.md §9). Must
    /// be called on a drained executor. The default refuses anything
    /// but `none`: an executor that silently ignored a requested fix
    /// would corrupt the equivalence suite, so supporting it is an
    /// explicit opt-in.
    fn set_staleness_fix(&mut self, kind: FixKind) -> Result<()> {
        anyhow::ensure!(
            kind == FixKind::None,
            "this executor does not support --staleness-fix {}",
            kind.name()
        );
        Ok(())
    }
}

/// One partition's stage compute, owned by a single worker thread of
/// the threaded runtime (`pipeline::threaded`). The per-partition
/// counterpart of `StageExecutor`: same forward/last/backward semantics
/// and update-visibility contract, minus the partition index — each
/// worker holds exactly one partition's weights (the paper's one-copy
/// discipline; no stashing).
///
/// Implementations are constructed *on the worker thread* by a
/// `threaded::WorkerBackend` (PJRT handles are not `Send`), so the
/// stage type itself needs no `Send` bound: only the factory and the
/// tensors crossing the channel registers do.
pub trait WorkerStage {
    /// Forward of a non-last partition; applies BN-state updates
    /// internally, never touches weights.
    fn forward(&mut self, seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Fused last stage: forward + loss + backward + weight update.
    fn last(&mut self, seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult>;

    /// Backward on the saved carry_in of the same mini-batch; applies
    /// the weight update; returns gcarry_in.
    fn backward(
        &mut self,
        seed: i32,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>>;

    /// Hand the partition's weights back at shutdown (the worker owns
    /// the only copy during training).
    fn into_params(self) -> PartitionParams
    where
        Self: Sized;

    /// Install a staleness fix on this stage (DESIGN.md §9). Same
    /// opt-in contract as [`StageExecutor::set_staleness_fix`]: the
    /// default refuses anything but `none` rather than silently
    /// ignoring the request.
    fn set_staleness_fix(&mut self, kind: FixKind) -> Result<()> {
        anyhow::ensure!(
            kind == FixKind::None,
            "this stage does not support --staleness-fix {}",
            kind.name()
        );
        Ok(())
    }
}

/// Production executor: PJRT programs + host-owned weights.
pub struct XlaExecutor {
    /// The config contract the stage programs were compiled against.
    pub meta: ConfigMeta,
    /// One engine (programs + weights + SGD) per partition.
    pub engines: Vec<PartitionEngine>,
}

impl XlaExecutor {
    /// Load and wire the config's compiled stage programs: one
    /// [`PartitionEngine`] per partition.
    pub fn new(
        runtime: &Runtime,
        meta: ConfigMeta,
        params: ModelParams,
        optims: Vec<Sgd>,
    ) -> Result<Self> {
        anyhow::ensure!(
            optims.len() == meta.partitions.len(),
            "need one optimizer per partition"
        );
        anyhow::ensure!(
            params.partitions.len() == meta.partitions.len(),
            "params/partitions arity mismatch"
        );
        let programs = runtime.load_config(&meta)?;
        let engines = meta
            .partitions
            .iter()
            .cloned()
            .zip(programs)
            .zip(params.partitions)
            .zip(optims)
            .map(|(((pm, prog), pp), opt)| PartitionEngine::new(pm, prog, pp, opt))
            .collect();
        Ok(XlaExecutor { meta, engines })
    }

    /// Snapshot the current weights (e.g. after training, for eval or
    /// checkpointing).
    pub fn params_snapshot(&self) -> ModelParams {
        ModelParams {
            partitions: self.engines.iter().map(|e| e.params.clone()).collect(),
        }
    }

    /// Per-partition applied-update counts (schedule assertions).
    pub fn update_counts(&self) -> Vec<usize> {
        self.engines.iter().map(|e| e.update_count).collect()
    }

    /// Per-partition mitigation counters (see
    /// [`PartitionEngine::fix_stats`]).
    pub fn fix_stats(&self) -> Vec<super::mitigation::FixStats> {
        self.engines.iter().map(PartitionEngine::fix_stats).collect()
    }
}

impl StageExecutor for XlaExecutor {
    fn num_partitions(&self) -> usize {
        self.engines.len()
    }

    fn forward(&mut self, p: usize, seed: i32, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        self.engines[p].forward(seed, carry)
    }

    fn last(&mut self, seed: i32, carry: &[Tensor], labels: &IntTensor) -> Result<LastResult> {
        let p = self.engines.len() - 1;
        self.engines[p].last(seed, carry, labels)
    }

    fn backward(
        &mut self,
        p: usize,
        seed: i32,
        carry_in: &[Tensor],
        gcarry_out: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.engines[p].backward(seed, carry_in, gcarry_out)
    }

    fn eval_forward(&mut self, p: usize, carry: &[Tensor]) -> Result<Vec<Tensor>> {
        self.engines[p].eval_forward(carry)
    }

    fn params_snapshot(&self) -> ModelParams {
        XlaExecutor::params_snapshot(self)
    }

    fn set_staleness_fix(&mut self, kind: FixKind) -> Result<()> {
        for engine in &mut self.engines {
            engine.set_staleness_fix(kind);
        }
        Ok(())
    }
}
