//! Checkpointing: save/restore ModelParams (+ iteration counter) to a
//! self-describing binary format.
//!
//! Enables (a) resuming interrupted runs and (b) the paper's hybrid
//! schedule split across *processes*: train the pipelined prefix,
//! checkpoint, and finish non-pipelined elsewhere — the same weights
//! flow through both schedules, exactly as in-process hybrid.
//!
//! Format (little-endian):
//!   magic "PSCKPT01" | u64 iter | u32 n_partitions
//!   per partition: u64 version | u32 n_params | u32 n_state
//!     per tensor: u32 rank | u64 dims[rank] | f32 data[numel]
//! followed by a u32 FNV-1a checksum of everything before it.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::{ModelParams, PartitionParams};
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"PSCKPT01";

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn tensor(&mut self, t: &Tensor) {
        self.u32(t.shape.len() as u32);
        for &d in t.shape.iter() {
            self.u64(d as u64);
        }
        for v in t.data() {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("checkpoint truncated at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn tensor(&mut self) -> Result<Tensor> {
        let rank = self.u32()? as usize;
        if rank > crate::tensor::MAX_RANK {
            bail!("implausible tensor rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u64()? as usize);
        }
        let numel: usize = shape.iter().product();
        if numel > (1 << 31) {
            bail!("implausible tensor size {numel}");
        }
        let raw = self.take(numel * 4)?;
        // Decode straight into a pooled buffer: restore allocates no
        // fresh backing stores once the pool is warm.
        let mut buf = crate::pool::acquire(numel);
        for (dst, c) in buf.as_mut_slice().iter_mut().zip(raw.chunks_exact(4)) {
            *dst = f32::from_le_bytes(c.try_into().unwrap());
        }
        Tensor::from_pooled(&shape, buf)
    }
}

/// Serialize params + iteration counter.
pub fn save(path: &Path, params: &ModelParams, iter: u64) -> Result<()> {
    let mut w = Writer { buf: Vec::new() };
    w.buf.extend_from_slice(MAGIC);
    w.u64(iter);
    w.u32(params.partitions.len() as u32);
    for p in &params.partitions {
        w.u64(p.version);
        w.u32(p.params.len() as u32);
        w.u32(p.state.len() as u32);
        for t in &p.params {
            w.tensor(t);
        }
        for t in &p.state {
            w.tensor(t);
        }
    }
    let sum = fnv1a(&w.buf);
    w.u32(sum);
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(&w.buf)?;
    Ok(())
}

/// Load params + iteration counter, verifying magic and checksum.
pub fn load(path: &Path) -> Result<(ModelParams, u64)> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut bytes)?;
    if bytes.len() < MAGIC.len() + 4 {
        bail!("{}: not a checkpoint (too small)", path.display());
    }
    let (body, sumb) = bytes.split_at(bytes.len() - 4);
    let want = u32::from_le_bytes(sumb.try_into().unwrap());
    if fnv1a(body) != want {
        bail!("{}: checksum mismatch (corrupt checkpoint)", path.display());
    }
    let mut r = Reader { b: body, pos: 0 };
    if r.take(8)? != MAGIC {
        bail!("{}: bad magic (not a pipestale checkpoint)", path.display());
    }
    let iter = r.u64()?;
    let n_parts = r.u32()? as usize;
    if n_parts > 1024 {
        bail!("implausible partition count {n_parts}");
    }
    let mut partitions = Vec::with_capacity(n_parts);
    for _ in 0..n_parts {
        let version = r.u64()?;
        let n_params = r.u32()? as usize;
        let n_state = r.u32()? as usize;
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            params.push(r.tensor()?);
        }
        let mut state = Vec::with_capacity(n_state);
        for _ in 0..n_state {
            state.push(r.tensor()?);
        }
        partitions.push(PartitionParams { params, state, version });
    }
    if r.pos != body.len() {
        bail!("{}: trailing bytes after checkpoint body", path.display());
    }
    Ok((ModelParams { partitions }, iter))
}

/// Validate a loaded checkpoint against a config's partition specs
/// (shape-level compatibility before handing weights to executables).
pub fn validate(params: &ModelParams, meta: &crate::meta::ConfigMeta) -> Result<()> {
    if params.partitions.len() != meta.partitions.len() {
        bail!(
            "checkpoint has {} partitions, config {} has {}",
            params.partitions.len(),
            meta.config,
            meta.partitions.len()
        );
    }
    for (pp, pm) in params.partitions.iter().zip(&meta.partitions) {
        if pp.params.len() != pm.params.len() || pp.state.len() != pm.state.len() {
            bail!("partition {} tensor arity mismatch", pm.index);
        }
        for (t, spec) in pp.params.iter().zip(&pm.params) {
            if t.shape != spec.shape {
                bail!("{}: shape {:?} != {:?}", spec.name, t.shape, spec.shape);
            }
        }
        for (t, spec) in pp.state.iter().zip(&pm.state) {
            if t.shape != spec.shape {
                bail!("{}: shape {:?} != {:?}", spec.name, t.shape, spec.shape);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::meta::ConfigMeta;
    use crate::util::rng::Pcg32;
    use std::path::PathBuf;

    fn root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ckpt_{}_{name}", std::process::id()))
    }

    fn sample() -> ModelParams {
        let meta = ConfigMeta::load_named(&root(), "quickstart_lenet").unwrap();
        let mut mp = ModelParams::init(&meta.partitions, 3).unwrap();
        let mut rng = Pcg32::seeded(9);
        for p in &mut mp.partitions {
            p.version = 17;
            for t in &mut p.params {
                for v in t.data_mut() {
                    *v = rng.normal();
                }
            }
        }
        mp
    }

    #[test]
    fn roundtrip_bit_exact() {
        if !crate::artifacts_present() { eprintln!("skipping: artifacts not built"); return; }
        let mp = sample();
        let p = tmp("rt");
        save(&p, &mp, 123).unwrap();
        let (back, iter) = load(&p).unwrap();
        assert_eq!(iter, 123);
        assert_eq!(back.partitions.len(), mp.partitions.len());
        for (a, b) in back.partitions.iter().zip(&mp.partitions) {
            assert_eq!(a.version, b.version);
            assert_eq!(a.params, b.params);
            assert_eq!(a.state, b.state);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_corruption() {
        if !crate::artifacts_present() { eprintln!("skipping: artifacts not built"); return; }
        let mp = sample();
        let p = tmp("corrupt");
        save(&p, &mp, 1).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        if !crate::artifacts_present() { eprintln!("skipping: artifacts not built"); return; }
        let p = tmp("garbage");
        std::fs::write(&p, b"not a checkpoint at all................").unwrap();
        assert!(load(&p).is_err());
        let mp = sample();
        save(&p, &mp, 1).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 3]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn validate_against_meta() {
        if !crate::artifacts_present() { eprintln!("skipping: artifacts not built"); return; }
        let meta = ConfigMeta::load_named(&root(), "quickstart_lenet").unwrap();
        let mp = sample();
        validate(&mp, &meta).unwrap();
        let other = ConfigMeta::load_named(&root(), "resnet20_4s").unwrap();
        assert!(validate(&mp, &other).is_err());
    }
}
