//! Checkpointing: save/restore ModelParams (+ iteration counter) to a
//! self-describing binary format.
//!
//! Enables (a) resuming interrupted runs, (b) the paper's hybrid
//! schedule split across *processes*: train the pipelined prefix,
//! checkpoint, and finish non-pipelined elsewhere — the same weights
//! flow through both schedules, exactly as in-process hybrid — and
//! (c) the supervised checkpoint-restart loop of the fault-tolerant
//! threaded driver (DESIGN.md §8) via [`CheckpointStore`], a rotating
//! last-K directory with newest-valid selection.
//!
//! Crash consistency: `save` writes the full image to a sibling
//! `*.tmp`, fsyncs, then renames into place — a reader never observes
//! a half-written checkpoint under the final name, and a crash mid-
//! save leaves the previous checkpoint intact. Torn or corrupted
//! files are still detectable (power loss after rename, bit rot): the
//! trailing FNV-1a checksum covers every byte of the body, and
//! `CheckpointStore::newest_valid` skips files that fail it.
//!
//! Format (little-endian):
//!   magic "PSCKPT01" | u64 iter | u32 n_partitions
//!   per partition: u64 version | u32 n_params | u32 n_state
//!     per tensor: u32 rank | u64 dims[rank] | f32 data[numel]
//! followed by a u32 FNV-1a checksum of everything before it.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::{ModelParams, PartitionParams};
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"PSCKPT01";

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn tensor(&mut self, t: &Tensor) {
        self.u32(t.shape.len() as u32);
        for &d in t.shape.iter() {
            self.u64(d as u64);
        }
        for v in t.data() {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("checkpoint truncated at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn tensor(&mut self) -> Result<Tensor> {
        let rank = self.u32()? as usize;
        if rank > crate::tensor::MAX_RANK {
            bail!("implausible tensor rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u64()? as usize);
        }
        let numel: usize = shape.iter().product();
        if numel > (1 << 31) {
            bail!("implausible tensor size {numel}");
        }
        let raw = self.take(numel * 4)?;
        // Decode straight into a pooled buffer: restore allocates no
        // fresh backing stores once the pool is warm.
        let mut buf = crate::pool::acquire(numel);
        for (dst, c) in buf.as_mut_slice().iter_mut().zip(raw.chunks_exact(4)) {
            *dst = f32::from_le_bytes(c.try_into().unwrap());
        }
        Tensor::from_pooled(&shape, buf)
    }
}

/// Serialize params + iteration counter, crash-consistently: the image
/// is written to a sibling `*.tmp`, fsynced, and renamed into place,
/// so the final path only ever holds a complete checkpoint (an existing
/// file at `path` survives a crash mid-save untouched).
pub fn save(path: &Path, params: &ModelParams, iter: u64) -> Result<()> {
    let mut w = Writer { buf: Vec::new() };
    w.buf.extend_from_slice(MAGIC);
    w.u64(iter);
    w.u32(params.partitions.len() as u32);
    for p in &params.partitions {
        w.u64(p.version);
        w.u32(p.params.len() as u32);
        w.u32(p.state.len() as u32);
        for t in &p.params {
            w.tensor(t);
        }
        for t in &p.state {
            w.tensor(t);
        }
    }
    let sum = fnv1a(&w.buf);
    w.u32(sum);
    // Temp file in the same directory: rename is atomic only within
    // one filesystem. The pid suffix keeps concurrent savers apart.
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(format!(".{}.tmp", std::process::id()));
    let tmp = path.with_file_name(name);
    let write = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&w.buf)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    })();
    if write.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    write
}

/// Load params + iteration counter, verifying magic and checksum.
pub fn load(path: &Path) -> Result<(ModelParams, u64)> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut bytes)?;
    if bytes.len() < MAGIC.len() + 4 {
        bail!("{}: not a checkpoint (too small)", path.display());
    }
    let (body, sumb) = bytes.split_at(bytes.len() - 4);
    let want = u32::from_le_bytes(sumb.try_into().unwrap());
    if fnv1a(body) != want {
        bail!("{}: checksum mismatch (corrupt checkpoint)", path.display());
    }
    let mut r = Reader { b: body, pos: 0 };
    if r.take(8)? != MAGIC {
        bail!("{}: bad magic (not a pipestale checkpoint)", path.display());
    }
    let iter = r.u64()?;
    let n_parts = r.u32()? as usize;
    if n_parts > 1024 {
        bail!("implausible partition count {n_parts}");
    }
    let mut partitions = Vec::with_capacity(n_parts);
    for _ in 0..n_parts {
        let version = r.u64()?;
        let n_params = r.u32()? as usize;
        let n_state = r.u32()? as usize;
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            params.push(r.tensor()?);
        }
        let mut state = Vec::with_capacity(n_state);
        for _ in 0..n_state {
            state.push(r.tensor()?);
        }
        partitions.push(PartitionParams { params, state, version });
    }
    if r.pos != body.len() {
        bail!("{}: trailing bytes after checkpoint body", path.display());
    }
    Ok((ModelParams { partitions }, iter))
}

/// Validate a loaded checkpoint against a config's partition specs
/// (shape-level compatibility before handing weights to executables).
pub fn validate(params: &ModelParams, meta: &crate::meta::ConfigMeta) -> Result<()> {
    if params.partitions.len() != meta.partitions.len() {
        bail!(
            "checkpoint has {} partitions, config {} has {}",
            params.partitions.len(),
            meta.config,
            meta.partitions.len()
        );
    }
    for (pp, pm) in params.partitions.iter().zip(&meta.partitions) {
        if pp.params.len() != pm.params.len() || pp.state.len() != pm.state.len() {
            bail!("partition {} tensor arity mismatch", pm.index);
        }
        for (t, spec) in pp.params.iter().zip(&pm.params) {
            if t.shape != spec.shape {
                bail!("{}: shape {:?} != {:?}", spec.name, t.shape, spec.shape);
            }
        }
        for (t, spec) in pp.state.iter().zip(&pm.state) {
            if t.shape != spec.shape {
                bail!("{}: shape {:?} != {:?}", spec.name, t.shape, spec.shape);
            }
        }
    }
    Ok(())
}

/// Rotating last-K checkpoint directory for supervised restart: every
/// `save` is atomic (see [`save`]) and named `ckpt_<iter>.pst`; older
/// files beyond `keep` are pruned; [`CheckpointStore::newest_valid`]
/// restores the newest file that passes the checksum (and, when a meta
/// is given, shape validation), *skipping* corrupt or mismatched files
/// instead of failing while an older valid one exists.
#[derive(Debug, Clone)]
pub struct CheckpointStore {
    dir: PathBuf,
    keep: usize,
}

const CKPT_PREFIX: &str = "ckpt_";
const CKPT_SUFFIX: &str = ".pst";

impl CheckpointStore {
    /// Open (creating if needed) a checkpoint directory keeping the
    /// newest `keep` files.
    pub fn open(dir: &Path, keep: usize) -> Result<Self> {
        bail_if_zero(keep)?;
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(CheckpointStore { dir: dir.to_path_buf(), keep })
    }

    /// The directory this store rotates in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of the checkpoint for iteration `iter`.
    pub fn path_for(&self, iter: u64) -> PathBuf {
        self.dir.join(format!("{CKPT_PREFIX}{iter:010}{CKPT_SUFFIX}"))
    }

    /// Atomically save a checkpoint for `iter` and prune beyond `keep`.
    /// Returns the written path.
    pub fn save(&self, params: &ModelParams, iter: u64) -> Result<PathBuf> {
        let path = self.path_for(iter);
        save(&path, params, iter)?;
        self.prune()?;
        Ok(path)
    }

    /// All checkpoints on disk, as (iter, path) sorted by iter
    /// ascending. Files that don't match the naming scheme (including
    /// in-flight `*.tmp` writes) are ignored.
    pub fn list(&self) -> Vec<(u64, PathBuf)> {
        let mut out = Vec::new();
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return out;
        };
        for entry in rd.filter_map(|e| e.ok()) {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(mid) =
                name.strip_prefix(CKPT_PREFIX).and_then(|r| r.strip_suffix(CKPT_SUFFIX))
            else {
                continue;
            };
            if let Ok(iter) = mid.parse::<u64>() {
                out.push((iter, entry.path()));
            }
        }
        out.sort();
        out
    }

    /// Restore the newest checkpoint that loads cleanly — checksum,
    /// magic, structural bounds, a header iter that matches the
    /// filename, and (when `meta` is given) per-tensor shape
    /// validation. Corrupt, truncated, or mismatched files are logged
    /// and skipped so an older valid checkpoint still wins. `None`
    /// when no valid checkpoint exists.
    pub fn newest_valid(&self, meta: Option<&crate::meta::ConfigMeta>) -> Option<(ModelParams, u64)> {
        for (iter, path) in self.list().into_iter().rev() {
            match load(&path) {
                Ok((params, at)) => {
                    if at != iter {
                        log::warn!(
                            "skipping {}: header iter {at} != filename iter {iter}",
                            path.display()
                        );
                        continue;
                    }
                    if let Some(m) = meta {
                        if let Err(e) = validate(&params, m) {
                            log::warn!("skipping {}: {e:#}", path.display());
                            continue;
                        }
                    }
                    return Some((params, at));
                }
                Err(e) => log::warn!("skipping corrupt checkpoint {}: {e:#}", path.display()),
            }
        }
        None
    }

    fn prune(&self) -> Result<()> {
        let mut all = self.list();
        while all.len() > self.keep {
            let (iter, path) = all.remove(0);
            std::fs::remove_file(&path)
                .with_context(|| format!("pruning checkpoint {}", path.display()))?;
            log::debug!("pruned checkpoint iter {iter} ({})", path.display());
        }
        Ok(())
    }
}

fn bail_if_zero(keep: usize) -> Result<()> {
    if keep == 0 {
        bail!("checkpoint store must keep at least one file");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native_config;
    use crate::util::rng::Pcg32;
    use std::path::PathBuf;

    // Native built-in configs keep the whole module testable offline
    // (no artifacts): ModelParams::init works from the in-crate meta.
    fn native_meta() -> crate::meta::ConfigMeta {
        native_config("native_lenet_small").unwrap()
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ckpt_{}_{name}", std::process::id()))
    }

    fn sample() -> ModelParams {
        let meta = native_meta();
        let mut mp = ModelParams::init(&meta.partitions, 3).unwrap();
        let mut rng = Pcg32::seeded(9);
        for p in &mut mp.partitions {
            p.version = 17;
            for t in &mut p.params {
                for v in t.data_mut() {
                    *v = rng.normal();
                }
            }
        }
        mp
    }

    #[test]
    fn roundtrip_bit_exact() {
        let mp = sample();
        let p = tmp("rt");
        save(&p, &mp, 123).unwrap();
        let (back, iter) = load(&p).unwrap();
        assert_eq!(iter, 123);
        assert_eq!(back.partitions.len(), mp.partitions.len());
        for (a, b) in back.partitions.iter().zip(&mp.partitions) {
            assert_eq!(a.version, b.version);
            assert_eq!(a.params, b.params);
            assert_eq!(a.state, b.state);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn save_is_atomic_no_tmp_left_and_overwrites() {
        let dir = tmp("atomic_dir");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("weights.pst");
        let mp = sample();
        save(&p, &mp, 7).unwrap();
        // Overwriting an existing checkpoint goes through the same
        // tmp+rename path.
        save(&p, &mp, 8).unwrap();
        let (_, iter) = load(&p).unwrap();
        assert_eq!(iter, 8);
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_corruption() {
        let mp = sample();
        let p = tmp("corrupt");
        save(&p, &mp, 1).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let p = tmp("garbage");
        std::fs::write(&p, b"not a checkpoint at all................").unwrap();
        assert!(load(&p).is_err());
        let mp = sample();
        save(&p, &mp, 1).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 3]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_wrong_magic_with_valid_checksum() {
        // A wrong magic must be rejected on its own, not only via the
        // checksum: rewrite the header and re-checksum the body.
        let mp = sample();
        let p = tmp("magic");
        save(&p, &mp, 1).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let mut body = bytes[..bytes.len() - 4].to_vec();
        body[..8].copy_from_slice(b"XXCKPT99");
        let sum = fnv1a(&body);
        body.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(&p, &body).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn validate_against_meta() {
        let meta = native_meta();
        let mp = sample();
        validate(&mp, &meta).unwrap();
        // A config with a different partitioning must be rejected.
        let other = native_config("native_lenet_small_4s").unwrap();
        assert_ne!(meta.partitions.len(), other.partitions.len());
        assert!(validate(&mp, &other).is_err());
    }

    #[test]
    fn store_rotates_and_restores_newest_valid_of_k() {
        let dir = tmp("store_rot");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 3).unwrap();
        assert!(CheckpointStore::open(&dir, 0).is_err(), "keep=0 must be rejected");
        let mp = sample();
        for iter in [10u64, 20, 30, 40, 50] {
            store.save(&mp, iter).unwrap();
        }
        let iters: Vec<u64> = store.list().into_iter().map(|(i, _)| i).collect();
        assert_eq!(iters, vec![30, 40, 50], "rotation keeps the newest 3");

        // Newest valid with everything intact: 50.
        let meta = native_meta();
        let (_, at) = store.newest_valid(Some(&meta)).unwrap();
        assert_eq!(at, 50);

        // Bit-flip 50 -> selection falls back to 40.
        let p50 = store.path_for(50);
        let mut bytes = std::fs::read(&p50).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p50, &bytes).unwrap();
        let (_, at) = store.newest_valid(Some(&meta)).unwrap();
        assert_eq!(at, 40, "corrupt newest must be skipped, not fatal");

        // Truncate 40 -> falls back to 30.
        let p40 = store.path_for(40);
        let bytes = std::fs::read(&p40).unwrap();
        std::fs::write(&p40, &bytes[..bytes.len() / 3]).unwrap();
        let (restored, at) = store.newest_valid(Some(&meta)).unwrap();
        assert_eq!(at, 30);
        assert_eq!(restored.partitions.len(), mp.partitions.len());

        // Damage 30 too -> nothing valid remains.
        std::fs::write(store.path_for(30), b"gone").unwrap();
        assert!(store.newest_valid(Some(&meta)).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_skips_shape_mismatched_checkpoints() {
        let dir = tmp("store_shape");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 4).unwrap();
        let meta = native_meta();
        store.save(&sample(), 10).unwrap();
        // A newer checkpoint from a *different* config: valid bytes,
        // wrong shapes for this meta.
        let other = native_config("native_lenet_small_4s").unwrap();
        let other_params = ModelParams::init(&other.partitions, 1).unwrap();
        store.save(&other_params, 20).unwrap();
        let (_, at) = store.newest_valid(Some(&meta)).unwrap();
        assert_eq!(at, 10, "shape-mismatched newer checkpoint must be skipped");
        // Without a meta there is no shape gate: the newest loads.
        let (_, at) = store.newest_valid(None).unwrap();
        assert_eq!(at, 20);
        std::fs::remove_dir_all(&dir).ok();
    }
}
