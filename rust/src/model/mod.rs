//! Parameter & state stores: the coordinator-owned weight copies.
//!
//! One `PartitionParams` per pipeline partition. Initialization mirrors
//! python/compile/layers.py::init_value exactly in *distribution* (He
//! normal / Glorot uniform / zeros / ones); bit-level equality with numpy
//! is not required because both sides train from their own seeds.

pub mod checkpoint;

use anyhow::Result;

use crate::meta::PartitionMeta;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Weights + BN state for one partition.
#[derive(Clone, Debug)]
pub struct PartitionParams {
    pub params: Vec<Tensor>,
    pub state: Vec<Tensor>,
    /// Monotone count of applied updates (staleness bookkeeping).
    pub version: u64,
}

impl PartitionParams {
    pub fn init(meta: &PartitionMeta, rng: &mut Pcg32) -> Result<Self> {
        let mut params = Vec::with_capacity(meta.params.len());
        for spec in &meta.params {
            let mut t = Tensor::zeros(&spec.shape);
            match spec.init.as_str() {
                "zeros" => {}
                "ones" => t.data_mut().fill(1.0),
                "he" => rng.fill_he(t.data_mut(), spec.fan_in),
                "glorot" => {
                    let fan_out = *spec.shape.last().unwrap_or(&1);
                    rng.fill_glorot(t.data_mut(), spec.fan_in, fan_out);
                }
                other => anyhow::bail!("unknown init {other:?} for {}", spec.name),
            }
            params.push(t);
        }
        let mut state = Vec::with_capacity(meta.state.len());
        for spec in &meta.state {
            state.push(match spec.init.as_str() {
                "ones" => Tensor::ones(&spec.shape),
                _ => Tensor::zeros(&spec.shape),
            });
        }
        Ok(PartitionParams { params, state, version: 0 })
    }

    pub fn num_scalars(&self) -> usize {
        self.params.iter().map(|t| t.numel()).sum()
    }
}

/// All partitions of one model instance.
#[derive(Clone, Debug)]
pub struct ModelParams {
    pub partitions: Vec<PartitionParams>,
}

impl ModelParams {
    pub fn init(parts: &[PartitionMeta], seed: u64) -> Result<Self> {
        // One RNG stream for the whole model, walked in partition order —
        // the same weights regardless of how the model is partitioned
        // (paired baselines share initialization across PPVs with equal
        // partition boundaries walk order; see scheduler tests).
        let mut rng = Pcg32::seeded(seed);
        let partitions = parts
            .iter()
            .map(|p| PartitionParams::init(p, &mut rng))
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelParams { partitions })
    }

    pub fn total_scalars(&self) -> usize {
        self.partitions.iter().map(|p| p.num_scalars()).sum()
    }

    pub fn all_finite(&self) -> bool {
        self.partitions
            .iter()
            .all(|p| p.params.iter().chain(p.state.iter()).all(Tensor::is_finite))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::meta::ConfigMeta;
    use std::path::PathBuf;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn init_respects_specs() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let m = ConfigMeta::load_named(&artifacts_root(), "quickstart_lenet").unwrap();
        let mp = ModelParams::init(&m.partitions, 42).unwrap();
        assert_eq!(mp.total_scalars(), m.total_params());
        assert!(mp.all_finite());
        // biases are zero-initialized
        for (p, pm) in mp.partitions.iter().zip(m.partitions.iter()) {
            for (t, spec) in p.params.iter().zip(pm.params.iter()) {
                if spec.init == "zeros" {
                    assert!(t.data().iter().all(|&v| v == 0.0), "{}", spec.name);
                } else {
                    assert!(t.norm() > 0.0, "{}", spec.name);
                }
            }
        }
    }

    #[test]
    fn init_is_seed_deterministic() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let m = ConfigMeta::load_named(&artifacts_root(), "quickstart_lenet").unwrap();
        let a = ModelParams::init(&m.partitions, 7).unwrap();
        let b = ModelParams::init(&m.partitions, 7).unwrap();
        let c = ModelParams::init(&m.partitions, 8).unwrap();
        assert_eq!(a.partitions[0].params[0], b.partitions[0].params[0]);
        assert_ne!(a.partitions[0].params[0], c.partitions[0].params[0]);
    }

    #[test]
    fn bn_state_init_mean_zero_var_one() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let m = ConfigMeta::load_named(&artifacts_root(), "resnet20_4s").unwrap();
        let mp = ModelParams::init(&m.partitions, 1).unwrap();
        for (p, pm) in mp.partitions.iter().zip(m.partitions.iter()) {
            for (t, spec) in p.state.iter().zip(pm.state.iter()) {
                if spec.name.ends_with("/var") {
                    assert!(t.data().iter().all(|&v| v == 1.0));
                } else {
                    assert!(t.data().iter().all(|&v| v == 0.0));
                }
            }
        }
    }
}
