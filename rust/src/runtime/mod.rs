//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! One `Runtime` wraps one PJRT client ("one accelerator"). Stage
//! programs are compiled once per process and cached. HLO *text* is the
//! interchange format (jax >= 0.5 protos are rejected by xla_extension
//! 0.5.1 — see DESIGN.md §1 and /opt/xla-example/README.md).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::meta::{ConfigMeta, PartitionMeta};
use crate::tensor::{numel, seed_literal, IntTensor, Tensor};

/// A compiled stage program plus its output signature.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    /// Expected output shapes, positionally (f32 unless noted).
    pub out_shapes: Vec<Vec<usize>>,
    pub name: String,
}

impl Program {
    /// Execute with positional literal inputs; unpack the output tuple
    /// into host tensors using the recorded shapes. Output tensors are
    /// built in pooled storage (`Tensor::from_literal`), so at steady
    /// state this path performs no backing-store allocations.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        // aot.py lowers with return_tuple=True: outputs arrive as a tuple.
        let parts = lit.to_tuple().context("decompose output tuple")?;
        if parts.len() != self.out_shapes.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.out_shapes.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, shape) in parts.into_iter().zip(self.out_shapes.iter()) {
            if lit.element_count() != numel(shape) {
                bail!(
                    "{}: output numel mismatch: literal {} vs shape {:?}",
                    self.name,
                    lit.element_count(),
                    shape
                );
            }
            out.push(Tensor::from_literal(&lit, shape)?);
        }
        Ok(out)
    }
}

/// All compiled programs for one partition.
pub struct StagePrograms {
    pub fwd: Option<Program>,
    pub bwd: Option<Program>,
    pub fwd_eval: Option<Program>,
    pub last: Option<Program>,
    pub last_eval: Option<Program>,
}

/// One PJRT device context; owns a client and compiles stage programs.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn compile_hlo_text(&self, path: &Path, name: &str, out_shapes: Vec<Vec<usize>>) -> Result<Program> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Program { exe, out_shapes, name: name.to_string() })
    }

    /// Compile every program of one partition, deriving output signatures
    /// from meta.json.
    pub fn load_partition(&self, meta: &ConfigMeta, part: &PartitionMeta) -> Result<StagePrograms> {
        let state_shapes: Vec<Vec<usize>> = part.state.iter().map(|s| s.shape.clone()).collect();
        let param_shapes: Vec<Vec<usize>> = part.params.iter().map(|p| p.shape.clone()).collect();
        let mut sp = StagePrograms { fwd: None, bwd: None, fwd_eval: None, last: None, last_eval: None };

        if part.is_last() {
            // last: (loss, correct, gcarry_in.., dparams.., new_state..)
            let mut shapes = vec![vec![], vec![]];
            shapes.extend(part.carry_in.clone());
            shapes.extend(param_shapes.clone());
            shapes.extend(state_shapes.clone());
            sp.last = Some(self.compile_hlo_text(
                &meta.program_path(part, "last")?,
                &format!("{}/stage{}_last", meta.config, part.index),
                shapes,
            )?);
            // last_eval: (logits,)
            sp.last_eval = Some(self.compile_hlo_text(
                &meta.program_path(part, "last_eval")?,
                &format!("{}/stage{}_last_eval", meta.config, part.index),
                vec![vec![meta.batch, meta.num_classes]],
            )?);
        } else {
            // fwd: (carry_out.., new_state..)
            let mut shapes = part.carry_out.clone();
            shapes.extend(state_shapes.clone());
            sp.fwd = Some(self.compile_hlo_text(
                &meta.program_path(part, "fwd")?,
                &format!("{}/stage{}_fwd", meta.config, part.index),
                shapes,
            )?);
            // bwd: (gcarry_in.., dparams..)
            let mut shapes = part.carry_in.clone();
            shapes.extend(param_shapes.clone());
            sp.bwd = Some(self.compile_hlo_text(
                &meta.program_path(part, "bwd")?,
                &format!("{}/stage{}_bwd", meta.config, part.index),
                shapes,
            )?);
            // fwd_eval: (carry_out..)
            sp.fwd_eval = Some(self.compile_hlo_text(
                &meta.program_path(part, "fwd_eval")?,
                &format!("{}/stage{}_fwd_eval", meta.config, part.index),
                part.carry_out.clone(),
            )?);
        }
        Ok(sp)
    }

    /// Compile all partitions of a config.
    pub fn load_config(&self, meta: &ConfigMeta) -> Result<Vec<StagePrograms>> {
        if meta.meta_only {
            bail!("{} is a meta-only config (no HLO artifacts)", meta.config);
        }
        meta.partitions.iter().map(|p| self.load_partition(meta, p)).collect()
    }
}

/// Reusable positional-input assembly for fwd/bwd/last calls.
///
/// Replaces the per-call `InputBuilder` (which allocated a fresh
/// `Vec<Literal>` every stage execution): each `PartitionEngine` owns
/// one `InputScratch` and the outer vec's capacity persists across the
/// whole run. Call `clear()` first, push positionally, then pass
/// `literals()` to `Program::run`.
#[derive(Default)]
pub struct InputScratch {
    literals: Vec<xla::Literal>,
}

impl InputScratch {
    pub fn new() -> Self {
        InputScratch { literals: Vec::new() }
    }

    /// Drop the previous call's literals, keeping the vec's capacity.
    pub fn clear(&mut self) {
        self.literals.clear();
    }

    pub fn push_tensors(&mut self, ts: &[Tensor]) -> Result<()> {
        self.literals.reserve(ts.len());
        for t in ts {
            self.literals.push(t.to_literal()?);
        }
        Ok(())
    }

    pub fn push_seed(&mut self, seed: i32) {
        self.literals.push(seed_literal(seed));
    }

    pub fn push_ints(&mut self, t: &IntTensor) -> Result<()> {
        self.literals.push(t.to_literal()?);
        Ok(())
    }

    pub fn literals(&self) -> &[xla::Literal] {
        &self.literals
    }
}

/// True when the crate is linked against a real XLA backend rather than
/// the bundled stub; XLA-dependent tests and benches gate on this.
pub fn backend_available() -> bool {
    !xla::IS_STUB
}
