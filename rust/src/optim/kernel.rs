//! Fused SGD update kernels (the §Perf L3 hot loop).
//!
//! One pass per parameter tensor: weight decay, momentum accumulation
//! and the parameter update happen in a single traversal over
//! contiguous slices. The mode (vanilla / momentum / Nesterov) is
//! dispatched once per tensor, never per element, and each inner loop
//! runs over re-bound equal-length slices so LLVM drops the bounds
//! checks and auto-vectorizes.
//!
//! `reference_update` preserves the pre-fusion scalar loops verbatim;
//! `tests/pool_and_kernel.rs` asserts the fused kernel matches it
//! **bitwise** across momentum/Nesterov/weight-decay combinations, and
//! the micro bench reports fused-vs-reference throughput.

/// Fused update: `p <- p - lr * step(g + wd*p)` with optional
/// (Nesterov) momentum. `v` must be `Some` iff `mu != 0`, with
/// `v.len() == p.len()`; callers validate lengths (`Sgd::step`).
pub fn fused_update(
    p: &mut [f32],
    g: &[f32],
    v: Option<&mut [f32]>,
    lr: f32,
    mu: f32,
    nesterov: bool,
    wd: f32,
) {
    let n = p.len();
    assert_eq!(g.len(), n, "grad/param length mismatch");
    let g = &g[..n];
    match v {
        None => {
            // Hard error even in release: silently dropping momentum
            // would corrupt training, and the check is per-tensor.
            assert_eq!(mu, 0.0, "momentum {mu} requires a velocity buffer");
            for i in 0..n {
                let d = g[i] + wd * p[i];
                p[i] -= lr * d;
            }
        }
        Some(v) => {
            assert_eq!(v.len(), n, "velocity/param length mismatch");
            let v = &mut v[..n];
            if nesterov {
                for i in 0..n {
                    let d = g[i] + wd * p[i];
                    let vn = mu * v[i] + d;
                    v[i] = vn;
                    p[i] -= lr * (d + mu * vn);
                }
            } else {
                for i in 0..n {
                    let d = g[i] + wd * p[i];
                    let vn = mu * v[i] + d;
                    v[i] = vn;
                    p[i] -= lr * vn;
                }
            }
        }
    }
}

/// The pre-fusion update loops, kept verbatim as the differential-test
/// oracle and the "before" side of the SGD micro bench.
pub fn reference_update(
    p: &mut [f32],
    g: &[f32],
    v: &mut [f32],
    lr: f32,
    mu: f32,
    nesterov: bool,
    wd: f32,
) {
    debug_assert_eq!(p.len(), g.len());
    if mu == 0.0 {
        for (pv, gv) in p.iter_mut().zip(g) {
            let d = gv + wd * *pv;
            *pv -= lr * d;
        }
    } else if nesterov {
        for ((pv, gv), vv) in p.iter_mut().zip(g).zip(v.iter_mut()) {
            let d = gv + wd * *pv;
            *vv = mu * *vv + d;
            *pv -= lr * (d + mu * *vv);
        }
    } else {
        for ((pv, gv), vv) in p.iter_mut().zip(g).zip(v.iter_mut()) {
            let d = gv + wd * *pv;
            *vv = mu * *vv + d;
            *pv -= lr * *vv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vanilla_matches_reference_bitwise() {
        let p0: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let g: Vec<f32> = (0..37).map(|i| (i as f32 * 0.7).cos()).collect();
        let mut pa = p0.clone();
        let mut pb = p0;
        let mut vr = vec![0.0; 37];
        fused_update(&mut pa, &g, None, 0.1, 0.0, false, 5e-4);
        reference_update(&mut pb, &g, &mut vr, 0.1, 0.0, false, 5e-4);
        for (a, b) in pa.iter().zip(&pb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn momentum_and_nesterov_match_reference_bitwise() {
        for &nesterov in &[false, true] {
            let mut pa: Vec<f32> = (0..61).map(|i| (i as f32 * 0.3).sin()).collect();
            let mut pb = pa.clone();
            let mut va = vec![0.0f32; 61];
            let mut vb = vec![0.0f32; 61];
            let g: Vec<f32> = (0..61).map(|i| (i as f32 * 1.3).cos()).collect();
            for _step in 0..4 {
                fused_update(&mut pa, &g, Some(&mut va), 0.05, 0.9, nesterov, 1e-4);
                reference_update(&mut pb, &g, &mut vb, 0.05, 0.9, nesterov, 1e-4);
            }
            for (a, b) in pa.iter().zip(&pb).chain(va.iter().zip(&vb)) {
                assert_eq!(a.to_bits(), b.to_bits(), "nesterov={nesterov}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn rejects_mismatched_grads() {
        let mut p = [0.0f32; 4];
        let g = [0.0f32; 3];
        fused_update(&mut p, &g, None, 0.1, 0.0, false, 0.0);
    }
}
