//! SGD optimizer + learning-rate schedules (the paper's Appendix A/B).
//!
//! The paper trains with SGD + momentum (Nesterov for AlexNet/VGG) and
//! per-network LR schedules; the 4-stage "actual" runs additionally use a
//! *per-backward-stage* learning rate (Table 7: the BKS_2 stage of deeper
//! ResNets needs a smaller LR to tolerate staleness). `Sgd` therefore
//! carries an optional per-partition LR scale.

pub mod kernel;

use anyhow::{ensure, Result};

use crate::pool::{self, PoolVec};
use crate::tensor::Tensor;

/// Learning-rate schedule, evaluated per iteration.
#[derive(Debug, Clone)]
#[allow(missing_docs)] // variant fields are the formula inputs documented per variant
pub enum Schedule {
    /// Constant `base`.
    Const { base: f64 },
    /// base * gamma^(iter / every)  (Caffe "step")
    Step { base: f64, gamma: f64, every: usize },
    /// base * gamma^(#milestones passed)  (paper: "decreased by 10x twice")
    MultiStep { base: f64, gamma: f64, milestones: Vec<usize> },
    /// base * (1 + gamma*iter)^(-power)  (Caffe "inv", LeNet-5)
    Inv { base: f64, gamma: f64, power: f64 },
    /// base * 0.5^(iter / every)  (VGG: halved every 50 epochs)
    HalfEvery { base: f64, every: usize },
}

impl Schedule {
    /// The learning rate at (0-based) iteration `iter`.
    ///
    /// ```
    /// use pipestale::optim::Schedule;
    /// let s = Schedule::MultiStep { base: 1.0, gamma: 0.1, milestones: vec![10, 20] };
    /// assert_eq!(s.lr(5), 1.0);
    /// assert!((s.lr(15) - 0.1).abs() < 1e-12);
    /// assert!((s.lr(25) - 0.01).abs() < 1e-12);
    /// ```
    pub fn lr(&self, iter: usize) -> f64 {
        match self {
            Schedule::Const { base } => *base,
            Schedule::Step { base, gamma, every } => {
                base * gamma.powi((iter / every) as i32)
            }
            Schedule::MultiStep { base, gamma, milestones } => {
                let passed = milestones.iter().filter(|&&m| iter >= m).count();
                base * gamma.powi(passed as i32)
            }
            Schedule::Inv { base, gamma, power } => {
                base * (1.0 + gamma * iter as f64).powf(-power)
            }
            Schedule::HalfEvery { base, every } => {
                base * 0.5f64.powi((iter / every) as i32)
            }
        }
    }
}

/// SGD with momentum / Nesterov / weight decay, one velocity buffer per
/// parameter tensor of one partition. Velocity buffers are leased from
/// the tensor pool, so they recycle across partitions and runs.
#[derive(Debug, Clone)]
pub struct Sgd {
    /// Learning-rate schedule.
    pub schedule: Schedule,
    /// Momentum coefficient (0.0 = vanilla SGD, no velocity buffers).
    pub momentum: f32,
    /// Nesterov look-ahead (AlexNet/VGG presets).
    pub nesterov: bool,
    /// L2 weight decay folded into the gradient.
    pub weight_decay: f32,
    /// Per-partition multiplier on the scheduled LR (Table 7).
    pub lr_scale: f32,
    velocity: Vec<PoolVec>,
}

impl Sgd {
    /// New optimizer with LR scale 1.0 and empty velocity.
    pub fn new(schedule: Schedule, momentum: f32, nesterov: bool, weight_decay: f32) -> Self {
        Sgd { schedule, momentum, nesterov, weight_decay, lr_scale: 1.0, velocity: Vec::new() }
    }

    /// Set the per-partition LR multiplier (builder style).
    pub fn with_lr_scale(mut self, scale: f32) -> Self {
        self.lr_scale = scale;
        self
    }

    /// The scheduled learning rate at `iter` with this optimizer's
    /// per-partition scale applied — exactly the value `step(iter, ..)`
    /// would use (weight prediction extrapolates with it).
    pub fn effective_lr(&self, iter: usize) -> f32 {
        (self.schedule.lr(iter) as f32) * self.lr_scale
    }

    /// True once momentum velocity buffers exist (they initialize
    /// lazily on the first step with momentum ≠ 0).
    pub fn has_velocity(&self) -> bool {
        !self.velocity.is_empty()
    }

    /// Read-only view of parameter `i`'s velocity buffer, if
    /// initialized. Weight prediction reads these; nothing outside
    /// `step` may write them.
    pub fn velocity(&self, i: usize) -> Option<&[f32]> {
        self.velocity.get(i).map(|v| v.as_slice())
    }

    /// Apply one update: params <- params - lr * (grad + wd*param), via
    /// the fused kernel. This is the L3 hot loop (§Perf).
    ///
    /// Momentum buffers initialize lazily exactly once (first step); any
    /// later params/velocity arity or length mismatch is an error —
    /// silently resetting momentum would corrupt optimizer state across
    /// a checkpoint restore or a partition change.
    pub fn step(&mut self, iter: usize, params: &mut [Tensor], grads: &[Tensor]) -> Result<()> {
        ensure!(
            params.len() == grads.len(),
            "sgd step: {} params vs {} grads",
            params.len(),
            grads.len()
        );
        let lr = (self.schedule.lr(iter) as f32) * self.lr_scale;
        let mu = self.momentum;
        let wd = self.weight_decay;
        if mu != 0.0 {
            if self.velocity.is_empty() {
                self.velocity =
                    params.iter().map(|p| pool::acquire_zeroed(p.numel())).collect();
            }
            ensure!(
                self.velocity.len() == params.len(),
                "sgd step: velocity holds {} buffers but got {} param tensors; \
                 refusing to silently reset momentum (fresh optimizer required \
                 after repartitioning)",
                self.velocity.len(),
                params.len()
            );
        }
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            ensure!(
                p.numel() == g.numel(),
                "sgd step: param {i} has {} elements, grad has {}",
                p.numel(),
                g.numel()
            );
            if mu == 0.0 {
                kernel::fused_update(p.data_mut(), g.data(), None, lr, mu, false, wd);
            } else {
                let v = &mut self.velocity[i];
                ensure!(
                    v.len() == p.numel(),
                    "sgd step: velocity {i} has {} elements, param has {}; \
                     refusing to silently reset momentum",
                    v.len(),
                    p.numel()
                );
                kernel::fused_update(
                    p.data_mut(),
                    g.data(),
                    Some(v.as_mut_slice()),
                    lr,
                    mu,
                    self.nesterov,
                    wd,
                );
            }
        }
        Ok(())
    }
}

/// Paper hyperparameter presets (Appendix A, simulated runs).
pub fn paper_schedule(model: &str, total_iters: usize) -> (Schedule, f32, bool, f32) {
    match model {
        // LeNet-5: SGD lr 0.01 inv policy, momentum 0.9, wd 5e-4
        "lenet5" => (
            Schedule::Inv { base: 0.01, gamma: 1e-4, power: 0.75 },
            0.9,
            false,
            5e-4,
        ),
        // AlexNet: Nesterov, lr 1e-3 dropped 10x twice, wd 4e-3
        "alexnet" => (
            Schedule::MultiStep {
                base: 1e-3,
                gamma: 0.1,
                milestones: vec![total_iters / 2, 3 * total_iters / 4],
            },
            0.9,
            true,
            4e-3,
        ),
        // VGG: Nesterov, lr 0.1 halved periodically, wd 5e-4
        m if m.starts_with("vgg") => (
            Schedule::HalfEvery { base: 0.05, every: (total_iters / 5).max(1) },
            0.9,
            true,
            5e-4,
        ),
        // ResNet: lr 0.1 (non-pipelined) dropped 10x twice, wd 1e-4
        m if m.starts_with("resnet") => (
            Schedule::MultiStep {
                base: 0.05,
                gamma: 0.1,
                milestones: vec![total_iters / 2, 3 * total_iters / 4],
            },
            0.9,
            false,
            1e-4,
        ),
        _ => (Schedule::Const { base: 0.01 }, 0.9, false, 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Tensor {
        Tensor::from_vec(&[v.len()], v.to_vec()).unwrap()
    }

    #[test]
    fn schedules_evaluate() {
        assert_eq!(Schedule::Const { base: 0.1 }.lr(1000), 0.1);
        let s = Schedule::MultiStep { base: 1.0, gamma: 0.1, milestones: vec![10, 20] };
        assert_eq!(s.lr(5), 1.0);
        assert!((s.lr(10) - 0.1).abs() < 1e-12);
        assert!((s.lr(25) - 0.01).abs() < 1e-12);
        let h = Schedule::HalfEvery { base: 1.0, every: 4 };
        assert_eq!(h.lr(3), 1.0);
        assert_eq!(h.lr(4), 0.5);
        assert_eq!(h.lr(8), 0.25);
        let i = Schedule::Inv { base: 1.0, gamma: 1.0, power: 1.0 };
        assert!((i.lr(1) - 0.5).abs() < 1e-12);
        let st = Schedule::Step { base: 1.0, gamma: 0.1, every: 10 };
        assert!((st.lr(19) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn vanilla_sgd_update() {
        let mut o = Sgd::new(Schedule::Const { base: 0.5 }, 0.0, false, 0.0);
        let mut p = vec![t(&[1.0, 2.0])];
        o.step(0, &mut p, &[t(&[1.0, -1.0])]).unwrap();
        assert_eq!(p[0].data(), &[0.5, 2.5]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut o = Sgd::new(Schedule::Const { base: 1.0 }, 0.9, false, 0.0);
        let mut p = vec![t(&[0.0])];
        o.step(0, &mut p, &[t(&[1.0])]).unwrap(); // v=1, p=-1
        o.step(1, &mut p, &[t(&[1.0])]).unwrap(); // v=1.9, p=-2.9
        assert!((p[0].data()[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn nesterov_differs_from_plain() {
        let g = t(&[1.0]);
        let mut plain = Sgd::new(Schedule::Const { base: 1.0 }, 0.9, false, 0.0);
        let mut nest = Sgd::new(Schedule::Const { base: 1.0 }, 0.9, true, 0.0);
        let mut pp = vec![t(&[0.0])];
        let mut pn = vec![t(&[0.0])];
        plain.step(0, &mut pp, std::slice::from_ref(&g)).unwrap();
        nest.step(0, &mut pn, std::slice::from_ref(&g)).unwrap();
        assert!(pn[0].data()[0] < pp[0].data()[0]); // nesterov looks ahead
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut o = Sgd::new(Schedule::Const { base: 0.1 }, 0.0, false, 0.5);
        let mut p = vec![t(&[1.0])];
        o.step(0, &mut p, &[t(&[0.0])]).unwrap();
        assert!((p[0].data()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn lr_scale_applies() {
        let mut o = Sgd::new(Schedule::Const { base: 1.0 }, 0.0, false, 0.0).with_lr_scale(0.1);
        let mut p = vec![t(&[0.0])];
        o.step(0, &mut p, &[t(&[1.0])]).unwrap();
        assert!((p[0].data()[0] + 0.1).abs() < 1e-7);
    }

    #[test]
    fn velocity_arity_mismatch_is_an_explicit_error() {
        // Seed behavior silently re-zeroed momentum when the param list
        // changed mid-training; that must now fail loudly.
        let mut o = Sgd::new(Schedule::Const { base: 0.1 }, 0.9, false, 0.0);
        let mut p1 = vec![t(&[0.0])];
        o.step(0, &mut p1, &[t(&[1.0])]).unwrap();
        let mut p2 = vec![t(&[0.0]), t(&[0.0])];
        let err = o.step(1, &mut p2, &[t(&[1.0]), t(&[1.0])]).unwrap_err();
        assert!(err.to_string().contains("refusing to silently reset"), "{err}");
    }

    #[test]
    fn velocity_length_mismatch_is_an_explicit_error() {
        let mut o = Sgd::new(Schedule::Const { base: 0.1 }, 0.9, false, 0.0);
        let mut p1 = vec![t(&[0.0, 0.0])];
        o.step(0, &mut p1, &[t(&[1.0, 1.0])]).unwrap();
        let mut p2 = vec![t(&[0.0, 0.0, 0.0])];
        assert!(o.step(1, &mut p2, &[t(&[1.0, 1.0, 1.0])]).is_err());
    }

    #[test]
    fn vanilla_mode_skips_velocity_allocation() {
        let mut o = Sgd::new(Schedule::Const { base: 0.1 }, 0.0, false, 0.0);
        let mut p = vec![t(&[1.0; 16])];
        o.step(0, &mut p, &[t(&[1.0; 16])]).unwrap();
        // changing arity is fine without momentum: no state to corrupt
        let mut p2 = vec![t(&[1.0]), t(&[2.0])];
        o.step(1, &mut p2, &[t(&[0.0]), t(&[0.0])]).unwrap();
    }

    #[test]
    fn paper_presets_exist_for_all_models() {
        for m in ["lenet5", "alexnet", "vgg16", "resnet20", "resnet110"] {
            let (s, mom, _, wd) = paper_schedule(m, 1000);
            assert!(s.lr(0) > 0.0);
            assert!(mom > 0.0);
            assert!(wd >= 0.0);
        }
    }
}
