//! Analytic memory model (paper Table 6 + §6.7 PipeDream comparison).
//!
//! The paper computes Table 6 with torchsummary: per-network activation
//! and weight footprints, plus the *increase* pipelining causes by
//! holding activations for in-flight mini-batches. Our accounting uses
//! the layer metadata from meta.json:
//!
//! * `activations` — Σ over layers of the layer-output elements per
//!   sample (torchsummary counts every module output; our per-layer
//!   accounting is the same shape, slightly smaller absolute MB);
//! * `increase` — each non-final partition p must hold its carry-in for
//!   `degree(p) = 2(K-p)` extra in-flight batches (the activation FIFO
//!   depth minus the live copy). Our jax bwd recomputes the partition
//!   forward from the carry-in, so the carry-in is *all* we store — the
//!   paper's PyTorch autograd stores every internal activation instead,
//!   which we also report as `increase_paper_style`.
//!
//! By default no weight copies are stashed in either accounting — the
//! paper's core memory claim vs PipeDream (§6.7), quantified by
//! `pipedream_stash_bytes`. Opting into `--staleness-fix stash`
//! (DESIGN.md §9) buys back PipeDream's consistency at exactly the
//! stash cost modeled by [`stash_ring_costs`]: one ring slot per
//! in-flight mini-batch, of which at most `degree` ever materialize
//! thanks to copy-on-write tensor clones.

use crate::meta::ConfigMeta;

#[derive(Debug, Clone)]
pub struct MemoryReport {
    pub config: String,
    pub model: String,
    pub ppv: Vec<usize>,
    /// Per-sample activation bytes of the whole network (f32).
    pub activations_per_sample: f64,
    /// Weight bytes (batch-independent).
    pub weight_bytes: f64,
    /// Extra per-sample bytes: carry-in copies only (our implementation).
    pub increase_per_sample: f64,
    /// Extra per-sample bytes if every stage-internal activation is kept
    /// for the delayed backward (the paper's PyTorch accounting).
    pub increase_paper_style_per_sample: f64,
}

impl MemoryReport {
    pub fn from_meta(meta: &ConfigMeta) -> Self {
        let f32b = 4.0;
        let activations_per_sample: f64 = meta
            .layers
            .iter()
            .map(|l| l.carry_elems_per_sample as f64 * f32b)
            .sum();

        let mut increase = 0.0;
        let mut increase_paper = 0.0;
        for part in &meta.partitions {
            let degree = meta.degree_of_staleness(part.index) as f64;
            if degree == 0.0 {
                continue;
            }
            // carry-in elements of this partition (the register contents)
            let carry_in_elems: usize = part
                .carry_in
                .iter()
                .map(|s| s[1..].iter().product::<usize>())
                .sum();
            increase += degree * carry_in_elems as f64 * f32b;
            // paper-style: all layer outputs inside the partition, one
            // extra copy per in-flight mini-batch beyond the live one.
            // Table 6's numbers correspond to degree/2 = K-i+1 extra
            // copies (activations live for 2(K-i+1) *cycles*, but a new
            // mini-batch enters every 2 cycles in the paired mapping):
            // ResNet-20 PPV (7): increase/activations = 2.58/3.84 = 67%
            // = share of partition-1 activations — exactly 1 copy.
            let copies = degree / 2.0;
            let internal: f64 = meta.layers[part.layer_lo - 1..part.layer_hi]
                .iter()
                .map(|l| l.carry_elems_per_sample as f64 * f32b)
                .sum();
            increase_paper += copies * internal;
        }

        MemoryReport {
            config: meta.config.clone(),
            model: meta.model.clone(),
            ppv: meta.ppv.clone(),
            activations_per_sample,
            weight_bytes: meta.total_params() as f64 * f32b,
            increase_per_sample: increase,
            increase_paper_style_per_sample: increase_paper,
        }
    }

    /// Paper's "Increase %" column: increase relative to the baseline
    /// activation footprint (batch-size independent ratio).
    pub fn increase_pct_paper_style(&self) -> f64 {
        100.0 * self.increase_paper_style_per_sample / self.activations_per_sample
    }

    pub fn increase_pct(&self) -> f64 {
        100.0 * self.increase_per_sample / self.activations_per_sample
    }

    /// Total training footprint at a given batch size, our implementation.
    pub fn total_bytes(&self, batch: usize) -> f64 {
        self.weight_bytes
            + (self.activations_per_sample + self.increase_per_sample) * batch as f64
    }
}

/// One partition's footprint in the `pipestale memory` per-stage table.
/// Works on any `ConfigMeta` — artifact-loaded or synthesized without an
/// artifacts dir (the `--partition auto` path).
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionMemoryRow {
    /// Partition index as recorded by the config metadata (1-based).
    pub partition: usize,
    /// Inclusive 1-based paper-layer range the partition covers.
    pub layer_range: (usize, usize),
    /// Bytes of the partition's live weights (f32).
    pub weight_bytes: f64,
    /// Bytes of one mini-batch's carry-in (the register contents).
    pub carry_in_bytes: f64,
}

/// Per-partition memory rows for the CLI's per-stage table (printed
/// next to the analytic compute share and the imbalance ratio).
pub fn partition_memory_rows(meta: &ConfigMeta) -> Vec<PartitionMemoryRow> {
    meta.partitions
        .iter()
        .map(|p| {
            let carry_elems: usize =
                p.carry_in.iter().map(|s| s.iter().product::<usize>()).sum();
            PartitionMemoryRow {
                partition: p.index,
                layer_range: (p.layer_lo, p.layer_hi),
                weight_bytes: p.param_count as f64 * 4.0,
                carry_in_bytes: carry_elems as f64 * 4.0,
            }
        })
        .collect()
}

/// Weight-stash ring cost of `--staleness-fix stash` for one partition
/// (DESIGN.md §9).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StashRingCost {
    /// Partition index as carried by the config metadata.
    pub partition: usize,
    /// Degree of staleness: updates applied between a batch's forward
    /// and its backward at full occupancy.
    pub degree: usize,
    /// Peak ring length: one stashed weight version per in-flight
    /// mini-batch = degree + 1 (matches the activation-FIFO depth; the
    /// fused last stage never stashes).
    pub ring_slots: usize,
    /// Ring bytes if every slot held a distinct copy — this is exactly
    /// the `stashed_bytes_high_water` a full-occupancy run reports in
    /// its [`crate::pipeline::FixStats`].
    pub ring_bytes: f64,
    /// Extra bytes that can actually materialize: stash clones are
    /// copy-on-write and alias the live weights until an update lands,
    /// so at most `degree` slots ever diverge from the live copy.
    pub extra_bytes: f64,
}

/// Per-partition cost of the `stash` mitigation ring: the price of
/// PipeDream-style weight stashing when switched on, zero otherwise.
/// Note our paired-mapping schedule keeps `2(K-p)` batches in flight —
/// roughly twice PipeDream's 1F1B depth — so this is larger than
/// [`pipedream_stash_bytes`] for the same network.
pub fn stash_ring_costs(meta: &ConfigMeta) -> Vec<StashRingCost> {
    meta.partitions
        .iter()
        .map(|part| {
            let degree = meta.degree_of_staleness(part.index);
            let ring_slots = if degree == 0 { 0 } else { degree + 1 };
            let bytes_per_copy = part.param_count as f64 * 4.0;
            StashRingCost {
                partition: part.index,
                degree,
                ring_slots,
                ring_bytes: ring_slots as f64 * bytes_per_copy,
                extra_bytes: degree as f64 * bytes_per_copy,
            }
        })
        .collect()
}

/// Total worst-case materialized bytes of the stash rings across all
/// partitions (the honest "what does `--staleness-fix stash` cost me"
/// number for `pipestale memory`).
pub fn stash_extra_bytes_total(meta: &ConfigMeta) -> f64 {
    stash_ring_costs(meta).iter().map(|c| c.extra_bytes).sum()
}

/// PipeDream-style weight stashing estimate (§6.7): partition p (1-based
/// of P) keeps one weight version per in-flight batch = P - p + 1 copies;
/// extra = Σ_p (P - p) * weight_bytes_p beyond the single live copy.
pub fn pipedream_stash_bytes(meta: &ConfigMeta) -> f64 {
    let p = meta.partitions.len();
    meta.partitions
        .iter()
        .enumerate()
        .map(|(i, part)| ((p - 1 - i) as f64) * part.param_count as f64 * 4.0)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn report(name: &str) -> MemoryReport {
        MemoryReport::from_meta(&ConfigMeta::load_named(&root(), name).unwrap())
    }

    #[test]
    fn resnet20_full_width_magnitudes() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let r = report("resnet20_mem");
        // ~0.27M params -> ~1.08 MB weights (paper: 1.03 MB)
        assert!(r.weight_bytes > 0.9e6 && r.weight_bytes < 1.3e6, "{}", r.weight_bytes);
        // per-sample activations within 2x of the paper's 3.84 MB/sample
        // (torchsummary counts every module output, we count layer outputs)
        assert!(
            r.activations_per_sample > 0.5e6 && r.activations_per_sample < 8e6,
            "{}",
            r.activations_per_sample
        );
        assert!(r.increase_per_sample > 0.0);
        assert!(r.increase_paper_style_per_sample >= r.increase_per_sample);
    }

    #[test]
    fn increase_pct_is_modest_and_stable_for_deeper_resnets() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        // Paper Table 6: ~57-67%, roughly constant with depth.
        let pcts: Vec<f64> = [20usize, 56, 110, 224, 362]
            .iter()
            .map(|d| report(&format!("resnet{d}_mem")).increase_pct_paper_style())
            .collect();
        for w in &pcts {
            assert!(*w > 20.0 && *w < 150.0, "{pcts:?}");
        }
        // deeper nets converge to a stable ratio (max spread of the last
        // three below 10 points, as in the paper's 57/57/57)
        let tail = &pcts[2..];
        let spread = tail.iter().cloned().fold(f64::MIN, f64::max)
            - tail.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 10.0, "{pcts:?}");
    }

    #[test]
    fn our_recompute_scheme_beats_paper_style_storage() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let r = report("resnet110_mem");
        assert!(r.increase_per_sample < r.increase_paper_style_per_sample / 2.0);
    }

    #[test]
    fn pipedream_stash_is_extra_weight_copies() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let meta = ConfigMeta::load_named(&root(), "resnet20_fine8").unwrap();
        let stash = pipedream_stash_bytes(&meta);
        assert!(stash > 0.0);
        // stash never exceeds (P-1) x full weights
        let p = meta.partitions.len() as f64;
        assert!(stash <= (p - 1.0) * meta.total_params() as f64 * 4.0);
    }

    #[test]
    fn stash_ring_costs_match_schedule_depths() {
        // Native configs need no artifacts: P=4 -> degrees 6,4,2,0 and
        // ring slots degree+1 everywhere except the fused last stage.
        let meta = crate::backend::native_config("native_lenet_small_4s").unwrap();
        let costs = stash_ring_costs(&meta);
        assert_eq!(costs.len(), 4);
        assert_eq!(costs.iter().map(|c| c.degree).collect::<Vec<_>>(), vec![6, 4, 2, 0]);
        assert_eq!(costs.iter().map(|c| c.ring_slots).collect::<Vec<_>>(), vec![7, 5, 3, 0]);
        for c in &costs {
            let per_copy = c.ring_bytes / c.ring_slots.max(1) as f64;
            assert!((c.extra_bytes - c.degree as f64 * per_copy).abs() < 1e-6);
            assert!(c.extra_bytes <= c.ring_bytes);
        }
        // last stage stashes nothing
        assert_eq!(costs[3].ring_bytes, 0.0);
        assert_eq!(stash_extra_bytes_total(&meta), costs.iter().map(|c| c.extra_bytes).sum());
    }

    #[test]
    fn stash_ring_exceeds_pipedream_estimate() {
        // Our paired mapping keeps ~2x PipeDream's in-flight depth, so
        // the stash ring costs at least as much as the §6.7 estimate.
        let meta = crate::backend::native_config("native_lenet_small_4s").unwrap();
        assert!(stash_extra_bytes_total(&meta) >= pipedream_stash_bytes(&meta));
    }

    #[test]
    fn partition_rows_cover_all_layers_and_weights() {
        // Works on a synthesized meta — no artifacts dir involved (the
        // same shape the --partition auto path produces).
        let meta = crate::backend::native_config("native_lenet_small_4s").unwrap();
        let rows = partition_memory_rows(&meta);
        assert_eq!(rows.len(), meta.partitions.len());
        // Layer ranges chain contiguously over 1..=num_layers.
        assert_eq!(rows[0].layer_range.0, 1);
        assert_eq!(rows.last().unwrap().layer_range.1, meta.num_layers);
        for w in rows.windows(2) {
            assert_eq!(w[0].layer_range.1 + 1, w[1].layer_range.0);
        }
        // Weight bytes sum to the whole model's.
        let total: f64 = rows.iter().map(|r| r.weight_bytes).sum();
        assert_eq!(total, meta.total_params() as f64 * 4.0);
        // Carry-in includes the batch dimension (full mini-batch bytes).
        let p0 = &meta.partitions[0];
        let elems: usize = p0.carry_in.iter().map(|s| s.iter().product::<usize>()).sum();
        assert_eq!(rows[0].carry_in_bytes, elems as f64 * 4.0);
        assert!(rows[0].carry_in_bytes > 0.0);
    }

    #[test]
    fn total_bytes_scales_with_batch() {
        if !crate::artifacts_present() { crate::util::skip_marker("artifacts not built"); return; }
        let r = report("resnet20_mem");
        assert!(r.total_bytes(128) > r.total_bytes(1));
    }
}
